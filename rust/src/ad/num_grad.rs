//! Central finite differences — the independent numerical oracle that every
//! analytic/AD derivative in the crate is tested against, and the
//! ground-truth Jacobian for Fig. 15 (the paper uses finite differences
//! there too).
//!
//! The vector-JVP core is shared with the test suite through
//! `util::testkit::fd_jvp_central` (one implementation, one set of FD
//! tolerances); the kink-aware variant lives there too as
//! `util::testkit::fd_jvp`.

/// Central-difference gradient of a scalar function.
pub fn grad_fd(f: impl Fn(&[f64]) -> f64, x: &[f64], h: f64) -> Vec<f64> {
    let n = x.len();
    let mut g = vec![0.0; n];
    let mut xp = x.to_vec();
    for i in 0..n {
        let xi = x[i];
        xp[i] = xi + h;
        let fp = f(&xp);
        xp[i] = xi - h;
        let fm = f(&xp);
        xp[i] = xi;
        g[i] = (fp - fm) / (2.0 * h);
    }
    g
}

/// Central-difference JVP of a vector function: (f(x+hv) − f(x−hv)) / 2h.
/// Delegates to the shared testkit implementation.
pub fn jvp_fd(f: impl Fn(&[f64]) -> Vec<f64>, x: &[f64], v: &[f64], h: f64) -> Vec<f64> {
    crate::util::testkit::fd_jvp_central(f, x, v, h)
}

/// Full dense Jacobian by central differences (p outputs × n inputs).
pub fn jacobian_fd(f: impl Fn(&[f64]) -> Vec<f64>, x: &[f64], h: f64) -> Vec<Vec<f64>> {
    let n = x.len();
    let mut cols = Vec::with_capacity(n);
    let mut xp = x.to_vec();
    for j in 0..n {
        let xj = x[j];
        xp[j] = xj + h;
        let fp = f(&xp);
        xp[j] = xj - h;
        let fm = f(&xp);
        xp[j] = xj;
        cols.push(fp.iter().zip(&fm).map(|(&a, &b)| (a - b) / (2.0 * h)).collect::<Vec<f64>>());
    }
    // transpose columns → rows
    let p = cols[0].len();
    (0..p).map(|i| (0..n).map(|j| cols[j][i]).collect()).collect()
}

/// VJP via the dense FD Jacobian (test-only helper).
pub fn vjp_fd(f: impl Fn(&[f64]) -> Vec<f64>, x: &[f64], u: &[f64], h: f64) -> Vec<f64> {
    let jac = jacobian_fd(f, x, h);
    let n = x.len();
    let mut out = vec![0.0; n];
    for (i, row) in jac.iter().enumerate() {
        for j in 0..n {
            out[j] += u[i] * row[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_of_quadratic() {
        let g = grad_fd(|x| x[0] * x[0] + 3.0 * x[1], &[2.0, 5.0], 1e-6);
        assert!((g[0] - 4.0).abs() < 1e-6);
        assert!((g[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn jvp_linear_map() {
        let f = |x: &[f64]| vec![2.0 * x[0] + x[1], -x[1]];
        let j = jvp_fd(f, &[1.0, 1.0], &[1.0, 2.0], 1e-6);
        assert!((j[0] - 4.0).abs() < 1e-8);
        assert!((j[1] + 2.0).abs() < 1e-8);
    }

    #[test]
    fn jacobian_and_vjp_consistent() {
        let f = |x: &[f64]| vec![x[0] * x[1], x[0].exp()];
        let x = [0.5, 2.0];
        let jac = jacobian_fd(f, &x, 1e-6);
        assert!((jac[0][0] - 2.0).abs() < 1e-6);
        assert!((jac[0][1] - 0.5).abs() < 1e-6);
        assert!((jac[1][0] - 0.5f64.exp()).abs() < 1e-6);
        let u = [1.0, 1.0];
        let v = vjp_fd(f, &x, &u, 1e-6);
        assert!((v[0] - (2.0 + 0.5f64.exp())).abs() < 1e-6);
        assert!((v[1] - 0.5).abs() < 1e-6);
    }
}
