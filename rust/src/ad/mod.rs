//! From-scratch automatic differentiation.
//!
//! The paper's recipe is "autodiff of F + implicit function theorem". The
//! autodiff half is built here:
//!
//! - [`real`]: a `Real` scalar trait so user code (optimality mappings,
//!   objectives, energies) is written once, generically, and evaluated with
//!   plain `f64`, forward-mode [`dual::Dual`] numbers (JVPs), second-order
//!   duals (`Dual<Dual<f64>>`, Hessian-vector products by
//!   forward-over-forward), or reverse-mode [`tape::Var`] (gradients/VJPs).
//! - [`num_grad`]: central finite differences, used by tests as an
//!   independent oracle for every analytic/AD derivative in the crate.

pub mod dual;
pub mod num_grad;
pub mod real;
pub mod tape;

pub use dual::Dual;
pub use real::Real;
pub use tape::{grad as tape_grad, Tape, Var};
