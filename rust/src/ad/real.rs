//! The `Real` scalar abstraction: write a numeric program once, evaluate it
//! with f64 / dual numbers / tape variables. This is what lets the crate's
//! optimality mappings be "user code that autodiff handles", as in the paper.

use std::fmt::Debug;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Scalar field with the elementary functions the catalog needs.
pub trait Real:
    Copy
    + Clone
    + Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
{
    fn from_f64(x: f64) -> Self;
    /// Primal (value) part, discarding derivative information.
    fn value(&self) -> f64;

    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn sqrt(self) -> Self;
    /// max(self, 0) — the ReLU/positive-part used by projections.
    fn relu(self) -> Self;
    fn abs(self) -> Self;
    /// Branch on the primal value: if value >= 0 pick `a` else `b`.
    /// (This is how non-smooth operators pick their a.e.-derivative branch.)
    fn select_ge0(self, a: Self, b: Self) -> Self {
        if self.value() >= 0.0 {
            a
        } else {
            b
        }
    }
    fn powi(self, n: i32) -> Self {
        let mut out = Self::from_f64(1.0);
        let neg = n < 0;
        for _ in 0..n.abs() {
            out = out * self;
        }
        if neg {
            Self::from_f64(1.0) / out
        } else {
            out
        }
    }
    fn max_r(self, other: Self) -> Self {
        if self.value() >= other.value() {
            self
        } else {
            other
        }
    }
    fn min_r(self, other: Self) -> Self {
        if self.value() <= other.value() {
            self
        } else {
            other
        }
    }
}

impl Real for f64 {
    fn from_f64(x: f64) -> f64 {
        x
    }
    fn value(&self) -> f64 {
        *self
    }
    fn exp(self) -> f64 {
        f64::exp(self)
    }
    fn ln(self) -> f64 {
        f64::ln(self)
    }
    fn sqrt(self) -> f64 {
        f64::sqrt(self)
    }
    fn relu(self) -> f64 {
        if self > 0.0 {
            self
        } else {
            0.0
        }
    }
    fn abs(self) -> f64 {
        f64::abs(self)
    }
}

/// Dot product over any Real.
pub fn dot_r<T: Real>(a: &[T], b: &[T]) -> T {
    assert_eq!(a.len(), b.len());
    let mut s = T::from_f64(0.0);
    for i in 0..a.len() {
        s = s + a[i] * b[i];
    }
    s
}

/// Sum over any Real.
pub fn sum_r<T: Real>(a: &[T]) -> T {
    let mut s = T::from_f64(0.0);
    for &x in a {
        s = s + x;
    }
    s
}

/// Lift an f64 slice into any Real.
pub fn lift<T: Real>(xs: &[f64]) -> Vec<T> {
    xs.iter().map(|&x| T::from_f64(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_real_ops() {
        let x = <f64 as Real>::from_f64(2.0);
        assert_eq!(x.powi(3), 8.0);
        assert_eq!(x.powi(-1), 0.5);
        assert_eq!((-1.5f64).relu(), 0.0);
        assert_eq!(1.5f64.relu(), 1.5);
        assert_eq!(2.0f64.max_r(3.0), 3.0);
        assert_eq!(2.0f64.min_r(3.0), 2.0);
    }

    #[test]
    fn generic_helpers() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot_r(&a, &b), 32.0);
        assert_eq!(sum_r(&a), 6.0);
        let lifted: Vec<f64> = lift(&a);
        assert_eq!(lifted, a.to_vec());
    }
}
