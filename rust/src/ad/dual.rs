//! Forward-mode autodiff with dual numbers, generic over any [`Real`] so
//! that `Dual<Dual<f64>>` gives exact second-order (Hessian-vector) products
//! by forward-over-forward composition.

use super::real::Real;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Dual number v + εd (ε² = 0).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dual<T: Real = f64> {
    pub v: T,
    pub d: T,
}

impl<T: Real> Dual<T> {
    pub fn new(v: T, d: T) -> Dual<T> {
        Dual { v, d }
    }
    pub fn constant(v: T) -> Dual<T> {
        Dual { v, d: T::from_f64(0.0) }
    }
    /// Seed with tangent 1 (the variable being differentiated).
    pub fn seeded(v: T) -> Dual<T> {
        Dual { v, d: T::from_f64(1.0) }
    }
}

impl<T: Real> Add for Dual<T> {
    type Output = Dual<T>;
    fn add(self, o: Dual<T>) -> Dual<T> {
        Dual { v: self.v + o.v, d: self.d + o.d }
    }
}
impl<T: Real> Sub for Dual<T> {
    type Output = Dual<T>;
    fn sub(self, o: Dual<T>) -> Dual<T> {
        Dual { v: self.v - o.v, d: self.d - o.d }
    }
}
impl<T: Real> Mul for Dual<T> {
    type Output = Dual<T>;
    fn mul(self, o: Dual<T>) -> Dual<T> {
        Dual { v: self.v * o.v, d: self.d * o.v + self.v * o.d }
    }
}
impl<T: Real> Div for Dual<T> {
    type Output = Dual<T>;
    fn div(self, o: Dual<T>) -> Dual<T> {
        Dual { v: self.v / o.v, d: (self.d * o.v - self.v * o.d) / (o.v * o.v) }
    }
}
impl<T: Real> Neg for Dual<T> {
    type Output = Dual<T>;
    fn neg(self) -> Dual<T> {
        Dual { v: -self.v, d: -self.d }
    }
}

impl<T: Real> Real for Dual<T> {
    fn from_f64(x: f64) -> Dual<T> {
        Dual::constant(T::from_f64(x))
    }
    fn value(&self) -> f64 {
        self.v.value()
    }
    fn exp(self) -> Dual<T> {
        let e = self.v.exp();
        Dual { v: e, d: self.d * e }
    }
    fn ln(self) -> Dual<T> {
        Dual { v: self.v.ln(), d: self.d / self.v }
    }
    fn sqrt(self) -> Dual<T> {
        let s = self.v.sqrt();
        Dual { v: s, d: self.d / (T::from_f64(2.0) * s) }
    }
    fn relu(self) -> Dual<T> {
        if self.v.value() > 0.0 {
            self
        } else {
            Dual::constant(T::from_f64(0.0))
        }
    }
    fn abs(self) -> Dual<T> {
        if self.v.value() >= 0.0 {
            self
        } else {
            -self
        }
    }
}

/// JVP of a vector function written generically: returns (f(x), ∂f(x)·v).
pub fn jvp<FVec>(f: FVec, x: &[f64], v: &[f64]) -> (Vec<f64>, Vec<f64>)
where
    FVec: Fn(&[Dual<f64>]) -> Vec<Dual<f64>>,
{
    assert_eq!(x.len(), v.len());
    let xd: Vec<Dual<f64>> = x.iter().zip(v).map(|(&xi, &vi)| Dual::new(xi, vi)).collect();
    let out = f(&xd);
    (out.iter().map(|o| o.v).collect(), out.iter().map(|o| o.d).collect())
}

/// Gradient of a scalar function by forward mode (d passes — fine for small d,
/// used as a cross-check against the reverse tape).
pub fn grad_forward<FS>(f: FS, x: &[f64]) -> Vec<f64>
where
    FS: Fn(&[Dual<f64>]) -> Dual<f64>,
{
    let mut g = vec![0.0; x.len()];
    let mut xd: Vec<Dual<f64>> = x.iter().map(|&xi| Dual::constant(xi)).collect();
    for i in 0..x.len() {
        xd[i].d = 1.0;
        g[i] = f(&xd).d;
        xd[i].d = 0.0;
    }
    g
}

/// Hessian-vector product of a scalar generic function via forward-over-
/// forward: H(x)·v = d/dε ∇f(x + εv).
pub fn hvp<FS>(f: FS, x: &[f64], v: &[f64]) -> Vec<f64>
where
    FS: Fn(&[Dual<Dual<f64>>]) -> Dual<Dual<f64>>,
{
    let n = x.len();
    let mut out = vec![0.0; n];
    // Outer dual carries direction v; inner dual extracts one gradient coord.
    let mut xd: Vec<Dual<Dual<f64>>> = (0..n)
        .map(|i| Dual::new(Dual::new(x[i], 0.0), Dual::new(v[i], 0.0)))
        .collect();
    for i in 0..n {
        xd[i].v.d = 1.0; // seed inner (gradient) direction e_i
        let y = f(&xd);
        out[i] = y.d.d; // ∂²/∂ε∂x_i
        xd[i].v.d = 0.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::real::dot_r;

    fn rosen<T: Real>(x: &[T]) -> T {
        let a = T::from_f64(1.0) - x[0];
        let b = x[1] - x[0] * x[0];
        a * a + T::from_f64(100.0) * b * b
    }

    #[test]
    fn jvp_of_linear_map_is_exact() {
        let f = |x: &[Dual<f64>]| vec![x[0] * Dual::constant(2.0) + x[1], x[1] * x[1]];
        let (y, dy) = jvp(f, &[3.0, 4.0], &[1.0, 0.5]);
        assert_eq!(y, vec![10.0, 16.0]);
        assert!((dy[0] - 2.5).abs() < 1e-15);
        assert!((dy[1] - 4.0).abs() < 1e-15); // 2*x1*v1 = 2*4*0.5
    }

    #[test]
    fn grad_forward_rosenbrock() {
        let g = grad_forward(|x| rosen(x), &[1.2, 1.0]);
        // analytic: dx0 = -2(1-x0) - 400 x0 (x1 - x0²); dx1 = 200 (x1 - x0²)
        let x0 = 1.2;
        let x1 = 1.0;
        let g0 = -2.0 * (1.0 - x0) - 400.0 * x0 * (x1 - x0 * x0);
        let g1 = 200.0 * (x1 - x0 * x0);
        assert!((g[0] - g0).abs() < 1e-10);
        assert!((g[1] - g1).abs() < 1e-10);
    }

    #[test]
    fn hvp_of_quadratic_is_matrix_product() {
        // f(x) = ½ xᵀ diag(1,2,3) x → H v = diag(1,2,3) v
        let f = |x: &[Dual<Dual<f64>>]| {
            let c1 = Dual::<Dual<f64>>::from_f64(0.5);
            let w = [1.0, 2.0, 3.0];
            let mut s = Dual::<Dual<f64>>::from_f64(0.0);
            for i in 0..3 {
                s = s + Dual::<Dual<f64>>::from_f64(w[i]) * x[i] * x[i];
            }
            c1 * s
        };
        let h = hvp(f, &[0.3, -0.7, 2.0], &[1.0, 1.0, 1.0]);
        assert!((h[0] - 1.0).abs() < 1e-12);
        assert!((h[1] - 2.0).abs() < 1e-12);
        assert!((h[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn elementary_function_rules() {
        let x = Dual::seeded(2.0);
        assert!((x.exp().d - 2.0f64.exp()).abs() < 1e-12);
        assert!((x.ln().d - 0.5).abs() < 1e-12);
        assert!((x.sqrt().d - 0.25 / 2.0f64.sqrt() * 2.0).abs() < 1e-12);
        assert!(((x * x).d - 4.0).abs() < 1e-12);
        // d/dε |−(2+ε)| = sign(−2)·(−1) = 1
        assert!((Real::abs(-x).d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relu_branches_on_value() {
        assert_eq!(Dual::new(1.0, 5.0).relu().d, 5.0);
        assert_eq!(Dual::new(-1.0, 5.0).relu().d, 0.0);
    }

    #[test]
    fn generic_dot_with_duals() {
        let a = [Dual::seeded(1.0), Dual::constant(2.0)];
        let b = [Dual::constant(3.0), Dual::constant(4.0)];
        let d = dot_r(&a, &b);
        assert_eq!(d.v, 11.0);
        assert_eq!(d.d, 3.0);
    }
}
