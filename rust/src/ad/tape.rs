//! Reverse-mode autodiff on an explicit tape (Wengert list).
//!
//! Scalar-level graph; each `Var` owns an index into a shared arena. Used
//! for gradients of scalar objectives (MD energy, outer losses) and for
//! VJPs of user mappings via one reverse sweep per output (fine for the
//! moderate output dimensions the experiments use; the catalog mappings
//! override with analytic VJPs on hot paths).

use std::cell::RefCell;
use std::ops::{Add, Div, Mul, Neg, Sub};
use std::rc::Rc;

#[derive(Clone, Copy, Debug)]
struct Node {
    // Up to two parents with local partial derivatives.
    parents: [usize; 2],
    partials: [f64; 2],
    n_parents: u8,
}

/// Shared tape arena.
#[derive(Clone, Default)]
pub struct Tape {
    nodes: Rc<RefCell<Vec<Node>>>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.borrow().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Create an input (leaf) variable.
    pub fn var(&self, value: f64) -> Var {
        let idx = self.push(Node { parents: [0, 0], partials: [0.0, 0.0], n_parents: 0 });
        Var { tape: self.clone(), idx, v: value }
    }

    /// Lift a slice into tape variables.
    pub fn vars(&self, values: &[f64]) -> Vec<Var> {
        values.iter().map(|&v| self.var(v)).collect()
    }

    fn push(&self, n: Node) -> usize {
        let mut nodes = self.nodes.borrow_mut();
        nodes.push(n);
        nodes.len() - 1
    }

    /// Reverse sweep from `output`: returns adjoints for every node.
    pub fn backward(&self, output: &Var) -> Vec<f64> {
        let nodes = self.nodes.borrow();
        let mut adj = vec![0.0; nodes.len()];
        adj[output.idx] = 1.0;
        for i in (0..=output.idx).rev() {
            let a = adj[i];
            if a == 0.0 {
                continue;
            }
            let n = &nodes[i];
            for p in 0..n.n_parents as usize {
                adj[n.parents[p]] += a * n.partials[p];
            }
        }
        adj
    }
}

/// A scalar variable living on a tape.
#[derive(Clone)]
pub struct Var {
    tape: Tape,
    idx: usize,
    pub v: f64,
}

impl std::fmt::Debug for Var {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Var({}, idx={})", self.v, self.idx)
    }
}

impl Var {
    pub fn value(&self) -> f64 {
        self.v
    }

    fn unary(&self, value: f64, partial: f64) -> Var {
        let idx = self.tape.push(Node {
            parents: [self.idx, 0],
            partials: [partial, 0.0],
            n_parents: 1,
        });
        Var { tape: self.tape.clone(), idx, v: value }
    }

    fn binary(&self, other: &Var, value: f64, pa: f64, pb: f64) -> Var {
        let idx = self.tape.push(Node {
            parents: [self.idx, other.idx],
            partials: [pa, pb],
            n_parents: 2,
        });
        Var { tape: self.tape.clone(), idx, v: value }
    }

    /// Adjoint of this variable after a backward sweep.
    pub fn adjoint(&self, adjoints: &[f64]) -> f64 {
        adjoints[self.idx]
    }
}

// --- operator overloads on references (Var is cheap-cloneable) ---

impl Add for &Var {
    type Output = Var;
    fn add(self, o: &Var) -> Var {
        self.binary(o, self.v + o.v, 1.0, 1.0)
    }
}
impl Sub for &Var {
    type Output = Var;
    fn sub(self, o: &Var) -> Var {
        self.binary(o, self.v - o.v, 1.0, -1.0)
    }
}
impl Mul for &Var {
    type Output = Var;
    fn mul(self, o: &Var) -> Var {
        self.binary(o, self.v * o.v, o.v, self.v)
    }
}
impl Div for &Var {
    type Output = Var;
    fn div(self, o: &Var) -> Var {
        self.binary(o, self.v / o.v, 1.0 / o.v, -self.v / (o.v * o.v))
    }
}

// Owned-value operator impls so `Var` satisfies `Real`.
impl Add for Var {
    type Output = Var;
    fn add(self, o: Var) -> Var {
        (&self).add(&o)
    }
}
impl Sub for Var {
    type Output = Var;
    fn sub(self, o: Var) -> Var {
        (&self).sub(&o)
    }
}
impl Mul for Var {
    type Output = Var;
    fn mul(self, o: Var) -> Var {
        (&self).mul(&o)
    }
}
impl Div for Var {
    type Output = Var;
    fn div(self, o: Var) -> Var {
        (&self).div(&o)
    }
}
impl Neg for Var {
    type Output = Var;
    fn neg(self) -> Var {
        self.unary(-self.v, -1.0)
    }
}

// NOTE: `Real` requires Copy, which a tape Var cannot satisfy (it owns an Rc).
// Tape programs therefore use `Var` directly with reference operators; the
// generic `Real` path is served by f64/Dual. `grad` below is the main entry.

/// Gradient of a scalar tape program.
pub fn grad(f: impl Fn(&[Var]) -> Var, x: &[f64]) -> (f64, Vec<f64>) {
    let tape = Tape::new();
    let vars = tape.vars(x);
    let out = f(&vars);
    let adj = tape.backward(&out);
    (out.v, vars.iter().map(|v| v.adjoint(&adj)).collect())
}

/// VJP of a vector-valued tape program: uᵀ ∂f(x). One tape build, one
/// backward sweep per nonzero output is avoided by seeding a weighted sum —
/// uᵀf is a scalar whose gradient is exactly uᵀ∂f.
pub fn vjp(f: impl Fn(&[Var]) -> Vec<Var>, x: &[f64], u: &[f64]) -> Vec<f64> {
    let tape = Tape::new();
    let vars = tape.vars(x);
    let outs = f(&vars);
    assert_eq!(outs.len(), u.len());
    // s = Σ u_i f_i(x); ∇s = uᵀ ∂f.
    let mut s = tape.var(0.0);
    for (o, &ui) in outs.iter().zip(u) {
        let w = tape.var(ui); // constant leaf (gets zero adjoint influence back)
        s = &s + &(&w * o);
    }
    let adj = tape.backward(&s);
    vars.iter().map(|v| v.adjoint(&adj)).collect()
}

// --- elementary functions on Var ---
impl Var {
    pub fn exp_v(&self) -> Var {
        let e = self.v.exp();
        self.unary(e, e)
    }
    pub fn ln_v(&self) -> Var {
        self.unary(self.v.ln(), 1.0 / self.v)
    }
    pub fn sqrt_v(&self) -> Var {
        let s = self.v.sqrt();
        self.unary(s, 0.5 / s)
    }
    pub fn powi_v(&self, n: i32) -> Var {
        self.unary(self.v.powi(n), n as f64 * self.v.powi(n - 1))
    }
    pub fn relu_v(&self) -> Var {
        if self.v > 0.0 {
            self.unary(self.v, 1.0)
        } else {
            self.unary(0.0, 0.0)
        }
    }
    pub fn scale(&self, c: f64) -> Var {
        self.unary(self.v * c, c)
    }
    pub fn add_const(&self, c: f64) -> Var {
        self.unary(self.v + c, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::num_grad;

    #[test]
    fn grad_of_quadratic() {
        let (val, g) = grad(
            |x| {
                let a = &x[0] * &x[0];
                let b = &x[1] * &x[1];
                let s = &a + &b;
                s.scale(0.5)
            },
            &[3.0, -4.0],
        );
        assert!((val - 12.5).abs() < 1e-12);
        assert!((g[0] - 3.0).abs() < 1e-12);
        assert!((g[1] + 4.0).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let f = |x: &[Var]| {
            let e = x[0].exp_v();
            let l = x[1].add_const(3.0).ln_v();
            let p = &e * &l;
            let q = x[2].sqrt_v();
            &p + &(&q / &x[0])
        };
        let x0 = [0.7, 1.3, 2.5];
        let (_, g) = grad(f, &x0);
        let gfd = num_grad::grad_fd(
            |x| x[0].exp() * (x[1] + 3.0).ln() + x[2].sqrt() / x[0],
            &x0,
            1e-6,
        );
        for i in 0..3 {
            assert!((g[i] - gfd[i]).abs() < 1e-5, "i={i}: {} vs {}", g[i], gfd[i]);
        }
    }

    #[test]
    fn vjp_matches_jacobian_transpose() {
        // f(x) = [x0*x1, x0+x1, x1²] at (2,3); J = [[3,2],[1,1],[0,6]]
        let f = |x: &[Var]| vec![&x[0] * &x[1], &x[0] + &x[1], &x[1] * &x[1]];
        let u = [1.0, -1.0, 0.5];
        let v = vjp(f, &[2.0, 3.0], &u);
        // Jᵀu = [3*1 + 1*(-1) + 0, 2*1 + 1*(-1) + 6*0.5] = [2, 4]
        assert!((v[0] - 2.0).abs() < 1e-12);
        assert!((v[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn relu_zero_grad_in_inactive_branch() {
        let (_, g) = grad(|x| x[0].relu_v(), &[-2.0]);
        assert_eq!(g[0], 0.0);
        let (_, g) = grad(|x| x[0].relu_v(), &[2.0]);
        assert_eq!(g[0], 1.0);
    }

    #[test]
    fn reused_subexpression_accumulates() {
        // f = (x²)·(x²) = x⁴ → f' = 4x³
        let (_, g) = grad(
            |x| {
                let sq = &x[0] * &x[0];
                &sq * &sq
            },
            &[1.5],
        );
        assert!((g[0] - 4.0 * 1.5f64.powi(3)).abs() < 1e-12);
    }
}
