//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts (HLO text, see
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//! Python never runs here; the artifacts are produced once by
//! `make artifacts`.
//!
//! Artifact manifest: `artifacts/manifest.json` maps oracle names to files
//! and shapes. `XlaOracle` adapts an executable pair (eval + jvp products)
//! into the same [`crate::diff::spec::RootMap`] interface the native Rust
//! oracles implement — the engine cannot tell the difference, which is the
//! cleanest possible demonstration of the paper's modularity claim.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// Input shapes (row-major dims per argument).
    pub in_shapes: Vec<Vec<usize>>,
    /// Output arity.
    pub n_outputs: usize,
}

/// Parsed manifest.
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let mut entries = HashMap::new();
        for item in doc.get("oracles").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = item.str_or("name", "").to_string();
            let file = item.str_or("file", "").to_string();
            let in_shapes = item
                .get("in_shapes")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .map(|s| {
                            s.as_arr()
                                .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            let n_outputs = item.usize_or("n_outputs", 1);
            entries.insert(name.clone(), ArtifactEntry { name, file, in_shapes, n_outputs });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }
}

/// A compiled XLA executable with f32 I/O helpers.
pub struct XlaExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub entry: ArtifactEntry,
}

/// The runtime: one PJRT CPU client + an executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<XlaExecutable>>>,
}

impl XlaRuntime {
    pub fn new(artifacts_dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(XlaRuntime { client, manifest, cache: Default::default() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has_oracle(&self, name: &str) -> bool {
        self.manifest.entries.contains_key(name)
    }

    /// Load (or fetch cached) an executable by oracle name.
    pub fn load(&self, name: &str) -> Result<std::rc::Rc<XlaExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("no oracle '{name}' in manifest"))?
            .clone();
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let wrapped = std::rc::Rc::new(XlaExecutable { exe, entry });
        self.cache.borrow_mut().insert(name.to_string(), wrapped.clone());
        Ok(wrapped)
    }

    /// Execute an oracle on f64 slices (converted to f32 on the way in and
    /// back on the way out — the artifacts are compiled in f32).
    pub fn call(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let exe = self.load(name)?;
        exe.call_f64(inputs)
    }
}

impl XlaExecutable {
    /// Execute with f64→f32→f64 conversion. Inputs must match the manifest
    /// shapes elementwise (flattened row-major).
    pub fn call_f64(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(
            inputs.len() == self.entry.in_shapes.len(),
            "oracle '{}' expects {} inputs, got {}",
            self.entry.name,
            self.entry.in_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.entry.in_shapes) {
            let numel: usize = shape.iter().product();
            anyhow::ensure!(
                data.len() == numel,
                "oracle '{}': input size {} != shape {:?}",
                self.entry.name,
                data.len(),
                shape
            );
            let f32data: Vec<f32> = data.iter().map(|&x| x as f32).collect();
            let lit = xla::Literal::vec1(&f32data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute '{}': {e:?}", self.entry.name))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True → decompose the tuple.
        let mut out_lit = out_lit;
        let parts = out_lit.decompose_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let mut outs = Vec::with_capacity(parts.len());
        for part in parts {
            let v: Vec<f32> = part.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            outs.push(v.into_iter().map(|x| x as f64).collect());
        }
        Ok(outs)
    }
}

/// Ridge optimality oracle backed by XLA artifacts — implements the same
/// `RootMap` as the native `ml::ridge::RidgeRoot`, but every product runs
/// through the AOT-compiled JAX graph (which itself calls the Pallas matmul
/// kernel). See python/compile/model.py.
pub struct XlaRidgeRoot<'rt> {
    pub rt: &'rt XlaRuntime,
    pub d: usize,
    /// Flattened m×d design matrix and m targets, fed to the oracles as
    /// runtime arguments (shared via artifacts/ridge_data.json).
    pub design: Vec<f64>,
    pub targets: Vec<f64>,
}

impl crate::diff::spec::RootMap for XlaRidgeRoot<'_> {
    fn dim_x(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        self.d
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let r = self
            .rt
            .call("ridge_f", &[x, theta, &self.design, &self.targets])
            .expect("ridge_f oracle");
        out.copy_from_slice(&r[0]);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = self
            .rt
            .call("ridge_f_jvp_x", &[x, theta, v, &self.design, &self.targets])
            .expect("ridge_f_jvp_x oracle");
        out.copy_from_slice(&r[0]);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_x(x, theta, u, out); // Hessian symmetric
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = self.rt.call("ridge_f_jvp_theta", &[x, theta, v]).expect("ridge_f_jvp_theta");
        out.copy_from_slice(&r[0]);
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        // For ridge, ∂₂F = diag(x) is symmetric too.
        self.jvp_theta(x, theta, u, out);
    }
    fn a_symmetric(&self) -> bool {
        true
    }
}

/// Default artifacts directory (env override: IDIFF_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("IDIFF_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("idiff_manifest_test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"oracles": [{"name": "f", "file": "f.hlo.txt", "in_shapes": [[4], [4]], "n_outputs": 1}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = &m.entries["f"];
        assert_eq!(e.in_shapes, vec![vec![4], vec![4]]);
        assert_eq!(e.n_outputs, 1);
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = std::env::temp_dir().join("idiff_no_such_dir_xyz");
        assert!(Manifest::load(&dir).is_err());
    }
}
