//! Derivative-mode selection: implicit vs truncated-unroll vs one-step.
//!
//! The three mechanisms share one interface (Jacobian products of `T`) but
//! sit at different points on the accuracy/latency curve at a converged
//! fixed point x*(θ) with contraction factor ρ = ‖∂₁T(x*, θ)‖₂:
//!
//! | mode      | cost per JVP/VJP            | relative error    |
//! |-----------|-----------------------------|-------------------|
//! | implicit  | one linear solve (or a      | solver tolerance  |
//! |           | cached factorization)       |                   |
//! | unroll(k) | k Jacobian products         | ≤ ρᵏ              |
//! | one-step  | 1 Jacobian product          | ≤ ρ               |
//!
//! [`ModePolicy`] encodes the serving tier's decision rule: a warm
//! θ-factorization cache makes implicit both exact and cheapest, so always
//! take it; on a cache miss, a contraction (ρ < `rho_max`) admits the
//! Bolte-style one-step bound, so answer Jacobian-free with zero
//! factorizations; when T barely contracts, unroll just enough terms to hit
//! `err_target`, and past `max_unroll` terms give up and pay the solve.

/// Requested derivative mode — the serve protocol's `"mode"` field and the
/// mode parameter of `bilevel::hypergrad_fixed_point_mode`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DiffMode {
    /// Solve the IFT linear system (paper Eq. 2); exact up to solver tol.
    Implicit,
    /// k-term truncated unrolling at x* (Neumann series); error O(ρᵏ).
    Unroll,
    /// Single-step differentiation (Bolte et al., 2023); error O(ρ).
    OneStep,
    /// Let [`ModePolicy`] pick from the cache state + estimated ρ.
    Auto,
}

impl DiffMode {
    /// Parse the protocol spelling; `None` on anything else.
    pub fn parse(s: &str) -> Option<DiffMode> {
        match s {
            "implicit" => Some(DiffMode::Implicit),
            "unroll" => Some(DiffMode::Unroll),
            "one-step" => Some(DiffMode::OneStep),
            "auto" => Some(DiffMode::Auto),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DiffMode::Implicit => "implicit",
            DiffMode::Unroll => "unroll",
            DiffMode::OneStep => "one-step",
            DiffMode::Auto => "auto",
        }
    }
}

/// A concrete execution plan after `Auto` is resolved (`Unroll` carries the
/// chosen term count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModeDecision {
    Implicit,
    Unroll(usize),
    OneStep,
}

impl ModeDecision {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModeDecision::Implicit => "implicit",
            ModeDecision::Unroll(_) => "unroll",
            ModeDecision::OneStep => "one-step",
        }
    }
}

/// Accuracy/latency policy resolving [`DiffMode::Auto`].
#[derive(Clone, Copy, Debug)]
pub struct ModePolicy {
    /// Serve one-step whenever the estimated ρ stays below this: the O(ρ)
    /// bound is then meaningful and the answer costs one Jacobian product.
    /// The default admits every contraction the estimator can certify
    /// (ρ bounded away from 1 by more than estimation noise).
    pub rho_max: f64,
    /// Relative Jacobian-error target for the unroll fallback when ρ is too
    /// close to 1 for one-step.
    pub err_target: f64,
    /// Latency cap on unroll terms; needing more than this means the
    /// iterative implicit solve is the cheaper route to `err_target`.
    pub max_unroll: usize,
}

impl Default for ModePolicy {
    fn default() -> Self {
        ModePolicy { rho_max: 0.999, err_target: 1e-3, max_unroll: 512 }
    }
}

impl ModePolicy {
    /// Resolve `Auto` from the θ-cache state and the estimated contraction
    /// factor at (x*, θ). `rho` comes from
    /// [`super::one_step::estimate_contraction`] — Jacobian products only,
    /// so the decision itself never solves, factorizes or densifies.
    pub fn select(&self, rho: f64, cache_warm: bool) -> ModeDecision {
        if cache_warm {
            // A cached factorization makes implicit exact AND cheapest.
            return ModeDecision::Implicit;
        }
        if rho.is_finite() && rho < self.rho_max {
            return ModeDecision::OneStep;
        }
        if rho.is_finite() && rho < 1.0 {
            // Terms needed for ρᵏ ≤ err_target.
            let k = (self.err_target.ln() / rho.ln()).ceil();
            if k.is_finite() && k >= 1.0 && (k as usize) <= self.max_unroll {
                return ModeDecision::Unroll(k as usize);
            }
        }
        // Not (certifiably) a contraction: Jacobian-free modes carry no
        // bound, so pay the solve.
        ModeDecision::Implicit
    }

    /// Resolve an explicitly requested mode (`Unroll` gets a term count
    /// from `err_target` when the caller didn't pass one).
    pub fn resolve(&self, mode: DiffMode, rho: f64, cache_warm: bool, iters: Option<usize>) -> ModeDecision {
        match mode {
            DiffMode::Implicit => ModeDecision::Implicit,
            DiffMode::OneStep => ModeDecision::OneStep,
            DiffMode::Unroll => {
                ModeDecision::Unroll(iters.unwrap_or_else(|| self.default_unroll_terms(rho)))
            }
            DiffMode::Auto => self.select(rho, cache_warm),
        }
    }

    /// Term count hitting `err_target` for a given ρ, clamped to
    /// [1, `max_unroll`] (used when `"mode":"unroll"` arrives without an
    /// explicit `"iters"`).
    pub fn default_unroll_terms(&self, rho: f64) -> usize {
        if !(rho.is_finite() && rho > 0.0 && rho < 1.0) {
            return self.max_unroll;
        }
        let k = (self.err_target.ln() / rho.ln()).ceil();
        (k.max(1.0) as usize).min(self.max_unroll)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_mode() {
        for m in [DiffMode::Implicit, DiffMode::Unroll, DiffMode::OneStep, DiffMode::Auto] {
            assert_eq!(DiffMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(DiffMode::parse("onestep"), None);
        assert_eq!(DiffMode::parse(""), None);
    }

    #[test]
    fn warm_cache_always_wins() {
        let p = ModePolicy::default();
        for rho in [0.0, 0.5, 0.9999, 2.0, f64::NAN] {
            assert_eq!(p.select(rho, true), ModeDecision::Implicit);
        }
    }

    #[test]
    fn cold_cache_contractions_go_one_step() {
        let p = ModePolicy::default();
        assert_eq!(p.select(0.3, false), ModeDecision::OneStep);
        assert_eq!(p.select(0.99, false), ModeDecision::OneStep);
    }

    #[test]
    fn near_unit_rho_unrolls_and_divergent_rho_solves() {
        let p = ModePolicy { rho_max: 0.9, err_target: 1e-3, max_unroll: 512 };
        match p.select(0.95, false) {
            ModeDecision::Unroll(k) => {
                // 0.95^k ≤ 1e-3 ⇒ k ≥ 135.
                assert!((130..=140).contains(&k), "k = {k}");
            }
            other => panic!("expected unroll, got {other:?}"),
        }
        // ρ so close to 1 that k would blow the latency cap → implicit.
        assert_eq!(p.select(0.99999, false), ModeDecision::Implicit);
        // Not a contraction at all → implicit.
        assert_eq!(p.select(1.5, false), ModeDecision::Implicit);
        assert_eq!(p.select(f64::NAN, false), ModeDecision::Implicit);
    }

    #[test]
    fn explicit_unroll_respects_caller_iters() {
        let p = ModePolicy::default();
        assert_eq!(
            p.resolve(DiffMode::Unroll, 0.5, false, Some(7)),
            ModeDecision::Unroll(7)
        );
        // Without iters, fall back to the err_target-derived count.
        match p.resolve(DiffMode::Unroll, 0.5, false, None) {
            ModeDecision::Unroll(k) => assert!(k >= 10, "0.5^k ≤ 1e-3 needs k ≥ 10, got {k}"),
            other => panic!("expected unroll, got {other:?}"),
        }
        assert_eq!(p.resolve(DiffMode::Auto, 0.5, true, None), ModeDecision::Implicit);
        assert_eq!(p.resolve(DiffMode::OneStep, 2.0, true, None), ModeDecision::OneStep);
    }
}
