//! Differentiating a root (paper §2.1): implicit JVP / VJP (single and
//! multi-RHS block variants), dense Jacobian via ONE block solve, and the
//! `CustomRoot` decorator-equivalent that attaches them to a solver.

use super::spec::RootMap;
use crate::linalg::mat::Mat;
use crate::linalg::op::LinOp;
use crate::linalg::solve::{self, BlockSolveReport, Factorization, LinearSolveConfig, SolveReport};

/// The A = −∂₁F operator at (x, θ), matrix-free, with native block products
/// via the mapping's batched JVP/VJP — a block-CG iteration costs one
/// batched Jacobian product (one GEMM for catalog mappings) instead of k
/// scalar products.
struct AOp<'a, M: RootMap + ?Sized> {
    m: &'a M,
    x: &'a [f64],
    theta: &'a [f64],
}

impl<M: RootMap + ?Sized> LinOp for AOp<'_, M> {
    fn dim(&self) -> usize {
        self.m.dim_x()
    }
    fn apply(&self, v: &[f64], y: &mut [f64]) {
        self.m.jvp_x(self.x, self.theta, v, y);
        for yi in y.iter_mut() {
            *yi = -*yi;
        }
    }
    fn apply_t(&self, u: &[f64], y: &mut [f64]) {
        self.m.vjp_x(self.x, self.theta, u, y);
        for yi in y.iter_mut() {
            *yi = -*yi;
        }
    }
    fn apply_block(&self, v: &Mat, y: &mut Mat) {
        self.m.jvp_x_batch(self.x, self.theta, v, y);
        for yi in y.data.iter_mut() {
            *yi = -*yi;
        }
    }
    fn apply_t_block(&self, u: &Mat, y: &mut Mat) {
        self.m.vjp_x_batch(self.x, self.theta, u, y);
        for yi in y.data.iter_mut() {
            *yi = -*yi;
        }
    }
    fn is_symmetric(&self) -> bool {
        self.m.a_symmetric()
    }
}

/// Forward-mode implicit differentiation: J v where A J = B (Eq. 2), i.e.
/// solve A (Jv) = B v. Returns (Jv, solve report).
pub fn implicit_jvp<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    v_theta: &[f64],
    cfg: &LinearSolveConfig,
) -> (Vec<f64>, SolveReport) {
    let d = m.dim_x();
    let mut bv = vec![0.0; d];
    m.jvp_theta(x_star, theta, v_theta, &mut bv);
    let a = AOp { m, x: x_star, theta };
    let mut jv = vec![0.0; d];
    let rep = solve::solve(&a, &bv, &mut jv, cfg);
    (jv, rep)
}

/// Forward-mode implicit differentiation for a BLOCK of directions: with
/// V ∈ R^{n×k} (one direction per column), assemble B·V in one batched
/// product and solve A X = B V as a single block solve sharing one operator
/// application per iteration. Column j equals `implicit_jvp` on column j.
pub fn implicit_jvp_multi<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    v_thetas: &Mat,
    cfg: &LinearSolveConfig,
) -> (Mat, BlockSolveReport) {
    let d = m.dim_x();
    assert_eq!(v_thetas.rows, m.dim_theta(), "direction block rows must be dim_theta");
    let k = v_thetas.cols;
    let mut bv = Mat::zeros(d, k);
    m.jvp_theta_batch(x_star, theta, v_thetas, &mut bv);
    let a = AOp { m, x: x_star, theta };
    let mut jv = Mat::zeros(d, k);
    let rep = solve::solve_block(&a, &bv, &mut jv, cfg);
    (jv, rep)
}

/// Reverse-mode implicit differentiation: vᵀJ.
/// Solves Aᵀ u = v once, then returns uᵀB = ∂₂Fᵀ u.
pub fn implicit_vjp<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    v_x: &[f64],
    cfg: &LinearSolveConfig,
) -> (Vec<f64>, SolveReport) {
    let d = m.dim_x();
    let n = m.dim_theta();
    let a = AOp { m, x: x_star, theta };
    let mut u = vec![0.0; d];
    let rep = solve::solve_t(&a, v_x, &mut u, cfg);
    let mut out = vec![0.0; n];
    m.vjp_theta(x_star, theta, &u, &mut out);
    (out, rep)
}

/// Reverse-mode implicit differentiation for a BLOCK of cotangents: with
/// V ∈ R^{d×k} (one cotangent per column), solve Aᵀ U = V once as a block,
/// then apply ∂₂Fᵀ to the whole block — the multi-cotangent version of the
/// paper's VJP-reuse trick. Returns the n×k block of vᵀJ rows-as-columns.
pub fn implicit_vjp_multi<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    v_xs: &Mat,
    cfg: &LinearSolveConfig,
) -> (Mat, BlockSolveReport) {
    let d = m.dim_x();
    let n = m.dim_theta();
    assert_eq!(v_xs.rows, d, "cotangent block rows must be dim_x");
    let k = v_xs.cols;
    let a = AOp { m, x: x_star, theta };
    let mut u = Mat::zeros(d, k);
    let rep = solve::solve_t_block(&a, v_xs, &mut u, cfg);
    let mut out = Mat::zeros(n, k);
    m.vjp_theta_batch(x_star, theta, &u, &mut out);
    (out, rep)
}

/// Materialize A = −∂₁F at (x*, θ) with ONE batched Jacobian product
/// (A·I_d) and factor it — Cholesky when the mapping is symmetric, pivoted
/// LU otherwise. The factorization amortizes every subsequent JVP/VJP at
/// this (x*, θ) down to an O(d²) substitution with NO iterative solve —
/// the serve subsystem's θ-keyed cache stores exactly this object. Returns
/// None if A is numerically singular (x* not a regular root).
pub fn factorize_root<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
) -> Option<Factorization> {
    factorize_root_prec(m, x_star, theta, crate::linalg::solve::SolvePrecision::F64)
}

/// Largest d the direct path will densify: above this, a d×d materialization
/// (d²·8 bytes) plus an O(d³) factorization stops being an optimization over
/// the matrix-free iterative solvers, so [`factorize_root`] declines and
/// callers (the serve cache in particular) stay on the sparse/iterative
/// path. gene_expr-scale problems (d ≳ 10⁴) sit far above this line.
pub const FACTORIZE_DENSE_LIMIT: usize = 4096;

/// Precision-aware [`factorize_root`]: `MixedF32` factors A in f32 and
/// wraps every substitution in f64 iterative refinement (see
/// `linalg::solve::Factorization`). Returns None when d exceeds
/// [`FACTORIZE_DENSE_LIMIT`] — never densify a large-d operator — or when
/// A is numerically singular.
pub fn factorize_root_prec<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    precision: crate::linalg::solve::SolvePrecision,
) -> Option<Factorization> {
    if m.dim_x() > FACTORIZE_DENSE_LIMIT {
        return None;
    }
    let a = AOp { m, x: x_star, theta };
    Factorization::of_op_prec(&a, precision)
}

/// Forward-mode implicit JVP through a prefactored A (see
/// [`factorize_root`]): J v = A⁻¹ (B v). Substitution only — issues no
/// iterative solve and does not touch the solve counter.
pub fn implicit_jvp_factored<M: RootMap + ?Sized>(
    m: &M,
    fact: &Factorization,
    x_star: &[f64],
    theta: &[f64],
    v_theta: &[f64],
) -> Vec<f64> {
    let mut bv = vec![0.0; m.dim_x()];
    m.jvp_theta(x_star, theta, v_theta, &mut bv);
    fact.solve(&bv)
}

/// Reverse-mode implicit VJP through a prefactored A: vᵀJ = (A⁻ᵀ v)ᵀ B.
pub fn implicit_vjp_factored<M: RootMap + ?Sized>(
    m: &M,
    fact: &Factorization,
    x_star: &[f64],
    theta: &[f64],
    v_x: &[f64],
) -> Vec<f64> {
    let u = fact.solve_t(v_x);
    let mut out = vec![0.0; m.dim_theta()];
    m.vjp_theta(x_star, theta, &u, &mut out);
    out
}

/// Block of forward-mode JVPs through a prefactored A (columns of
/// `v_thetas`, n×k): X = A⁻¹ (B V) by k substitutions.
pub fn implicit_jvp_multi_factored<M: RootMap + ?Sized>(
    m: &M,
    fact: &Factorization,
    x_star: &[f64],
    theta: &[f64],
    v_thetas: &Mat,
) -> Mat {
    assert_eq!(v_thetas.rows, m.dim_theta(), "direction block rows must be dim_theta");
    let mut bv = Mat::zeros(m.dim_x(), v_thetas.cols);
    m.jvp_theta_batch(x_star, theta, v_thetas, &mut bv);
    fact.solve_mat(&bv)
}

/// Block of reverse-mode VJPs through a prefactored A (columns of `v_xs`,
/// d×k): out = Bᵀ (A⁻ᵀ V), n×k.
pub fn implicit_vjp_multi_factored<M: RootMap + ?Sized>(
    m: &M,
    fact: &Factorization,
    x_star: &[f64],
    theta: &[f64],
    v_xs: &Mat,
) -> Mat {
    assert_eq!(v_xs.rows, m.dim_x(), "cotangent block rows must be dim_x");
    let u = fact.solve_t_mat(v_xs);
    let mut out = Mat::zeros(m.dim_theta(), v_xs.cols);
    m.vjp_theta_batch(x_star, theta, &u, &mut out);
    out
}

/// The paper's VJP-reuse trick: factor the Aᵀu = v solve out so several
/// θ-blocks (or several B's) can reuse one solve. Returns u.
pub fn implicit_vjp_u<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    v_x: &[f64],
    cfg: &LinearSolveConfig,
) -> (Vec<f64>, SolveReport) {
    let a = AOp { m, x: x_star, theta };
    let mut u = vec![0.0; m.dim_x()];
    let rep = solve::solve_t(&a, v_x, &mut u, cfg);
    (u, rep)
}

/// Solver configuration for dense Jacobians: full-restart GMRES is exact
/// within d iterations even on the indefinite saddle systems KKT mappings
/// produce (where BiCGSTAB can break down); CG kicks in automatically for
/// symmetric mappings.
fn jacobian_cfg<M: RootMap + ?Sized>(m: &M) -> LinearSolveConfig {
    let d_full = m.dim_x().max(1);
    if m.a_symmetric() {
        LinearSolveConfig::default()
    } else {
        LinearSolveConfig {
            kind: crate::linalg::solve::LinearSolverKind::Gmres,
            tol: 1e-11,
            max_iter: 6 * d_full,
            gmres_restart: d_full.min(400),
            ..Default::default()
        }
    }
}

/// Dense Jacobian ∂x*(θ) ∈ R^{d×n} via ONE block solve: A X = B·I_n with
/// all n basis directions as a single multi-RHS block (used for Fig. 3 /
/// Fig. 15 error studies; hot paths use jvp/vjp). The former column-by-
/// column assembly survives as [`jacobian_via_root_columns`] for validation
/// and speedup benches.
pub fn jacobian_via_root<M: RootMap + ?Sized>(m: &M, x_star: &[f64], theta: &[f64]) -> Mat {
    let cfg = jacobian_cfg(m);
    let (jac, _rep) = implicit_jvp_multi(m, x_star, theta, &Mat::eye(m.dim_theta()), &cfg);
    jac
}

/// Reference dense-Jacobian path: n independent column solves (the
/// pre-batching behavior). Kept to validate the block path bit-for-bit at
/// solver tolerance and to measure the column-vs-block speedup.
pub fn jacobian_via_root_columns<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
) -> Mat {
    let cfg = jacobian_cfg(m);
    let (d, n) = (m.dim_x(), m.dim_theta());
    let mut jac = Mat::zeros(d, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let (col, _rep) = implicit_jvp(m, x_star, theta, &e, &cfg);
        for i in 0..d {
            *jac.at_mut(i, j) = col[i];
        }
        e[j] = 0.0;
    }
    jac
}

/// `@custom_root`: pairs a solver closure with an optimality mapping,
/// exposing `solve`, `jvp` and `vjp` — the Rust analogue of decorating a
/// solver in Figure 1 of the paper. The solver is a black box (it may be a
/// hand-written loop, a closed-form solve, an XLA executable…); only `F`
/// enters the differentiation rule.
pub struct CustomRoot<M: RootMap, S>
where
    S: Fn(&[f64], &[f64]) -> Vec<f64>,
{
    pub mapping: M,
    pub solver: S,
    pub cfg: LinearSolveConfig,
}

impl<M: RootMap, S> CustomRoot<M, S>
where
    S: Fn(&[f64], &[f64]) -> Vec<f64>,
{
    pub fn new(mapping: M, solver: S) -> Self {
        CustomRoot { mapping, solver, cfg: LinearSolveConfig::default() }
    }

    pub fn with_cfg(mut self, cfg: LinearSolveConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Run the wrapped solver: x*(θ) from `init`.
    pub fn solve(&self, init: &[f64], theta: &[f64]) -> Vec<f64> {
        (self.solver)(init, theta)
    }

    /// Forward-mode derivative of the solution in direction `v_theta`.
    pub fn jvp(&self, x_star: &[f64], theta: &[f64], v_theta: &[f64]) -> Vec<f64> {
        implicit_jvp(&self.mapping, x_star, theta, v_theta, &self.cfg).0
    }

    /// Reverse-mode derivative: vᵀ ∂x*(θ).
    pub fn vjp(&self, x_star: &[f64], theta: &[f64], v_x: &[f64]) -> Vec<f64> {
        implicit_vjp(&self.mapping, x_star, theta, v_x, &self.cfg).0
    }

    /// Forward-mode derivatives for a block of directions (columns of
    /// `v_thetas`, n×k) sharing one block solve.
    pub fn jvp_multi(&self, x_star: &[f64], theta: &[f64], v_thetas: &Mat) -> Mat {
        implicit_jvp_multi(&self.mapping, x_star, theta, v_thetas, &self.cfg).0
    }

    /// Reverse-mode derivatives for a block of cotangents (columns of
    /// `v_xs`, d×k) sharing one block solve.
    pub fn vjp_multi(&self, x_star: &[f64], theta: &[f64], v_xs: &Mat) -> Mat {
        implicit_vjp_multi(&self.mapping, x_star, theta, v_xs, &self.cfg).0
    }

    /// Dense Jacobian of the solution (one block solve).
    pub fn jacobian(&self, x_star: &[f64], theta: &[f64]) -> Mat {
        jacobian_via_root(&self.mapping, x_star, theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::spec::ClosureRoot;
    use crate::linalg::vecops;

    /// F(x, θ) = x − Mθ for a fixed matrix M → x*(θ) = Mθ, ∂x* = M.
    fn linear_root() -> ClosureRoot<impl Fn(&[f64], &[f64], &mut [f64])> {
        ClosureRoot {
            d: 2,
            n: 3,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                let m = [[1.0, 2.0, 0.5], [-1.0, 0.0, 3.0]];
                for i in 0..2 {
                    out[i] = x[i] - (m[i][0] * th[0] + m[i][1] * th[1] + m[i][2] * th[2]);
                }
            },
            symmetric: true, // A = I
        }
    }

    #[test]
    fn jvp_recovers_matrix_column() {
        let f = linear_root();
        let th = [1.0, 2.0, 3.0];
        let x = [1.0 + 4.0 + 1.5, -1.0 + 9.0];
        let cfg = LinearSolveConfig::default();
        let (jv, rep) = implicit_jvp(&f, &x, &th, &[1.0, 0.0, 0.0], &cfg);
        assert!(rep.converged);
        assert!((jv[0] - 1.0).abs() < 1e-8);
        assert!((jv[1] + 1.0).abs() < 1e-8);
    }

    #[test]
    fn vjp_recovers_matrix_row() {
        let f = linear_root();
        let th = [1.0, 2.0, 3.0];
        let x = [6.5, 8.0];
        let cfg = LinearSolveConfig::default();
        let (vj, rep) = implicit_vjp(&f, &x, &th, &[1.0, 0.0], &cfg);
        assert!(rep.converged);
        assert!((vj[0] - 1.0).abs() < 1e-8);
        assert!((vj[1] - 2.0).abs() < 1e-8);
        assert!((vj[2] - 0.5).abs() < 1e-8);
    }

    #[test]
    fn jvp_vjp_adjoint_identity() {
        // ⟨v_x, J v_θ⟩ = ⟨Jᵀ v_x, v_θ⟩ for arbitrary directions.
        let f = linear_root();
        let th = [0.3, -1.0, 0.7];
        let x = [0.3 - 2.0 + 0.35, -0.3 + 2.1];
        let cfg = LinearSolveConfig::default();
        let v_theta = [0.2, 0.4, -0.6];
        let v_x = [1.5, -0.5];
        let (jv, _) = implicit_jvp(&f, &x, &th, &v_theta, &cfg);
        let (vj, _) = implicit_vjp(&f, &x, &th, &v_x, &cfg);
        let lhs = vecops::dot(&v_x, &jv);
        let rhs = vecops::dot(&vj, &v_theta);
        assert!((lhs - rhs).abs() < 1e-8, "{lhs} vs {rhs}");
    }

    #[test]
    fn dense_jacobian_matches() {
        let f = linear_root();
        let th = [1.0, 1.0, 1.0];
        let x = [3.5, 2.0];
        let j = jacobian_via_root(&f, &x, &th);
        let expected = [[1.0, 2.0, 0.5], [-1.0, 0.0, 3.0]];
        for i in 0..2 {
            for k in 0..3 {
                assert!((j.at(i, k) - expected[i][k]).abs() < 1e-8);
            }
        }
    }

    /// The acceptance property of the batching PR: a dense Jacobian is ONE
    /// block solve, where the column path issues dim_theta independent
    /// solves — and the two agree to solver tolerance.
    #[test]
    fn dense_jacobian_is_one_block_solve() {
        use crate::linalg::solve::counter;
        let f = linear_root();
        let th = [1.0, 1.0, 1.0];
        let x = [3.5, 2.0];
        counter::reset();
        let j_block = jacobian_via_root(&f, &x, &th);
        assert_eq!(counter::count(), 1, "batched dense Jacobian must issue one block solve");
        let j_cols = jacobian_via_root_columns(&f, &x, &th);
        assert_eq!(counter::count(), 1 + 3, "column path is dim_theta independent solves");
        for i in 0..j_block.data.len() {
            assert!(
                (j_block.data[i] - j_cols.data[i]).abs() < 1e-8,
                "element {i}: {} vs {}",
                j_block.data[i],
                j_cols.data[i]
            );
        }
    }

    #[test]
    fn multi_jvp_vjp_match_single_columns() {
        let f = linear_root();
        let th = [0.5, -1.5, 2.0];
        let x = [0.5 - 3.0 + 1.0, -0.5 + 6.0];
        let cfg = LinearSolveConfig::default();
        // three θ-directions at once
        let v = Mat::from_vec(3, 3, vec![1.0, 0.0, 0.3, 0.0, 1.0, -0.7, 0.0, 0.0, 2.0]);
        let (jv_block, rep) = implicit_jvp_multi(&f, &x, &th, &v, &cfg);
        assert!(rep.converged);
        assert_eq!(rep.rhs, 3);
        let mut vc = vec![0.0; 3];
        for j in 0..3 {
            v.col_into(j, &mut vc);
            let (jv, _) = implicit_jvp(&f, &x, &th, &vc, &cfg);
            for i in 0..2 {
                assert!((jv_block.at(i, j) - jv[i]).abs() < 1e-9);
            }
        }
        // two x-cotangents at once
        let u = Mat::from_vec(2, 2, vec![1.0, 0.25, 0.0, -1.0]);
        let (vj_block, rep) = implicit_vjp_multi(&f, &x, &th, &u, &cfg);
        assert!(rep.converged);
        let mut uc = vec![0.0; 2];
        for j in 0..2 {
            u.col_into(j, &mut uc);
            let (vj, _) = implicit_vjp(&f, &x, &th, &uc, &cfg);
            for i in 0..3 {
                assert!((vj_block.at(i, j) - vj[i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn batched_jacobian_matches_columns_on_nonsymmetric_map() {
        // Non-symmetric A exercises the blocked GMRES dispatch.
        let f = ClosureRoot {
            d: 2,
            n: 2,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                out[0] = 2.0 * x[0] + x[1] - th[0];
                out[1] = x[0] * x[1] - th[1] + x[1];
            },
            symmetric: false,
        };
        let th = [3.0, 2.0];
        let x = [1.0, 1.0];
        let jb = jacobian_via_root(&f, &x, &th);
        let jc = jacobian_via_root_columns(&f, &x, &th);
        for i in 0..jb.data.len() {
            assert!((jb.data[i] - jc.data[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn factored_paths_match_iterative_with_zero_solves() {
        use crate::linalg::solve::counter;
        let f = ClosureRoot {
            d: 2,
            n: 2,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                out[0] = 2.0 * x[0] + x[1] - th[0];
                out[1] = x[0] * x[1] - th[1] + x[1];
            },
            symmetric: false,
        };
        let th = [3.0, 2.0];
        let x = [1.0, 1.0];
        let cfg = LinearSolveConfig::default();
        let v_theta = [0.3, -1.2];
        let v_x = [0.7, 0.4];
        let (jv, _) = implicit_jvp(&f, &x, &th, &v_theta, &cfg);
        let (vj, _) = implicit_vjp(&f, &x, &th, &v_x, &cfg);
        counter::reset();
        let fact = factorize_root(&f, &x, &th).expect("regular root");
        let jv_f = implicit_jvp_factored(&f, &fact, &x, &th, &v_theta);
        let vj_f = implicit_vjp_factored(&f, &fact, &x, &th, &v_x);
        assert_eq!(counter::count(), 0, "factored paths must issue no iterative solve");
        for i in 0..2 {
            assert!((jv[i] - jv_f[i]).abs() < 1e-8, "jvp {i}: {} vs {}", jv[i], jv_f[i]);
            assert!((vj[i] - vj_f[i]).abs() < 1e-8, "vjp {i}: {} vs {}", vj[i], vj_f[i]);
        }
        // block variants column-match the scalar factored paths
        let vt = Mat::from_vec(2, 2, vec![0.3, 1.0, -1.2, 0.5]);
        let jb = implicit_jvp_multi_factored(&f, &fact, &x, &th, &vt);
        let vvx = Mat::from_vec(2, 2, vec![0.7, -0.2, 0.4, 1.1]);
        let vb = implicit_vjp_multi_factored(&f, &fact, &x, &th, &vvx);
        let mut c = vec![0.0; 2];
        for j in 0..2 {
            vt.col_into(j, &mut c);
            let jc = implicit_jvp_factored(&f, &fact, &x, &th, &c);
            vvx.col_into(j, &mut c);
            let vc = implicit_vjp_factored(&f, &fact, &x, &th, &c);
            for i in 0..2 {
                assert!((jb.at(i, j) - jc[i]).abs() < 1e-12);
                assert!((vb.at(i, j) - vc[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn custom_root_wraps_solver() {
        let f = linear_root();
        let cr = CustomRoot::new(f, |_init: &[f64], th: &[f64]| {
            let m = [[1.0, 2.0, 0.5], [-1.0, 0.0, 3.0]];
            (0..2)
                .map(|i| m[i][0] * th[0] + m[i][1] * th[1] + m[i][2] * th[2])
                .collect()
        });
        let th = [2.0, 0.0, 1.0];
        let x = cr.solve(&[0.0, 0.0], &th);
        assert!((x[0] - 2.5).abs() < 1e-12);
        let j = cr.jacobian(&x, &th);
        assert!((j.at(1, 2) - 3.0).abs() < 1e-8);
    }

    #[test]
    fn one_dimensional_root_scalar_formula() {
        // d=1: F(x, θ) = x² − θ (x* = √θ); ∇x* = 1/(2√θ) = Bᵀ/A.
        let f = ClosureRoot {
            d: 1,
            n: 1,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                out[0] = x[0] * x[0] - th[0];
            },
            symmetric: false,
        };
        let th = [4.0];
        let x = [2.0];
        let cfg = LinearSolveConfig::default();
        let (j, rep) = implicit_jvp(&f, &x, &th, &[1.0], &cfg);
        assert!(rep.converged);
        assert!((j[0] - 0.25).abs() < 1e-6);
    }
}
