//! User-facing specification traits: how an optimality mapping `F` or a
//! fixed-point map `T` exposes itself to the engine.
//!
//! Mirrors the paper's design: the engine only ever needs the four Jacobian
//! products of `F` (∂₁F·v, ∂₂F·v, ∂₁Fᵀ·u, ∂₂Fᵀ·u). Catalog mappings
//! implement them via composition/autodiff; defaults fall back to central
//! finite differences so *any* `eval`-only mapping still works out of the
//! box (at FD accuracy).

use crate::ad::num_grad;
use crate::linalg::mat::Mat;
// The shared column-loop fallback for batched Jacobian products lives with
// the operator layer; re-exported here for the mapping catalog.
pub use crate::linalg::op::batch_cols;

/// An optimality mapping F : R^d × R^n → R^d with root x*(θ).
pub trait RootMap {
    /// Dimension d of the variable x.
    fn dim_x(&self) -> usize;
    /// Dimension n of the parameter θ.
    fn dim_theta(&self) -> usize;

    /// out = F(x, θ).
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]);

    /// out = ∂₁F(x, θ) · v.
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|xx| self.eval_vec(xx, theta), x, v, fd_step(x));
        out.copy_from_slice(&r);
    }

    /// out = ∂₂F(x, θ) · v.
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|tt| self.eval_vec(x, tt), theta, v, fd_step(theta));
        out.copy_from_slice(&r);
    }

    /// out = ∂₁F(x, θ)ᵀ · u.
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|xx| self.eval_vec(xx, theta), x, u, fd_step(x));
        out.copy_from_slice(&r);
    }

    /// out = ∂₂F(x, θ)ᵀ · u.
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|tt| self.eval_vec(x, tt), theta, u, fd_step(theta));
        out.copy_from_slice(&r);
    }

    /// Whether A = −∂₁F is symmetric (enables CG; true for stationary-point
    /// mappings of twice-differentiable objectives, where A is the Hessian).
    fn a_symmetric(&self) -> bool {
        false
    }

    /// out = ∂₁F(x, θ) · V for a block of directions (columns of V ∈ R^{d×k}).
    /// Default loops [`RootMap::jvp_x`] per column; catalog mappings override
    /// with one GEMM so a block-CG iteration costs one batched product.
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_x(), v, out, |vc, oc| self.jvp_x(x, theta, vc, oc));
    }

    /// out = ∂₁F(x, θ)ᵀ · U for a block of cotangents (U ∈ R^{d×k}).
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_x(), u, out, |uc, oc| self.vjp_x(x, theta, uc, oc));
    }

    /// out = ∂₂F(x, θ) · V, V ∈ R^{n×k} → out ∈ R^{d×k} (assembles B·V for
    /// the block system A X = B V in one shot).
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        batch_cols(self.dim_theta(), self.dim_x(), v, out, |vc, oc| {
            self.jvp_theta(x, theta, vc, oc)
        });
    }

    /// out = ∂₂F(x, θ)ᵀ · U, U ∈ R^{d×k} → out ∈ R^{n×k}.
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_theta(), u, out, |uc, oc| {
            self.vjp_theta(x, theta, uc, oc)
        });
    }

    /// Convenience allocating eval.
    fn eval_vec(&self, x: &[f64], theta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim_x()];
        self.eval(x, theta, &mut out);
        out
    }
}

fn fd_step(v: &[f64]) -> f64 {
    let scale = v.iter().fold(1.0f64, |m, &x| m.max(x.abs()));
    1e-6 * scale
}

/// A fixed-point mapping T : R^d × R^n → R^d with x*(θ) = T(x*(θ), θ).
pub trait FixedPointMap {
    fn dim_x(&self) -> usize;
    fn dim_theta(&self) -> usize;

    /// out = T(x, θ).
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]);

    /// out = ∂₁T(x, θ) · v.
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|xx| self.eval_vec(xx, theta), x, v, fd_step(x));
        out.copy_from_slice(&r);
    }

    /// out = ∂₂T(x, θ) · v.
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|tt| self.eval_vec(x, tt), theta, v, fd_step(theta));
        out.copy_from_slice(&r);
    }

    /// out = ∂₁T(x, θ)ᵀ · u.
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|xx| self.eval_vec(xx, theta), x, u, fd_step(x));
        out.copy_from_slice(&r);
    }

    /// out = ∂₂T(x, θ)ᵀ · u.
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|tt| self.eval_vec(x, tt), theta, u, fd_step(theta));
        out.copy_from_slice(&r);
    }

    /// Whether I − ∂₁T is symmetric.
    fn a_symmetric(&self) -> bool {
        false
    }

    /// Batched ∂₁T·V (columns of V); see [`RootMap::jvp_x_batch`].
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_x(), v, out, |vc, oc| self.jvp_x(x, theta, vc, oc));
    }

    /// Batched ∂₁Tᵀ·U.
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_x(), u, out, |uc, oc| self.vjp_x(x, theta, uc, oc));
    }

    /// Batched ∂₂T·V (V ∈ R^{n×k} → out ∈ R^{d×k}).
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        batch_cols(self.dim_theta(), self.dim_x(), v, out, |vc, oc| {
            self.jvp_theta(x, theta, vc, oc)
        });
    }

    /// Batched ∂₂Tᵀ·U (U ∈ R^{d×k} → out ∈ R^{n×k}).
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        batch_cols(self.dim_x(), self.dim_theta(), u, out, |uc, oc| {
            self.vjp_theta(x, theta, uc, oc)
        });
    }

    fn eval_vec(&self, x: &[f64], theta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim_x()];
        self.eval(x, theta, &mut out);
        out
    }
}

/// Adapter: a fixed point T becomes the root map F(x, θ) = T(x, θ) − x
/// (paper Eq. 3), so A = I − ∂₁T and B = ∂₂T.
pub struct FixedPointResidual<T: FixedPointMap>(pub T);

impl<T: FixedPointMap> RootMap for FixedPointResidual<T> {
    fn dim_x(&self) -> usize {
        self.0.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.0.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.0.eval(x, theta, out);
        for i in 0..x.len() {
            out[i] -= x[i];
        }
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.0.jvp_x(x, theta, v, out);
        for i in 0..v.len() {
            out[i] -= v[i];
        }
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.0.jvp_theta(x, theta, v, out);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.0.vjp_x(x, theta, u, out);
        for i in 0..u.len() {
            out[i] -= u[i];
        }
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.0.vjp_theta(x, theta, u, out);
    }
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.0.jvp_x_batch(x, theta, v, out);
        for (o, vi) in out.data.iter_mut().zip(v.data.iter()) {
            *o -= *vi;
        }
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.0.vjp_x_batch(x, theta, u, out);
        for (o, ui) in out.data.iter_mut().zip(u.data.iter()) {
            *o -= *ui;
        }
    }
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.0.jvp_theta_batch(x, theta, v, out);
    }
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.0.vjp_theta_batch(x, theta, u, out);
    }
    fn a_symmetric(&self) -> bool {
        self.0.a_symmetric()
    }
}

/// A RootMap defined by plain closures over generic evaluation — the
/// "user writes F directly in Python" analogue. Derivatives come from the
/// finite-difference defaults unless wrapped by catalog types.
pub struct ClosureRoot<E>
where
    E: Fn(&[f64], &[f64], &mut [f64]),
{
    pub d: usize,
    pub n: usize,
    pub f: E,
    pub symmetric: bool,
}

impl<E: Fn(&[f64], &[f64], &mut [f64])> RootMap for ClosureRoot<E> {
    fn dim_x(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        self.n
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        (self.f)(x, theta, out)
    }
    fn a_symmetric(&self) -> bool {
        self.symmetric
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Quad; // F(x, θ) = x − θ (root x* = θ), d = n = 2

    impl RootMap for Quad {
        fn dim_x(&self) -> usize {
            2
        }
        fn dim_theta(&self) -> usize {
            2
        }
        fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
            for i in 0..2 {
                out[i] = x[i] - theta[i];
            }
        }
    }

    #[test]
    fn fd_defaults_give_identity_jacobians() {
        let m = Quad;
        let x = [1.0, 2.0];
        let th = [1.0, 2.0];
        let mut out = [0.0; 2];
        m.jvp_x(&x, &th, &[1.0, 0.0], &mut out);
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!(out[1].abs() < 1e-6);
        m.jvp_theta(&x, &th, &[0.0, 1.0], &mut out);
        assert!(out[0].abs() < 1e-6);
        assert!((out[1] + 1.0).abs() < 1e-6);
        m.vjp_x(&x, &th, &[2.0, 3.0], &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
        assert!((out[1] - 3.0).abs() < 1e-6);
    }

    struct Contraction; // T(x, θ) = 0.5 x + θ, fixed point x* = 2θ

    impl FixedPointMap for Contraction {
        fn dim_x(&self) -> usize {
            1
        }
        fn dim_theta(&self) -> usize {
            1
        }
        fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
            out[0] = 0.5 * x[0] + theta[0];
        }
    }

    #[test]
    fn batch_defaults_match_column_loop() {
        let m = Quad;
        let x = [1.0, 2.0];
        let th = [1.0, 2.0];
        let v = Mat::from_vec(2, 3, vec![1.0, 0.0, 0.5, 0.0, 1.0, -2.0]);
        let mut out = Mat::zeros(2, 3);
        m.jvp_x_batch(&x, &th, &v, &mut out);
        let mut vc = vec![0.0; 2];
        let mut oc = [0.0; 2];
        for j in 0..3 {
            v.col_into(j, &mut vc);
            m.jvp_x(&x, &th, &vc, &mut oc);
            for i in 0..2 {
                assert!((out.at(i, j) - oc[i]).abs() < 1e-12);
            }
        }
        let mut out_t = Mat::zeros(2, 3);
        m.vjp_theta_batch(&x, &th, &v, &mut out_t);
        for j in 0..3 {
            v.col_into(j, &mut vc);
            m.vjp_theta(&x, &th, &vc, &mut oc);
            for i in 0..2 {
                assert!((out_t.at(i, j) - oc[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn residual_batch_subtracts_identity() {
        let r = FixedPointResidual(Contraction);
        // ∂₁F·V = (∂₁T − I)·V = −0.5·V for the contraction.
        let v = Mat::from_vec(1, 2, vec![1.0, -3.0]);
        let mut out = Mat::zeros(1, 2);
        r.jvp_x_batch(&[2.0], &[1.0], &v, &mut out);
        assert!((out.at(0, 0) + 0.5).abs() < 1e-6);
        assert!((out.at(0, 1) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn residual_adapter() {
        let r = FixedPointResidual(Contraction);
        let mut out = [0.0];
        // F(2θ, θ) = 0
        r.eval(&[2.0], &[1.0], &mut out);
        assert!(out[0].abs() < 1e-12);
        // ∂₁F = ∂₁T − I = −0.5
        r.jvp_x(&[2.0], &[1.0], &[1.0], &mut out);
        assert!((out[0] + 0.5).abs() < 1e-6);
        // ∂₂F = ∂₂T = 1
        r.jvp_theta(&[2.0], &[1.0], &[1.0], &mut out);
        assert!((out[0] - 1.0).abs() < 1e-6);
    }
}
