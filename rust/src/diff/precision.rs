//! Jacobian precision guarantees (paper §3, Theorem 1, Corollaries 1–2).
//!
//! `J(x̂, θ)` evaluated at an *approximate* solution x̂ satisfies
//! `‖J(x̂,θ) − ∂x*(θ)‖ ≤ (β/α + γR/α²)‖x̂ − x*(θ)‖`. This module computes the
//! empirical quantities used in the Fig. 3 overlay: the theoretical constant
//! for a given quadratic/regularized problem and the bound line.

use crate::linalg::mat::Mat;
use crate::linalg::solve::SolvePrecision;

/// Constants of Theorem 1 (for problems where they can be computed).
#[derive(Clone, Copy, Debug)]
pub struct PrecisionConstants {
    /// α: lower bound on ‖A(x,θ)v‖/‖v‖ (strong convexity of f for the
    /// gradient-descent fixed point).
    pub alpha: f64,
    /// β: Lipschitz constant of B in x.
    pub beta: f64,
    /// γ: Lipschitz constant of A in x (operator norm).
    pub gamma: f64,
    /// R: bound on ‖B(x*,θ)‖.
    pub r: f64,
}

impl PrecisionConstants {
    /// The slope C = β/α + γR/α² of Theorem 1's bound.
    pub fn bound_slope(&self) -> f64 {
        self.beta / self.alpha + self.gamma * self.r / (self.alpha * self.alpha)
    }

    /// Theorem 1's bound on the Jacobian error for a given iterate error.
    pub fn bound(&self, iterate_err: f64) -> f64 {
        self.bound_slope() * iterate_err
    }

    /// Largest iterate error ‖x̂ − x*‖ for which Theorem 1 still certifies
    /// a Jacobian error below `target`: ε ≤ target / C.
    pub fn max_iterate_err(&self, target: f64) -> f64 {
        target / self.bound_slope().max(1e-300)
    }

    /// Theorem-1 gate for arithmetic policies: a solve stopping at absolute
    /// residual ‖A x̂ − b‖ ≤ `resid` certifies an iterate error ≤ resid/α
    /// and hence a Jacobian error ≤ C·resid/α. The policy is admissible for
    /// `target` iff that certified error fits.
    pub fn admits_residual(&self, resid: f64, target: f64) -> bool {
        self.bound(resid / self.alpha) <= target
    }
}

/// Pick the cheapest arithmetic policy whose certified Jacobian error meets
/// `target`: mixed (f32 inner, f64-refined) solves stop at `mixed_resid`;
/// fall back to full f64 when only it certifies the target.
pub fn select_precision(
    consts: &PrecisionConstants,
    mixed_resid: f64,
    target: f64,
) -> SolvePrecision {
    if consts.admits_residual(mixed_resid, target) {
        SolvePrecision::MixedF32
    } else {
        SolvePrecision::F64
    }
}

/// Constants for ridge regression f(x, θ) = ½‖Φx − y‖² + ½Σθᵢxᵢ²
/// (the Fig. 3 problem) with per-coordinate regularization θ ∈ R^d:
/// A(x,θ) = ΦᵀΦ + diag(θ) (x-independent ⇒ γ = 0),
/// B(x,θ) = −∂₂∇₁f = −diag(x) ⇒ β = 1 (‖diag(x)−diag(x')‖ = ‖x−x'‖),
/// R = ‖x*‖.
pub fn ridge_constants(phi: &Mat, theta: &[f64], x_star: &[f64]) -> PrecisionConstants {
    let gram = phi.gram();
    // α = λ_min(ΦᵀΦ) + min θ ≥ min θ (cheap lower bound: power-iterate the
    // inverse is overkill; use min θ plus smallest Gershgorin estimate ≥ 0).
    let min_theta = theta.iter().cloned().fold(f64::INFINITY, f64::min);
    let alpha = min_theta + lambda_min_lower(&gram).max(0.0);
    let r = crate::linalg::vecops::norm2(x_star);
    PrecisionConstants { alpha, beta: 1.0, gamma: 0.0, r }
}

/// Crude symmetric-PSD λ_min lower bound by inverse power iteration would
/// need a solve; instead return 0 when Gershgorin cannot certify positivity
/// (the θ term already makes α positive for ridge).
fn lambda_min_lower(a: &Mat) -> f64 {
    let n = a.rows;
    let mut lo = f64::INFINITY;
    for i in 0..n {
        let mut off = 0.0;
        for j in 0..n {
            if j != i {
                off += a.at(i, j).abs();
            }
        }
        lo = lo.min(a.at(i, i) - off);
    }
    lo
}

/// Empirical check record: one (iterate error, jacobian error) pair.
#[derive(Clone, Copy, Debug)]
pub struct ErrorPair {
    pub iterate_err: f64,
    pub jacobian_err: f64,
}

/// Verify Theorem 1 empirically: every pair must satisfy the bound (with
/// slack for numerical error). Returns the worst observed ratio
/// jacobian_err / bound(iterate_err).
pub fn check_bound(consts: &PrecisionConstants, pairs: &[ErrorPair], slack: f64) -> f64 {
    let mut worst: f64 = 0.0;
    for p in pairs {
        if p.iterate_err <= 0.0 {
            continue;
        }
        let ratio = p.jacobian_err / consts.bound(p.iterate_err).max(1e-300);
        worst = worst.max(ratio);
        assert!(
            ratio <= 1.0 + slack,
            "Theorem 1 violated: err={} bound={} ratio={}",
            p.jacobian_err,
            consts.bound(p.iterate_err),
            ratio
        );
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn slope_formula() {
        let c = PrecisionConstants { alpha: 2.0, beta: 1.0, gamma: 0.5, r: 4.0 };
        assert!((c.bound_slope() - (0.5 + 0.5)).abs() < 1e-12);
        assert!((c.bound(0.1) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn precision_gate_orders_policies() {
        let c = PrecisionConstants { alpha: 2.0, beta: 1.0, gamma: 0.0, r: 1.0 };
        // C = 0.5, certified jac err = 0.5·resid/2 = resid/4.
        assert!(c.admits_residual(1e-10, 1e-8));
        assert!(!c.admits_residual(1e-4, 1e-8));
        assert!((c.max_iterate_err(1e-6) - 2e-6).abs() < 1e-18);
        assert_eq!(select_precision(&c, 1e-9, 1e-6), SolvePrecision::MixedF32);
        assert_eq!(select_precision(&c, 1e-3, 1e-6), SolvePrecision::F64);
    }

    #[test]
    fn ridge_constants_positive() {
        let mut rng = Rng::new(1);
        let phi = Mat::randn(30, 5, &mut rng);
        let theta = vec![1.0; 5];
        let x = rng.normal_vec(5);
        let c = ridge_constants(&phi, &theta, &x);
        assert!(c.alpha >= 1.0);
        assert_eq!(c.gamma, 0.0);
        assert!(c.r > 0.0);
    }

    #[test]
    fn check_bound_accepts_valid_pairs() {
        let c = PrecisionConstants { alpha: 1.0, beta: 1.0, gamma: 0.0, r: 1.0 };
        let pairs = [
            ErrorPair { iterate_err: 0.1, jacobian_err: 0.05 },
            ErrorPair { iterate_err: 1.0, jacobian_err: 0.9 },
        ];
        let worst = check_bound(&c, &pairs, 0.0);
        assert!(worst <= 1.0);
    }

    #[test]
    #[should_panic(expected = "Theorem 1 violated")]
    fn check_bound_rejects_violations() {
        let c = PrecisionConstants { alpha: 1.0, beta: 1.0, gamma: 0.0, r: 1.0 };
        let pairs = [ErrorPair { iterate_err: 0.1, jacobian_err: 0.5 }];
        check_bound(&c, &pairs, 0.0);
    }
}
