//! `@custom_fixed_point`: implicit differentiation on top of a solver given a
//! fixed-point iteration T (paper §2.1, "Differentiating a fixed point").

use super::spec::{FixedPointMap, FixedPointResidual, RootMap};
use crate::linalg::mat::Mat;
use crate::linalg::solve::LinearSolveConfig;

/// Pairs a black-box solver with a fixed-point mapping T; differentiation
/// goes through the residual F(x, θ) = T(x, θ) − x.
pub struct CustomFixedPoint<T: FixedPointMap, S>
where
    S: Fn(&[f64], &[f64]) -> Vec<f64>,
{
    pub residual: FixedPointResidual<T>,
    pub solver: S,
    pub cfg: LinearSolveConfig,
}

impl<T: FixedPointMap, S> CustomFixedPoint<T, S>
where
    S: Fn(&[f64], &[f64]) -> Vec<f64>,
{
    pub fn new(t: T, solver: S) -> Self {
        CustomFixedPoint {
            residual: FixedPointResidual(t),
            solver,
            cfg: LinearSolveConfig::default(),
        }
    }

    pub fn with_cfg(mut self, cfg: LinearSolveConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn solve(&self, init: &[f64], theta: &[f64]) -> Vec<f64> {
        (self.solver)(init, theta)
    }

    /// ∂x*(θ)·v via A = I − ∂₁T, B = ∂₂T.
    pub fn jvp(&self, x_star: &[f64], theta: &[f64], v_theta: &[f64]) -> Vec<f64> {
        super::root::implicit_jvp(&self.residual, x_star, theta, v_theta, &self.cfg).0
    }

    /// vᵀ∂x*(θ).
    pub fn vjp(&self, x_star: &[f64], theta: &[f64], v_x: &[f64]) -> Vec<f64> {
        super::root::implicit_vjp(&self.residual, x_star, theta, v_x, &self.cfg).0
    }

    /// Block of forward-mode derivatives (columns of `v_thetas`, n×k) via
    /// one block solve through the residual.
    pub fn jvp_multi(&self, x_star: &[f64], theta: &[f64], v_thetas: &Mat) -> Mat {
        super::root::implicit_jvp_multi(&self.residual, x_star, theta, v_thetas, &self.cfg).0
    }

    /// Block of reverse-mode derivatives (columns of `v_xs`, d×k) via one
    /// block solve through the residual.
    pub fn vjp_multi(&self, x_star: &[f64], theta: &[f64], v_xs: &Mat) -> Mat {
        super::root::implicit_vjp_multi(&self.residual, x_star, theta, v_xs, &self.cfg).0
    }

    /// Dense Jacobian (one block solve through the residual).
    pub fn jacobian(&self, x_star: &[f64], theta: &[f64]) -> Mat {
        super::root::jacobian_via_root(&self.residual, x_star, theta)
    }

    /// Residual norm ‖T(x, θ) − x‖ — a convergence diagnostic.
    pub fn residual_norm(&self, x: &[f64], theta: &[f64]) -> f64 {
        let mut out = vec![0.0; x.len()];
        self.residual.eval(x, theta, &mut out);
        crate::linalg::vecops::norm2(&out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::spec::FixedPointMap;

    /// T(x, θ) = 0.5 x + θ → x*(θ) = 2θ, ∂x* = 2.
    struct Affine;
    impl FixedPointMap for Affine {
        fn dim_x(&self) -> usize {
            1
        }
        fn dim_theta(&self) -> usize {
            1
        }
        fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
            out[0] = 0.5 * x[0] + theta[0];
        }
    }

    #[test]
    fn fixed_point_jacobian() {
        let cfp = CustomFixedPoint::new(Affine, |init: &[f64], theta: &[f64]| {
            // naive fixed-point iteration as the black-box solver
            let mut x = init.to_vec();
            for _ in 0..200 {
                x[0] = 0.5 * x[0] + theta[0];
            }
            x
        });
        let theta = [3.0];
        let x = cfp.solve(&[0.0], &theta);
        assert!((x[0] - 6.0).abs() < 1e-9);
        assert!(cfp.residual_norm(&x, &theta) < 1e-9);
        let j = cfp.jacobian(&x, &theta);
        assert!((j.at(0, 0) - 2.0).abs() < 1e-6);
        let jv = cfp.jvp(&x, &theta, &[1.0]);
        assert!((jv[0] - 2.0).abs() < 1e-6);
        let vj = cfp.vjp(&x, &theta, &[1.0]);
        assert!((vj[0] - 2.0).abs() < 1e-6);
    }

    /// Gradient-descent fixed point on a quadratic: T(x,θ) = x − η∇₁f,
    /// f = ½(x−θ)² → x* = θ; η must cancel (paper Eq. 5 remark).
    struct GdQuad {
        eta: f64,
    }
    impl FixedPointMap for GdQuad {
        fn dim_x(&self) -> usize {
            1
        }
        fn dim_theta(&self) -> usize {
            1
        }
        fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
            out[0] = x[0] - self.eta * (x[0] - theta[0]);
        }
    }

    #[test]
    fn step_size_cancels_in_linear_system() {
        for eta in [0.1, 0.5, 1.3] {
            let cfp = CustomFixedPoint::new(GdQuad { eta }, |_i: &[f64], th: &[f64]| th.to_vec());
            let theta = [2.0];
            let x = cfp.solve(&[0.0], &theta);
            let j = cfp.jacobian(&x, &theta);
            assert!((j.at(0, 0) - 1.0).abs() < 1e-6, "eta={eta}: {}", j.at(0, 0));
        }
    }
}
