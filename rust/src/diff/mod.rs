//! The implicit-differentiation engine — paper §2.1.
//!
//! Given an optimality mapping `F(x, θ)` with root `x*(θ)` (or a fixed-point
//! map `T`), the implicit function theorem gives the linear system (Eq. 2)
//!
//! ```text
//!   A J = B,   A = −∂₁F(x*, θ) ∈ R^{d×d},   B = ∂₂F(x*, θ) ∈ R^{d×n}
//! ```
//!
//! - JVP: solve A (J v) = B v  (forward mode)
//! - VJP: solve Aᵀ u = v, then vᵀJ = uᵀB  (reverse mode; one solve is
//!   reused across different θ-blocks, as the paper notes)
//!
//! All solves are matrix-free through [`crate::linalg::LinOp`]; only JVPs and
//! VJPs of `F` are ever required.
//!
//! Multi-RHS: `implicit_jvp_multi` / `implicit_vjp_multi` batch k directions
//! or cotangents into ONE block solve (dense Jacobians are the n-basis
//! special case), amortizing the Krylov work the way Margossian & Betancourt
//! (2021) prescribe.
//!
//! Solve-free alternatives live in [`one_step`] (single-step and truncated
//! Neumann differentiation at x*, error O(ρ) / O(ρᵏ) in the contraction
//! factor ρ = ‖∂₁T‖) with the accuracy/latency selection policy in
//! [`mode`].

pub mod fixed_point;
pub mod mode;
pub mod one_step;
pub mod precision;
pub mod root;
pub mod spec;

pub use fixed_point::CustomFixedPoint;
pub use mode::{DiffMode, ModeDecision, ModePolicy};
pub use one_step::{
    estimate_contraction, neumann_jvp, neumann_jvp_multi, neumann_vjp, neumann_vjp_multi,
    one_step_jvp, one_step_jvp_multi, one_step_vjp, one_step_vjp_multi, GradientStepMap,
};
pub use root::{
    implicit_jvp, implicit_jvp_multi, implicit_vjp, implicit_vjp_multi, jacobian_via_root,
    jacobian_via_root_columns, CustomRoot,
};
pub use spec::{FixedPointMap, FixedPointResidual, RootMap};
