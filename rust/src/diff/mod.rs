//! The implicit-differentiation engine — paper §2.1.
//!
//! Given an optimality mapping `F(x, θ)` with root `x*(θ)` (or a fixed-point
//! map `T`), the implicit function theorem gives the linear system (Eq. 2)
//!
//! ```text
//!   A J = B,   A = −∂₁F(x*, θ) ∈ R^{d×d},   B = ∂₂F(x*, θ) ∈ R^{d×n}
//! ```
//!
//! - JVP: solve A (J v) = B v  (forward mode)
//! - VJP: solve Aᵀ u = v, then vᵀJ = uᵀB  (reverse mode; one solve is
//!   reused across different θ-blocks, as the paper notes)
//!
//! All solves are matrix-free through [`crate::linalg::LinOp`]; only JVPs and
//! VJPs of `F` are ever required.
//!
//! Multi-RHS: `implicit_jvp_multi` / `implicit_vjp_multi` batch k directions
//! or cotangents into ONE block solve (dense Jacobians are the n-basis
//! special case), amortizing the Krylov work the way Margossian & Betancourt
//! (2021) prescribe.

pub mod fixed_point;
pub mod precision;
pub mod root;
pub mod spec;

pub use fixed_point::CustomFixedPoint;
pub use root::{
    implicit_jvp, implicit_jvp_multi, implicit_vjp, implicit_vjp_multi, jacobian_via_root,
    jacobian_via_root_columns, CustomRoot,
};
pub use spec::{FixedPointMap, FixedPointResidual, RootMap};
