//! One-step differentiation (Bolte, Pauwels & Vaiter, 2023) — the third
//! derivative mode next to implicit diff (`diff/root.rs`) and full unrolling
//! (`unroll/`).
//!
//! At the converged fixed point x*(θ) = T(x*, θ) the implicit Jacobian is
//!
//! ```text
//!   J_impl = (I − ∂₁T)⁻¹ ∂₂T
//! ```
//!
//! One-step differentiation backpropagates through a SINGLE application of T
//! and drops the (I − ∂₁T)⁻¹ factor entirely:
//!
//! ```text
//!   J_one = ∂₂T(x*, θ),   J_impl − J_one = ∂₁T · J_impl
//! ```
//!
//! so the error is controlled by the contraction factor ρ = ‖∂₁T(x*, θ)‖₂:
//! ‖(J_impl − J_one)v‖ ≤ ρ·‖J_impl v‖. No linear system is solved, no
//! factorization is needed and no trajectory is taped — a JVP/VJP costs one
//! Jacobian product of T. The k-term truncation ("Neumann unrolling at x*",
//! [`neumann_jvp`]) interpolates between the two: J_k = Σ_{i<k} (∂₁T)^i ∂₂T
//! with error ‖(J_impl − J_k)v‖ ≤ ρᵏ·‖J_impl v‖, and k → ∞ recovers
//! implicit diff. [`estimate_contraction`] measures ρ by power iteration on
//! ∂₁Tᵀ∂₁T (Jacobian products only), which is what the mode-selection policy
//! in [`super::mode`] consumes.
//!
//! Everything here is generic over [`FixedPointMap`]; root-map catalog
//! entries without a native fixed point get one via [`GradientStepMap`]
//! (T = x − η·F with η tuned by the same power iteration).

use super::spec::{FixedPointMap, RootMap};
use crate::linalg::mat::Mat;
use crate::linalg::vecops;
use crate::util::rng::Rng;

/// One-step JVP: out = ∂₂T(x*, θ)·v, v ∈ R^n. Jacobian-free — no solve.
pub fn one_step_jvp<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    v: &[f64],
) -> Vec<f64> {
    assert_eq!(v.len(), t.dim_theta(), "one_step_jvp: v must have dim_theta entries");
    let mut out = vec![0.0; t.dim_x()];
    t.jvp_theta(x_star, theta, v, &mut out);
    out
}

/// One-step VJP: out = ∂₂T(x*, θ)ᵀ·u, u ∈ R^d → out ∈ R^n.
pub fn one_step_vjp<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    u: &[f64],
) -> Vec<f64> {
    assert_eq!(u.len(), t.dim_x(), "one_step_vjp: u must have dim_x entries");
    let mut out = vec![0.0; t.dim_theta()];
    t.vjp_theta(x_star, theta, u, &mut out);
    out
}

/// Block one-step JVP: ∂₂T·V for V ∈ R^{n×k} → R^{d×k} in one batched product.
pub fn one_step_jvp_multi<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    v: &Mat,
) -> Mat {
    assert_eq!(v.rows, t.dim_theta(), "one_step_jvp_multi: V must be n × k");
    let mut out = Mat::zeros(t.dim_x(), v.cols);
    t.jvp_theta_batch(x_star, theta, v, &mut out);
    out
}

/// Block one-step VJP: ∂₂Tᵀ·U for U ∈ R^{d×k} → R^{n×k}.
pub fn one_step_vjp_multi<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    u: &Mat,
) -> Mat {
    assert_eq!(u.rows, t.dim_x(), "one_step_vjp_multi: U must be d × k");
    let mut out = Mat::zeros(t.dim_theta(), u.cols);
    t.vjp_theta_batch(x_star, theta, u, &mut out);
    out
}

/// k-term truncated (Neumann) JVP at x*: dx_k with dx_0 = 0 and
/// dx_{i+1} = ∂₁T·dx_i + ∂₂T·v, i.e. dx_k = Σ_{i<k} (∂₁T)^i ∂₂T v.
/// k = 1 is exactly [`one_step_jvp`]; k → ∞ converges to the implicit JVP
/// at rate ρᵏ when T is a contraction at x*.
pub fn neumann_jvp<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    v: &[f64],
    k: usize,
) -> Vec<f64> {
    assert!(k >= 1, "neumann_jvp: need at least one term");
    let b = one_step_jvp(t, x_star, theta, v);
    let mut dx = b.clone();
    let mut tmp = vec![0.0; t.dim_x()];
    for _ in 1..k {
        t.jvp_x(x_star, theta, &dx, &mut tmp);
        for i in 0..dx.len() {
            dx[i] = tmp[i] + b[i];
        }
    }
    dx
}

/// k-term truncated VJP at x*: ∂₂Tᵀ · Σ_{i<k} (∂₁Tᵀ)^i u — the exact
/// adjoint of [`neumann_jvp`] (the same truncated sum, transposed), so the
/// adjoint identity ⟨u, J_k v⟩ = ⟨J_kᵀ u, v⟩ holds to round-off for every k.
pub fn neumann_vjp<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    u: &[f64],
    k: usize,
) -> Vec<f64> {
    assert!(k >= 1, "neumann_vjp: need at least one term");
    assert_eq!(u.len(), t.dim_x(), "neumann_vjp: u must have dim_x entries");
    let mut w = u.to_vec();
    let mut acc = u.to_vec();
    let mut tmp = vec![0.0; t.dim_x()];
    for _ in 1..k {
        t.vjp_x(x_star, theta, &w, &mut tmp);
        w.copy_from_slice(&tmp);
        vecops::axpy(1.0, &w, &mut acc);
    }
    one_step_vjp(t, x_star, theta, &acc)
}

/// Block [`neumann_jvp`]: V ∈ R^{n×k_rhs} → R^{d×k_rhs}, one batched
/// Jacobian product per Neumann term.
pub fn neumann_jvp_multi<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    v: &Mat,
    k: usize,
) -> Mat {
    assert!(k >= 1, "neumann_jvp_multi: need at least one term");
    let b = one_step_jvp_multi(t, x_star, theta, v);
    let mut dx = b.clone();
    let mut tmp = Mat::zeros(dx.rows, dx.cols);
    for _ in 1..k {
        t.jvp_x_batch(x_star, theta, &dx, &mut tmp);
        for (d, (ti, bi)) in dx.data.iter_mut().zip(tmp.data.iter().zip(b.data.iter())) {
            *d = *ti + *bi;
        }
    }
    dx
}

/// Block [`neumann_vjp`]: U ∈ R^{d×k_rhs} → R^{n×k_rhs}.
pub fn neumann_vjp_multi<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    u: &Mat,
    k: usize,
) -> Mat {
    assert!(k >= 1, "neumann_vjp_multi: need at least one term");
    let mut w = u.clone();
    let mut acc = u.clone();
    let mut tmp = Mat::zeros(u.rows, u.cols);
    for _ in 1..k {
        t.vjp_x_batch(x_star, theta, &w, &mut tmp);
        w.data.copy_from_slice(&tmp.data);
        for (a, wi) in acc.data.iter_mut().zip(w.data.iter()) {
            *a += *wi;
        }
    }
    one_step_vjp_multi(t, x_star, theta, &acc)
}

/// Power iteration on MᵀM for a square operator M given by its forward and
/// transposed products; returns the dominant singular value σ_max(M),
/// approached from below. Deterministic for a fixed seed.
fn power_sigma(
    d: usize,
    mut apply: impl FnMut(&[f64], &mut [f64]),
    mut apply_t: impl FnMut(&[f64], &mut [f64]),
    iters: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut v = rng.normal_vec(d);
    let nv = vecops::norm2(&v);
    if nv == 0.0 {
        return 0.0;
    }
    vecops::scale(&mut v, 1.0 / nv);
    let mut w = vec![0.0; d];
    let mut z = vec![0.0; d];
    let mut sigma = 0.0;
    for _ in 0..iters {
        apply(&v, &mut w);
        sigma = vecops::norm2(&w);
        if sigma < 1e-300 {
            return 0.0;
        }
        apply_t(&w, &mut z);
        let nz = vecops::norm2(&z);
        if nz < 1e-300 {
            return sigma;
        }
        for (vi, zi) in v.iter_mut().zip(z.iter()) {
            *vi = *zi / nz;
        }
    }
    sigma
}

/// Default power-iteration length for contraction estimation: enough for a
/// two-digit σ_max estimate on the catalog spectra, cheap enough to run per
/// request (each iteration is one JVP + one VJP of T, no solves).
pub const CONTRACTION_POWER_ITERS: usize = 30;

/// Estimate the contraction factor ρ = ‖∂₁T(x*, θ)‖₂ by power iteration on
/// ∂₁Tᵀ∂₁T. Costs `iters` JVP/VJP pairs of T — no linear solves, no
/// densification — and is deterministic for a fixed seed. The estimate
/// approaches σ_max from below, which is why the bound assertions in the
/// mode tests carry a slack constant C > 1.
pub fn estimate_contraction<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    iters: usize,
    seed: u64,
) -> f64 {
    power_sigma(
        t.dim_x(),
        |v, o| t.jvp_x(x_star, theta, v, o),
        |u, o| t.vjp_x(x_star, theta, u, o),
        iters,
        seed,
    )
}

/// Fixed-point view of an arbitrary [`RootMap`]: T(x, θ) = x − η·F(x, θ).
/// Any root of F is a fixed point of T, and for stationary-point mappings
/// (F = ∇f, Hessian H ⪰ λ_min I) the tuned step η = 1/σ_max(H) makes T a
/// contraction with ρ = 1 − λ_min/λ_max < 1. This is what gives the
/// catalog's root-map-only problems (ridge, logreg, quad, sparse_logreg) a
/// uniform one-step/unroll mode without writing a bespoke T for each.
pub struct GradientStepMap<'a> {
    pub root: &'a dyn RootMap,
    pub eta: f64,
}

impl<'a> GradientStepMap<'a> {
    /// Tune η = 1/σ_max(∂₁F(x, θ)) by power iteration (falls back to η = 1
    /// when the operator is numerically zero).
    pub fn tuned(root: &'a dyn RootMap, x: &[f64], theta: &[f64]) -> GradientStepMap<'a> {
        let sigma = power_sigma(
            root.dim_x(),
            |v, o| root.jvp_x(x, theta, v, o),
            |u, o| root.vjp_x(x, theta, u, o),
            CONTRACTION_POWER_ITERS,
            0x6d0de5e1,
        );
        let eta = if sigma > 1e-300 { 1.0 / sigma } else { 1.0 };
        GradientStepMap { root, eta }
    }
}

impl FixedPointMap for GradientStepMap<'_> {
    fn dim_x(&self) -> usize {
        self.root.dim_x()
    }
    fn dim_theta(&self) -> usize {
        self.root.dim_theta()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.root.eval(x, theta, out);
        for (o, xi) in out.iter_mut().zip(x.iter()) {
            *o = *xi - self.eta * *o;
        }
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.root.jvp_x(x, theta, v, out);
        for (o, vi) in out.iter_mut().zip(v.iter()) {
            *o = *vi - self.eta * *o;
        }
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.root.vjp_x(x, theta, u, out);
        for (o, ui) in out.iter_mut().zip(u.iter()) {
            *o = *ui - self.eta * *o;
        }
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.root.jvp_theta(x, theta, v, out);
        vecops::scale(out, -self.eta);
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.root.vjp_theta(x, theta, u, out);
        vecops::scale(out, -self.eta);
    }
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.root.jvp_x_batch(x, theta, v, out);
        for (o, vi) in out.data.iter_mut().zip(v.data.iter()) {
            *o = *vi - self.eta * *o;
        }
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.root.vjp_x_batch(x, theta, u, out);
        for (o, ui) in out.data.iter_mut().zip(u.data.iter()) {
            *o = *ui - self.eta * *o;
        }
    }
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.root.jvp_theta_batch(x, theta, v, out);
        vecops::scale(&mut out.data, -self.eta);
    }
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.root.vjp_theta_batch(x, theta, u, out);
        vecops::scale(&mut out.data, -self.eta);
    }
    fn a_symmetric(&self) -> bool {
        self.root.a_symmetric()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::root::implicit_jvp;
    use crate::diff::spec::FixedPointResidual;
    use crate::linalg::LinearSolveConfig;

    /// T(x, θ) = A x + B θ with ‖A‖ < 1: implicit Jacobian (I − A)⁻¹B.
    struct Affine {
        a: Mat,
        b: Mat,
    }

    impl FixedPointMap for Affine {
        fn dim_x(&self) -> usize {
            self.a.rows
        }
        fn dim_theta(&self) -> usize {
            self.b.cols
        }
        fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
            self.a.matvec_into(x, out);
            let bt = self.b.matvec(theta);
            for i in 0..out.len() {
                out[i] += bt[i];
            }
        }
        fn jvp_x(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
            self.a.matvec_into(v, out);
        }
        fn vjp_x(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
            out.copy_from_slice(&self.a.matvec_t(u));
        }
        fn jvp_theta(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
            self.b.matvec_into(v, out);
        }
        fn vjp_theta(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
            out.copy_from_slice(&self.b.matvec_t(u));
        }
        fn a_symmetric(&self) -> bool {
            false
        }
    }

    fn affine(seed: u64, rho: f64) -> Affine {
        let mut rng = Rng::new(seed);
        let mut a = Mat::randn(4, 4, &mut rng);
        // Scale to spectral norm ≈ rho (power iteration for the true norm).
        let sigma = power_sigma(
            4,
            |v, o| a.matvec_into(v, o),
            |u, o| o.copy_from_slice(&a.matvec_t(u)),
            200,
            1,
        );
        vecops::scale(&mut a.data, rho / sigma);
        let b = Mat::randn(4, 3, &mut rng);
        Affine { a, b }
    }

    #[test]
    fn one_step_error_is_bounded_by_contraction_factor() {
        let t = affine(5, 0.6);
        let x = vec![0.0; 4];
        let th = vec![0.1, -0.4, 0.7];
        let v = vec![1.0, 0.5, -2.0];
        let res = FixedPointResidual(affine(5, 0.6));
        let (jv_impl, rep) =
            implicit_jvp(&res, &x, &th, &v, &LinearSolveConfig::default());
        assert!(rep.converged);
        let jv_one = one_step_jvp(&t, &x, &th, &v);
        let err = vecops::norm2(&vecops::sub(&jv_impl, &jv_one));
        let rho = estimate_contraction(&t, &x, &th, 100, 7);
        assert!((rho - 0.6).abs() < 0.01, "rho estimate {rho} should be ≈ 0.6");
        assert!(
            err <= 1.05 * rho * vecops::norm2(&jv_impl),
            "one-step err {err} vs bound {}",
            rho * vecops::norm2(&jv_impl)
        );
    }

    #[test]
    fn neumann_converges_geometrically_and_k1_is_one_step() {
        let t = affine(9, 0.5);
        let x = vec![0.0; 4];
        let th = vec![0.3, 0.3, -0.1];
        let v = vec![-1.0, 2.0, 0.4];
        let res = FixedPointResidual(affine(9, 0.5));
        let (jv_impl, _) = implicit_jvp(&res, &x, &th, &v, &LinearSolveConfig::default());
        let k1 = neumann_jvp(&t, &x, &th, &v, 1);
        let one = one_step_jvp(&t, &x, &th, &v);
        for i in 0..4 {
            assert_eq!(k1[i], one[i], "k = 1 must be exactly one-step");
        }
        let nj = vecops::norm2(&jv_impl);
        let mut prev = f64::INFINITY;
        for k in [1usize, 4, 8, 16] {
            let jk = neumann_jvp(&t, &x, &th, &v, k);
            let err = vecops::norm2(&vecops::sub(&jv_impl, &jk));
            // ‖(J_impl − J_k)v‖ = ‖A^k J_impl v‖ ≤ ρ^k·‖J_impl v‖, ρ = 0.5.
            assert!(
                err <= 1.01 * 0.5f64.powi(k as i32) * nj + 1e-9,
                "k = {k}: err {err} exceeds geometric bound"
            );
            assert!(err < prev + 1e-12, "error must not grow with k");
            prev = err;
        }
    }

    #[test]
    fn neumann_vjp_is_exact_adjoint_of_neumann_jvp() {
        let t = affine(13, 0.7);
        let x = vec![0.2; 4];
        let th = vec![0.5, -0.5, 1.0];
        let mut rng = Rng::new(3);
        let v = rng.normal_vec(3);
        let u = rng.normal_vec(4);
        for k in [1usize, 2, 5, 9] {
            let jv = neumann_jvp(&t, &x, &th, &v, k);
            let ju = neumann_vjp(&t, &x, &th, &u, k);
            let lhs = vecops::dot(&u, &jv);
            let rhs = vecops::dot(&ju, &v);
            assert!(
                (lhs - rhs).abs() < 1e-12 * (1.0 + lhs.abs()),
                "adjoint identity at k = {k}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn multi_variants_match_column_loops() {
        let t = affine(21, 0.4);
        let x = vec![0.1; 4];
        let th = vec![0.2, 0.9, -0.3];
        let mut rng = Rng::new(4);
        let v = Mat::randn(3, 5, &mut rng);
        let u = Mat::randn(4, 5, &mut rng);
        for k in [1usize, 6] {
            let jm = neumann_jvp_multi(&t, &x, &th, &v, k);
            let um = neumann_vjp_multi(&t, &x, &th, &u, k);
            for j in 0..5 {
                let jc = neumann_jvp(&t, &x, &th, &v.col(j), k);
                let uc = neumann_vjp(&t, &x, &th, &u.col(j), k);
                for i in 0..4 {
                    assert!((jm.at(i, j) - jc[i]).abs() < 1e-12);
                }
                for i in 0..3 {
                    assert!((um.at(i, j) - uc[i]).abs() < 1e-12);
                }
            }
        }
        let om = one_step_vjp_multi(&t, &x, &th, &u);
        for j in 0..5 {
            let oc = one_step_vjp(&t, &x, &th, &u.col(j));
            for i in 0..3 {
                assert!((om.at(i, j) - oc[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gradient_step_map_is_a_tuned_contraction_on_spd_roots() {
        // F = ∇f for f(x, θ) = ½xᵀQx − θᵀx with SPD Q: root map via closure-
        // free analytic products through a tiny inline RootMap.
        struct QuadRoot {
            q: Mat,
        }
        impl RootMap for QuadRoot {
            fn dim_x(&self) -> usize {
                self.q.rows
            }
            fn dim_theta(&self) -> usize {
                self.q.rows
            }
            fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
                self.q.matvec_into(x, out);
                for i in 0..out.len() {
                    out[i] -= theta[i];
                }
            }
            fn jvp_x(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
                self.q.matvec_into(v, out);
            }
            fn vjp_x(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
                self.q.matvec_into(u, out); // symmetric
            }
            fn jvp_theta(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
                for i in 0..out.len() {
                    out[i] = -v[i];
                }
            }
            fn vjp_theta(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
                for i in 0..out.len() {
                    out[i] = -u[i];
                }
            }
            fn a_symmetric(&self) -> bool {
                true
            }
        }
        let mut rng = Rng::new(31);
        let q = Mat::randn(7, 5, &mut rng).gram().plus_diag(0.5);
        let root = QuadRoot { q };
        let x = rng.normal_vec(5);
        let th = rng.normal_vec(5);
        let t = GradientStepMap::tuned(&root, &x, &th);
        let rho = estimate_contraction(&t, &x, &th, 100, 11);
        assert!(rho < 1.0, "tuned gradient step must contract, got rho = {rho}");
        // Fixed-point check: x* = Q⁻¹θ satisfies T(x*) = x*.
        let chol = crate::linalg::chol::Cholesky::factor(&root.q).unwrap();
        let xs = chol.solve(&th);
        let txs = t.eval_vec(&xs, &th);
        let err = vecops::norm2(&vecops::sub(&txs, &xs));
        assert!(err < 1e-10, "x* must be a fixed point of the tuned map, err {err}");
    }
}
