//! Molecular dynamics substrate (paper §4.4, JAX-MD [76] stand-in):
//! 2-D soft-sphere packing in a periodic box, FIRE relaxation, and the
//! normalized-force optimality mapping F(x, θ) = −∇E(Lx)/… whose root is the
//! energy-minimizing configuration; θ is the small-particle diameter.

use crate::diff::spec::RootMap;

/// Soft-sphere system: k particles in a periodic square box of side `l`,
/// positions stored normalized in [0,1)² (x ∈ R^{2k}), half the particles
/// with diameter 1.0 and half with diameter θ (paper: θ = 0.6).
pub struct SoftSphereSystem {
    pub n_particles: usize,
    pub box_side: f64,
    /// Which particles carry the θ diameter (the "blue" particles).
    pub small: Vec<bool>,
    pub epsilon: f64,
}

impl SoftSphereSystem {
    pub fn new(n_particles: usize, box_side: f64) -> SoftSphereSystem {
        let small = (0..n_particles).map(|i| i % 2 == 1).collect();
        SoftSphereSystem { n_particles, box_side, small, epsilon: 1.0 }
    }

    pub fn dim(&self) -> usize {
        2 * self.n_particles
    }

    fn diameter(&self, i: usize, theta: f64) -> f64 {
        if self.small[i] {
            theta
        } else {
            1.0
        }
    }

    /// Minimum-image displacement between normalized positions (physical units).
    #[inline]
    fn min_image(&self, a: f64, b: f64) -> f64 {
        let mut d = (a - b) * self.box_side;
        let l = self.box_side;
        while d > 0.5 * l {
            d -= l;
        }
        while d < -0.5 * l {
            d += l;
        }
        d
    }

    /// Total energy: Σ_{i<j} (ε/2)(1 − r/σ_ij)² for r < σ_ij.
    pub fn energy(&self, x: &[f64], theta: f64) -> f64 {
        let n = self.n_particles;
        let mut e = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                let dx = self.min_image(x[2 * i], x[2 * j]);
                let dy = self.min_image(x[2 * i + 1], x[2 * j + 1]);
                let r = (dx * dx + dy * dy).sqrt();
                let sigma = 0.5 * (self.diameter(i, theta) + self.diameter(j, theta));
                if r < sigma {
                    let t = 1.0 - r / sigma;
                    e += 0.5 * self.epsilon * t * t;
                }
            }
        }
        e
    }

    /// Forces in NORMALIZED coordinates: F = −∂E/∂x_norm = −L ∂E/∂x_phys.
    pub fn forces(&self, x: &[f64], theta: f64, out: &mut [f64]) {
        let n = self.n_particles;
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..n {
            for j in i + 1..n {
                let dx = self.min_image(x[2 * i], x[2 * j]);
                let dy = self.min_image(x[2 * i + 1], x[2 * j + 1]);
                let r2 = dx * dx + dy * dy;
                let r = r2.sqrt();
                let sigma = 0.5 * (self.diameter(i, theta) + self.diameter(j, theta));
                if r < sigma && r > 1e-12 {
                    // dE/dr = −(ε/σ)(1 − r/σ); physical force on i along +Δ.
                    let mag = self.epsilon / sigma * (1.0 - r / sigma);
                    let fx = mag * dx / r * self.box_side;
                    let fy = mag * dy / r * self.box_side;
                    out[2 * i] += fx;
                    out[2 * i + 1] += fy;
                    out[2 * j] -= fx;
                    out[2 * j + 1] -= fy;
                }
            }
        }
    }

    /// Hessian-vector product of the energy in normalized coordinates:
    /// out = ∂²E/∂x² · v (= −∂F/∂x · v).
    pub fn hessian_vp(&self, x: &[f64], theta: f64, v: &[f64], out: &mut [f64]) {
        let n = self.n_particles;
        let l = self.box_side;
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..n {
            for j in i + 1..n {
                let dx = self.min_image(x[2 * i], x[2 * j]);
                let dy = self.min_image(x[2 * i + 1], x[2 * j + 1]);
                let r2 = dx * dx + dy * dy;
                let r = r2.sqrt();
                let sigma = 0.5 * (self.diameter(i, theta) + self.diameter(j, theta));
                if r < sigma && r > 1e-12 {
                    // Pair Hessian in physical coords:
                    // H = (ε/σ²) uuᵀ − (ε/σ)(1−r/σ)(I − uuᵀ)/r; u = Δ/r.
                    let ux = dx / r;
                    let uy = dy / r;
                    let a = self.epsilon / (sigma * sigma); // uuᵀ coefficient
                    let b = -self.epsilon / sigma * (1.0 - r / sigma) / r; // (I−uuᵀ)
                    // relative tangent in physical coords
                    let dvx = (v[2 * i] - v[2 * j]) * l;
                    let dvy = (v[2 * i + 1] - v[2 * j + 1]) * l;
                    let udot = ux * dvx + uy * dvy;
                    let hx = a * ux * udot + b * (dvx - ux * udot);
                    let hy = a * uy * udot + b * (dvy - uy * udot);
                    // chain: normalized-coordinate second derivative gains L²
                    // (one L already in dvx, one here)
                    out[2 * i] += hx * l;
                    out[2 * i + 1] += hy * l;
                    out[2 * j] -= hx * l;
                    out[2 * j + 1] -= hy * l;
                }
            }
        }
    }

    /// Mixed derivative ∂F/∂θ (normalized coords): differentiate the force
    /// magnitude w.r.t. σ then σ w.r.t. θ (0.5 per small particle in pair).
    pub fn force_theta_jvp(&self, x: &[f64], theta: f64, out: &mut [f64]) {
        let n = self.n_particles;
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..n {
            for j in i + 1..n {
                let dsigma = 0.5 * ((self.small[i] as u8 + self.small[j] as u8) as f64);
                if dsigma == 0.0 {
                    continue;
                }
                let dx = self.min_image(x[2 * i], x[2 * j]);
                let dy = self.min_image(x[2 * i + 1], x[2 * j + 1]);
                let r = (dx * dx + dy * dy).sqrt();
                let sigma = 0.5 * (self.diameter(i, theta) + self.diameter(j, theta));
                if r < sigma && r > 1e-12 {
                    // mag(σ) = (ε/σ)(1 − r/σ) = ε/σ − εr/σ²
                    // dmag/dσ = −ε/σ² + 2εr/σ³
                    let dmag =
                        (-self.epsilon / (sigma * sigma) + 2.0 * self.epsilon * r / (sigma * sigma * sigma))
                            * dsigma;
                    let fx = dmag * dx / r * self.box_side;
                    let fy = dmag * dy / r * self.box_side;
                    out[2 * i] += fx;
                    out[2 * i + 1] += fy;
                    out[2 * j] -= fx;
                    out[2 * j + 1] -= fy;
                }
            }
        }
    }

    /// Relax the packing with FIRE from `x0`. Returns final positions.
    pub fn relax(&self, x0: &[f64], theta: f64, cfg: &crate::solvers::fire::FireConfig) -> Vec<f64> {
        let force = |x: &[f64], out: &mut [f64]| self.forces(x, theta, out);
        let (x, _trace) = crate::solvers::fire::fire_minimize(force, x0, cfg);
        x
    }
}

/// Optimality mapping for the MD sensitivity analysis: F(x, θ) = forces
/// (root = energy minimum); θ = [diameter].
pub struct MdForceRoot<'a>(pub &'a SoftSphereSystem);

impl RootMap for MdForceRoot<'_> {
    fn dim_x(&self) -> usize {
        self.0.dim()
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.0.forces(x, theta[0], out);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        // ∂F/∂x = −H
        self.0.hessian_vp(x, theta[0], v, out);
        for o in out.iter_mut() {
            *o = -*o;
        }
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_x(x, theta, u, out); // Hessian symmetric
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.0.force_theta_jvp(x, theta[0], out);
        for o in out.iter_mut() {
            *o *= v[0];
        }
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let mut jt = vec![0.0; x.len()];
        self.0.force_theta_jvp(x, theta[0], &mut jt);
        out[0] = crate::linalg::vecops::dot(&jt, u);
    }
    fn a_symmetric(&self) -> bool {
        true // A = H symmetric (PSD at a minimum, possibly singular — BiCGSTAB/regularized CG handles it)
    }
}

/// Random initial packing in [0,1)².
pub fn random_packing(n: usize, rng: &mut crate::util::rng::Rng) -> Vec<f64> {
    rng.uniform_vec(2 * n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn small_system() -> SoftSphereSystem {
        SoftSphereSystem::new(8, 3.0)
    }

    #[test]
    fn forces_match_energy_gradient() {
        let sys = small_system();
        let mut rng = Rng::new(1);
        let x = random_packing(8, &mut rng);
        let theta = 0.6;
        let mut f = vec![0.0; 16];
        sys.forces(&x, theta, &mut f);
        let g = crate::ad::num_grad::grad_fd(|xx| sys.energy(xx, theta), &x, 1e-7);
        for i in 0..16 {
            assert!((f[i] + g[i]).abs() < 1e-5, "i={i}: {} vs {}", f[i], -g[i]);
        }
    }

    #[test]
    fn hessian_vp_matches_fd() {
        let sys = small_system();
        let mut rng = Rng::new(2);
        let x = random_packing(8, &mut rng);
        let theta = 0.7;
        let v = rng.normal_vec(16);
        let mut h = vec![0.0; 16];
        sys.hessian_vp(&x, theta, &v, &mut h);
        // H v = −∂F/∂x v
        let fd = crate::ad::num_grad::jvp_fd(
            |xx| {
                let mut f = vec![0.0; 16];
                sys.forces(xx, theta, &mut f);
                f
            },
            &x,
            &v,
            1e-7,
        );
        for i in 0..16 {
            assert!((h[i] + fd[i]).abs() < 1e-4, "i={i}: {} vs {}", h[i], -fd[i]);
        }
    }

    #[test]
    fn force_theta_matches_fd() {
        let sys = small_system();
        let mut rng = Rng::new(3);
        let x = random_packing(8, &mut rng);
        let theta = 0.65;
        let mut jt = vec![0.0; 16];
        sys.force_theta_jvp(&x, theta, &mut jt);
        let h = 1e-7;
        let mut fp = vec![0.0; 16];
        sys.forces(&x, theta + h, &mut fp);
        let mut fm = vec![0.0; 16];
        sys.forces(&x, theta - h, &mut fm);
        for i in 0..16 {
            let fd = (fp[i] - fm[i]) / (2.0 * h);
            assert!((jt[i] - fd).abs() < 1e-4, "i={i}: {} vs {fd}", jt[i]);
        }
    }

    #[test]
    fn relaxation_reduces_energy_and_forces() {
        let sys = SoftSphereSystem::new(12, 2.5);
        let mut rng = Rng::new(4);
        let x0 = random_packing(12, &mut rng);
        let theta = 0.6;
        let e0 = sys.energy(&x0, theta);
        let cfg = crate::solvers::fire::FireConfig { max_iter: 20000, force_tol: 1e-9, ..Default::default() };
        let x = sys.relax(&x0, theta, &cfg);
        let e1 = sys.energy(&x, theta);
        assert!(e1 <= e0 + 1e-12);
        let mut f = vec![0.0; 24];
        sys.forces(&x, theta, &mut f);
        assert!(crate::linalg::vecops::norm2(&f) < 1e-6, "residual force {}", crate::linalg::vecops::norm2(&f));
    }

    #[test]
    fn energy_translation_invariant() {
        let sys = small_system();
        let mut rng = Rng::new(5);
        let x = random_packing(8, &mut rng);
        let shifted: Vec<f64> = x.iter().map(|v| (v + 0.37).rem_euclid(1.0)).collect();
        let e1 = sys.energy(&x, 0.6);
        let e2 = sys.energy(&shifted, 0.6);
        assert!((e1 - e2).abs() < 1e-10);
    }
}
