//! Synthetic dataset substrates.
//!
//! The paper's data inputs that are gated (scikit-learn's diabetes, MNIST,
//! TCGA breast-cancer expression) are replaced by generators matching their
//! shapes and the statistical structure each experiment relies on — see
//! DESIGN.md §Substitutions.

pub mod classification;
pub mod digits;
pub mod gene_expr;
pub mod regression;
pub mod splits;
