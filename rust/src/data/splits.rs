//! Train/validation/test splitting utilities (paper Appendix F.2 uses
//! repeated 60/20/20 random splits).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<usize>,
    pub val: Vec<usize>,
    pub test: Vec<usize>,
}

/// Random fractional split. Fractions must sum to ≤ 1; remainder goes to test.
pub fn random_split(m: usize, frac_train: f64, frac_val: f64, rng: &mut Rng) -> Split {
    let perm = rng.permutation(m);
    let n_train = (m as f64 * frac_train).round() as usize;
    let n_val = (m as f64 * frac_val).round() as usize;
    Split {
        train: perm[..n_train].to_vec(),
        val: perm[n_train..(n_train + n_val).min(m)].to_vec(),
        test: perm[(n_train + n_val).min(m)..].to_vec(),
    }
}

/// Select rows of a matrix by index.
pub fn take_rows(x: &crate::linalg::Mat, idx: &[usize]) -> crate::linalg::Mat {
    let mut out = crate::linalg::Mat::zeros(idx.len(), x.cols);
    for (dst, &src) in idx.iter().enumerate() {
        out.row_mut(dst).copy_from_slice(x.row(src));
    }
    out
}

/// Select entries of a vector by index.
pub fn take<Tv: Copy>(v: &[Tv], idx: &[usize]) -> Vec<Tv> {
    idx.iter().map(|&i| v[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_partitions_everything() {
        let mut rng = Rng::new(1);
        let s = random_split(100, 0.6, 0.2, &mut rng);
        assert_eq!(s.train.len(), 60);
        assert_eq!(s.val.len(), 20);
        assert_eq!(s.test.len(), 20);
        let mut all: Vec<usize> = s.train.iter().chain(&s.val).chain(&s.test).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn take_rows_selects() {
        let x = crate::linalg::Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let sub = take_rows(&x, &[2, 0]);
        assert_eq!(sub.row(0), &[4.0, 5.0]);
        assert_eq!(sub.row(1), &[0.0, 1.0]);
    }
}
