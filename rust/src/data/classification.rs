//! Port of scikit-learn's `make_classification` [Guyon 50] — the generator
//! the multiclass-SVM experiment uses (Appendix F.1: m=700, k=5, 10%
//! informative features, the rest noise).

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

pub struct ClassificationDataset {
    pub x: Mat,          // m × p features
    pub labels: Vec<usize>, // class ids in [0, k)
    pub k: usize,
}

impl ClassificationDataset {
    /// One-hot label matrix m × k.
    pub fn one_hot(&self) -> Mat {
        let m = self.labels.len();
        let mut y = Mat::zeros(m, self.k);
        for (i, &c) in self.labels.iter().enumerate() {
            *y.at_mut(i, c) = 1.0;
        }
        y
    }
}

/// Generate a k-class dataset: informative features are Gaussian clusters at
/// class-dependent centroids (hypercube vertices scaled by `class_sep`); the
/// remaining features are pure noise.
pub fn make_classification(
    m: usize,
    p: usize,
    k: usize,
    informative_frac: f64,
    class_sep: f64,
    rng: &mut Rng,
) -> ClassificationDataset {
    let n_inf = ((p as f64 * informative_frac).round() as usize).clamp(1, p);
    // Class centroids in the informative subspace.
    let mut centroids = Mat::zeros(k, n_inf);
    for c in 0..k {
        for j in 0..n_inf {
            // Deterministic hypercube-ish pattern + jitter.
            let sign = if ((c >> (j % 8)) & 1) == 1 { 1.0 } else { -1.0 };
            *centroids.at_mut(c, j) = class_sep * sign + 0.3 * rng.normal();
        }
    }
    let mut x = Mat::zeros(m, p);
    let mut labels = Vec::with_capacity(m);
    for i in 0..m {
        let c = i % k; // balanced classes
        labels.push(c);
        for j in 0..n_inf {
            *x.at_mut(i, j) = centroids.at(c, j) + rng.normal();
        }
        for j in n_inf..p {
            *x.at_mut(i, j) = rng.normal();
        }
    }
    // Shuffle rows so class order is not trivially sorted.
    let perm = rng.permutation(m);
    let mut xs = Mat::zeros(m, p);
    let mut ls = vec![0usize; m];
    for (dst, &src) in perm.iter().enumerate() {
        xs.row_mut(dst).copy_from_slice(x.row(src));
        ls[dst] = labels[src];
    }
    ClassificationDataset { x: xs, labels: ls, k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let mut rng = Rng::new(1);
        let ds = make_classification(100, 20, 5, 0.1, 2.0, &mut rng);
        assert_eq!(ds.x.rows, 100);
        assert_eq!(ds.x.cols, 20);
        assert_eq!(ds.labels.len(), 100);
        let mut counts = vec![0; 5];
        for &c in &ds.labels {
            counts[c] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn one_hot_rows_sum_to_one() {
        let mut rng = Rng::new(2);
        let ds = make_classification(30, 10, 3, 0.2, 1.0, &mut rng);
        let y = ds.one_hot();
        for i in 0..30 {
            let s: f64 = y.row(i).iter().sum();
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn classes_are_separable_in_informative_dims() {
        // Nearest-centroid on informative features should beat chance easily.
        let mut rng = Rng::new(3);
        let k = 4;
        let ds = make_classification(200, 30, k, 0.2, 3.0, &mut rng);
        let n_inf = 6;
        // compute class means
        let mut means = Mat::zeros(k, n_inf);
        let mut counts = vec![0.0; k];
        for i in 0..200 {
            let c = ds.labels[i];
            counts[c] += 1.0;
            for j in 0..n_inf {
                *means.at_mut(c, j) += ds.x.at(i, j);
            }
        }
        for c in 0..k {
            for j in 0..n_inf {
                *means.at_mut(c, j) /= counts[c];
            }
        }
        let mut correct = 0;
        for i in 0..200 {
            let mut best = 0;
            let mut bestd = f64::INFINITY;
            for c in 0..k {
                let d: f64 = (0..n_inf)
                    .map(|j| (ds.x.at(i, j) - means.at(c, j)).powi(2))
                    .sum();
                if d < bestd {
                    bestd = d;
                    best = c;
                }
            }
            if best == ds.labels[i] {
                correct += 1;
            }
        }
        assert!(correct > 150, "accuracy {correct}/200");
    }
}
