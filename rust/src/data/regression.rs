//! Diabetes-like regression data (Fig. 3 substitute): standardized,
//! correlated features with a dense linear signal plus noise — the same
//! shape (m=442, p=10) and conditioning regime as [Efron et al., 35].

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

/// Generate (Φ, y) with correlated standardized columns.
pub fn diabetes_like(m: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    // Latent factors induce column correlation (like body measurements).
    let n_latent = (p / 2).max(1);
    let factors = Mat::randn(m, n_latent, &mut rng);
    let mixing = Mat::randn(n_latent, p, &mut rng);
    let mut x = factors.matmul(&mixing);
    for v in x.data.iter_mut() {
        *v += 0.5 * rng.normal();
    }
    // Standardize columns (mean 0, norm 1 — like sklearn's diabetes).
    for j in 0..p {
        let mut mean = 0.0;
        for i in 0..m {
            mean += x.at(i, j);
        }
        mean /= m as f64;
        let mut norm = 0.0;
        for i in 0..m {
            let c = x.at(i, j) - mean;
            *x.at_mut(i, j) = c;
            norm += c * c;
        }
        let norm = norm.sqrt().max(1e-12);
        for i in 0..m {
            *x.at_mut(i, j) /= norm;
        }
    }
    let w_true = rng.normal_vec(p);
    let mut y = x.matvec(&w_true);
    for v in y.iter_mut() {
        *v = *v * 100.0 + 5.0 * rng.normal(); // diabetes-scale targets
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn columns_standardized() {
        let (x, y) = diabetes_like(442, 10, 1);
        assert_eq!(x.rows, 442);
        assert_eq!(y.len(), 442);
        for j in 0..10 {
            let col = x.col(j);
            let mean: f64 = col.iter().sum::<f64>() / 442.0;
            let norm: f64 = col.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(mean.abs() < 1e-10);
            assert!((norm - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn features_are_correlated() {
        let (x, _) = diabetes_like(442, 10, 2);
        // with latent factors, at least one off-diagonal |corr| should be large
        let mut max_corr = 0.0f64;
        for a in 0..10 {
            for b in a + 1..10 {
                let ca = x.col(a);
                let cb = x.col(b);
                let corr: f64 = ca.iter().zip(&cb).map(|(u, v)| u * v).sum();
                max_corr = max_corr.max(corr.abs());
            }
        }
        assert!(max_corr > 0.3, "max |corr| = {max_corr}");
    }

    #[test]
    fn signal_is_learnable() {
        let (x, y) = diabetes_like(200, 8, 3);
        // Least squares residual should be far below total variance.
        let ridge = crate::ml::ridge::RidgeProblem::new(x.clone(), y.clone());
        let w = ridge.solve_closed_form(1e-6);
        let pred = x.matvec(&w);
        let ss_res: f64 = pred.iter().zip(&y).map(|(p, t)| (p - t) * (p - t)).sum();
        let ymean: f64 = y.iter().sum::<f64>() / y.len() as f64;
        let ss_tot: f64 = y.iter().map(|t| (t - ymean) * (t - ymean)).sum();
        assert!(ss_res < 0.2 * ss_tot, "R² too low: {}", 1.0 - ss_res / ss_tot);
    }
}
