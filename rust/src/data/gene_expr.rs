//! Synthetic gene-expression cohort (TCGA breast-cancer substitute, paper
//! §4.3 / Appendix F.2): m = 299 patients with the paper's 200/99 survival
//! split, p genes of which a sparse subset carries the survival signal —
//! the structure the task-driven dictionary-learning claim relies on.

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

pub struct GeneExprCohort {
    pub x: Mat,          // m × p log-expression values (standardized)
    pub labels: Vec<f64>, // 1.0 = survived ≥ 5y, 0.0 = died < 5y
}

/// Generate the cohort. `n_informative` genes carry the signal through a
/// low-rank pathway structure (genes co-express in modules, like real data).
pub fn make_cohort(m1: usize, m0: usize, p: usize, n_informative: usize, seed: u64) -> GeneExprCohort {
    let mut rng = Rng::new(seed);
    let m = m1 + m0;
    let n_modules = 10;
    // Module loadings: each gene belongs softly to a module.
    let loadings = Mat::randn(n_modules, p, &mut rng);
    // Patient module activities.
    let activities = Mat::randn(m, n_modules, &mut rng);
    let mut x = activities.matmul(&loadings);
    for v in x.data.iter_mut() {
        *v = 0.6 * *v + 0.8 * rng.normal(); // per-gene noise
    }
    // Survival signal: sparse weights on informative genes, injected through
    // a shift of those genes' expression by class.
    let info: Vec<usize> = rng.choose(p, n_informative);
    let labels: Vec<f64> = (0..m).map(|i| if i < m1 { 1.0 } else { 0.0 }).collect();
    // Weak, patient-heterogeneous signal: effect sizes ~0.25 with per-patient
    // modulation, so single-split AUCs land in the paper's 65–80% band
    // instead of saturating.
    for i in 0..m {
        let sign = if labels[i] > 0.5 { 1.0 } else { -1.0 };
        let patient_mod = 0.5 + rng.uniform(); // 0.5–1.5 heterogeneity
        for (rank, &g) in info.iter().enumerate() {
            let strength = 0.25 * (1.0 - 0.5 * rank as f64 / n_informative as f64);
            *x.at_mut(i, g) += sign * strength * patient_mod;
        }
    }
    // Standardize genes.
    for j in 0..p {
        let mut mean = 0.0;
        for i in 0..m {
            mean += x.at(i, j);
        }
        mean /= m as f64;
        let mut var = 0.0;
        for i in 0..m {
            let c = x.at(i, j) - mean;
            *x.at_mut(i, j) = c;
            var += c * c;
        }
        let sd = (var / m as f64).sqrt().max(1e-12);
        for i in 0..m {
            *x.at_mut(i, j) /= sd;
        }
    }
    // Shuffle patients.
    let perm = rng.permutation(m);
    let mut xs = Mat::zeros(m, p);
    let mut ls = vec![0.0; m];
    for (dst, &src) in perm.iter().enumerate() {
        xs.row_mut(dst).copy_from_slice(x.row(src));
        ls[dst] = labels[src];
    }
    GeneExprCohort { x: xs, labels: ls }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_shape_matches_paper() {
        let c = make_cohort(200, 99, 1000, 50, 1);
        assert_eq!(c.x.rows, 299);
        assert_eq!(c.x.cols, 1000);
        let pos = c.labels.iter().filter(|&&l| l > 0.5).count();
        assert_eq!(pos, 200);
    }

    #[test]
    fn genes_standardized() {
        let c = make_cohort(50, 30, 100, 10, 2);
        for j in 0..100 {
            let col = c.x.col(j);
            let mean: f64 = col.iter().sum::<f64>() / 80.0;
            let var: f64 = col.iter().map(|v| v * v).sum::<f64>() / 80.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-8);
        }
    }

    #[test]
    fn signal_is_detectable() {
        // A simple mean-difference classifier on the top-|t| gene should beat
        // chance — the downstream AUC experiments depend on this.
        let c = make_cohort(100, 60, 200, 20, 3);
        let m = 160;
        // pick gene with max |class-mean difference|
        let mut best_gene = 0;
        let mut best_diff = 0.0f64;
        for j in 0..200 {
            let mut s1 = 0.0;
            let mut s0 = 0.0;
            for i in 0..m {
                if c.labels[i] > 0.5 {
                    s1 += c.x.at(i, j);
                } else {
                    s0 += c.x.at(i, j);
                }
            }
            let diff = (s1 / 100.0 - s0 / 60.0).abs();
            if diff > best_diff {
                best_diff = diff;
                best_gene = j;
            }
        }
        assert!(best_diff > 0.5, "no separable gene found");
        // threshold at 0: accuracy above chance
        let mut correct = 0;
        for i in 0..m {
            let pred = if c.x.at(i, best_gene) > 0.0 { 1.0 } else { 0.0 };
            if (pred - c.labels[i]).abs() < 0.5 {
                correct += 1;
            }
        }
        assert!(correct as f64 / m as f64 > 0.6, "accuracy {correct}/{m}");
    }
}
