//! Synthetic 28×28 10-class digit-like images (MNIST substitute for the
//! dataset-distillation experiment). Each class has a smooth Gaussian-bump
//! prototype; samples are noisy prototypes. Distillation should recover
//! per-class prototypes — the role Fig. 5's distilled digits play.

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

pub const SIDE: usize = 28;
pub const PIXELS: usize = SIDE * SIDE;

/// Class prototypes: each class places 3 Gaussian bumps at class-specific
/// locations (deterministic given the class id).
pub fn class_prototype(class: usize) -> Vec<f64> {
    let mut img = vec![0.0; PIXELS];
    for b in 0..3 {
        // deterministic pseudo-positions per (class, bump)
        let cx = 4.0 + 20.0 * (((class * 7 + b * 13 + 3) % 11) as f64 / 10.0);
        let cy = 4.0 + 20.0 * (((class * 5 + b * 17 + 1) % 11) as f64 / 10.0);
        let sigma = 2.0 + ((class + b) % 3) as f64;
        for y in 0..SIDE {
            for x in 0..SIDE {
                let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
                img[y * SIDE + x] += (-d2 / (2.0 * sigma * sigma)).exp();
            }
        }
    }
    // normalize to [0, 1]
    let max = img.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    for v in img.iter_mut() {
        *v /= max;
    }
    img
}

pub struct DigitsDataset {
    pub x: Mat,            // m × 784, values in [0, 1]-ish
    pub labels: Vec<usize>, // 0..10
}

/// Sample m noisy digit images, balanced across 10 classes.
pub fn make_digits(m: usize, noise: f64, rng: &mut Rng) -> DigitsDataset {
    let protos: Vec<Vec<f64>> = (0..10).map(class_prototype).collect();
    let mut x = Mat::zeros(m, PIXELS);
    let mut labels = Vec::with_capacity(m);
    for i in 0..m {
        let c = i % 10;
        labels.push(c);
        let row = x.row_mut(i);
        for j in 0..PIXELS {
            row[j] = (protos[c][j] + noise * rng.normal()).clamp(-0.5, 1.5);
        }
    }
    let perm = rng.permutation(m);
    let mut xs = Mat::zeros(m, PIXELS);
    let mut ls = vec![0usize; m];
    for (dst, &src) in perm.iter().enumerate() {
        xs.row_mut(dst).copy_from_slice(x.row(src));
        ls[dst] = labels[src];
    }
    DigitsDataset { x: xs, labels: ls }
}

/// Render an image row as coarse ASCII art (for the Fig. 5 dump).
pub fn ascii_render(img: &[f64]) -> String {
    let ramp = [' ', '.', ':', '+', '#', '@'];
    let mut out = String::new();
    for y in (0..SIDE).step_by(2) {
        for x in 0..SIDE {
            let v = img[y * SIDE + x].clamp(0.0, 1.0);
            let idx = ((v * (ramp.len() - 1) as f64).round() as usize).min(ramp.len() - 1);
            out.push(ramp[idx]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_distinct_across_classes() {
        for a in 0..10 {
            for b in a + 1..10 {
                let pa = class_prototype(a);
                let pb = class_prototype(b);
                let d: f64 = pa.iter().zip(&pb).map(|(x, y)| (x - y) * (x - y)).sum();
                assert!(d > 1.0, "classes {a},{b} too similar: {d}");
            }
        }
    }

    #[test]
    fn dataset_shape_and_balance() {
        let mut rng = Rng::new(1);
        let ds = make_digits(100, 0.1, &mut rng);
        assert_eq!(ds.x.rows, 100);
        assert_eq!(ds.x.cols, 784);
        let mut counts = vec![0; 10];
        for &c in &ds.labels {
            counts[c] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn samples_close_to_their_prototype() {
        let mut rng = Rng::new(2);
        let ds = make_digits(50, 0.05, &mut rng);
        for i in 0..50 {
            let proto = class_prototype(ds.labels[i]);
            let d: f64 = ds.x.row(i).iter().zip(&proto).map(|(x, p)| (x - p) * (x - p)).sum();
            let d_other: f64 = ds
                .x
                .row(i)
                .iter()
                .zip(&class_prototype((ds.labels[i] + 1) % 10))
                .map(|(x, p)| (x - p) * (x - p))
                .sum();
            assert!(d < d_other, "sample {i} closer to wrong prototype");
        }
    }

    #[test]
    fn ascii_render_shape() {
        let img = class_prototype(3);
        let art = ascii_render(&img);
        assert_eq!(art.lines().count(), 14);
        assert!(art.lines().all(|l| l.chars().count() == 28));
    }
}
