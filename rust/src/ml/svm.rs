//! Multiclass SVM trained in the dual (Crammer & Singer [27]) — the paper's
//! §4.1 hyper-parameter optimization experiment.
//!
//! Inner problem (dual):  x*(θ) = argmin_{x ∈ △^k×…×△^k} f(x, θ)
//!   f(x, θ) = (θ/2)‖W(x, θ)‖²_F + ⟨x, Y_tr⟩,  W(x, θ) = X_trᵀ(Y_tr − x)/θ
//! Outer problem: validation squared loss of W(x*(θ), θ), with θ = exp(λ).
//!
//! The Objective implementation provides all oracle products analytically,
//! so the same model drives the mirror-descent fixed point, the projected-
//! gradient fixed point AND the exact-row BCD solver (Fig. 4 a–c).

use crate::linalg::mat::Mat;
use crate::mappings::objective::Objective;
use crate::ml::design::Design;
use crate::proj::simplex;

pub struct MulticlassSvm {
    /// m × p training design — dense or CSR ([`Design`]); every oracle is a
    /// product with X or Xᵀ, so sparse designs run matrix-free at large p.
    pub x_tr: Design,
    pub y_tr: Mat, // m × k one-hot
    pub k: usize,
    /// Cached spectral norm of XᵀX (power iteration, lazy).
    sigma2: std::cell::Cell<f64>,
}

impl MulticlassSvm {
    pub fn new(x_tr: impl Into<Design>, y_tr: Mat) -> MulticlassSvm {
        let x_tr = x_tr.into();
        assert_eq!(x_tr.rows(), y_tr.rows);
        let k = y_tr.cols;
        MulticlassSvm { x_tr, y_tr, k, sigma2: std::cell::Cell::new(0.0) }
    }

    /// λ_max(XᵀX) by power iteration (cached; tight vs the Frobenius bound,
    /// which can overestimate by ~√rank and cripple PG step sizes).
    pub fn spectral_norm_xtx(&self) -> f64 {
        let cached = self.sigma2.get();
        if cached > 0.0 {
            return cached;
        }
        let p = self.p();
        let mut v = vec![1.0; p];
        let mut lam = 1.0;
        for _ in 0..60 {
            let xv = self.x_tr.matvec(&v);
            let mut w = self.x_tr.matvec_t(&xv);
            lam = crate::linalg::vecops::norm2(&w).max(1e-30);
            for wi in w.iter_mut() {
                *wi /= lam;
            }
            v = w;
        }
        self.sigma2.set(lam);
        lam
    }

    /// The projected-gradient step 0.9·θ/λ_max(XᵀX).
    pub fn pg_step(&self, theta: f64) -> f64 {
        0.9 * theta / self.spectral_norm_xtx()
    }

    pub fn m(&self) -> usize {
        self.x_tr.rows()
    }
    pub fn p(&self) -> usize {
        self.x_tr.cols()
    }

    /// Dual-primal map W(x, θ) = Xᵀ(Y − x)/θ ∈ R^{p×k}.
    pub fn primal_w(&self, x: &[f64], theta: f64) -> Mat {
        let (m, k) = (self.m(), self.k);
        let mut diff = Mat::zeros(m, k);
        for i in 0..m {
            for j in 0..k {
                *diff.at_mut(i, j) = (self.y_tr.at(i, j) - x[i * k + j]) / theta;
            }
        }
        self.x_tr.t_matmul(&diff)
    }

    /// Feasible initializer x₀ = 1/k (paper Appendix F.1).
    pub fn init(&self) -> Vec<f64> {
        vec![1.0 / self.k as f64; self.m() * self.k]
    }

    /// Exact-row block coordinate descent: each row subproblem has isotropic
    /// Hessian (‖X_i‖²/θ)I over the simplex, so the exact row minimizer is a
    /// single projected Newton step. W is maintained incrementally.
    pub fn solve_bcd(&self, theta: f64, sweeps: usize) -> Vec<f64> {
        let (m, k) = (self.m(), self.k);
        let mut x = self.init();
        let mut w = self.primal_w(&x, theta);
        let row_sq: Vec<f64> = (0..m).map(|i| self.x_tr.row_sq_norm(i)).collect();
        let mut scores = vec![0.0; k];
        let mut grad_row = vec![0.0; k];
        let mut target = vec![0.0; k];
        let mut new_row = vec![0.0; k];
        let mut delta = vec![0.0; k];
        for _ in 0..sweeps {
            for i in 0..m {
                // grad_i = −X_i W + Y_i (W is p×k row-major, so its flat
                // data indexes as w[a·k + b] — exactly the score gather)
                self.x_tr.score_row(i, &w.data, k, &mut scores);
                for b in 0..k {
                    grad_row[b] = -scores[b] + self.y_tr.at(i, b);
                }
                let lip = row_sq[i] / theta;
                if lip <= 0.0 {
                    continue;
                }
                for b in 0..k {
                    target[b] = x[i * k + b] - grad_row[b] / lip;
                }
                simplex::project_simplex(&target, &mut new_row);
                // W += x_i ⊗ (x_old − x_new)/θ
                for b in 0..k {
                    delta[b] = (x[i * k + b] - new_row[b]) / theta;
                    x[i * k + b] = new_row[b];
                }
                self.x_tr.add_outer(i, 1.0, &delta, k, &mut w.data);
            }
        }
        x
    }

    /// Outer validation loss L(θ) = ½‖X_val W − Y_val‖²_F and its gradients.
    pub fn outer_loss(&self, x_val: &Mat, y_val: &Mat, x: &[f64], theta: f64) -> f64 {
        let w = self.primal_w(x, theta);
        let pred = x_val.matmul(&w);
        let mut l = 0.0;
        for i in 0..pred.data.len() {
            let d = pred.data[i] - y_val.data[i];
            l += d * d;
        }
        0.5 * l
    }

    /// (∇_x L, ∂L/∂θ) of the outer loss at (x, θ).
    pub fn outer_grads(&self, x_val: &Mat, y_val: &Mat, x: &[f64], theta: f64) -> (Vec<f64>, f64) {
        let (m, k) = (self.m(), self.k);
        let w = self.primal_w(x, theta);
        let pred = x_val.matmul(&w);
        let mut resid = Mat::zeros(x_val.rows, k);
        for i in 0..resid.data.len() {
            resid.data[i] = pred.data[i] - y_val.data[i];
        }
        // dL/dW = X_valᵀ R (p×k)
        let dldw = x_val.t_matmul(&resid);
        // dL/dx = −X dL/dW / θ (m×k)
        let dldx_m = self.x_tr.matmul(&dldw);
        let mut grad_x = vec![0.0; m * k];
        for i in 0..m * k {
            grad_x[i] = -dldx_m.data[i] / theta;
        }
        // dL/dθ(direct) = ⟨dL/dW, ∂W/∂θ⟩ = ⟨dL/dW, −W/θ⟩
        let dldtheta = -crate::linalg::vecops::dot(&dldw.data, &w.data) / theta;
        (grad_x, dldtheta)
    }
}

/// The SVM dual objective as a generic [`Objective`] (θ scalar).
impl Objective for MulticlassSvm {
    fn dim_x(&self) -> usize {
        self.m() * self.k
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn value(&self, x: &[f64], theta: &[f64]) -> f64 {
        let th = theta[0];
        let w = self.primal_w(x, th);
        let wnorm2 = crate::linalg::vecops::dot(&w.data, &w.data);
        0.5 * th * wnorm2 + crate::linalg::vecops::dot(x, &self.y_tr.data)
    }
    fn grad_x(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let th = theta[0];
        // ∇ = −X W + Y (m×k)
        let w = self.primal_w(x, th);
        let xw = self.x_tr.matmul(&w);
        for i in 0..out.len() {
            out[i] = -xw.data[i] + self.y_tr.data[i];
        }
    }
    fn hvp_xx(&self, _x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let th = theta[0];
        let (m, k) = (self.m(), self.k);
        // H v = (1/θ) X Xᵀ v (blockwise over classes)
        let vm = Mat { rows: m, cols: k, data: v.to_vec() };
        let xtv = self.x_tr.t_matmul(&vm); // p×k
        let xxtv = self.x_tr.matmul(&xtv); // m×k
        for i in 0..out.len() {
            out[i] = xxtv.data[i] / th;
        }
    }
    /// Batched HVP: reshape each of the c columns (an m×k dual block) and
    /// stack them side by side into one m×(k·c) matrix, so the whole block
    /// costs TWO packed GEMMs instead of 2c small ones.
    fn hvp_xx_batch(&self, _x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        let th = theta[0];
        let (m, k) = (self.m(), self.k);
        let c = v.cols;
        assert_eq!(v.rows, m * k, "batched HVP input rows must be m·k");
        assert_eq!((out.rows, out.cols), (m * k, c), "batched HVP output must be m·k × c");
        let kc = k * c;
        let mut stacked = Mat::zeros(m, kc);
        for i in 0..m {
            for b in 0..k {
                let row = i * k + b;
                for j in 0..c {
                    stacked.data[i * kc + j * k + b] = v.data[row * c + j];
                }
            }
        }
        let xtv = self.x_tr.t_matmul(&stacked); // p×(k·c)
        let xxtv = self.x_tr.matmul(&xtv); // m×(k·c)
        for i in 0..m {
            for b in 0..k {
                let row = i * k + b;
                for j in 0..c {
                    out.data[row * c + j] = xxtv.data[i * kc + j * k + b] / th;
                }
            }
        }
    }
    fn jvp_x_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        // ∂θ∇₁f = X Xᵀ(Y−x)/θ² = (XW)/θ
        let th = theta[0];
        let w = self.primal_w(x, th);
        let xw = self.x_tr.matmul(&w);
        for i in 0..out.len() {
            out[i] = xw.data[i] / th * v[0];
        }
    }
    fn vjp_x_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let th = theta[0];
        let w = self.primal_w(x, th);
        let xw = self.x_tr.matmul(&w);
        out[0] = crate::linalg::vecops::dot(&xw.data, u) / th;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classification::make_classification;
    use crate::util::rng::Rng;

    fn small_svm(seed: u64) -> MulticlassSvm {
        let mut rng = Rng::new(seed);
        let ds = make_classification(24, 10, 3, 0.3, 2.0, &mut rng);
        let y = ds.one_hot();
        MulticlassSvm::new(ds.x, y)
    }

    #[test]
    fn oracles_match_fd() {
        let svm = small_svm(1);
        let mut rng = Rng::new(2);
        let d = svm.dim_x();
        let x = rng.uniform_vec(d);
        let theta = [1.3];
        let g = svm.grad_x_vec(&x, &theta);
        let gfd = crate::ad::num_grad::grad_fd(|xx| svm.value(xx, &theta), &x, 1e-6);
        for i in 0..d {
            assert!((g[i] - gfd[i]).abs() < 1e-4, "grad {i}: {} vs {}", g[i], gfd[i]);
        }
        let v = rng.normal_vec(d);
        let mut h = vec![0.0; d];
        svm.hvp_xx(&x, &theta, &v, &mut h);
        let hfd = crate::ad::num_grad::jvp_fd(|xx| svm.grad_x_vec(xx, &theta), &x, &v, 1e-6);
        for i in 0..d {
            assert!((h[i] - hfd[i]).abs() < 1e-4);
        }
        let mut c = vec![0.0; d];
        svm.jvp_x_theta(&x, &theta, &[1.0], &mut c);
        let cfd = crate::ad::num_grad::jvp_fd(|tt| svm.grad_x_vec(&x, tt), &theta, &[1.0], 1e-6);
        for i in 0..d {
            assert!((c[i] - cfd[i]).abs() < 1e-3, "cross {i}: {} vs {}", c[i], cfd[i]);
        }
    }

    #[test]
    fn batched_hvp_matches_column_loop() {
        let svm = small_svm(7);
        let mut rng = Rng::new(8);
        let d = svm.dim_x();
        let x = rng.uniform_vec(d);
        let theta = [0.8];
        let v = Mat::randn(d, 5, &mut rng);
        let mut fast = Mat::zeros(d, 5);
        svm.hvp_xx_batch(&x, &theta, &v, &mut fast);
        let mut vc = vec![0.0; d];
        let mut oc = vec![0.0; d];
        for j in 0..5 {
            v.col_into(j, &mut vc);
            svm.hvp_xx(&x, &theta, &vc, &mut oc);
            for i in 0..d {
                assert!(
                    (fast.at(i, j) - oc[i]).abs() < 1e-10,
                    "({i},{j}): {} vs {}",
                    fast.at(i, j),
                    oc[i]
                );
            }
        }
    }

    #[test]
    fn bcd_reaches_projected_fixed_point() {
        let svm = small_svm(3);
        let theta = 1.0;
        let x = svm.solve_bcd(theta, 400);
        // fixed-point residual of the projected-gradient map must be small
        let g = svm.grad_x_vec(&x, &[theta]);
        let eta = svm.pg_step(theta);
        let y: Vec<f64> = (0..x.len()).map(|i| x[i] - eta * g[i]).collect();
        let mut z = vec![0.0; x.len()];
        simplex::project_rows_simplex(&y, svm.k, &mut z);
        let res = crate::linalg::vecops::rel_err(&z, &x);
        assert!(res < 1e-6, "fixed-point residual {res}");
    }

    #[test]
    fn bcd_feasible() {
        let svm = small_svm(4);
        let x = svm.solve_bcd(0.7, 100);
        for i in 0..svm.m() {
            let row = &x[i * svm.k..(i + 1) * svm.k];
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(row.iter().all(|&v| v >= -1e-12));
        }
    }

    #[test]
    fn dense_and_csr_designs_agree() {
        // Same SVM, dense vs CSR design. The sparse oracles use SpMM rather
        // than packed GEMM, so agreement is to solver precision, not bitwise
        // (unlike logreg, whose row primitives replay the dense order).
        let (m, p, k) = (20, 12, 3);
        let mut rng = Rng::new(9);
        let mut data = Vec::with_capacity(m * p);
        for _ in 0..m * p {
            data.push(if rng.uniform() < 0.35 { rng.normal() } else { 0.0 });
        }
        let x = Mat::from_vec(m, p, data);
        let y = {
            let mut y = Mat::zeros(m, k);
            for i in 0..m {
                *y.at_mut(i, i % k) = 1.0;
            }
            y
        };
        let csr = crate::linalg::sparse::CsrMat::from_dense(&x);
        let svm_d = MulticlassSvm::new(x, y.clone());
        let svm_s = MulticlassSvm::new(csr, y);
        assert!(svm_s.x_tr.is_sparse());
        let d = svm_d.dim_x();
        let xdual = rng.uniform_vec(d);
        let theta = [1.1];
        let gd = svm_d.grad_x_vec(&xdual, &theta);
        let gs = svm_s.grad_x_vec(&xdual, &theta);
        let v = rng.normal_vec(d);
        let mut hd = vec![0.0; d];
        let mut hs = vec![0.0; d];
        svm_d.hvp_xx(&xdual, &theta, &v, &mut hd);
        svm_s.hvp_xx(&xdual, &theta, &v, &mut hs);
        let mut cd = vec![0.0; d];
        let mut cs = vec![0.0; d];
        svm_d.jvp_x_theta(&xdual, &theta, &[1.0], &mut cd);
        svm_s.jvp_x_theta(&xdual, &theta, &[1.0], &mut cs);
        for i in 0..d {
            assert!((gd[i] - gs[i]).abs() < 1e-10, "grad {i}: {} vs {}", gd[i], gs[i]);
            assert!((hd[i] - hs[i]).abs() < 1e-10, "hvp {i}: {} vs {}", hd[i], hs[i]);
            assert!((cd[i] - cs[i]).abs() < 1e-10, "cross {i}: {} vs {}", cd[i], cs[i]);
        }
        // BCD on both backings reaches the same fixed point.
        let xb_d = svm_d.solve_bcd(0.9, 200);
        let xb_s = svm_s.solve_bcd(0.9, 200);
        assert!(crate::linalg::vecops::rel_err(&xb_d, &xb_s) < 1e-8);
    }

    #[test]
    fn outer_grads_match_fd() {
        let svm = small_svm(5);
        let mut rng = Rng::new(6);
        let ds_val = make_classification(10, 10, 3, 0.3, 2.0, &mut rng);
        let y_val = ds_val.one_hot();
        let x = rng.uniform_vec(svm.dim_x());
        let theta = 0.9;
        let (gx, gt) = svm.outer_grads(&ds_val.x, &y_val, &x, theta);
        let lfd = crate::ad::num_grad::grad_fd(
            |xx| svm.outer_loss(&ds_val.x, &y_val, xx, theta),
            &x,
            1e-6,
        );
        for i in 0..x.len() {
            assert!((gx[i] - lfd[i]).abs() < 1e-4);
        }
        let h = 1e-6;
        let fd_t = (svm.outer_loss(&ds_val.x, &y_val, &x, theta + h)
            - svm.outer_loss(&ds_val.x, &y_val, &x, theta - h))
            / (2.0 * h);
        assert!((gt - fd_t).abs() < 1e-4, "{gt} vs {fd_t}");
    }
}
