//! Dense-or-sparse design matrices for the ML substrates.
//!
//! The logreg/SVM objectives only touch their design matrix X through a
//! handful of primitives: per-row score gathers (Wᵀx_i), rank-one outer
//! updates (x_i ⊗ g), and X/Xᵀ mat-vec/mat-mat products. [`Design`] closes
//! that surface over either a dense [`Mat`] or a [`CsrMat`] (with a
//! precomputed transpose for gather-form parallel Xᵀ products), so the
//! d ≫ 10⁴ catalog entries run the *same* oracle code without ever
//! materializing a dense m×p — let alone d×d — array.
//!
//! The CSR row primitives visit stored nonzeros in ascending column order,
//! which is exactly the order the dense loops visit entries under their
//! `if x != 0.0` skip guards. Accumulations therefore agree **bitwise**
//! between the two backings (asserted by the tests below and by the
//! dense-vs-CSR sweeps in `tests/grad_check.rs`).

use crate::linalg::mat::Mat;
use crate::linalg::sparse::CsrMat;
use crate::linalg::vecops;

/// A design matrix, dense or row-compressed sparse.
#[derive(Clone, Debug)]
pub enum Design {
    Dense(Mat),
    /// CSR plus its transpose (built once at construction) so that
    /// Xᵀ products use the parallel gather form, not the serial scatter.
    Csr { csr: CsrMat, csr_t: CsrMat },
}

impl From<Mat> for Design {
    fn from(m: Mat) -> Design {
        Design::Dense(m)
    }
}

impl From<CsrMat> for Design {
    fn from(csr: CsrMat) -> Design {
        let csr_t = csr.transpose();
        Design::Csr { csr, csr_t }
    }
}

impl Design {
    pub fn rows(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows,
            Design::Csr { csr, .. } => csr.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Design::Dense(m) => m.cols,
            Design::Csr { csr, .. } => csr.cols,
        }
    }

    /// Stored nonzeros (dense counts every entry).
    pub fn nnz(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows * m.cols,
            Design::Csr { csr, .. } => csr.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Design::Csr { .. })
    }

    pub fn backing(&self) -> &'static str {
        match self {
            Design::Dense(_) => "dense",
            Design::Csr { .. } => "csr",
        }
    }

    /// scores[b] = Σ_a x_ia · w[a·k + b] — the per-row score gather Wᵀx_i
    /// (W is p×k row-major, flattened). Zero-skip on the dense path; CSR
    /// visits the identical entry sequence, so both backings accumulate in
    /// the same order and agree bitwise.
    #[inline]
    pub fn score_row(&self, i: usize, w: &[f64], k: usize, scores: &mut [f64]) {
        scores.iter_mut().for_each(|s| *s = 0.0);
        match self {
            Design::Dense(m) => {
                let xi = m.row(i);
                for (a, &xa) in xi.iter().enumerate() {
                    if xa != 0.0 {
                        let wrow = &w[a * k..(a + 1) * k];
                        for b in 0..k {
                            scores[b] += xa * wrow[b];
                        }
                    }
                }
            }
            Design::Csr { csr, .. } => {
                let (cols, vals) = csr.row(i);
                for (&a, &xa) in cols.iter().zip(vals) {
                    let wrow = &w[a * k..(a + 1) * k];
                    for b in 0..k {
                        scores[b] += xa * wrow[b];
                    }
                }
            }
        }
    }

    /// out[a·k + b] += (x_ia · scale) · g[b] — rank-one outer update
    /// x_i ⊗ g into a p×k row-major accumulator. Same zero-skip/order
    /// guarantee as [`Design::score_row`].
    #[inline]
    pub fn add_outer(&self, i: usize, scale: f64, g: &[f64], k: usize, out: &mut [f64]) {
        match self {
            Design::Dense(m) => {
                let xi = m.row(i);
                for (a, &v) in xi.iter().enumerate() {
                    let xa = v * scale;
                    if xa != 0.0 {
                        let orow = &mut out[a * k..(a + 1) * k];
                        for b in 0..k {
                            orow[b] += xa * g[b];
                        }
                    }
                }
            }
            Design::Csr { csr, .. } => {
                let (cols, vals) = csr.row(i);
                for (&a, &v) in cols.iter().zip(vals) {
                    let xa = v * scale;
                    if xa != 0.0 {
                        let orow = &mut out[a * k..(a + 1) * k];
                        for b in 0..k {
                            orow[b] += xa * g[b];
                        }
                    }
                }
            }
        }
    }

    /// ‖x_i‖² over stored entries.
    pub fn row_sq_norm(&self, i: usize) -> f64 {
        match self {
            Design::Dense(m) => {
                let xi = m.row(i);
                vecops::dot(xi, xi)
            }
            Design::Csr { csr, .. } => {
                let (_, vals) = csr.row(i);
                vals.iter().map(|v| v * v).sum()
            }
        }
    }

    /// y = X v.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        match self {
            Design::Dense(m) => m.matvec(v),
            Design::Csr { csr, .. } => csr.matvec(v),
        }
    }

    /// y = Xᵀ u (gather form on both backings).
    pub fn matvec_t(&self, u: &[f64]) -> Vec<f64> {
        match self {
            Design::Dense(m) => m.matvec_t(u),
            Design::Csr { csr_t, .. } => csr_t.matvec(u),
        }
    }

    /// C = X · B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        match self {
            Design::Dense(m) => m.matmul(b),
            Design::Csr { csr, .. } => {
                let mut c = Mat::zeros(csr.rows, b.cols);
                csr.spmm_into(b, &mut c);
                c
            }
        }
    }

    /// C = Xᵀ · B (gather form on both backings).
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        match self {
            Design::Dense(m) => m.t_matmul(b),
            Design::Csr { csr_t, .. } => {
                let mut c = Mat::zeros(csr_t.rows, b.cols);
                csr_t.spmm_into(b, &mut c);
                c
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// ~density fraction nonzero, rest exact zeros — exercises the skip
    /// guards on the dense path.
    fn sparse_dense_pair(m: usize, p: usize, density: f64, seed: u64) -> (Design, Design) {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(m * p);
        for _ in 0..m * p {
            data.push(if rng.uniform() < density { rng.normal() } else { 0.0 });
        }
        let d = Mat::from_vec(m, p, data);
        let s = CsrMat::from_dense(&d);
        (Design::from(d), Design::from(s))
    }

    #[test]
    fn row_primitives_bitwise_match_across_backings() {
        let (m, p, k) = (19, 13, 4);
        let (dense, csr) = sparse_dense_pair(m, p, 0.3, 1);
        assert!(!dense.is_sparse() && csr.is_sparse());
        assert_eq!(dense.rows(), m);
        assert_eq!(csr.cols(), p);
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(p * k);
        let g = rng.normal_vec(k);
        let mut sd = vec![0.0; k];
        let mut ss = vec![0.0; k];
        let mut od = vec![0.0; p * k];
        let mut os = vec![0.0; p * k];
        for i in 0..m {
            dense.score_row(i, &w, k, &mut sd);
            csr.score_row(i, &w, k, &mut ss);
            for b in 0..k {
                assert_eq!(sd[b].to_bits(), ss[b].to_bits(), "score row {i} col {b}");
            }
            dense.add_outer(i, 0.37, &g, k, &mut od);
            csr.add_outer(i, 0.37, &g, k, &mut os);
            assert_eq!(
                dense.row_sq_norm(i).to_bits(),
                csr.row_sq_norm(i).to_bits(),
                "row_sq {i}"
            );
        }
        for j in 0..p * k {
            assert_eq!(od[j].to_bits(), os[j].to_bits(), "outer {j}");
        }
    }

    #[test]
    fn products_match_dense_reference() {
        let (m, p) = (37, 21);
        let (dense, csr) = sparse_dense_pair(m, p, 0.25, 3);
        let mut rng = Rng::new(4);
        let v = rng.normal_vec(p);
        let u = rng.normal_vec(m);
        let yd = dense.matvec(&v);
        let ys = csr.matvec(&v);
        for i in 0..m {
            assert!((yd[i] - ys[i]).abs() < 1e-12);
        }
        let td = dense.matvec_t(&u);
        let ts = csr.matvec_t(&u);
        for j in 0..p {
            assert!((td[j] - ts[j]).abs() < 1e-12);
        }
        let b = Mat::randn(p, 5, &mut rng);
        let cd = dense.matmul(&b);
        let cs = csr.matmul(&b);
        for i in 0..cd.data.len() {
            assert!((cd.data[i] - cs.data[i]).abs() < 1e-11);
        }
        let bt = Mat::randn(m, 6, &mut rng);
        let ctd = dense.t_matmul(&bt);
        let cts = csr.t_matmul(&bt);
        for i in 0..ctd.data.len() {
            assert!((ctd.data[i] - cts.data[i]).abs() < 1e-11);
        }
    }
}
