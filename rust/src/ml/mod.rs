//! Model substrates for the paper's experiments.
//!
//! - [`ridge`]: ridge regression with closed-form solution + Jacobian (Fig. 3)
//! - [`logreg`]: multiclass logistic regression (dataset distillation, §4.2)
//! - [`svm`]: multiclass SVM dual, Crammer–Singer (Fig. 4, §4.1)
//! - [`dict`]: (task-driven) dictionary learning (Table 2, §4.3)
//! - [`metrics`]: AUC and friends
//! - [`design`]: dense-or-CSR design matrices backing logreg/SVM at large d

pub mod design;
pub mod dict;
pub mod logreg;
pub mod metrics;
pub mod ridge;
pub mod svm;
