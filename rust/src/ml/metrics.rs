//! Evaluation metrics: AUC (Mann–Whitney), accuracy, MSE.

/// ROC AUC of scores against binary labels (1.0 positive), via the
/// Mann–Whitney U statistic with tie correction.
pub fn auc(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // ranks with ties averaged
    let mut ranks = vec![0.0; scores.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for &id in idx.iter().take(j + 1).skip(i) {
            ranks[id] = avg_rank;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l > 0.5).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum_pos: f64 = (0..labels.len()).filter(|&i| labels[i] > 0.5).map(|i| ranks[i]).sum();
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos * n_neg) as f64
}

/// Classification accuracy of argmax scores (m×k row-major) vs labels.
pub fn accuracy(scores: &[f64], k: usize, labels: &[usize]) -> f64 {
    let m = labels.len();
    let mut correct = 0;
    for i in 0..m {
        let row = &scores[i * k..(i + 1) * k];
        let argmax = (0..k).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
        if argmax == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / m as f64
}

/// Mean squared error.
pub fn mse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    pred.iter().zip(target).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_is_one() {
        let s = [0.1, 0.2, 0.8, 0.9];
        let l = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc(&s, &l), 1.0);
    }

    #[test]
    fn inverted_is_zero() {
        let s = [0.9, 0.8, 0.1, 0.2];
        let l = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc(&s, &l), 0.0);
    }

    #[test]
    fn random_is_half() {
        let mut rng = crate::util::rng::Rng::new(1);
        let n = 4000;
        let s = rng.uniform_vec(n);
        let l: Vec<f64> = (0..n).map(|i| (i % 2) as f64).collect();
        let a = auc(&s, &l);
        assert!((a - 0.5).abs() < 0.03, "auc = {a}");
    }

    #[test]
    fn ties_handled() {
        let s = [0.5, 0.5, 0.5, 0.5];
        let l = [0.0, 1.0, 0.0, 1.0];
        assert!((auc(&s, &l) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_invariant_to_monotone_transform() {
        let s = [0.1, 0.4, 0.35, 0.8];
        let l = [0.0, 1.0, 0.0, 1.0];
        let s2: Vec<f64> = s.iter().map(|x| f64::exp(x * 10.0)).collect();
        assert!((auc(&s, &l) - auc(&s2, &l)).abs() < 1e-12);
    }

    #[test]
    fn accuracy_and_mse() {
        let scores = [1.0, 0.0, 0.0, 1.0]; // 2 samples × 2 classes
        assert_eq!(accuracy(&scores, 2, &[0, 1]), 1.0);
        assert_eq!(accuracy(&scores, 2, &[1, 0]), 0.0);
        assert!((mse(&[1.0, 2.0], &[0.0, 0.0]) - 2.5).abs() < 1e-12);
    }
}
