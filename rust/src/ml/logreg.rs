//! Multiclass logistic regression and the dataset-distillation inner
//! objective (paper §4.2, Eq. 10):
//!
//! inner:  x*(θ) = argmin_W  (1/k)Σ_c ℓ(c, θ_c W) + ε‖W‖²
//! outer:  min_θ  (1/m)Σ_i ℓ(y_i, X_i W*)
//!
//! Variables are the flattened p×k weight matrix W; θ is the flattened k×p
//! distilled-image matrix. All four Jacobian products of ∇₁f are analytic
//! (softmax algebra), validated against finite differences.

use crate::linalg::mat::Mat;
use crate::mappings::objective::Objective;
use crate::ml::design::Design;
use crate::proj::simplex::{softmax, softmax_jacobian_product};

/// Softmax cross-entropy loss and its gradient w.r.t. scores.
/// Returns (loss, p − e_y).
pub fn ce_loss_grad(scores: &[f64], label: usize) -> (f64, Vec<f64>) {
    let k = scores.len();
    let mut p = vec![0.0; k];
    softmax(scores, &mut p);
    let loss = -(p[label].max(1e-300)).ln();
    let mut g = p;
    g[label] -= 1.0;
    (loss, g)
}

/// Mean CE loss of W (p×k flattened) on (X, labels).
pub fn mean_ce_loss(w: &[f64], x: &Mat, labels: &[usize], k: usize) -> f64 {
    let p = x.cols;
    let mut total = 0.0;
    let mut scores = vec![0.0; k];
    for i in 0..x.rows {
        row_scores(w, x.row(i), p, k, &mut scores);
        let (l, _) = ce_loss_grad(&scores, labels[i]);
        total += l;
    }
    total / x.rows as f64
}

/// Gradient of mean CE loss w.r.t. W (p×k flattened).
pub fn mean_ce_grad(w: &[f64], x: &Mat, labels: &[usize], k: usize, out: &mut [f64]) {
    let p = x.cols;
    out.iter_mut().for_each(|o| *o = 0.0);
    let mut scores = vec![0.0; k];
    let inv_m = 1.0 / x.rows as f64;
    for i in 0..x.rows {
        let xi = x.row(i);
        row_scores(w, xi, p, k, &mut scores);
        let (_, g) = ce_loss_grad(&scores, labels[i]);
        // out += x_i ⊗ g
        for a in 0..p {
            let xa = xi[a] * inv_m;
            if xa != 0.0 {
                let orow = &mut out[a * k..(a + 1) * k];
                for b in 0..k {
                    orow[b] += xa * g[b];
                }
            }
        }
    }
}

#[inline]
fn row_scores(w: &[f64], xi: &[f64], p: usize, k: usize, scores: &mut [f64]) {
    scores.iter_mut().for_each(|s| *s = 0.0);
    for a in 0..p {
        let xa = xi[a];
        if xa != 0.0 {
            let wrow = &w[a * k..(a + 1) * k];
            for b in 0..k {
                scores[b] += xa * wrow[b];
            }
        }
    }
}

/// L2-regularized multiclass logistic regression as a hyper-parameter
/// learning problem: f(W, θ) = mean CE(X, y; W) + (θ/2)‖W‖², θ = [λ] the
/// scalar regularization strength. All four oracle products are analytic
/// (softmax algebra), so the stationary mapping F = ∇₁f is solve-free and
/// A = ∇²f = H_CE + λI is SPD for λ > 0 (CG + Cholesky apply). This is the
/// "logreg" entry of the serve catalog.
///
/// The design is a [`Design`] — dense or CSR — and every oracle goes
/// through its row primitives, whose accumulation order is identical for
/// both backings (bitwise-equal gradients, see `ml/design.rs`). At
/// d = p·k ≫ 10⁴ the CSR backing keeps the whole implicit-diff pipeline
/// matrix-free: A = H_CE + λI is only ever *applied* (rank ≤ m·k + identity),
/// never materialized.
pub struct LogRegProblem {
    pub x: Design, // m × p design
    pub labels: Vec<usize>,
    pub k: usize,
}

impl LogRegProblem {
    pub fn new(x: impl Into<Design>, labels: Vec<usize>, k: usize) -> LogRegProblem {
        let x = x.into();
        assert_eq!(x.rows(), labels.len());
        assert!(labels.iter().all(|&l| l < k));
        LogRegProblem { x, labels, k }
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// Fit W by backtracking gradient descent (strongly convex for λ > 0).
    pub fn fit(&self, theta: &[f64]) -> Vec<f64> {
        let cfg = crate::solvers::gd::GdConfig {
            step: 4.0,
            max_iter: 4000,
            tol: 1e-10,
            backtracking: true,
        };
        crate::solvers::gd::gradient_descent(self, &vec![0.0; self.dim_x()], theta, &cfg).0
    }
}

impl Objective for LogRegProblem {
    fn dim_x(&self) -> usize {
        self.p() * self.k
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn value(&self, w: &[f64], theta: &[f64]) -> f64 {
        let k = self.k;
        let m = self.x.rows();
        let mut total = 0.0;
        let mut scores = vec![0.0; k];
        for i in 0..m {
            self.x.score_row(i, w, k, &mut scores);
            let (l, _) = ce_loss_grad(&scores, self.labels[i]);
            total += l;
        }
        total / m as f64 + 0.5 * theta[0] * crate::linalg::vecops::dot(w, w)
    }
    fn grad_x(&self, w: &[f64], theta: &[f64], out: &mut [f64]) {
        let k = self.k;
        let m = self.x.rows();
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut scores = vec![0.0; k];
        let inv_m = 1.0 / m as f64;
        for i in 0..m {
            self.x.score_row(i, w, k, &mut scores);
            let (_, g) = ce_loss_grad(&scores, self.labels[i]);
            self.x.add_outer(i, inv_m, &g, k, out);
        }
        for i in 0..w.len() {
            out[i] += theta[0] * w[i];
        }
    }
    fn hvp_xx(&self, w: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let k = self.k;
        let m = self.x.rows();
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut s = vec![0.0; k];
        let mut prob = vec![0.0; k];
        let mut ds = vec![0.0; k];
        let mut dp = vec![0.0; k];
        let inv_m = 1.0 / m as f64;
        for i in 0..m {
            self.x.score_row(i, w, k, &mut s);
            softmax(&s, &mut prob);
            self.x.score_row(i, v, k, &mut ds); // ds = Vᵀ x_i
            softmax_jacobian_product(&prob, &ds, &mut dp);
            self.x.add_outer(i, inv_m, &dp, k, out);
        }
        for i in 0..v.len() {
            out[i] += theta[0] * v[i];
        }
    }
    fn jvp_x_theta(&self, w: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        // ∂λ∇₁f = W, so the cross product is rank-one in λ.
        for i in 0..w.len() {
            out[i] = v[0] * w[i];
        }
    }
    fn vjp_x_theta(&self, w: &[f64], _theta: &[f64], u: &[f64], out: &mut [f64]) {
        out[0] = crate::linalg::vecops::dot(w, u);
    }
}

/// Dataset-distillation inner objective over W (flattened p×k);
/// θ = flattened k×p distilled images, one per class (labels 0..k).
pub struct DistillInnerObjective {
    pub p: usize,
    pub k: usize,
    pub l2reg: f64, // ε in the paper (1e-3)
}

impl DistillInnerObjective {
    /// scores for distilled example c: s_c = Wᵀ θ_c ∈ R^k.
    fn scores(&self, w: &[f64], theta: &[f64], c: usize, out: &mut [f64]) {
        let (p, k) = (self.p, self.k);
        row_scores(w, &theta[c * p..(c + 1) * p], p, k, out);
    }
}

impl Objective for DistillInnerObjective {
    fn dim_x(&self) -> usize {
        self.p * self.k
    }
    fn dim_theta(&self) -> usize {
        self.k * self.p
    }
    fn value(&self, w: &[f64], theta: &[f64]) -> f64 {
        let k = self.k;
        let mut total = 0.0;
        let mut s = vec![0.0; k];
        for c in 0..k {
            self.scores(w, theta, c, &mut s);
            let (l, _) = ce_loss_grad(&s, c);
            total += l;
        }
        total / k as f64 + self.l2reg * crate::linalg::vecops::dot(w, w)
    }
    fn grad_x(&self, w: &[f64], theta: &[f64], out: &mut [f64]) {
        let (p, k) = (self.p, self.k);
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut s = vec![0.0; k];
        let inv_k = 1.0 / k as f64;
        for c in 0..k {
            self.scores(w, theta, c, &mut s);
            let (_, g) = ce_loss_grad(&s, c);
            let tc = &theta[c * p..(c + 1) * p];
            for a in 0..p {
                let ta = tc[a] * inv_k;
                if ta != 0.0 {
                    let orow = &mut out[a * k..(a + 1) * k];
                    for b in 0..k {
                        orow[b] += ta * g[b];
                    }
                }
            }
        }
        for i in 0..w.len() {
            out[i] += 2.0 * self.l2reg * w[i];
        }
    }
    fn hvp_xx(&self, w: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (p, k) = (self.p, self.k);
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut s = vec![0.0; k];
        let mut pc = vec![0.0; k];
        let mut ds = vec![0.0; k];
        let mut dp = vec![0.0; k];
        let inv_k = 1.0 / k as f64;
        for c in 0..k {
            self.scores(w, theta, c, &mut s);
            softmax(&s, &mut pc);
            let tc = &theta[c * p..(c + 1) * p];
            row_scores(v, tc, p, k, &mut ds); // ds = Vᵀθ_c
            softmax_jacobian_product(&pc, &ds, &mut dp);
            for a in 0..p {
                let ta = tc[a] * inv_k;
                if ta != 0.0 {
                    let orow = &mut out[a * k..(a + 1) * k];
                    for b in 0..k {
                        orow[b] += ta * dp[b];
                    }
                }
            }
        }
        for i in 0..v.len() {
            out[i] += 2.0 * self.l2reg * v[i];
        }
    }
    fn jvp_x_theta(&self, w: &[f64], theta: &[f64], dtheta: &[f64], out: &mut [f64]) {
        let (p, k) = (self.p, self.k);
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut s = vec![0.0; k];
        let mut pc = vec![0.0; k];
        let mut ds = vec![0.0; k];
        let mut dp = vec![0.0; k];
        let inv_k = 1.0 / k as f64;
        for c in 0..k {
            self.scores(w, theta, c, &mut s);
            softmax(&s, &mut pc);
            let (_, g) = ce_loss_grad(&s, c);
            let tc = &theta[c * p..(c + 1) * p];
            let dtc = &dtheta[c * p..(c + 1) * p];
            // ds = Wᵀ dθ_c
            row_scores(w, dtc, p, k, &mut ds);
            softmax_jacobian_product(&pc, &ds, &mut dp);
            for a in 0..p {
                let orow = &mut out[a * k..(a + 1) * k];
                let ta = tc[a] * inv_k;
                let dta = dtc[a] * inv_k;
                for b in 0..k {
                    orow[b] += ta * dp[b] + dta * g[b];
                }
            }
        }
    }
    fn vjp_x_theta(&self, w: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (p, k) = (self.p, self.k);
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut s = vec![0.0; k];
        let mut pc = vec![0.0; k];
        let mut a_c = vec![0.0; k];
        let mut ja = vec![0.0; k];
        let inv_k = 1.0 / k as f64;
        for c in 0..k {
            self.scores(w, theta, c, &mut s);
            softmax(&s, &mut pc);
            let (_, g) = ce_loss_grad(&s, c);
            let tc = &theta[c * p..(c + 1) * p];
            // a_c = Uᵀ θ_c  (k-vector): a_c[b] = Σ_a θ_c[a] U[a,b]
            row_scores(u, tc, p, k, &mut a_c);
            softmax_jacobian_product(&pc, &a_c, &mut ja);
            let orow = &mut out[c * p..(c + 1) * p];
            for a in 0..p {
                // term1: (W · Jₛ a_c)[a]; term2: (U g)[a]
                let wrow = &w[a * k..(a + 1) * k];
                let urow = &u[a * k..(a + 1) * k];
                let mut t1 = 0.0;
                let mut t2 = 0.0;
                for b in 0..k {
                    t1 += wrow[b] * ja[b];
                    t2 += urow[b] * g[b];
                }
                orow[a] += inv_k * (t1 + t2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ce_loss_grad_sane() {
        let (l, g) = ce_loss_grad(&[10.0, 0.0, 0.0], 0);
        assert!(l < 1e-3);
        assert!(g[0].abs() < 1e-3);
        let s: f64 = g.iter().sum();
        assert!(s.abs() < 1e-12); // gradient sums to zero
    }

    #[test]
    fn distill_oracles_match_fd() {
        let (p, k) = (6, 3);
        let obj = DistillInnerObjective { p, k, l2reg: 1e-2 };
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(p * k);
        let theta = rng.normal_vec(k * p);
        // grad vs FD
        let g = obj.grad_x_vec(&w, &theta);
        let gfd = crate::ad::num_grad::grad_fd(|ww| obj.value(ww, &theta), &w, 1e-6);
        for i in 0..p * k {
            assert!((g[i] - gfd[i]).abs() < 1e-5, "grad {i}: {} vs {}", g[i], gfd[i]);
        }
        // hvp vs FD
        let v = rng.normal_vec(p * k);
        let mut h = vec![0.0; p * k];
        obj.hvp_xx(&w, &theta, &v, &mut h);
        let hfd = crate::ad::num_grad::jvp_fd(|ww| obj.grad_x_vec(ww, &theta), &w, &v, 1e-6);
        for i in 0..p * k {
            assert!((h[i] - hfd[i]).abs() < 1e-4, "hvp {i}: {} vs {}", h[i], hfd[i]);
        }
        // cross jvp vs FD
        let dth = rng.normal_vec(k * p);
        let mut cj = vec![0.0; p * k];
        obj.jvp_x_theta(&w, &theta, &dth, &mut cj);
        let cfd = crate::ad::num_grad::jvp_fd(|tt| obj.grad_x_vec(&w, tt), &theta, &dth, 1e-6);
        for i in 0..p * k {
            assert!((cj[i] - cfd[i]).abs() < 1e-4, "cross {i}: {} vs {}", cj[i], cfd[i]);
        }
        // cross vjp via adjoint identity
        let u = rng.normal_vec(p * k);
        let mut cv = vec![0.0; k * p];
        obj.vjp_x_theta(&w, &theta, &u, &mut cv);
        let lhs = crate::linalg::vecops::dot(&u, &cj);
        let rhs = crate::linalg::vecops::dot(&cv, &dth);
        assert!((lhs - rhs).abs() < 1e-8, "{lhs} vs {rhs}");
    }

    #[test]
    fn logreg_oracles_match_fd() {
        let (m, p, k) = (14, 5, 3);
        let mut rng = Rng::new(11);
        let x = Mat::randn(m, p, &mut rng);
        let labels: Vec<usize> = (0..m).map(|i| i % k).collect();
        let lr = LogRegProblem::new(x, labels, k);
        let w = rng.normal_vec(p * k);
        let theta = [0.3];
        let g = lr.grad_x_vec(&w, &theta);
        let gfd = crate::ad::num_grad::grad_fd(|ww| lr.value(ww, &theta), &w, 1e-6);
        for i in 0..p * k {
            assert!((g[i] - gfd[i]).abs() < 1e-5, "grad {i}: {} vs {}", g[i], gfd[i]);
        }
        let v = rng.normal_vec(p * k);
        let mut h = vec![0.0; p * k];
        lr.hvp_xx(&w, &theta, &v, &mut h);
        let hfd = crate::ad::num_grad::jvp_fd(|ww| lr.grad_x_vec(ww, &theta), &w, &v, 1e-6);
        for i in 0..p * k {
            assert!((h[i] - hfd[i]).abs() < 1e-4, "hvp {i}: {} vs {}", h[i], hfd[i]);
        }
        let mut c = vec![0.0; p * k];
        lr.jvp_x_theta(&w, &theta, &[1.0], &mut c);
        let cfd = crate::ad::num_grad::jvp_fd(|tt| lr.grad_x_vec(&w, tt), &theta, &[1.0], 1e-6);
        for i in 0..p * k {
            assert!((c[i] - cfd[i]).abs() < 1e-5, "cross {i}: {} vs {}", c[i], cfd[i]);
        }
        // adjoint identity for the θ cross products
        let u = rng.normal_vec(p * k);
        let mut vt = vec![0.0];
        lr.vjp_x_theta(&w, &theta, &u, &mut vt);
        let lhs = crate::linalg::vecops::dot(&u, &c);
        assert!((lhs - vt[0]).abs() < 1e-10);
    }

    #[test]
    fn logreg_fit_reaches_stationarity() {
        let (m, p, k) = (20, 4, 3);
        let mut rng = Rng::new(12);
        let x = Mat::randn(m, p, &mut rng);
        let labels: Vec<usize> = (0..m).map(|i| i % k).collect();
        let lr = LogRegProblem::new(x, labels, k);
        let theta = [0.5];
        let w = lr.fit(&theta);
        let g = lr.grad_x_vec(&w, &theta);
        assert!(crate::linalg::vecops::norm2(&g) < 1e-8, "‖∇f‖ = {}", crate::linalg::vecops::norm2(&g));
    }

    #[test]
    fn training_on_prototypes_classifies_prototypes() {
        // Inner GD on W with θ = class prototypes should classify them.
        let (p, k) = (16, 4);
        let mut rng = Rng::new(2);
        let theta = rng.normal_vec(k * p);
        let obj = DistillInnerObjective { p, k, l2reg: 1e-3 };
        let (w, _) = crate::solvers::gd::gradient_descent(
            &obj,
            &vec![0.0; p * k],
            &theta,
            &crate::solvers::gd::GdConfig { step: 0.5, max_iter: 3000, tol: 1e-8, backtracking: true },
        );
        let mut s = vec![0.0; k];
        for c in 0..k {
            row_scores(&w, &theta[c * p..(c + 1) * p], p, k, &mut s);
            let argmax = (0..k).max_by(|&a, &b| s[a].partial_cmp(&s[b]).unwrap()).unwrap();
            assert_eq!(argmax, c);
        }
    }

    #[test]
    fn dense_and_csr_oracles_agree_bitwise() {
        // Same problem, two design backings: every oracle product must agree
        // to the last bit (the CSR row iteration replays the dense zero-skip
        // accumulation order exactly).
        let (m, p, k) = (18, 7, 3);
        let mut rng = Rng::new(21);
        let mut data = Vec::with_capacity(m * p);
        for _ in 0..m * p {
            data.push(if rng.uniform() < 0.4 { rng.normal() } else { 0.0 });
        }
        let x = Mat::from_vec(m, p, data);
        let labels: Vec<usize> = (0..m).map(|i| i % k).collect();
        let csr = crate::linalg::sparse::CsrMat::from_dense(&x);
        let lr_d = LogRegProblem::new(x, labels.clone(), k);
        let lr_s = LogRegProblem::new(csr, labels, k);
        assert!(lr_s.x.is_sparse());
        let w = rng.normal_vec(p * k);
        let v = rng.normal_vec(p * k);
        let theta = [0.2];
        assert_eq!(lr_d.value(&w, &theta).to_bits(), lr_s.value(&w, &theta).to_bits());
        let gd = lr_d.grad_x_vec(&w, &theta);
        let gs = lr_s.grad_x_vec(&w, &theta);
        let mut hd = vec![0.0; p * k];
        let mut hs = vec![0.0; p * k];
        lr_d.hvp_xx(&w, &theta, &v, &mut hd);
        lr_s.hvp_xx(&w, &theta, &v, &mut hs);
        for i in 0..p * k {
            assert_eq!(gd[i].to_bits(), gs[i].to_bits(), "grad {i}");
            assert_eq!(hd[i].to_bits(), hs[i].to_bits(), "hvp {i}");
        }
    }

    #[test]
    fn mean_ce_grad_matches_fd() {
        let (m, p, k) = (12, 5, 3);
        let mut rng = Rng::new(3);
        let x = Mat::randn(m, p, &mut rng);
        let labels: Vec<usize> = (0..m).map(|i| i % k).collect();
        let w = rng.normal_vec(p * k);
        let mut g = vec![0.0; p * k];
        mean_ce_grad(&w, &x, &labels, k, &mut g);
        let gfd = crate::ad::num_grad::grad_fd(|ww| mean_ce_loss(ww, &x, &labels, k), &w, 1e-6);
        for i in 0..p * k {
            assert!((g[i] - gfd[i]).abs() < 1e-5);
        }
    }
}
