//! Ridge regression — the paper's running example (Fig. 1) and the Fig. 3
//! error-study problem: x*(θ) = argmin ‖Φx − y‖² + Σᵢθᵢxᵢ², which has a
//! closed-form solution AND a closed-form Jacobian, making it the exact
//! ground truth against which implicit/unrolled estimates are scored.

use crate::diff::spec::RootMap;
use crate::linalg::chol::Cholesky;
use crate::linalg::mat::Mat;
use crate::mappings::objective::Objective;

pub struct RidgeProblem {
    pub x: Mat, // m × p design (Φ)
    pub y: Vec<f64>,
    /// Precomputed Gram ΦᵀΦ and Φᵀy.
    pub gram: Mat,
    pub xty: Vec<f64>,
}

impl RidgeProblem {
    pub fn new(x: Mat, y: Vec<f64>) -> RidgeProblem {
        assert_eq!(x.rows, y.len());
        let gram = x.gram();
        let xty = x.matvec_t(&y);
        RidgeProblem { x, y, gram, xty }
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }

    /// Closed-form solution for scalar θ: (G + θI)⁻¹Φᵀy.
    pub fn solve_closed_form(&self, theta: f64) -> Vec<f64> {
        let a = self.gram.plus_diag(theta);
        Cholesky::factor(&a).expect("ridge system SPD").solve(&self.xty)
    }

    /// Closed-form solution for per-coordinate θ ∈ R^p.
    pub fn solve_closed_form_vec(&self, theta: &[f64]) -> Vec<f64> {
        let mut a = self.gram.clone();
        for i in 0..self.dim() {
            *a.at_mut(i, i) += theta[i];
        }
        Cholesky::factor(&a).expect("ridge system SPD").solve(&self.xty)
    }

    /// Closed-form Jacobian ∂x*(θ) ∈ R^{p×p} for per-coordinate θ:
    /// column j = −(G + diag θ)⁻¹ e_j x*_j.
    pub fn jacobian_closed_form(&self, theta: &[f64]) -> Mat {
        let p = self.dim();
        let x_star = self.solve_closed_form_vec(theta);
        let mut a = self.gram.clone();
        for i in 0..p {
            *a.at_mut(i, i) += theta[i];
        }
        let ch = Cholesky::factor(&a).unwrap();
        let mut jac = Mat::zeros(p, p);
        let mut e = vec![0.0; p];
        for j in 0..p {
            e[j] = x_star[j];
            let col = ch.solve(&e);
            for i in 0..p {
                *jac.at_mut(i, j) = -col[i];
            }
            e[j] = 0.0;
        }
        jac
    }
}

/// Ridge as an objective f(x, θ) = ½‖Φx − y‖² + ½Σθᵢxᵢ² (θ per-coordinate).
/// (The ½ scaling matches Fig. 1; stationarity is unaffected.)
impl Objective for RidgeProblem {
    fn dim_x(&self) -> usize {
        self.dim()
    }
    fn dim_theta(&self) -> usize {
        self.dim()
    }
    fn value(&self, x: &[f64], theta: &[f64]) -> f64 {
        let r = self.x.matvec(x);
        let mut v = 0.0;
        for i in 0..r.len() {
            let d = r[i] - self.y[i];
            v += d * d;
        }
        for i in 0..x.len() {
            v += theta[i] * x[i] * x[i];
        }
        0.5 * v
    }
    fn grad_x(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        // Gx − Φᵀy + θ⊙x
        self.gram.matvec_into(x, out);
        for i in 0..x.len() {
            out[i] += theta[i] * x[i] - self.xty[i];
        }
    }
    fn hvp_xx(&self, _x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.gram.matvec_into(v, out);
        for i in 0..v.len() {
            out[i] += theta[i] * v[i];
        }
    }
    fn jvp_x_theta(&self, x: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        for i in 0..x.len() {
            out[i] = v[i] * x[i];
        }
    }
    fn vjp_x_theta(&self, x: &[f64], _theta: &[f64], u: &[f64], out: &mut [f64]) {
        for i in 0..x.len() {
            out[i] = u[i] * x[i];
        }
    }
    // Batched oracles: the Hessian is G + diag(θ), so a block HVP is one
    // packed GEMM plus a row-scaled add; the cross products are diagonal
    // (row-scaling by x*), so batches are a single streaming pass.
    fn hvp_xx_batch(&self, _x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.gram.matmul_into(v, out); // asserts the block shapes
        let k = v.cols;
        for i in 0..v.rows {
            let ti = theta[i];
            for j in 0..k {
                out.data[i * k + j] += ti * v.data[i * k + j];
            }
        }
    }
    fn jvp_x_theta_batch(&self, x: &[f64], _theta: &[f64], v: &Mat, out: &mut Mat) {
        assert_eq!(v.rows, x.len(), "batched cross-product input rows must be dim");
        assert_eq!((out.rows, out.cols), (v.rows, v.cols), "batched cross-product shape");
        let k = v.cols;
        for i in 0..v.rows {
            for j in 0..k {
                out.data[i * k + j] = v.data[i * k + j] * x[i];
            }
        }
    }
    fn vjp_x_theta_batch(&self, x: &[f64], _theta: &[f64], u: &Mat, out: &mut Mat) {
        assert_eq!(u.rows, x.len(), "batched cross-product input rows must be dim");
        assert_eq!((out.rows, out.cols), (u.rows, u.cols), "batched cross-product shape");
        let k = u.cols;
        for i in 0..u.rows {
            for j in 0..k {
                out.data[i * k + j] = u.data[i * k + j] * x[i];
            }
        }
    }
}

/// The ridge optimality mapping F(x, θ) = ∇₁f — `@custom_root` material.
pub struct RidgeRoot<'a>(pub &'a RidgeProblem);

impl RootMap for RidgeRoot<'_> {
    fn dim_x(&self) -> usize {
        self.0.dim()
    }
    fn dim_theta(&self) -> usize {
        self.0.dim()
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        self.0.grad_x(x, theta, out);
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.0.hvp_xx(x, theta, v, out);
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.0.hvp_xx(x, theta, u, out);
    }
    fn jvp_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        self.0.jvp_x_theta(x, theta, v, out);
    }
    fn vjp_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.0.vjp_x_theta(x, theta, u, out);
    }
    // Batched products route to the objective's one-GEMM overrides, so the
    // dense-Jacobian block solve costs one GEMM per CG iteration.
    fn jvp_x_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.0.hvp_xx_batch(x, theta, v, out);
    }
    fn vjp_x_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.0.hvp_xx_batch(x, theta, u, out);
    }
    fn jvp_theta_batch(&self, x: &[f64], theta: &[f64], v: &Mat, out: &mut Mat) {
        self.0.jvp_x_theta_batch(x, theta, v, out);
    }
    fn vjp_theta_batch(&self, x: &[f64], theta: &[f64], u: &Mat, out: &mut Mat) {
        self.0.vjp_x_theta_batch(x, theta, u, out);
    }
    fn a_symmetric(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::root::jacobian_via_root;

    fn problem(seed: u64) -> RidgeProblem {
        let (x, y) = crate::data::regression::diabetes_like(60, 8, seed);
        RidgeProblem::new(x, y)
    }

    #[test]
    fn closed_form_is_stationary() {
        let rp = problem(1);
        let theta = vec![2.0; 8];
        let x = rp.solve_closed_form_vec(&theta);
        let g = rp.grad_x_vec(&x, &theta);
        assert!(crate::linalg::vecops::norm2(&g) < 1e-10);
    }

    #[test]
    fn scalar_and_vector_theta_agree() {
        let rp = problem(2);
        let a = rp.solve_closed_form(3.0);
        let b = rp.solve_closed_form_vec(&vec![3.0; 8]);
        for i in 0..8 {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn implicit_jacobian_matches_closed_form() {
        let rp = problem(3);
        let theta = vec![1.5; 8];
        let x_star = rp.solve_closed_form_vec(&theta);
        let jac_true = rp.jacobian_closed_form(&theta);
        let root = RidgeRoot(&rp);
        let jac = jacobian_via_root(&root, &x_star, &theta);
        for i in 0..8 {
            for j in 0..8 {
                assert!(
                    (jac.at(i, j) - jac_true.at(i, j)).abs() < 1e-8,
                    "({i},{j}): {} vs {}",
                    jac.at(i, j),
                    jac_true.at(i, j)
                );
            }
        }
    }

    #[test]
    fn jacobian_closed_form_matches_fd() {
        let rp = problem(4);
        let theta = vec![0.8; 8];
        let jac = rp.jacobian_closed_form(&theta);
        let h = 1e-6;
        for j in 0..8 {
            let mut tp = theta.clone();
            tp[j] += h;
            let xp = rp.solve_closed_form_vec(&tp);
            let mut tm = theta.clone();
            tm[j] -= h;
            let xm = rp.solve_closed_form_vec(&tm);
            for i in 0..8 {
                let fd = (xp[i] - xm[i]) / (2.0 * h);
                assert!((jac.at(i, j) - fd).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn larger_regularization_shrinks_solution() {
        let rp = problem(5);
        let small = rp.solve_closed_form(0.01);
        let large = rp.solve_closed_form(100.0);
        assert!(
            crate::linalg::vecops::norm2(&large) < crate::linalg::vecops::norm2(&small)
        );
    }
}
