//! Dictionary learning (paper §4.3): elastic-net sparse coding as the inner
//! problem, with the dictionary θ ∈ R^{k×p} differentiated through the
//! proximal-gradient fixed point — no manual reparameterization as in
//! Mairal et al. [60].
//!
//! inner:  x*(θ) = argmin_x ½‖X − xθ‖²_F + λ₁‖x‖₁ + ½λ₂‖x‖²
//! outer:  logistic loss of (x*(θ) w + b) against labels (task-driven) or
//!         the reconstruction loss itself (unsupervised).

use crate::linalg::mat::Mat;
use crate::mappings::objective::Objective;

/// Reconstruction objective f(x, θ) = ½‖X − xθ‖²_F over codes x (m×k,
/// flattened); θ = flattened dictionary (k×p).
pub struct DictReconstruction {
    pub data: Mat, // m × p
    pub k: usize,
}

impl DictReconstruction {
    fn m(&self) -> usize {
        self.data.rows
    }
    fn p(&self) -> usize {
        self.data.cols
    }
    fn codes_mat(&self, x: &[f64]) -> Mat {
        Mat { rows: self.m(), cols: self.k, data: x.to_vec() }
    }
    fn dict_mat(&self, theta: &[f64]) -> Mat {
        Mat { rows: self.k, cols: self.p(), data: theta.to_vec() }
    }
    /// Residual R = xθ − X (m×p).
    fn residual(&self, x: &[f64], theta: &[f64]) -> Mat {
        let xm = self.codes_mat(x);
        let dm = self.dict_mat(theta);
        let mut r = xm.matmul(&dm);
        for i in 0..r.data.len() {
            r.data[i] -= self.data.data[i];
        }
        r
    }
}

impl Objective for DictReconstruction {
    fn dim_x(&self) -> usize {
        self.m() * self.k
    }
    fn dim_theta(&self) -> usize {
        self.k * self.p()
    }
    fn value(&self, x: &[f64], theta: &[f64]) -> f64 {
        let r = self.residual(x, theta);
        0.5 * crate::linalg::vecops::dot(&r.data, &r.data)
    }
    fn grad_x(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        // ∇_x = R θᵀ (m×k)
        let r = self.residual(x, theta);
        let dm = self.dict_mat(theta);
        let g = r.matmul_t(&dm);
        out.copy_from_slice(&g.data);
    }
    fn hvp_xx(&self, _x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        // H v = (Vθ)θᵀ
        let vm = Mat { rows: self.m(), cols: self.k, data: v.to_vec() };
        let dm = self.dict_mat(theta);
        let vd = vm.matmul(&dm);
        let h = vd.matmul_t(&dm);
        out.copy_from_slice(&h.data);
    }
    fn jvp_x_theta(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        // d(Rθᵀ) = (x dθ)θᵀ + R dθᵀ
        let xm = self.codes_mat(x);
        let dm = self.dict_mat(theta);
        let dv = Mat { rows: self.k, cols: self.p(), data: v.to_vec() };
        let r = self.residual(x, theta);
        let t1 = xm.matmul(&dv).matmul_t(&dm);
        let t2 = r.matmul_t(&dv);
        for i in 0..out.len() {
            out[i] = t1.data[i] + t2.data[i];
        }
    }
    fn vjp_x_theta(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        // ⟨U, (x dθ)θᵀ + R dθᵀ⟩ = ⟨xᵀU θ + Rᵀ U... derive:
        // term1: ⟨U, (x dθ)θᵀ⟩ = ⟨xᵀ U θ??⟩ — carefully:
        //   ⟨U, A dθ B⟩ = ⟨Aᵀ U Bᵀ, dθ⟩ with A = x (m×k), B = θᵀ? Here
        //   (x dθ)θᵀ: A = x, middle dθ (k×p), right θᵀ (p×k)?? dims: x(m×k)
        //   dθ(k×p) θᵀ(p×k) → m×k ✓. ⟨U, x dθ θᵀ⟩ = tr(Uᵀ x dθ θᵀ)
        //   = tr(θᵀ Uᵀ x dθ) = ⟨xᵀ U θ, dθ⟩ (k×p).
        // term2: ⟨U, R dθᵀ⟩ = tr(Uᵀ R dθᵀ) = ⟨Rᵀ U, dθᵀ⟩ = ⟨Uᵀ R, dθ⟩ (k×p).
        let xm = self.codes_mat(x);
        let dm = self.dict_mat(theta);
        let um = Mat { rows: self.m(), cols: self.k, data: u.to_vec() };
        let r = self.residual(x, theta);
        let xtu = xm.t_matmul(&um); // k×k
        let t1 = {
            // xᵀUθ: (k×k)(k×p) → k×p... wait xᵀU is k×k? x is m×k, U m×k →
            // xᵀU k×k; times θ (k×p) → k×p ✓
            xtu.matmul(&dm)
        };
        let t2 = um.t_matmul(&r); // Uᵀ R: k×p
        for i in 0..out.len() {
            out[i] = t1.data[i] + t2.data[i];
        }
    }
}

/// Logistic head over codes: L(w, b) with codes fixed (outer problem pieces).
pub fn logistic_loss(codes: &Mat, w: &[f64], b: f64, labels: &[f64], l2: f64) -> f64 {
    let m = codes.rows;
    let mut total = 0.0;
    for i in 0..m {
        let z = crate::linalg::vecops::dot(codes.row(i), w) + b;
        // log(1 + e^{-yz}) with y ∈ {−1, 1}
        let y = if labels[i] > 0.5 { 1.0 } else { -1.0 };
        let t = -y * z;
        total += if t > 30.0 { t } else { (1.0 + t.exp()).ln() };
    }
    total / m as f64 + 0.5 * l2 * crate::linalg::vecops::dot(w, w)
}

/// Gradients of the logistic head: (∂L/∂codes (m×k), ∂L/∂w, ∂L/∂b).
pub fn logistic_grads(
    codes: &Mat,
    w: &[f64],
    b: f64,
    labels: &[f64],
    l2: f64,
) -> (Mat, Vec<f64>, f64) {
    let m = codes.rows;
    let k = codes.cols;
    let mut gc = Mat::zeros(m, k);
    let mut gw = vec![0.0; k];
    let mut gb = 0.0;
    for i in 0..m {
        let z = crate::linalg::vecops::dot(codes.row(i), w) + b;
        let y = if labels[i] > 0.5 { 1.0 } else { -1.0 };
        let s = 1.0 / (1.0 + (y * z).exp()); // σ(−yz)
        let coef = -y * s / m as f64;
        for j in 0..k {
            *gc.at_mut(i, j) = coef * w[j];
            gw[j] += coef * codes.at(i, j);
        }
        gb += coef;
    }
    for j in 0..k {
        gw[j] += l2 * w[j];
    }
    (gc, gw, gb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reconstruction_oracles_match_fd() {
        let mut rng = Rng::new(1);
        let (m, p, k) = (5, 7, 3);
        let obj = DictReconstruction { data: Mat::randn(m, p, &mut rng), k };
        let x = rng.normal_vec(m * k);
        let theta = rng.normal_vec(k * p);
        let g = obj.grad_x_vec(&x, &theta);
        let gfd = crate::ad::num_grad::grad_fd(|xx| obj.value(xx, &theta), &x, 1e-6);
        for i in 0..g.len() {
            assert!((g[i] - gfd[i]).abs() < 1e-5);
        }
        let v = rng.normal_vec(m * k);
        let mut h = vec![0.0; m * k];
        obj.hvp_xx(&x, &theta, &v, &mut h);
        let hfd = crate::ad::num_grad::jvp_fd(|xx| obj.grad_x_vec(xx, &theta), &x, &v, 1e-6);
        for i in 0..h.len() {
            assert!((h[i] - hfd[i]).abs() < 1e-5);
        }
        let dv = rng.normal_vec(k * p);
        let mut c = vec![0.0; m * k];
        obj.jvp_x_theta(&x, &theta, &dv, &mut c);
        let cfd = crate::ad::num_grad::jvp_fd(|tt| obj.grad_x_vec(&x, tt), &theta, &dv, 1e-6);
        for i in 0..c.len() {
            assert!((c[i] - cfd[i]).abs() < 1e-5, "{} vs {}", c[i], cfd[i]);
        }
        // vjp adjoint identity
        let u = rng.normal_vec(m * k);
        let mut vj = vec![0.0; k * p];
        obj.vjp_x_theta(&x, &theta, &u, &mut vj);
        let lhs = crate::linalg::vecops::dot(&u, &c);
        let rhs = crate::linalg::vecops::dot(&vj, &dv);
        assert!((lhs - rhs).abs() < 1e-8, "{lhs} vs {rhs}");
    }

    #[test]
    fn logistic_grads_match_fd() {
        let mut rng = Rng::new(2);
        let (m, k) = (8, 4);
        let codes = Mat::randn(m, k, &mut rng);
        let w = rng.normal_vec(k);
        let b = 0.3;
        let labels: Vec<f64> = (0..m).map(|i| (i % 2) as f64).collect();
        let (gc, gw, gb) = logistic_grads(&codes, &w, b, &labels, 0.1);
        let gwfd = crate::ad::num_grad::grad_fd(
            |ww| logistic_loss(&codes, ww, b, &labels, 0.1),
            &w,
            1e-6,
        );
        for j in 0..k {
            assert!((gw[j] - gwfd[j]).abs() < 1e-6);
        }
        let h = 1e-6;
        let gbfd = (logistic_loss(&codes, &w, b + h, &labels, 0.1)
            - logistic_loss(&codes, &w, b - h, &labels, 0.1))
            / (2.0 * h);
        assert!((gb - gbfd).abs() < 1e-6);
        // codes gradient via FD on one entry
        let mut cp = codes.clone();
        *cp.at_mut(2, 1) += h;
        let mut cm = codes.clone();
        *cm.at_mut(2, 1) -= h;
        let fd = (logistic_loss(&cp, &w, b, &labels, 0.1)
            - logistic_loss(&cm, &w, b, &labels, 0.1))
            / (2.0 * h);
        assert!((gc.at(2, 1) - fd).abs() < 1e-6);
    }
}
