//! Hypergradient request server — a minimal line-protocol TCP service that
//! keeps the rust binary on the request path (Python is build-time only).
//!
//! Protocol (one JSON object per line):
//!   {"op": "ridge_jacobian", "theta": [...]}            → {"jacobian": [[...]]}
//!   {"op": "ridge_hypergrad", "theta": [...], "v": [..]} → {"grad": [...]}
//!   {"op": "ping"}                                       → {"ok": true}
//! Unknown ops return {"error": "..."}.

use crate::diff::root::{implicit_vjp, jacobian_via_root};
use crate::ml::ridge::{RidgeProblem, RidgeRoot};
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

pub struct HypergradServer {
    rp: RidgeProblem,
}

impl HypergradServer {
    pub fn new_default() -> HypergradServer {
        let (x, y) = crate::data::regression::diabetes_like(64, 8, 7);
        HypergradServer { rp: RidgeProblem::new(x, y) }
    }

    /// Handle one JSON request line.
    pub fn handle(&self, line: &str) -> Json {
        let req = match json::parse(line) {
            Ok(r) => r,
            Err(e) => return Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
        };
        match req.str_or("op", "") {
            "ping" => Json::obj(vec![("ok", Json::Bool(true))]),
            "ridge_jacobian" => {
                let theta = match parse_vec(&req, "theta", self.rp.dim()) {
                    Ok(t) => t,
                    Err(e) => return e,
                };
                let x_star = self.rp.solve_closed_form_vec(&theta);
                let root = RidgeRoot(&self.rp);
                let jac = jacobian_via_root(&root, &x_star, &theta);
                let rows: Vec<Json> =
                    (0..jac.rows).map(|i| Json::arr_f64(jac.row(i))).collect();
                Json::obj(vec![("jacobian", Json::Arr(rows))])
            }
            "ridge_hypergrad" => {
                let theta = match parse_vec(&req, "theta", self.rp.dim()) {
                    Ok(t) => t,
                    Err(e) => return e,
                };
                let v = match parse_vec(&req, "v", self.rp.dim()) {
                    Ok(t) => t,
                    Err(e) => return e,
                };
                let x_star = self.rp.solve_closed_form_vec(&theta);
                let root = RidgeRoot(&self.rp);
                let (g, _) = implicit_vjp(
                    &root,
                    &x_star,
                    &theta,
                    &v,
                    &crate::linalg::solve::LinearSolveConfig::default(),
                );
                Json::obj(vec![("grad", Json::arr_f64(&g))])
            }
            other => Json::obj(vec![("error", Json::Str(format!("unknown op '{other}'")))]),
        }
    }

    /// Serve until the process is killed. One thread per connection.
    pub fn serve(self, addr: &str) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        println!("hypergrad server listening on {addr}");
        let me = std::sync::Arc::new(self);
        for stream in listener.incoming() {
            let stream = stream?;
            let me = me.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(&me, stream);
            });
        }
        Ok(())
    }
}

fn handle_conn(server: &HypergradServer, stream: TcpStream) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = server.handle(&line);
        writer.write_all(resp.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn parse_vec(req: &Json, key: &str, expected: usize) -> Result<Vec<f64>, Json> {
    let arr = req
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Json::obj(vec![("error", Json::Str(format!("missing '{key}'")))]))?;
    let v: Vec<f64> = arr.iter().filter_map(Json::as_f64).collect();
    if v.len() != expected {
        return Err(Json::obj(vec![(
            "error",
            Json::Str(format!("'{key}' must have length {expected}")),
        )]));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping() {
        let s = HypergradServer::new_default();
        let r = s.handle(r#"{"op": "ping"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn jacobian_request() {
        let s = HypergradServer::new_default();
        let theta = vec![1.0; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("ridge_jacobian".into())),
            ("theta", Json::arr_f64(&theta)),
        ]);
        let r = s.handle(&req.to_string_compact());
        let jac = r.get("jacobian").and_then(Json::as_arr).expect("jacobian");
        assert_eq!(jac.len(), 8);
        // parity with the closed form
        let truth = s.rp.jacobian_closed_form(&theta);
        let row0 = jac[0].as_arr().unwrap();
        for j in 0..8 {
            assert!((row0[j].as_f64().unwrap() - truth.at(0, j)).abs() < 1e-6);
        }
    }

    #[test]
    fn hypergrad_request_and_errors() {
        let s = HypergradServer::new_default();
        let r = s.handle(r#"{"op": "nope"}"#);
        assert!(r.get("error").is_some());
        let r = s.handle("not json");
        assert!(r.get("error").is_some());
        let theta = vec![1.0; 8];
        let v = vec![1.0; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("ridge_hypergrad".into())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&v)),
        ]);
        let r = s.handle(&req.to_string_compact());
        let g = r.get("grad").and_then(Json::as_arr).expect("grad");
        assert_eq!(g.len(), 8);
    }
}
