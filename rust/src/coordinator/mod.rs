//! Experiment coordinator: a registry of named experiments (one per paper
//! table/figure), a config layer (CLI → JSON), a runner that times each
//! experiment and writes `results/<name>.json`/`.csv`, and the hypergradient
//! request server (see `serve`).

pub mod experiments;
pub mod serve;

use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::timer::Timer;

/// An experiment produces a JSON report (also written to results/).
pub type ExperimentFn = fn(&Args) -> Json;

/// Registry of all experiments, keyed by the paper artifact they regenerate.
pub fn registry() -> Vec<(&'static str, &'static str, ExperimentFn)> {
    vec![
        ("fig3", "Jacobian estimate error vs iterate error (ridge)", experiments::fig3::run),
        ("fig4a", "SVM hyperopt runtime — mirror descent solver + MD fixed point", experiments::fig4::run_md),
        ("fig4b", "SVM hyperopt runtime — prox-grad solver + PG fixed point", experiments::fig4::run_pg),
        ("fig4c", "SVM hyperopt runtime — BCD solver, MD & PG fixed points", experiments::fig4::run_bcd),
        ("fig13", "unrolling reverse-mode memory model + OOM boundary (16 GiB)", experiments::fig4::run_memory),
        ("fig14", "validation loss parity across methods", experiments::fig4::run_val_loss),
        ("fig15", "Jacobian error vs solution error (multiclass SVM)", experiments::fig15::run),
        ("distill", "dataset distillation: implicit vs unrolled (Fig. 5/16)", experiments::distill::run),
        ("table2", "cancer survival AUC: 4 methods (Table 2)", experiments::table2::run),
        ("fig17", "MD position sensitivity: implicit vs unrolled FIRE", experiments::md_sens::run),
        ("table1", "catalog coverage: every optimality mapping vs FD", experiments::table1::run),
        ("xla", "XLA runtime parity: native vs AOT ridge oracle", experiments::xla_parity::run),
    ]
}

/// Run one experiment by name; returns its report.
pub fn run_experiment(name: &str, args: &Args) -> Option<Json> {
    for (id, desc, f) in registry() {
        if id == name {
            println!("=== {id}: {desc} ===");
            let t = Timer::start();
            let report = f(args);
            let dt = t.elapsed_s();
            println!("=== {id} done in {:.2}s ===", dt);
            let _ = std::fs::create_dir_all("results");
            let wrapped = Json::obj(vec![
                ("experiment", Json::Str(id.to_string())),
                ("seconds", Json::Num(dt)),
                ("report", report.clone()),
            ]);
            let _ = std::fs::write(format!("results/{id}.json"), wrapped.to_string_pretty());
            return Some(report);
        }
    }
    None
}

/// List experiments for --help / `idiff list`.
pub fn list_experiments() {
    let mut t = crate::util::table::Table::new(&["id", "regenerates"]);
    for (id, desc, _) in registry() {
        t.row_strs(&[id, desc]);
    }
    t.print();
}
