//! Problem registry: the whole optimality-mapping catalog exposed as named
//! [`Problem`]s the server can solve and differentiate uniformly.
//!
//! Each entry packages (1) an inner solver for x*(θ), (2) a `RootMap` view
//! of its optimality/fixed-point mapping (built per request — mappings like
//! projected-gradient embed a θ-dependent step size), and (3) a linear-solve
//! configuration. Derivative products all route through the batched
//! implicit-diff engine, so k coalesced requests cost ONE block solve, and
//! through the factored paths when the θ-keyed cache holds A's
//! Cholesky/LU factorization.

use crate::diff::one_step::{estimate_contraction, GradientStepMap, CONTRACTION_POWER_ITERS};
use crate::diff::root::{
    factorize_root, implicit_jvp_multi, implicit_jvp_multi_factored, implicit_vjp_multi,
    implicit_vjp_multi_factored, jacobian_via_root,
};
use crate::diff::spec::{FixedPointMap, FixedPointResidual, RootMap};
use crate::linalg::mat::Mat;
use crate::linalg::solve::{
    BlockSolveReport, Factorization, LinearSolveConfig, LinearSolverKind, SolvePrecision,
};
use crate::linalg::sparse::CsrMat;
use crate::mappings::objective::{Objective, QuadObjective};
use crate::mappings::prox_grad::{ProjGradFixedPoint, ProxGradFixedPoint};
use crate::mappings::stationary::StationaryMapping;
use crate::ml::logreg::LogRegProblem;
use crate::ml::ridge::{RidgeProblem, RidgeRoot};
use crate::ml::svm::MulticlassSvm;
use crate::proj::simplex::{RowsSimplexProjection, SimplexProjection};
use crate::prox::LassoProx;
use crate::util::rng::Rng;

/// The solver + mapping core a catalog problem must provide. `with_root`
/// hands the caller a `RootMap` view valid for the given θ; everything else
/// (block VJP/JVP, factorization, Jacobian) is derived generically.
pub trait ProblemCore: Send + Sync {
    fn dim_x(&self) -> usize;
    fn dim_theta(&self) -> usize;
    /// Reject θ the problem cannot serve (wrong sign, NaN, …) with a
    /// client-facing message.
    fn validate_theta(&self, theta: &[f64]) -> Result<(), String>;
    /// Inner solve: x*(θ).
    fn solve(&self, theta: &[f64]) -> Vec<f64>;
    /// Linear-solve configuration for the implicit systems.
    fn cfg(&self) -> LinearSolveConfig {
        LinearSolveConfig::default()
    }
    /// Build the optimality mapping for θ and pass it to `f`.
    fn with_root(&self, theta: &[f64], f: &mut dyn FnMut(&dyn RootMap));
    /// Build a *contractive fixed-point* view T(x, θ) valid near (x*, θ) and
    /// pass it to `f` — the object the solve-free one-step / truncated-unroll
    /// derivative modes differentiate. The default wraps the `with_root`
    /// mapping in a step-tuned gradient step T = x − ηF (a contraction
    /// whenever ∂₁F is SPD at x*); cores whose mapping is natively a
    /// fixed-point iteration override this with that iteration directly.
    fn with_fixed_point(
        &self,
        x_star: &[f64],
        theta: &[f64],
        f: &mut dyn FnMut(&dyn FixedPointMap),
    ) {
        self.with_root(theta, &mut |m| {
            let t = GradientStepMap::tuned(m, x_star, theta);
            (*f)(&t);
        });
    }
}

/// A named, served catalog problem.
pub struct Problem {
    pub name: &'static str,
    pub describe: &'static str,
    core: Box<dyn ProblemCore>,
}

impl Problem {
    pub fn dim_x(&self) -> usize {
        self.core.dim_x()
    }
    pub fn dim_theta(&self) -> usize {
        self.core.dim_theta()
    }

    pub fn validate_theta(&self, theta: &[f64]) -> Result<(), String> {
        if theta.len() != self.dim_theta() {
            return Err(format!(
                "'theta' must have length {} for problem '{}'",
                self.dim_theta(),
                self.name
            ));
        }
        if let Some(bad) = theta.iter().find(|t| !t.is_finite()) {
            return Err(format!("'theta' contains non-finite entry {bad}"));
        }
        self.core.validate_theta(theta)
    }

    pub fn solve(&self, theta: &[f64]) -> Vec<f64> {
        self.core.solve(theta)
    }

    /// k cotangents (columns of `v`, d×k) → n×k hypergradient block via ONE
    /// block solve Aᵀ U = V.
    pub fn vjp_multi(&self, x_star: &[f64], theta: &[f64], v: &Mat) -> (Mat, BlockSolveReport) {
        let cfg = self.core.cfg();
        self.vjp_multi_cfg(x_star, theta, v, &cfg)
    }

    /// [`Problem::vjp_multi`] under an explicit arithmetic policy (the serve
    /// protocol's per-request `"precision"` field).
    pub fn vjp_multi_prec(
        &self,
        x_star: &[f64],
        theta: &[f64],
        v: &Mat,
        precision: SolvePrecision,
    ) -> (Mat, BlockSolveReport) {
        let cfg = self.core.cfg().with_precision(precision);
        self.vjp_multi_cfg(x_star, theta, v, &cfg)
    }

    fn vjp_multi_cfg(
        &self,
        x_star: &[f64],
        theta: &[f64],
        v: &Mat,
        cfg: &LinearSolveConfig,
    ) -> (Mat, BlockSolveReport) {
        let mut out = None;
        self.core.with_root(theta, &mut |m| {
            out = Some(implicit_vjp_multi(m, x_star, theta, v, cfg));
        });
        out.expect("with_root must invoke its callback")
    }

    /// k θ-directions (columns of `v`, n×k) → d×k JVP block via one block
    /// solve A X = B V.
    pub fn jvp_multi(&self, x_star: &[f64], theta: &[f64], v: &Mat) -> (Mat, BlockSolveReport) {
        let cfg = self.core.cfg();
        self.jvp_multi_cfg(x_star, theta, v, &cfg)
    }

    /// [`Problem::jvp_multi`] under an explicit arithmetic policy.
    pub fn jvp_multi_prec(
        &self,
        x_star: &[f64],
        theta: &[f64],
        v: &Mat,
        precision: SolvePrecision,
    ) -> (Mat, BlockSolveReport) {
        let cfg = self.core.cfg().with_precision(precision);
        self.jvp_multi_cfg(x_star, theta, v, &cfg)
    }

    fn jvp_multi_cfg(
        &self,
        x_star: &[f64],
        theta: &[f64],
        v: &Mat,
        cfg: &LinearSolveConfig,
    ) -> (Mat, BlockSolveReport) {
        let mut out = None;
        self.core.with_root(theta, &mut |m| {
            out = Some(implicit_jvp_multi(m, x_star, theta, v, cfg));
        });
        out.expect("with_root must invoke its callback")
    }

    /// Dense Jacobian ∂x*(θ) (one block solve).
    pub fn jacobian(&self, x_star: &[f64], theta: &[f64]) -> Mat {
        let mut out = None;
        self.core.with_root(theta, &mut |m| {
            out = Some(jacobian_via_root(m, x_star, theta));
        });
        out.expect("with_root must invoke its callback")
    }

    /// Materialize and factor A at (x*, θ) for the repeat-θ cache.
    pub fn factorize(&self, x_star: &[f64], theta: &[f64]) -> Option<Factorization> {
        let mut out = None;
        self.core.with_root(theta, &mut |m| {
            out = factorize_root(m, x_star, theta);
        });
        out
    }

    /// Factored (cache-hit) hypergradient block: substitutions only, zero
    /// iterative solves.
    pub fn vjp_multi_factored(
        &self,
        fact: &Factorization,
        x_star: &[f64],
        theta: &[f64],
        v: &Mat,
    ) -> Mat {
        let mut out = None;
        self.core.with_root(theta, &mut |m| {
            out = Some(implicit_vjp_multi_factored(m, fact, x_star, theta, v));
        });
        out.expect("with_root must invoke its callback")
    }

    /// Factored (cache-hit) JVP block.
    pub fn jvp_multi_factored(
        &self,
        fact: &Factorization,
        x_star: &[f64],
        theta: &[f64],
        v: &Mat,
    ) -> Mat {
        let mut out = None;
        self.core.with_root(theta, &mut |m| {
            out = Some(implicit_jvp_multi_factored(m, fact, x_star, theta, v));
        });
        out.expect("with_root must invoke its callback")
    }

    /// Factored dense Jacobian: A⁻¹(B·I_n) by substitutions.
    pub fn jacobian_factored(&self, fact: &Factorization, x_star: &[f64], theta: &[f64]) -> Mat {
        let eye = Mat::eye(self.dim_theta());
        self.jvp_multi_factored(fact, x_star, theta, &eye)
    }

    // ------------------------------------------- solve-free modes --

    /// One-step JVP block ∂₂T·V at (x*, θ): Jacobian-free, zero linear
    /// solves, zero factorizations (serve mode `"one-step"`). Error vs the
    /// implicit block is O(ρ) in the contraction factor.
    pub fn one_step_jvp_multi(&self, x_star: &[f64], theta: &[f64], v: &Mat) -> Mat {
        let mut out = None;
        self.core.with_fixed_point(x_star, theta, &mut |t| {
            out = Some(crate::diff::one_step::one_step_jvp_multi(t, x_star, theta, v));
        });
        out.expect("with_fixed_point must invoke its callback")
    }

    /// One-step VJP block ∂₂Tᵀ·U at (x*, θ) — the reverse-mode counterpart.
    pub fn one_step_vjp_multi(&self, x_star: &[f64], theta: &[f64], v: &Mat) -> Mat {
        let mut out = None;
        self.core.with_fixed_point(x_star, theta, &mut |t| {
            out = Some(crate::diff::one_step::one_step_vjp_multi(t, x_star, theta, v));
        });
        out.expect("with_fixed_point must invoke its callback")
    }

    /// k-term truncated-unroll (Neumann) JVP block at the converged point:
    /// Σ_{i<k}(∂₁T)^i ∂₂T · V, error O(ρᵏ), still zero solves.
    pub fn unroll_jvp_multi(&self, x_star: &[f64], theta: &[f64], v: &Mat, k: usize) -> Mat {
        let mut out = None;
        self.core.with_fixed_point(x_star, theta, &mut |t| {
            out = Some(crate::diff::one_step::neumann_jvp_multi(t, x_star, theta, v, k));
        });
        out.expect("with_fixed_point must invoke its callback")
    }

    /// k-term truncated-unroll VJP block — the exact adjoint of
    /// [`Problem::unroll_jvp_multi`].
    pub fn unroll_vjp_multi(&self, x_star: &[f64], theta: &[f64], v: &Mat, k: usize) -> Mat {
        let mut out = None;
        self.core.with_fixed_point(x_star, theta, &mut |t| {
            out = Some(crate::diff::one_step::neumann_vjp_multi(t, x_star, theta, v, k));
        });
        out.expect("with_fixed_point must invoke its callback")
    }

    /// Estimated contraction factor ρ ≈ ‖∂₁T(x*, θ)‖₂ of the fixed-point
    /// view (power iteration; Jacobian products only — no solves, no dense
    /// materialization). Drives the `"auto"` mode policy.
    pub fn contraction(&self, x_star: &[f64], theta: &[f64]) -> f64 {
        let mut out = f64::NAN;
        self.core.with_fixed_point(x_star, theta, &mut |t| {
            out = estimate_contraction(t, x_star, theta, CONTRACTION_POWER_ITERS, 0x1dea);
        });
        out
    }
}

/// The registry itself: a name → [`Problem`] catalog.
pub struct Registry {
    problems: Vec<Problem>,
}

impl Registry {
    /// The standard catalog: ridge, logreg, SVM, lasso (prox-grad),
    /// projected-GD (simplex) and an unconstrained stationary quadratic —
    /// one entry per optimality-mapping family the paper's Table 1 serves.
    pub fn standard() -> Registry {
        let mut problems = Vec::new();

        // ridge — closed-form solver + stationary root (Fig. 1 / Fig. 3).
        let (x, y) = crate::data::regression::diabetes_like(64, 8, 7);
        problems.push(Problem {
            name: "ridge",
            describe: "ridge regression, per-coordinate θ, closed-form inner solve",
            core: Box::new(RidgeCore { rp: RidgeProblem::new(x, y) }),
        });

        // logreg — L2-regularized multiclass logistic regression, GD solver.
        let mut rng = Rng::new(21);
        let ds = crate::data::classification::make_classification(40, 6, 3, 0.3, 2.0, &mut rng);
        problems.push(Problem {
            name: "logreg",
            describe: "multiclass logistic regression, θ = [λ] L2 strength, GD inner solve",
            core: Box::new(LogRegCore {
                m: StationaryMapping::new(LogRegProblem::new(ds.x, ds.labels, 3)),
            }),
        });

        // sparse_logreg — the same logreg family in the large-d regime:
        // d = p·k > FACTORIZE_DENSE_LIMIT over a CSR design, so the server
        // must stay matrix-free (CG on A = H_CE + λI, rank(H_CE) ≤ m·k;
        // factorization/densification are structurally impossible).
        let mut rng = Rng::new(26);
        let (sm, sp, sk, nnz_row) = (40usize, 6000usize, 3usize, 40usize);
        let mut trips = Vec::with_capacity(sm * nnz_row);
        let mut slabels = Vec::with_capacity(sm);
        let scale = 1.0 / (nnz_row as f64).sqrt();
        for i in 0..sm {
            slabels.push(i % sk);
            for _ in 0..nnz_row {
                let j = (rng.uniform() * sp as f64) as usize % sp;
                trips.push((i, j, scale * rng.normal()));
            }
        }
        let sx = CsrMat::from_triplets(sm, sp, &trips);
        problems.push(Problem {
            name: "sparse_logreg",
            describe: "multiclass logreg over a CSR design, d = 18000 — iterative-only serving",
            core: Box::new(LogRegCore {
                m: StationaryMapping::new(LogRegProblem::new(sx, slabels, sk)),
            }),
        });

        // svm — Crammer–Singer dual, BCD solver + projected-gradient
        // fixed point (Fig. 4's pairing).
        let mut rng = Rng::new(22);
        let ds = crate::data::classification::make_classification(24, 10, 3, 0.3, 2.0, &mut rng);
        let y_oh = ds.one_hot();
        problems.push(Problem {
            name: "svm",
            describe: "multiclass SVM dual, θ = [θ] > 0, BCD solver + PG fixed point",
            core: Box::new(SvmCore { x_tr: ds.x, y_tr: y_oh, k: 3 }),
        });

        // lasso — least squares + L1, FISTA solver + prox-grad fixed point.
        let mut rng = Rng::new(23);
        let xd = Mat::randn(40, 10, &mut rng);
        let w_true: Vec<f64> = (0..10).map(|i| if i % 3 == 0 { 1.5 } else { 0.0 }).collect();
        let mut yv = xd.matvec(&w_true);
        for v in yv.iter_mut() {
            *v += 0.01 * rng.normal();
        }
        problems.push(Problem {
            name: "lasso",
            describe: "lasso (½‖Xw−y‖² + λ‖w‖₁), θ = [λ] ≥ 0, FISTA + prox-grad fixed point",
            core: Box::new(LassoCore::new(xd, yv)),
        });

        // projgd — quadratic over the simplex, projected-gradient fixed
        // point; θ is the linear term (a "returns" vector).
        let mut rng = Rng::new(24);
        let q = Mat::randn(8, 5, &mut rng).gram().plus_diag(1.0);
        problems.push(Problem {
            name: "projgd",
            describe: "min ½xᵀQx − θᵀx over the simplex, projected-GD fixed point",
            core: Box::new(ProjGdCore::new(q)),
        });

        // quad — unconstrained stationary point with analytic everything;
        // the catalog's pure `StationaryMapping` entry.
        let mut rng = Rng::new(25);
        let q = Mat::randn(8, 6, &mut rng).gram().plus_diag(1.0);
        let r = Mat::randn(6, 4, &mut rng);
        let c = rng.normal_vec(6);
        problems.push(Problem {
            name: "quad",
            describe: "unconstrained quadratic stationary point, Cholesky inner solve",
            core: Box::new(QuadCore { m: StationaryMapping::new(QuadObjective { q, r, c }) }),
        });

        Registry { problems }
    }

    pub fn get(&self, name: &str) -> Option<&Problem> {
        self.problems.iter().find(|p| p.name == name)
    }

    pub fn problems(&self) -> &[Problem] {
        &self.problems
    }

    /// `[{name, dim_x, dim_theta}, …]` — the catalog fingerprint written
    /// into persistence manifests. Warm-start validates each restored entry
    /// against the live catalog, so this is informational (a human reading
    /// the manifest, plus a cheap cross-check target), not a trust boundary.
    pub fn catalog_signature(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(
            self.problems
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("name", Json::Str(p.name.to_string())),
                        ("dim_x", Json::Num(p.dim_x() as f64)),
                        ("dim_theta", Json::Num(p.dim_theta() as f64)),
                    ])
                })
                .collect(),
        )
    }

    /// FNV-1a fingerprint of the compact catalog signature. Every shard in
    /// a cluster must report the same value (the cluster tests assert it):
    /// ring routing is only meaningful when all shards serve one catalog.
    /// Reported by the `stats` op as a hex string.
    pub fn catalog_fingerprint(&self) -> u64 {
        super::cluster::ring::fnv1a(self.catalog_signature().to_string_compact().as_bytes())
    }
}

// ---------------------------------------------------------------- cores --

struct RidgeCore {
    rp: RidgeProblem,
}

impl ProblemCore for RidgeCore {
    fn dim_x(&self) -> usize {
        self.rp.dim()
    }
    fn dim_theta(&self) -> usize {
        self.rp.dim()
    }
    fn validate_theta(&self, theta: &[f64]) -> Result<(), String> {
        if theta.iter().any(|&t| t < 0.0) {
            return Err("ridge needs θ_i ≥ 0 (SPD system)".into());
        }
        Ok(())
    }
    fn solve(&self, theta: &[f64]) -> Vec<f64> {
        self.rp.solve_closed_form_vec(theta)
    }
    fn with_root(&self, _theta: &[f64], f: &mut dyn FnMut(&dyn RootMap)) {
        f(&RidgeRoot(&self.rp));
    }
}

struct LogRegCore {
    /// The mapping is θ-independent, so it is built ONCE and handed out by
    /// reference (contrast SvmCore, whose step size forces per-θ builds).
    m: StationaryMapping<LogRegProblem>,
}

impl ProblemCore for LogRegCore {
    fn dim_x(&self) -> usize {
        self.m.obj.dim_x()
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn validate_theta(&self, theta: &[f64]) -> Result<(), String> {
        if theta[0] <= 0.0 {
            return Err("logreg needs λ > 0 (strong convexity)".into());
        }
        Ok(())
    }
    fn solve(&self, theta: &[f64]) -> Vec<f64> {
        self.m.obj.fit(theta)
    }
    fn with_root(&self, _theta: &[f64], f: &mut dyn FnMut(&dyn RootMap)) {
        f(&self.m);
    }
}

struct SvmCore {
    x_tr: Mat,
    y_tr: Mat,
    k: usize,
}

impl SvmCore {
    /// MulticlassSvm caches its spectral norm in a `Cell` (not `Sync`), and
    /// the PG fixed point owns its objective with a θ-dependent step size —
    /// so the core stores the raw training data and builds the (small)
    /// problem per call instead of sharing one instance.
    fn svm(&self) -> MulticlassSvm {
        MulticlassSvm::new(self.x_tr.clone(), self.y_tr.clone())
    }
}

impl ProblemCore for SvmCore {
    fn dim_x(&self) -> usize {
        self.x_tr.rows * self.k
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn validate_theta(&self, theta: &[f64]) -> Result<(), String> {
        if theta[0] <= 0.0 {
            return Err("svm needs θ > 0".into());
        }
        Ok(())
    }
    fn solve(&self, theta: &[f64]) -> Vec<f64> {
        self.svm().solve_bcd(theta[0], 800)
    }
    fn cfg(&self) -> LinearSolveConfig {
        // PG fixed-point residual is non-symmetric; NormalCg as in Fig. 4
        // (tight tolerance: the κ²-amplified normal equations must still
        // land within 1e-5 of the factored direct path).
        LinearSolveConfig {
            kind: LinearSolverKind::NormalCg,
            tol: 1e-11,
            max_iter: 4000,
            gmres_restart: 30,
            ..Default::default()
        }
    }
    fn with_root(&self, theta: &[f64], f: &mut dyn FnMut(&dyn RootMap)) {
        let svm = self.svm();
        let eta = svm.pg_step(theta[0]);
        let proj = RowsSimplexProjection { m: self.x_tr.rows, k: self.k };
        let res = FixedPointResidual(ProjGradFixedPoint::new(svm, proj, eta));
        f(&res);
    }
    fn with_fixed_point(
        &self,
        _x_star: &[f64],
        theta: &[f64],
        f: &mut dyn FnMut(&dyn FixedPointMap),
    ) {
        // The PG iteration itself — no gradient-step wrapper needed.
        let svm = self.svm();
        let eta = svm.pg_step(theta[0]);
        let proj = RowsSimplexProjection { m: self.x_tr.rows, k: self.k };
        let fp = ProjGradFixedPoint::new(svm, proj, eta);
        f(&fp);
    }
}

struct LassoCore {
    /// Smooth part ½‖Xw−y‖² as a θ-free quadratic (R is d×0).
    obj: QuadObjective,
    /// 0.9 / λ_max(XᵀX): a safe prox-grad step.
    eta: f64,
}

impl LassoCore {
    fn new(x: Mat, y: Vec<f64>) -> LassoCore {
        let gram = x.gram();
        let xty = x.matvec_t(&y);
        // power iteration for λ_max(G)
        let d = gram.rows;
        let mut v = vec![1.0; d];
        let mut lam = 1.0;
        for _ in 0..80 {
            let mut w = gram.matvec(&v);
            lam = crate::linalg::vecops::norm2(&w).max(1e-30);
            for wi in w.iter_mut() {
                *wi /= lam;
            }
            v = w;
        }
        let c: Vec<f64> = xty.iter().map(|t| -t).collect();
        LassoCore {
            obj: QuadObjective { q: gram, r: Mat::zeros(d, 0), c },
            eta: 0.9 / lam,
        }
    }

    fn fixed_point(&self) -> ProxGradFixedPoint<QuadObjective, LassoProx> {
        let d = self.obj.q.rows;
        let obj = QuadObjective {
            q: self.obj.q.clone(),
            r: Mat::zeros(d, 0),
            c: self.obj.c.clone(),
        };
        ProxGradFixedPoint::new(obj, LassoProx { d }, self.eta)
    }
}

impl ProblemCore for LassoCore {
    fn dim_x(&self) -> usize {
        self.obj.q.rows
    }
    fn dim_theta(&self) -> usize {
        1 // θ = [λ], the prox parameter (the smooth part has none)
    }
    fn validate_theta(&self, theta: &[f64]) -> Result<(), String> {
        if theta[0] < 0.0 {
            return Err("lasso needs λ ≥ 0".into());
        }
        Ok(())
    }
    fn solve(&self, theta: &[f64]) -> Vec<f64> {
        let d = self.dim_x();
        let cfg = crate::solvers::prox_gd::ProxGdConfig {
            step: self.eta,
            max_iter: 20_000,
            tol: 1e-12,
            accelerated: true,
        };
        crate::solvers::prox_gd::prox_gradient_descent(
            &self.obj,
            &LassoProx { d },
            &vec![0.0; d],
            theta,
            &cfg,
        )
        .0
    }
    fn with_root(&self, _theta: &[f64], f: &mut dyn FnMut(&dyn RootMap)) {
        let res = FixedPointResidual(self.fixed_point());
        f(&res);
    }
    fn with_fixed_point(
        &self,
        _x_star: &[f64],
        _theta: &[f64],
        f: &mut dyn FnMut(&dyn FixedPointMap),
    ) {
        f(&self.fixed_point());
    }
}

struct ProjGdCore {
    q: Mat,
    eta: f64,
}

impl ProjGdCore {
    fn new(q: Mat) -> ProjGdCore {
        let d = q.rows;
        let mut v = vec![1.0; d];
        let mut lam = 1.0;
        for _ in 0..80 {
            let mut w = q.matvec(&v);
            lam = crate::linalg::vecops::norm2(&w).max(1e-30);
            for wi in w.iter_mut() {
                *wi /= lam;
            }
            v = w;
        }
        ProjGdCore { q, eta: 0.9 / lam }
    }

    fn fixed_point(&self) -> ProjGradFixedPoint<QuadObjective, SimplexProjection> {
        let d = self.q.rows;
        // f = ½xᵀQx − θᵀx: R = −I so ∂θ∇₁f = −I.
        let mut r = Mat::zeros(d, d);
        for i in 0..d {
            *r.at_mut(i, i) = -1.0;
        }
        let obj = QuadObjective { q: self.q.clone(), r, c: vec![0.0; d] };
        ProjGradFixedPoint::new(obj, SimplexProjection { d }, self.eta)
    }
}

impl ProblemCore for ProjGdCore {
    fn dim_x(&self) -> usize {
        self.q.rows
    }
    fn dim_theta(&self) -> usize {
        self.q.rows
    }
    fn validate_theta(&self, _theta: &[f64]) -> Result<(), String> {
        Ok(())
    }
    fn solve(&self, theta: &[f64]) -> Vec<f64> {
        let t = self.fixed_point();
        let d = self.dim_x();
        let mut x = vec![1.0 / d as f64; d];
        let mut nx = vec![0.0; d];
        for _ in 0..20_000 {
            t.eval(&x, theta, &mut nx);
            let delta: f64 =
                x.iter().zip(&nx).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            std::mem::swap(&mut x, &mut nx);
            if delta < 1e-13 {
                break;
            }
        }
        x
    }
    fn cfg(&self) -> LinearSolveConfig {
        LinearSolveConfig {
            kind: LinearSolverKind::NormalCg,
            tol: 1e-10,
            max_iter: 2000,
            gmres_restart: 30,
            ..Default::default()
        }
    }
    fn with_root(&self, _theta: &[f64], f: &mut dyn FnMut(&dyn RootMap)) {
        let res = FixedPointResidual(self.fixed_point());
        f(&res);
    }
    fn with_fixed_point(
        &self,
        _x_star: &[f64],
        _theta: &[f64],
        f: &mut dyn FnMut(&dyn FixedPointMap),
    ) {
        f(&self.fixed_point());
    }
}

struct QuadCore {
    m: StationaryMapping<QuadObjective>,
}

impl ProblemCore for QuadCore {
    fn dim_x(&self) -> usize {
        self.m.obj.q.rows
    }
    fn dim_theta(&self) -> usize {
        self.m.obj.r.cols
    }
    fn validate_theta(&self, _theta: &[f64]) -> Result<(), String> {
        Ok(())
    }
    fn solve(&self, theta: &[f64]) -> Vec<f64> {
        // x* = −Q⁻¹(Rθ + c)
        let ch = crate::linalg::chol::Cholesky::factor(&self.m.obj.q).expect("Q SPD");
        let rt = self.m.obj.r.matvec(theta);
        let rhs: Vec<f64> = rt.iter().zip(&self.m.obj.c).map(|(a, b)| -(a + b)).collect();
        ch.solve(&rhs)
    }
    fn with_root(&self, _theta: &[f64], f: &mut dyn FnMut(&dyn RootMap)) {
        f(&self.m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::densify;
    use crate::linalg::solve::counter;
    use crate::linalg::vecops;

    /// Every catalog entry: the inner solution is a fixed point / root of
    /// its mapping, the factored derivative paths match the iterative block
    /// paths, and the factored paths issue zero iterative solves. Entries
    /// past `FACTORIZE_DENSE_LIMIT` (sparse_logreg) must instead refuse to
    /// factorize and serve iteratively without EVER materializing a dense
    /// d×d operator (densify counter stays at zero).
    #[test]
    fn catalog_roots_and_factored_paths_agree() {
        let reg = Registry::standard();
        assert!(reg.get("nope").is_none());
        let mut rng = Rng::new(31);
        for p in reg.problems() {
            let n = p.dim_theta();
            let d = p.dim_x();
            let theta: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.4, 1.2)).collect();
            p.validate_theta(&theta).expect("standard θ must validate");
            let x_star = p.solve(&theta);
            // x* is a root of the mapping
            let mut res = vec![0.0; d];
            let mut resn = f64::NAN;
            p.core.with_root(&theta, &mut |m| {
                m.eval(&x_star, &theta, &mut res);
                resn = vecops::norm2(&res);
            });
            assert!(resn < 1e-5, "{}: residual {resn}", p.name);
            // iterative block VJP vs factored VJP
            let k = 3;
            let v = Mat::randn(d, k, &mut rng);
            counter::reset();
            densify::reset();
            let (g_iter, rep) = p.vjp_multi(&x_star, &theta, &v);
            assert!(rep.converged, "{}: {rep:?}", p.name);
            assert_eq!(counter::count(), 1, "{}: block VJP must be one solve", p.name);
            let fact = p.factorize(&x_star, &theta);
            if d > crate::diff::root::FACTORIZE_DENSE_LIMIT {
                // Large-d entries never materialize or factor a dense d×d.
                assert!(fact.is_none(), "{}: must refuse dense factorization", p.name);
                assert_eq!(densify::count(), 0, "{}: densified a d×d operator", p.name);
                let vt = Mat::randn(n, 2, &mut rng);
                let (_, rep) = p.jvp_multi(&x_star, &theta, &vt);
                assert!(rep.converged, "{}: {rep:?}", p.name);
                assert_eq!(densify::count(), 0, "{}: JVP densified", p.name);
                continue;
            }
            let fact = fact.expect("regular root");
            let g_fact = p.vjp_multi_factored(&fact, &x_star, &theta, &v);
            assert_eq!(counter::count(), 1, "{}: factored path must add zero solves", p.name);
            let scale = g_iter.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..g_iter.data.len() {
                assert!(
                    (g_iter.data[i] - g_fact.data[i]).abs() < 1e-5 * scale,
                    "{}: vjp[{i}] {} vs {}",
                    p.name,
                    g_iter.data[i],
                    g_fact.data[i]
                );
            }
            // iterative block JVP vs factored JVP
            let vt = Mat::randn(n, 2, &mut rng);
            let (j_iter, rep) = p.jvp_multi(&x_star, &theta, &vt);
            assert!(rep.converged, "{}: {rep:?}", p.name);
            let j_fact = p.jvp_multi_factored(&fact, &x_star, &theta, &vt);
            let scale = j_iter.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..j_iter.data.len() {
                assert!(
                    (j_iter.data[i] - j_fact.data[i]).abs() < 1e-5 * scale,
                    "{}: jvp[{i}] {} vs {}",
                    p.name,
                    j_iter.data[i],
                    j_fact.data[i]
                );
            }
        }
    }

    /// Every catalog entry exposes a fixed-point view T with T(x*, θ) = x*,
    /// and its estimated contraction factor ρ = ‖∂₁T‖₂ is at most 1 (every
    /// view composes nonexpansive maps with a tuned gradient step). Smooth
    /// strongly-convex entries must be strict contractions — that is what
    /// lets `"auto"` serve them one-step on a cold cache. The whole check is
    /// solve-free and never materializes a dense operator.
    #[test]
    fn catalog_fixed_point_views_are_contractions_at_the_solution() {
        let reg = Registry::standard();
        let mut rng = Rng::new(33);
        counter::reset();
        densify::reset();
        for p in reg.problems() {
            let n = p.dim_theta();
            let d = p.dim_x();
            let theta: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.5, 1.0)).collect();
            let x_star = p.solve(&theta);
            counter::reset();
            densify::reset();
            let mut tn = f64::NAN;
            p.core.with_fixed_point(&x_star, &theta, &mut |t| {
                assert_eq!(t.dim_x(), d, "{}", p.name);
                assert_eq!(t.dim_theta(), n, "{}", p.name);
                let mut tx = vec![0.0; d];
                t.eval(&x_star, &theta, &mut tx);
                tn = tx
                    .iter()
                    .zip(&x_star)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
            });
            assert!(tn < 1e-4, "{}: fixed-point residual {tn}", p.name);
            let rho = p.contraction(&x_star, &theta);
            assert!(rho.is_finite() && rho <= 1.0 + 1e-9, "{}: rho = {rho}", p.name);
            // The SVM dual quadratic is rank-deficient (gram of m > p rows),
            // so its PG step is only nonexpansive along null directions the
            // simplex projection keeps; every other entry is a strict
            // contraction at x*.
            if p.name != "svm" {
                assert!(rho < 1.0, "{}: rho = {rho} must contract", p.name);
            }
            assert_eq!(counter::count(), 0, "{}: mode path issued a solve", p.name);
            assert_eq!(densify::count(), 0, "{}: mode path densified", p.name);
        }
    }

    #[test]
    fn theta_validation_rejects_bad_inputs() {
        let reg = Registry::standard();
        assert!(reg.get("ridge").unwrap().validate_theta(&[1.0; 3]).is_err()); // wrong len
        assert!(reg.get("ridge").unwrap().validate_theta(&[-1.0; 8]).is_err()); // negative
        assert!(reg.get("svm").unwrap().validate_theta(&[0.0]).is_err()); // nonpositive
        assert!(reg.get("logreg").unwrap().validate_theta(&[f64::NAN]).is_err());
        assert!(reg.get("lasso").unwrap().validate_theta(&[0.2]).is_ok());
        assert!(reg.get("quad").unwrap().validate_theta(&[0.1, 0.2, 0.3, 0.4]).is_ok());
    }
}
