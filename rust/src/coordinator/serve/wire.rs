//! Zero-copy binary frame codec for the serve protocol.
//!
//! The hot serving path should not pay for JSON: formatting every f64 to
//! text and re-parsing it burns more time than the factored substitution
//! that answers a warm-θ request. This codec moves θ/v/result vectors as
//! raw little-endian f64 blocks, decoded straight into pooled buffers.
//!
//! # Request frame
//!
//! ```text
//! [0]     magic      0xB1  (never a JSON first byte — '{' is 0x7B)
//! [1]     version    2
//! [2..6]  u32 LE     deadline_ms (request budget; 0 = no deadline. The
//!                    router decrements this in place before relaying, so
//!                    a shard sees only the *remaining* budget.)
//! [6..10] u32 LE     payload length in bytes
//! payload:
//!   [0]      u8      opcode (OP_PING … OP_REPLICATE)
//!   [1]      u8      mode   (MODE_* — MODE_NONE when defaulted)
//!   [2]      u8      precision (PREC_F64 | PREC_MIXED)
//!   [3]      u8      reserved (must be 0)
//!   [4..8]   u32 LE  iters (explicit unroll depth; 0 = policy)
//!   [8..10]  u16 LE  name_len, then name bytes (UTF-8 problem name)
//!   [..]     u32 LE  n_theta, then n_theta × f64 LE
//!   [..]     u32 LE  n_v,     then n_v × f64 LE
//! OP_REPLICATE payload (shard→shard warm-state transfer) replaces the
//! name/θ/v tail after the 8 fixed prelude bytes with:
//!   [8..12]  u32 LE  doc_len, then doc bytes (UTF-8 replica-delta JSON)
//! ```
//!
//! Control ops (`ping`/`problems`/`stats`) send name/θ/v empty. Every
//! request op carries the full layout — fixed shape beats per-op special
//! cases at these sizes.
//!
//! # Reply frame
//!
//! ```text
//! [0]     magic      0xB1
//! [1]     version    2
//! [2]     status     0 = ok, 1 = error
//! [3]     flags      bit 0: answered from the θ-cache
//! [4..8]  u32 LE     payload length
//! ok payload:
//!   [0]      u8      mode byte (MODE_* of the serving mechanism, or MODE_NONE)
//!   [1..5]   u32 LE  batched (block-solve batch size; 0 for non-derivative ops)
//!   [5..9]   u32 LE  rows
//!   [9..13]  u32 LE  cols
//!   [..]     rows×cols × f64 LE, row-major (x / grad / jv as a column;
//!            the Jacobian as a matrix; empty for ping/problems/stats)
//!   [..]     u32 LE  text_len, then text bytes (compact JSON — only
//!            problems/stats use it; they are a debugging surface)
//! err payload:
//!   [0..4]   u32 LE  msg_len, then msg bytes (same strings as the JSON
//!            protocol's "error" field)
//! ```
//!
//! # Error policy
//!
//! A *framing* violation (wrong magic or version, payload length past the
//! server limit) means the byte stream can no longer be delimited: the
//! server sends one error frame and closes. A *well-framed but malformed*
//! payload (unknown opcode, truncated vector block, bad UTF-8, trailing
//! garbage) is an ordinary error frame and the connection stays usable —
//! exactly like a JSON request with a bad field.

use super::batcher::BatchOp;
use super::{Reply, Request};
use crate::diff::mode::DiffMode;
use crate::linalg::solve::SolvePrecision;
use crate::util::pool::Pool;
use std::io::Read;
use std::sync::Arc;

/// First byte of every frame. 0xB1 is outside ASCII, so no JSON line —
/// which must start with `{` (0x7B) or whitespace — can collide with it.
pub const MAGIC: u8 = 0xB1;
/// Bumped on any byte-layout change; both sides must agree exactly.
/// v2 widened the request header with a u32 deadline budget.
pub const VERSION: u8 = 2;
/// Request header: magic, version, u32 deadline_ms, u32 payload length.
pub const REQUEST_HEADER_LEN: usize = 10;
/// Byte offset of the u32 deadline_ms field inside the request header —
/// the router patches the remaining budget in place at this offset.
pub const REQUEST_DEADLINE_OFFSET: usize = 2;
/// Reply header: magic, version, status, flags, u32 payload length.
pub const REPLY_HEADER_LEN: usize = 8;

pub const OP_PING: u8 = 0;
pub const OP_PROBLEMS: u8 = 1;
pub const OP_STATS: u8 = 2;
pub const OP_SOLVE: u8 = 3;
pub const OP_VJP: u8 = 4;
pub const OP_JVP: u8 = 5;
pub const OP_JACOBIAN: u8 = 6;
/// Internal shard→shard op: install a warm-state replica delta. Never
/// routed — the replicator thread connects to its successor directly.
pub const OP_REPLICATE: u8 = 7;

pub const MODE_IMPLICIT: u8 = 0;
pub const MODE_UNROLL: u8 = 1;
pub const MODE_ONE_STEP: u8 = 2;
pub const MODE_AUTO: u8 = 3;
/// "field not set": derivative requests default to implicit, and replies
/// to non-derivative ops have no mode.
pub const MODE_NONE: u8 = 0xff;

pub const PREC_F64: u8 = 0;
pub const PREC_MIXED: u8 = 1;

pub const STATUS_OK: u8 = 0;
pub const STATUS_ERR: u8 = 1;
pub const FLAG_CACHED: u8 = 1;
/// Reply flag bit: this derivative was served solve-free because the solve
/// queue was saturated (mode-aware admission degrade). The JSON wire's
/// equivalent is a `"degraded": true` member.
pub const FLAG_DEGRADED: u8 = 2;

pub fn mode_to_byte(mode: DiffMode) -> u8 {
    match mode {
        DiffMode::Implicit => MODE_IMPLICIT,
        DiffMode::Unroll => MODE_UNROLL,
        DiffMode::OneStep => MODE_ONE_STEP,
        DiffMode::Auto => MODE_AUTO,
    }
}

fn mode_from_byte(b: u8) -> Result<DiffMode, String> {
    match b {
        MODE_IMPLICIT | MODE_NONE => Ok(DiffMode::Implicit),
        MODE_UNROLL => Ok(DiffMode::Unroll),
        MODE_ONE_STEP => Ok(DiffMode::OneStep),
        MODE_AUTO => Ok(DiffMode::Auto),
        other => Err(format!("bad mode byte {other:#04x}")),
    }
}

/// The mode *string* a reply carries (`"implicit"`, `"one-step"`, …) back
/// to its wire byte. Replies echo the engine's mode strings so both
/// protocols stay bitwise-comparable.
fn mode_byte_from_str(s: &str) -> u8 {
    match DiffMode::parse(s) {
        Some(m) => mode_to_byte(m),
        None => MODE_NONE,
    }
}

pub fn mode_str_from_byte(b: u8) -> &'static str {
    match b {
        MODE_IMPLICIT => "implicit",
        MODE_UNROLL => "unroll",
        MODE_ONE_STEP => "one-step",
        MODE_AUTO => "auto",
        _ => "",
    }
}

// ------------------------------------------------------------- cursor --

/// Bounds-checked little-endian reader over a frame payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated frame: {what} needs {n} bytes, {} left",
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, String> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Decode `n_elems` f64s straight into a pooled buffer.
    fn f64_block(
        &mut self,
        n_elems: usize,
        key: &str,
        pool: &Arc<Pool>,
    ) -> Result<crate::util::pool::PoolVec, String> {
        let bytes = self.take(n_elems * 8, key)?;
        let mut v = pool.take_f64(n_elems);
        for i in 0..n_elems {
            let mut raw = [0u8; 8];
            raw.copy_from_slice(&bytes[i * 8..i * 8 + 8]);
            let x = f64::from_le_bytes(raw);
            if !x.is_finite() {
                return Err(format!("'{key}[{i}]' is not a finite number"));
            }
            v[i] = x;
        }
        Ok(v)
    }
}

// ---------------------------------------------------------- server side --

/// Validate a request header; returns `(payload length, deadline_ms)`
/// (deadline 0 = none). An `Err` here is a framing violation — the caller
/// must close after replying.
pub fn parse_request_header(
    hdr: &[u8; REQUEST_HEADER_LEN],
    max_payload: usize,
) -> Result<(usize, u32), String> {
    if hdr[0] != MAGIC {
        return Err(format!("bad frame magic {:#04x}", hdr[0]));
    }
    if hdr[1] != VERSION {
        return Err(format!("unsupported protocol version {} (expected {VERSION})", hdr[1]));
    }
    let deadline_ms = u32::from_le_bytes([hdr[2], hdr[3], hdr[4], hdr[5]]);
    let len = u32::from_le_bytes([hdr[6], hdr[7], hdr[8], hdr[9]]) as usize;
    if len > max_payload {
        return Err(format!("request too large ({len} bytes > {max_payload} max)"));
    }
    Ok((len, deadline_ms))
}

/// Decode a request payload into the transport-neutral [`Request`]; θ and v
/// land in pooled buffers. Errors here are *payload* errors: the connection
/// stays open.
pub fn decode_request(payload: &[u8], pool: &Arc<Pool>) -> Result<Request, String> {
    let mut c = Cursor::new(payload);
    let opcode = c.u8("opcode")?;
    let mode_byte = c.u8("mode")?;
    let prec_byte = c.u8("precision")?;
    let _reserved = c.u8("reserved")?;
    let iters = c.u32("iters")? as usize;
    if opcode == OP_REPLICATE {
        let doc_len = c.u32("replica doc length")? as usize;
        let doc_bytes = c.take(doc_len, "replica doc")?;
        let doc = std::str::from_utf8(doc_bytes)
            .map_err(|_| "replica doc is not valid UTF-8".to_string())?
            .to_string();
        if c.remaining() != 0 {
            return Err(format!("trailing bytes in frame ({} after payload)", c.remaining()));
        }
        return Ok(Request::Replicate { doc });
    }
    let name_len = c.u16("name length")? as usize;
    let name_bytes = c.take(name_len, "problem name")?;
    let name = std::str::from_utf8(name_bytes)
        .map_err(|_| "problem name is not valid UTF-8".to_string())?
        .to_string();
    let n_theta = c.u32("theta length")? as usize;
    if c.remaining() < n_theta.saturating_mul(8) {
        return Err("truncated f64 block for 'theta'".to_string());
    }
    let theta = c.f64_block(n_theta, "theta", pool)?;
    let n_v = c.u32("v length")? as usize;
    if c.remaining() < n_v.saturating_mul(8) {
        return Err("truncated f64 block for 'v'".to_string());
    }
    let v = c.f64_block(n_v, "v", pool)?;
    if c.remaining() != 0 {
        return Err(format!("trailing bytes in frame ({} after payload)", c.remaining()));
    }
    if iters > 1_000_000 {
        return Err("'iters' must be a positive integer".to_string());
    }
    let precision = match prec_byte {
        PREC_F64 => SolvePrecision::F64,
        PREC_MIXED => SolvePrecision::MixedF32,
        other => return Err(format!("'precision' byte {other:#04x} is not valid")),
    };
    match opcode {
        OP_PING => Ok(Request::Ping),
        OP_PROBLEMS => Ok(Request::Problems),
        OP_STATS => Ok(Request::Stats),
        OP_SOLVE => Ok(Request::Solve { problem: name, theta }),
        OP_VJP | OP_JVP => Ok(Request::Derivative {
            problem: name,
            theta,
            v,
            op: if opcode == OP_VJP { BatchOp::Vjp } else { BatchOp::Jvp },
            mode: mode_from_byte(mode_byte)?,
            precision,
            iters,
        }),
        OP_JACOBIAN => Ok(Request::Jacobian { problem: name, theta }),
        other => Err(format!("unknown opcode {other}")),
    }
}

fn push_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn push_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append a reply frame (header + payload) to `out`.
pub fn encode_reply(reply: &Reply, out: &mut Vec<u8>) {
    let start = out.len();
    out.push(MAGIC);
    out.push(VERSION);
    let (status, cached, degraded) = match reply {
        Reply::Error(_) => (STATUS_ERR, false, false),
        Reply::Solution { cached, .. } => (STATUS_OK, *cached, false),
        Reply::Derivative { cached, degraded, .. } => (STATUS_OK, *cached, *degraded),
        Reply::Jacobian { cached, .. } => (STATUS_OK, *cached, false),
        _ => (STATUS_OK, false, false),
    };
    out.push(status);
    out.push(if cached { FLAG_CACHED } else { 0 } | if degraded { FLAG_DEGRADED } else { 0 });
    push_u32(out, 0); // payload length, patched below
    let body = out.len();
    match reply {
        Reply::Error(msg) => {
            push_u32(out, msg.len() as u32);
            out.extend_from_slice(msg.as_bytes());
        }
        Reply::Pong => {
            out.push(MODE_NONE);
            push_u32(out, 0); // batched
            push_u32(out, 0); // rows
            push_u32(out, 0); // cols
            push_u32(out, 0); // text_len
        }
        Reply::Text(j) => {
            out.push(MODE_NONE);
            push_u32(out, 0);
            push_u32(out, 0);
            push_u32(out, 0);
            let text = j.to_string_compact();
            push_u32(out, text.len() as u32);
            out.extend_from_slice(text.as_bytes());
        }
        Reply::Solution { x, .. } => {
            out.push(MODE_NONE);
            push_u32(out, 0);
            push_u32(out, x.len() as u32);
            push_u32(out, 1);
            push_f64s(out, x);
            push_u32(out, 0);
        }
        Reply::Derivative { out: data, batched, mode, .. } => {
            out.push(mode_byte_from_str(mode));
            push_u32(out, *batched as u32);
            push_u32(out, data.len() as u32);
            push_u32(out, 1);
            push_f64s(out, data);
            push_u32(out, 0);
        }
        Reply::Jacobian { jac, .. } => {
            out.push(MODE_NONE);
            push_u32(out, 0);
            push_u32(out, jac.rows as u32);
            push_u32(out, jac.cols as u32);
            for i in 0..jac.rows {
                push_f64s(out, jac.row(i));
            }
            push_u32(out, 0);
        }
    }
    let len = (out.len() - body) as u32;
    out[start + 4..start + 8].copy_from_slice(&len.to_le_bytes());
}

// ---------------------------------------------------------- client side --

/// A request as the client assembles it. θ/v are plain slices — the client
/// side of the codec is for tests, benches and SDKs, not the server path.
pub struct RequestFrame<'a> {
    pub opcode: u8,
    pub mode: u8,
    pub precision: u8,
    pub iters: u32,
    /// Deadline budget in milliseconds; 0 = no deadline.
    pub deadline_ms: u32,
    pub problem: &'a str,
    pub theta: &'a [f64],
    pub v: &'a [f64],
}

impl<'a> RequestFrame<'a> {
    /// A control-plane request (ping / problems / stats).
    pub fn control(opcode: u8) -> RequestFrame<'a> {
        RequestFrame {
            opcode,
            mode: MODE_NONE,
            precision: PREC_F64,
            iters: 0,
            deadline_ms: 0,
            problem: "",
            theta: &[],
            v: &[],
        }
    }
}

/// Append a full request frame (header + payload) to `out`.
pub fn encode_request(req: &RequestFrame, out: &mut Vec<u8>) {
    let start = out.len();
    out.push(MAGIC);
    out.push(VERSION);
    push_u32(out, req.deadline_ms);
    push_u32(out, 0); // payload length, patched below
    let body = out.len();
    out.push(req.opcode);
    out.push(req.mode);
    out.push(req.precision);
    out.push(0); // reserved
    push_u32(out, req.iters);
    debug_assert!(req.problem.len() <= u16::MAX as usize);
    out.extend_from_slice(&(req.problem.len() as u16).to_le_bytes());
    out.extend_from_slice(req.problem.as_bytes());
    push_u32(out, req.theta.len() as u32);
    push_f64s(out, req.theta);
    push_u32(out, req.v.len() as u32);
    push_f64s(out, req.v);
    let len = (out.len() - body) as u32;
    out[start + 6..start + 10].copy_from_slice(&len.to_le_bytes());
}

/// Append a full OP_REPLICATE frame carrying a replica-delta document.
/// Shard→shard only; replicas carry no deadline (best-effort background
/// work) and no name/θ/v tail — the doc length is u32, so deltas are not
/// bound by the u16 problem-name limit.
pub fn encode_replicate(doc: &[u8], out: &mut Vec<u8>) {
    let start = out.len();
    out.push(MAGIC);
    out.push(VERSION);
    push_u32(out, 0); // deadline: none
    push_u32(out, 0); // payload length, patched below
    let body = out.len();
    out.push(OP_REPLICATE);
    out.push(MODE_NONE);
    out.push(PREC_F64);
    out.push(0); // reserved
    push_u32(out, 0); // iters
    push_u32(out, doc.len() as u32);
    out.extend_from_slice(doc);
    let len = (out.len() - body) as u32;
    out[start + 6..start + 10].copy_from_slice(&len.to_le_bytes());
}

/// A decoded reply frame, client side.
#[derive(Debug, Clone)]
pub struct ReplyFrame {
    pub status: u8,
    pub cached: bool,
    /// Served solve-free under admission pressure (see [`FLAG_DEGRADED`]).
    pub degraded: bool,
    pub mode_byte: u8,
    pub batched: usize,
    pub rows: usize,
    pub cols: usize,
    /// rows×cols payload, row-major.
    pub data: Vec<f64>,
    /// Compact-JSON tail (problems/stats), empty otherwise.
    pub text: String,
    pub error: Option<String>,
}

/// Read one reply frame off a stream (blocking).
pub fn read_reply(r: &mut impl Read) -> std::io::Result<ReplyFrame> {
    use std::io::{Error, ErrorKind};
    let bad = |msg: String| Error::new(ErrorKind::InvalidData, msg);
    let mut hdr = [0u8; REPLY_HEADER_LEN];
    r.read_exact(&mut hdr)?;
    if hdr[0] != MAGIC || hdr[1] != VERSION {
        return Err(bad(format!("bad reply header {:#04x} {:#04x}", hdr[0], hdr[1])));
    }
    let status = hdr[2];
    let cached = hdr[3] & FLAG_CACHED != 0;
    let degraded = hdr[3] & FLAG_DEGRADED != 0;
    let len = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut c = Cursor::new(&payload);
    if status == STATUS_ERR {
        let n = c.u32("error length").map_err(&bad)? as usize;
        let msg = String::from_utf8_lossy(c.take(n, "error text").map_err(&bad)?).into_owned();
        return Ok(ReplyFrame {
            status,
            cached,
            degraded,
            mode_byte: MODE_NONE,
            batched: 0,
            rows: 0,
            cols: 0,
            data: Vec::new(),
            text: String::new(),
            error: Some(msg),
        });
    }
    let mode_byte = c.u8("mode").map_err(&bad)?;
    let batched = c.u32("batched").map_err(&bad)? as usize;
    let rows = c.u32("rows").map_err(&bad)? as usize;
    let cols = c.u32("cols").map_err(&bad)? as usize;
    let n = rows * cols;
    let bytes = c.take(n * 8, "f64 block").map_err(&bad)?;
    let mut data = Vec::with_capacity(n);
    for i in 0..n {
        let mut raw = [0u8; 8];
        raw.copy_from_slice(&bytes[i * 8..i * 8 + 8]);
        data.push(f64::from_le_bytes(raw));
    }
    let tn = c.u32("text length").map_err(&bad)? as usize;
    let text = String::from_utf8_lossy(c.take(tn, "text").map_err(&bad)?).into_owned();
    Ok(ReplyFrame {
        status,
        cached,
        degraded,
        mode_byte,
        batched,
        rows,
        cols,
        data,
        text,
        error: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::Pool;

    fn pool() -> Arc<Pool> {
        Pool::new(8)
    }

    #[test]
    fn request_round_trips_through_the_codec() {
        let theta = [1.0, -0.0, 2.0 + 1e-9, 5e-324];
        let v = [0.25, -3.5];
        let frame = RequestFrame {
            opcode: OP_VJP,
            mode: MODE_AUTO,
            precision: PREC_MIXED,
            iters: 7,
            deadline_ms: 250,
            problem: "ridge",
            theta: &theta,
            v: &v,
        };
        let mut out = Vec::new();
        encode_request(&frame, &mut out);
        assert_eq!(out[0], MAGIC);
        assert_eq!(out[1], VERSION);
        let deadline = u32::from_le_bytes([out[2], out[3], out[4], out[5]]);
        assert_eq!(deadline, 250);
        let len = u32::from_le_bytes([out[6], out[7], out[8], out[9]]) as usize;
        assert_eq!(len, out.len() - REQUEST_HEADER_LEN);
        let mut hdr = [0u8; REQUEST_HEADER_LEN];
        hdr.copy_from_slice(&out[..REQUEST_HEADER_LEN]);
        assert_eq!(parse_request_header(&hdr, 1 << 20), Ok((len, 250)));
        let req = decode_request(&out[REQUEST_HEADER_LEN..], &pool()).unwrap();
        match req {
            Request::Derivative { problem, theta: t, v: vv, op, mode, precision, iters } => {
                assert_eq!(problem, "ridge");
                assert_eq!(t.len(), 4);
                for i in 0..4 {
                    assert_eq!(t[i].to_bits(), theta[i].to_bits(), "theta[{i}]");
                }
                assert_eq!(&vv[..], &v[..]);
                assert!(matches!(op, BatchOp::Vjp));
                assert_eq!(mode, crate::diff::mode::DiffMode::Auto);
                assert_eq!(precision, crate::linalg::solve::SolvePrecision::MixedF32);
                assert_eq!(iters, 7);
            }
            _ => panic!("wrong request variant"),
        }
    }

    #[test]
    fn malformed_payloads_are_clean_errors() {
        let p = pool();
        // unknown opcode
        let mut out = Vec::new();
        encode_request(&RequestFrame { opcode: 99, ..RequestFrame::control(OP_PING) }, &mut out);
        let e = decode_request(&out[REQUEST_HEADER_LEN..], &p).unwrap_err();
        assert!(e.contains("unknown opcode"), "{e}");
        // truncated θ block: claim 4 f64s, supply 1
        let mut out = Vec::new();
        encode_request(
            &RequestFrame {
                opcode: OP_SOLVE,
                problem: "ridge",
                theta: &[1.0],
                ..RequestFrame::control(OP_SOLVE)
            },
            &mut out,
        );
        let theta_count_at = REQUEST_HEADER_LEN + 8 + 2 + "ridge".len();
        out[theta_count_at..theta_count_at + 4].copy_from_slice(&4u32.to_le_bytes());
        let e = decode_request(&out[REQUEST_HEADER_LEN..], &p).unwrap_err();
        assert!(e.contains("truncated"), "{e}");
        // trailing garbage
        let mut out = Vec::new();
        encode_request(&RequestFrame::control(OP_PING), &mut out);
        let len_fixed = (out.len() - REQUEST_HEADER_LEN + 2) as u32;
        out.extend_from_slice(&[0xde, 0xad]);
        out[6..10].copy_from_slice(&len_fixed.to_le_bytes());
        let e = decode_request(&out[REQUEST_HEADER_LEN..], &p).unwrap_err();
        assert!(e.contains("trailing"), "{e}");
        // non-finite θ entry
        let mut out = Vec::new();
        encode_request(
            &RequestFrame {
                opcode: OP_SOLVE,
                problem: "ridge",
                theta: &[f64::NAN],
                ..RequestFrame::control(OP_SOLVE)
            },
            &mut out,
        );
        let e = decode_request(&out[REQUEST_HEADER_LEN..], &p).unwrap_err();
        assert!(e.contains("not a finite number"), "{e}");
    }

    #[test]
    fn header_validation_catches_framing_violations() {
        let mut hdr = [0u8; REQUEST_HEADER_LEN];
        hdr[0] = MAGIC;
        hdr[1] = VERSION;
        hdr[6..10].copy_from_slice(&64u32.to_le_bytes());
        assert_eq!(parse_request_header(&hdr, 1024), Ok((64, 0)));
        hdr[REQUEST_DEADLINE_OFFSET..REQUEST_DEADLINE_OFFSET + 4]
            .copy_from_slice(&1500u32.to_le_bytes());
        assert_eq!(parse_request_header(&hdr, 1024), Ok((64, 1500)));
        let mut bad_magic = hdr;
        bad_magic[0] = b'{';
        assert!(parse_request_header(&bad_magic, 1024).unwrap_err().contains("magic"));
        let mut bad_ver = hdr;
        bad_ver[1] = 9;
        assert!(parse_request_header(&bad_ver, 1024).unwrap_err().contains("version"));
        let mut huge = hdr;
        huge[6..10].copy_from_slice(&(1u32 << 30).to_le_bytes());
        assert!(parse_request_header(&huge, 1024).unwrap_err().contains("too large"));
    }

    #[test]
    fn replicate_frames_round_trip_their_doc() {
        let doc = r#"{"format":"idiff-replica-delta","entries":[]}"#;
        let mut out = Vec::new();
        encode_replicate(doc.as_bytes(), &mut out);
        let mut hdr = [0u8; REQUEST_HEADER_LEN];
        hdr.copy_from_slice(&out[..REQUEST_HEADER_LEN]);
        let (len, deadline) = parse_request_header(&hdr, 1 << 20).unwrap();
        assert_eq!(deadline, 0);
        assert_eq!(len, out.len() - REQUEST_HEADER_LEN);
        match decode_request(&out[REQUEST_HEADER_LEN..], &pool()).unwrap() {
            Request::Replicate { doc: d } => assert_eq!(d, doc),
            _ => panic!("wrong request variant"),
        }
        // truncated doc is a clean payload error
        let mut short = out.clone();
        short.truncate(out.len() - 3);
        let short_len = (short.len() - REQUEST_HEADER_LEN) as u32;
        short[6..10].copy_from_slice(&short_len.to_le_bytes());
        let e = decode_request(&short[REQUEST_HEADER_LEN..], &pool()).unwrap_err();
        assert!(e.contains("truncated"), "{e}");
    }

    #[test]
    fn reply_frames_round_trip_ok_and_error() {
        // derivative reply
        let reply = Reply::Derivative {
            out: vec![1.5, -2.25, 1e-300],
            out_key: "grad",
            batched: 3,
            cached: true,
            degraded: true,
            mode: "one-step",
        };
        let mut buf = Vec::new();
        encode_reply(&reply, &mut buf);
        let f = read_reply(&mut &buf[..]).unwrap();
        assert_eq!(f.status, STATUS_OK);
        assert!(f.cached);
        assert!(f.degraded);
        assert_eq!(f.mode_byte, MODE_ONE_STEP);
        assert_eq!(mode_str_from_byte(f.mode_byte), "one-step");
        assert_eq!(f.batched, 3);
        assert_eq!((f.rows, f.cols), (3, 1));
        assert_eq!(f.data, vec![1.5, -2.25, 1e-300]);
        assert!(f.error.is_none());
        // error reply
        let mut buf = Vec::new();
        encode_reply(&Reply::Error("missing 'problem'".into()), &mut buf);
        let f = read_reply(&mut &buf[..]).unwrap();
        assert_eq!(f.status, STATUS_ERR);
        assert_eq!(f.error.as_deref(), Some("missing 'problem'"));
        // jacobian reply carries the matrix shape
        let jac = crate::linalg::mat::Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut buf = Vec::new();
        encode_reply(&Reply::Jacobian { jac, cached: false }, &mut buf);
        let f = read_reply(&mut &buf[..]).unwrap();
        assert_eq!((f.rows, f.cols), (2, 2));
        assert_eq!(f.data, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
