//! θ-keyed LRU cache of (x*(θ), factorization of A) per problem.
//!
//! A repeat-θ request skips BOTH the inner solve (x* is stored) and the
//! Krylov iteration (A's Cholesky/LU factor is stored; JVP/VJP become O(d²)
//! substitutions that never touch the solve counter). Keys hash the exact
//! f64 bit patterns of θ — serving is a memoization problem, not a nearest-
//! neighbor one.

use crate::linalg::solve::Factorization;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Exact-θ cache key: problem name + θ bit patterns.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct ThetaKey {
    pub problem: String,
    bits: Vec<u64>,
}

impl ThetaKey {
    pub fn new(problem: &str, theta: &[f64]) -> ThetaKey {
        ThetaKey {
            problem: problem.to_string(),
            bits: theta.iter().map(|t| t.to_bits()).collect(),
        }
    }

    /// Reconstruct θ from the stored bit patterns (exact — the manifest
    /// round trip depends on it).
    pub fn theta(&self) -> Vec<f64> {
        self.bits.iter().map(|&b| f64::from_bits(b)).collect()
    }
}

/// One cached (x*, factorization) pair, shared by reference so readers never
/// copy the factor.
#[derive(Clone)]
pub struct CacheEntry {
    pub x_star: Arc<Vec<f64>>,
    pub fact: Arc<Factorization>,
}

struct CacheInner {
    map: HashMap<ThetaKey, CacheEntry>,
    /// Recency order, most recent last. Capacity is small (tens of θ's), so
    /// the O(len) reshuffle on hit is noise next to an O(d²) substitution.
    order: Vec<ThetaKey>,
}

/// Thread-safe LRU of factorized problems.
pub struct FactorCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl FactorCache {
    pub fn new(capacity: usize) -> FactorCache {
        FactorCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), order: Vec::new() }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up θ; refreshes recency on hit.
    pub fn get(&self, key: &ThetaKey) -> Option<CacheEntry> {
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get(key).cloned() {
            Some(entry) => {
                inner.order.retain(|k| k != key);
                inner.order.push(key.clone());
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used θ
    /// beyond capacity.
    pub fn insert(&self, key: ThetaKey, entry: CacheEntry) {
        let mut inner = self.inner.lock().unwrap();
        inner.order.retain(|k| k != &key);
        inner.order.push(key.clone());
        inner.map.insert(key, entry);
        while inner.map.len() > self.capacity {
            let victim = inner.order.remove(0);
            inner.map.remove(&victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, evictions).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
        )
    }

    /// Every live entry, least-recently-used first — reinserting a snapshot
    /// in order reproduces the recency ranking (manifest persistence).
    pub fn snapshot(&self) -> Vec<(ThetaKey, CacheEntry)> {
        let inner = self.inner.lock().unwrap();
        inner
            .order
            .iter()
            .filter_map(|k| inner.map.get(k).map(|e| (k.clone(), e.clone())))
            .collect()
    }
}

struct RhoInner {
    map: HashMap<ThetaKey, f64>,
    order: Vec<ThetaKey>,
}

/// θ-keyed LRU of contraction estimates ρ(x*, θ) from power iteration.
///
/// `"mode":"auto"` (and depth-free unroll) needs ρ to pick a mode, and the
/// power iteration costs tens of Jacobian products — by far the dominant
/// term once the answer itself is solve-free. Repeat-(problem, θ) requests
/// must pay it once; this cache is keyed exactly like [`FactorCache`] and
/// persists in the same manifest.
pub struct RhoCache {
    inner: Mutex<RhoInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RhoCache {
    pub fn new(capacity: usize) -> RhoCache {
        RhoCache {
            inner: Mutex::new(RhoInner { map: HashMap::new(), order: Vec::new() }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up ρ; refreshes recency on hit.
    pub fn get(&self, key: &ThetaKey) -> Option<f64> {
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get(key).copied() {
            Some(rho) => {
                inner.order.retain(|k| k != key);
                inner.order.push(key.clone());
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(rho)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Look up ρ WITHOUT touching recency or the hit/miss counters. The
    /// admission degrade probe uses this: deciding whether a saturated
    /// `"mode":"auto"` request can be served solve-free must not distort
    /// the ρ-cache statistics the tests (and operators) reason about.
    pub fn peek(&self, key: &ThetaKey) -> Option<f64> {
        self.inner.lock().unwrap().map.get(key).copied()
    }

    pub fn insert(&self, key: ThetaKey, rho: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.order.retain(|k| k != &key);
        inner.order.push(key.clone());
        inner.map.insert(key, rho);
        while inner.map.len() > self.capacity {
            let victim = inner.order.remove(0);
            inner.map.remove(&victim);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Entries least-recently-used first (manifest persistence).
    pub fn snapshot(&self) -> Vec<(ThetaKey, f64)> {
        let inner = self.inner.lock().unwrap();
        inner
            .order
            .iter()
            .filter_map(|k| inner.map.get(k).map(|&rho| (k.clone(), rho)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;

    fn entry(v: f64) -> CacheEntry {
        let fact = Factorization::of_mat(&Mat::eye(2), true).unwrap();
        CacheEntry { x_star: Arc::new(vec![v; 2]), fact: Arc::new(fact) }
    }

    #[test]
    fn lru_evicts_oldest_and_counts() {
        let c = FactorCache::new(2);
        let k1 = ThetaKey::new("ridge", &[1.0]);
        let k2 = ThetaKey::new("ridge", &[2.0]);
        let k3 = ThetaKey::new("ridge", &[3.0]);
        c.insert(k1.clone(), entry(1.0));
        c.insert(k2.clone(), entry(2.0));
        assert!(c.get(&k1).is_some()); // k1 now most recent
        c.insert(k3.clone(), entry(3.0)); // evicts k2
        assert!(c.get(&k2).is_none());
        assert!(c.get(&k1).is_some());
        assert!(c.get(&k3).is_some());
        assert_eq!(c.len(), 2);
        let (h, m, e) = c.stats();
        assert_eq!((h, m, e), (4, 1, 1));
    }

    #[test]
    fn distinct_problems_and_bit_exact_thetas_are_distinct_keys() {
        let c = FactorCache::new(8);
        c.insert(ThetaKey::new("ridge", &[1.0]), entry(1.0));
        assert!(c.get(&ThetaKey::new("svm", &[1.0])).is_none());
        // 1.0 + 1e-16 rounds back to exactly 1.0 in f64 — same bits, a hit.
        assert!(c.get(&ThetaKey::new("ridge", &[1.0 + 1e-16])).is_some());
        // A genuinely different bit pattern misses.
        assert!(c.get(&ThetaKey::new("ridge", &[1.0000000001])).is_none());
        let x = c.get(&ThetaKey::new("ridge", &[1.0])).unwrap();
        assert_eq!(x.x_star[0], 1.0);
    }

    #[test]
    fn theta_key_reconstructs_theta_bit_exactly() {
        let theta = [1.0, -0.0, 2.0 + 1e-9, 5e-324];
        let k = ThetaKey::new("ridge", &theta);
        let back = k.theta();
        assert_eq!(back.len(), theta.len());
        for (a, b) in theta.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn snapshot_preserves_lru_order() {
        let c = FactorCache::new(4);
        c.insert(ThetaKey::new("ridge", &[1.0]), entry(1.0));
        c.insert(ThetaKey::new("ridge", &[2.0]), entry(2.0));
        c.get(&ThetaKey::new("ridge", &[1.0])); // 1.0 becomes most recent
        let snap = c.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0.theta(), vec![2.0]);
        assert_eq!(snap[1].0.theta(), vec![1.0]);
    }

    #[test]
    fn rho_cache_lru_and_counters() {
        let c = RhoCache::new(2);
        let k1 = ThetaKey::new("ridge", &[1.0]);
        let k2 = ThetaKey::new("ridge", &[2.0]);
        let k3 = ThetaKey::new("ridge", &[3.0]);
        assert_eq!(c.get(&k1), None);
        c.insert(k1.clone(), 0.5);
        c.insert(k2.clone(), 0.6);
        assert_eq!(c.get(&k1), Some(0.5)); // k1 now most recent
        c.insert(k3.clone(), 0.7); // evicts k2
        assert_eq!(c.get(&k2), None);
        assert_eq!(c.get(&k3), Some(0.7));
        assert_eq!(c.len(), 2);
        let (h, m) = c.stats();
        assert_eq!((h, m), (2, 2));
        assert_eq!(c.snapshot().len(), 2);
    }
}
