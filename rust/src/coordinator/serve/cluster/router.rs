//! Front-end router: one process, both wires, N shards behind it.
//!
//! `idiff route --shards host:port,host:port,...` speaks the *exact* client
//! protocols the shards speak — the JSON line protocol and the binary frame
//! protocol from `serve::wire`, auto-detected per connection by first byte —
//! so existing clients point at the router unchanged. Each data-plane
//! request is routed by the θ-consistent-hash ring ([`super::ring::Ring`])
//! over the healthy member set and forwarded over a pooled upstream
//! connection; replies are relayed verbatim (binary frames byte-for-byte,
//! JSON lines unmodified), so every error string and float bit pattern a
//! shard produces is exactly what the client sees.
//!
//! Failure handling: every shard sits behind a per-shard circuit breaker.
//! An upstream round-trip failure (after one fresh-connection retry, so a
//! stale pooled socket is not mistaken for a dead shard) counts against
//! the breaker; at `breaker_threshold` consecutive failures the breaker
//! **opens** — the shard's pooled connections are discarded and the ring
//! is rebuilt without it, so in-flight and future keys for its arcs
//! re-hash onto the survivors (served from their replicated warm state,
//! counted in `failovers`). An open breaker is probed by the health
//! thread on a *jittered exponential backoff* (base `health_secs`,
//! doubling per failed probe, capped at a minute): when the probe is due
//! the breaker goes **half-open**, exactly one ping decides — success
//! closes the breaker and folds the shard back into the ring, failure
//! re-opens it with a doubled backoff. All transitions are counted
//! (`breaker_opened` / `breaker_half_open` / `breaker_closed`).
//!
//! Deadlines: a request's budget (`"deadline_ms"` member / binary header
//! field) is decremented by the router's own elapsed time before each
//! relay, so shards always see the *remaining* budget; a budget that runs
//! out at the router is answered `{"error":"deadline_exceeded"}` locally.
//! An upstream error observed *after* the deadline passed does NOT trip
//! the breaker — a shard that is merely slower than one request's budget
//! is not dead.
//!
//! Control plane: `ping` answers locally, `stats` aggregates router
//! counters plus every healthy shard's stats, `problems` forwards like
//! any routed request (the catalog is identical cluster-wide — shards
//! publish a catalog fingerprint in `stats`).
//!
//! The router is stateless (no caches, no manifest): on SIGTERM/SIGINT it
//! stops admitting, drains in-flight requests (bounded by `drain_secs`),
//! and exits.

use super::super::{wire, Reply};
use super::actor::Mailbox;
use super::admit::{Admission, DEADLINE_EXCEEDED, OVERLOADED};
use super::faults;
use super::ring::{Ring, DEFAULT_VNODES};
use crate::util::json::{self, Json};
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use crate::util::signal;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Router knobs. Defaults mirror the shard server's posture: generous
/// bounds, nothing rejected until a limit is configured or a queue fills.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Upstream shard addresses (`host:port`). Ring member i = shards[i].
    pub shards: Vec<String>,
    /// Connection-actor threads.
    pub workers: usize,
    /// Bounded accept-queue depth; overflow is shed with `overloaded`.
    pub accept_queue: usize,
    /// Max concurrently forwarded requests (0 = unbounded).
    pub max_inflight: usize,
    /// Seconds between shard health pings.
    pub health_secs: u64,
    /// Virtual nodes per shard on the ring (must match nothing — the ring
    /// is router-local — but keep the default unless experimenting).
    pub vnodes: usize,
    /// Reject client JSON lines longer than this.
    pub max_line_bytes: usize,
    /// Close idle client connections after this long.
    pub idle_timeout: Duration,
    /// Upstream I/O timeout per forwarded request (covers a cold solve).
    pub upstream_timeout: Duration,
    /// Idle upstream connections kept pooled per shard per wire.
    pub upstream_idle: usize,
    /// Graceful-shutdown drain bound.
    pub drain_secs: u64,
    /// Upstream TCP connect timeout (`--connect-ms`).
    pub connect_timeout: Duration,
    /// Health-probe read timeout (`--probe-ms`).
    pub probe_timeout: Duration,
    /// Consecutive upstream failures that open a shard's circuit breaker
    /// (`--breaker-threshold`). The default of 1 keeps the pre-breaker
    /// behavior: the first failure fails over immediately.
    pub breaker_threshold: u32,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            shards: Vec::new(),
            workers: crate::util::parallel::default_workers(),
            accept_queue: 1024,
            max_inflight: 0,
            health_secs: 2,
            vnodes: DEFAULT_VNODES,
            max_line_bytes: 1 << 20,
            idle_timeout: Duration::from_secs(30),
            upstream_timeout: Duration::from_secs(30),
            upstream_idle: 16,
            drain_secs: 10,
            connect_timeout: Duration::from_millis(1500),
            probe_timeout: Duration::from_millis(2000),
            breaker_threshold: 1,
        }
    }
}

/// Monotonic router counters (reported by the `stats` op).
#[derive(Default)]
pub struct RouterStats {
    pub forwarded: AtomicU64,
    pub failovers: AtomicU64,
    pub health_transitions: AtomicU64,
    /// Requests answered `deadline_exceeded` at the router (budget ran out
    /// before or during the relay).
    pub deadline_exceeded: AtomicU64,
    pub breaker_opened: AtomicU64,
    pub breaker_half_open: AtomicU64,
    pub breaker_closed: AtomicU64,
}

/// Circuit-breaker state machine guarding one shard.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BreakerState {
    /// Serving: in the ring, failures counted against the threshold.
    Closed,
    /// Tripped: out of the ring, waiting out a jittered backoff.
    Open,
    /// Probation: exactly one health probe decides close vs re-open.
    HalfOpen,
}

impl BreakerState {
    fn as_str(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

struct Breaker {
    state: BreakerState,
    consecutive_failures: u32,
    /// When an open breaker's next half-open probe is due.
    next_probe_at: Instant,
    /// Current backoff (doubles per failed probe, capped).
    backoff: Duration,
}

/// Open-breaker probe backoff never exceeds this.
const BACKOFF_CAP: Duration = Duration::from_secs(60);

struct ShardHandle {
    addr: String,
    breaker: Mutex<Breaker>,
    json_conns: Mutex<Vec<TcpStream>>,
    bin_conns: Mutex<Vec<TcpStream>>,
}

impl ShardHandle {
    fn new(addr: String) -> ShardHandle {
        ShardHandle {
            addr,
            breaker: Mutex::new(Breaker {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                next_probe_at: Instant::now(),
                backoff: Duration::from_secs(1),
            }),
            json_conns: Mutex::new(Vec::new()),
            bin_conns: Mutex::new(Vec::new()),
        }
    }
}

pub struct Router {
    shards: Vec<ShardHandle>,
    /// Ring over the currently-healthy shard indices; rebuilt on every
    /// health transition.
    ring: RwLock<Ring>,
    pool: Arc<Pool>,
    pub admission: Admission,
    pub stats: RouterStats,
    restarts: Arc<AtomicU64>,
    give_ups: Arc<AtomicU64>,
    draining: AtomicBool,
    /// Monotone nonce folded into each backoff-jitter seed so repeated
    /// openings of the same breaker never reuse a jitter stream (no
    /// wall-clock seeding — the RNG stays deterministic per process run).
    jitter_nonce: AtomicU64,
    cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        assert!(!cfg.shards.is_empty(), "router needs at least one shard");
        let shards: Vec<ShardHandle> =
            cfg.shards.iter().map(|a| ShardHandle::new(a.clone())).collect();
        let members: Vec<u32> = (0..shards.len() as u32).collect();
        Router {
            ring: RwLock::new(Ring::new(&members, cfg.vnodes)),
            shards,
            pool: Pool::new(64),
            admission: Admission::new(cfg.max_inflight, 0),
            stats: RouterStats::default(),
            restarts: Arc::new(AtomicU64::new(0)),
            give_ups: Arc::new(AtomicU64::new(0)),
            draining: AtomicBool::new(false),
            jitter_nonce: AtomicU64::new(0),
            cfg,
        }
    }

    pub fn shard_addrs(&self) -> Vec<&str> {
        self.shards.iter().map(|s| s.addr.as_str()).collect()
    }

    fn breaker_state(&self, idx: usize) -> BreakerState {
        self.shards[idx].breaker.lock().unwrap().state
    }

    fn healthy_count(&self) -> usize {
        (0..self.shards.len()).filter(|&i| self.breaker_state(i) == BreakerState::Closed).count()
    }

    /// Ring over the shards whose breakers are closed. Half-open shards
    /// stay out: exactly one health probe — not client traffic — decides
    /// whether they come back.
    fn rebuild_ring(&self) {
        let members: Vec<u32> = (0..self.shards.len())
            .filter(|&i| self.breaker_state(i) == BreakerState::Closed)
            .map(|i| i as u32)
            .collect();
        *self.ring.write().unwrap() = Ring::new(&members, self.cfg.vnodes);
    }

    /// Jittered backoff: `base` plus up to 50% extra, so a fleet of
    /// routers probing the same dead shard does not thunder in sync.
    fn jittered(&self, idx: usize, base: Duration) -> Duration {
        let nonce = self.jitter_nonce.fetch_add(1, Ordering::Relaxed);
        let mut rng = Rng::new(0x6a69_7474_6572 ^ ((idx as u64) << 32) ^ nonce);
        base + Duration::from_millis((base.as_millis() as f64 * 0.5 * rng.uniform()) as u64)
    }

    /// One upstream failure against shard `idx`'s breaker. Closed trips to
    /// open at the threshold; a failed half-open probe re-opens with a
    /// doubled backoff. Opening discards the shard's pooled connections
    /// and rebuilds the ring without it.
    fn record_failure(&self, idx: usize) {
        let mut opened = false;
        {
            let mut b = self.shards[idx].breaker.lock().unwrap();
            b.consecutive_failures += 1;
            match b.state {
                BreakerState::Closed => {
                    if b.consecutive_failures >= self.cfg.breaker_threshold.max(1) {
                        b.state = BreakerState::Open;
                        b.backoff = Duration::from_secs(self.cfg.health_secs.max(1));
                        let wait = self.jittered(idx, b.backoff);
                        b.next_probe_at = Instant::now() + wait;
                        opened = true;
                    }
                }
                BreakerState::HalfOpen => {
                    b.state = BreakerState::Open;
                    b.backoff = (b.backoff * 2).min(BACKOFF_CAP);
                    let wait = self.jittered(idx, b.backoff);
                    b.next_probe_at = Instant::now() + wait;
                    opened = true;
                }
                BreakerState::Open => {}
            }
        }
        if opened {
            self.stats.breaker_opened.fetch_add(1, Ordering::Relaxed);
            self.stats.health_transitions.fetch_add(1, Ordering::Relaxed);
            // Dead shard: its pooled connections are garbage.
            self.shards[idx].json_conns.lock().unwrap().clear();
            self.shards[idx].bin_conns.lock().unwrap().clear();
            self.rebuild_ring();
        }
    }

    /// One successful round trip / probe against shard `idx`'s breaker:
    /// resets the failure count; a non-closed breaker closes and the shard
    /// folds back into the ring.
    fn record_success(&self, idx: usize) {
        let closed = {
            let mut b = self.shards[idx].breaker.lock().unwrap();
            b.consecutive_failures = 0;
            if b.state != BreakerState::Closed {
                b.state = BreakerState::Closed;
                true
            } else {
                false
            }
        };
        if closed {
            self.stats.breaker_closed.fetch_add(1, Ordering::Relaxed);
            self.stats.health_transitions.fetch_add(1, Ordering::Relaxed);
            self.rebuild_ring();
        }
    }

    fn route(&self, problem: &str, theta: &[f64]) -> Option<usize> {
        self.ring.read().unwrap().shard_for(problem, theta).map(|m| m as usize)
    }

    // ----------------------------------------------------- upstream I/O --

    fn connect(&self, idx: usize) -> std::io::Result<TcpStream> {
        let addr = &self.shards[idx].addr;
        let sock = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::NotFound, "bad shard addr"))?;
        let conn = TcpStream::connect_timeout(&sock, self.cfg.connect_timeout)?;
        conn.set_read_timeout(Some(self.cfg.upstream_timeout))?;
        conn.set_write_timeout(Some(self.cfg.upstream_timeout))?;
        conn.set_nodelay(true)?;
        Ok(conn)
    }

    /// Per-attempt upstream read timeout: the configured ceiling, shrunk to
    /// the request's remaining deadline budget so a past-due relay fails
    /// fast instead of waiting out the full upstream timeout.
    fn attempt_timeout(&self, deadline: Option<Instant>) -> Duration {
        match deadline {
            None => self.cfg.upstream_timeout,
            Some(d) => d
                .saturating_duration_since(Instant::now())
                .min(self.cfg.upstream_timeout)
                .max(Duration::from_millis(1)),
        }
    }

    fn checkin(&self, conns: &Mutex<Vec<TcpStream>>, conn: TcpStream) {
        let mut free = conns.lock().unwrap();
        if free.len() < self.cfg.upstream_idle {
            free.push(conn);
        }
    }

    /// One JSON round trip on `conn`; the reply line comes back without its
    /// trailing newline. A reply with NO trailing newline is a shard that
    /// died mid-line — that partial frame must count as an upstream failure
    /// (and fail over), never be relayed to the client as if complete.
    fn json_round_trip(conn: &mut TcpStream, line: &str) -> std::io::Result<String> {
        conn.write_all(line.as_bytes())?;
        conn.write_all(b"\n")?;
        let mut resp = String::new();
        let mut reader = BufReader::new(conn);
        if reader.read_line(&mut resp)? == 0 || !resp.ends_with('\n') {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "shard closed mid-reply",
            ));
        }
        while resp.ends_with('\n') || resp.ends_with('\r') {
            resp.pop();
        }
        Ok(resp)
    }

    /// Forward one JSON line to shard `idx`, reusing a pooled upstream
    /// connection when one is alive. A stale pooled socket gets ONE fresh
    /// retry before the failure counts against the shard.
    fn forward_json(
        &self,
        idx: usize,
        line: &str,
        deadline: Option<Instant>,
    ) -> std::io::Result<String> {
        let timeout = self.attempt_timeout(deadline);
        if let Some(mut conn) = self.shards[idx].json_conns.lock().unwrap().pop() {
            let _ = conn.set_read_timeout(Some(timeout));
            if let Ok(resp) = Self::json_round_trip(&mut conn, line) {
                self.checkin(&self.shards[idx].json_conns, conn);
                return Ok(resp);
            }
            // fall through: pooled conn was stale — retry fresh below
        }
        let mut conn = self.connect(idx)?;
        conn.set_read_timeout(Some(timeout))?;
        let resp = Self::json_round_trip(&mut conn, line)?;
        self.checkin(&self.shards[idx].json_conns, conn);
        Ok(resp)
    }

    /// One binary round trip: write the raw request frame, read the raw
    /// reply frame (header + payload) into `out` verbatim.
    fn binary_round_trip(
        conn: &mut TcpStream,
        frame: &[u8],
        out: &mut Vec<u8>,
    ) -> std::io::Result<()> {
        conn.write_all(frame)?;
        let mut hdr = [0u8; wire::REPLY_HEADER_LEN];
        conn.read_exact(&mut hdr)?;
        if hdr[0] != wire::MAGIC || hdr[1] != wire::VERSION {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad upstream reply header",
            ));
        }
        let len = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
        out.clear();
        out.extend_from_slice(&hdr);
        out.resize(wire::REPLY_HEADER_LEN + len, 0);
        conn.read_exact(&mut out[wire::REPLY_HEADER_LEN..])?;
        Ok(())
    }

    /// Forward one raw binary request frame to shard `idx`; the raw reply
    /// frame lands in `out`. Same stale-socket retry policy as JSON.
    fn forward_binary(
        &self,
        idx: usize,
        frame: &[u8],
        out: &mut Vec<u8>,
        deadline: Option<Instant>,
    ) -> std::io::Result<()> {
        let timeout = self.attempt_timeout(deadline);
        if let Some(mut conn) = self.shards[idx].bin_conns.lock().unwrap().pop() {
            let _ = conn.set_read_timeout(Some(timeout));
            if Self::binary_round_trip(&mut conn, frame, out).is_ok() {
                self.checkin(&self.shards[idx].bin_conns, conn);
                return Ok(());
            }
        }
        let mut conn = self.connect(idx)?;
        conn.set_read_timeout(Some(timeout))?;
        Self::binary_round_trip(&mut conn, frame, out)?;
        self.checkin(&self.shards[idx].bin_conns, conn);
        Ok(())
    }

    /// Route + forward with failover: every upstream failure counts
    /// against the shard's breaker; an opened breaker rebuilds the ring
    /// and the request re-hashes onto the survivors (served from their
    /// replicated warm state, counted in `failovers`). Bounded by the
    /// shard count. A failure observed after the request's deadline
    /// passed is answered `deadline_exceeded` WITHOUT tripping the
    /// breaker — slow is not dead.
    fn forward_routed<T>(
        &self,
        problem: &str,
        theta: &[f64],
        deadline: Option<Instant>,
        mut attempt: impl FnMut(&Self, usize) -> std::io::Result<T>,
    ) -> Result<T, String> {
        for tries in 0..self.shards.len().max(1) {
            if expired(deadline) {
                self.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                return Err(DEADLINE_EXCEEDED.to_string());
            }
            let Some(idx) = self.route(problem, theta) else { break };
            // Fault site: an injected forward fault counts like a real
            // upstream failure and exercises this exact failover path.
            if faults::at(faults::SITE_ROUTER_FORWARD).is_some() {
                self.record_failure(idx);
                continue;
            }
            match attempt(self, idx) {
                Ok(t) => {
                    self.record_success(idx);
                    self.stats.forwarded.fetch_add(1, Ordering::Relaxed);
                    if tries > 0 {
                        self.stats.failovers.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(t);
                }
                Err(_) => {
                    if expired(deadline) {
                        self.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                        return Err(DEADLINE_EXCEEDED.to_string());
                    }
                    self.record_failure(idx);
                }
            }
        }
        Err("no healthy shards".to_string())
    }

    // --------------------------------------------------- control plane --

    /// Aggregate stats: router counters plus each healthy shard's own
    /// `stats` reply (fetched over the binary wire). Both client wires
    /// serve THIS object, so the values are identical by construction.
    fn aggregate_stats(&self) -> Json {
        let mut rows = Vec::with_capacity(self.shards.len());
        let mut req = Vec::new();
        wire::encode_request(&wire::RequestFrame::control(wire::OP_STATS), &mut req);
        for (i, s) in self.shards.iter().enumerate() {
            let state = self.breaker_state(i);
            let healthy = state == BreakerState::Closed;
            let stats = if healthy {
                let mut raw = Vec::new();
                self.forward_binary(i, &req, &mut raw, None)
                    .ok()
                    .and_then(|_| wire::read_reply(&mut &raw[..]).ok())
                    .and_then(|f| json::parse(&f.text).ok())
            } else {
                None
            };
            rows.push(Json::obj(vec![
                ("addr", Json::Str(s.addr.clone())),
                ("healthy", Json::Bool(healthy)),
                ("state", Json::Str(state.as_str().to_string())),
                ("stats", stats.unwrap_or(Json::Null)),
            ]));
        }
        Json::obj(vec![
            ("router", Json::Bool(true)),
            ("shards_total", Json::Num(self.shards.len() as f64)),
            ("shards_healthy", Json::Num(self.healthy_count() as f64)),
            ("ring_size", Json::Num(self.healthy_count() as f64)),
            ("forwarded", Json::Num(self.stats.forwarded.load(Ordering::Relaxed) as f64)),
            ("failovers", Json::Num(self.stats.failovers.load(Ordering::Relaxed) as f64)),
            (
                "health_transitions",
                Json::Num(self.stats.health_transitions.load(Ordering::Relaxed) as f64),
            ),
            (
                "deadline_exceeded",
                Json::Num(self.stats.deadline_exceeded.load(Ordering::Relaxed) as f64),
            ),
            (
                "breaker_opened",
                Json::Num(self.stats.breaker_opened.load(Ordering::Relaxed) as f64),
            ),
            (
                "breaker_half_open",
                Json::Num(self.stats.breaker_half_open.load(Ordering::Relaxed) as f64),
            ),
            (
                "breaker_closed",
                Json::Num(self.stats.breaker_closed.load(Ordering::Relaxed) as f64),
            ),
            ("rejected", Json::Num(self.admission.rejected() as f64)),
            ("inflight", Json::Num(self.admission.inflight() as f64)),
            ("queue_depth", Json::Num(self.admission.queue_depth() as f64)),
            ("actor_restarts", Json::Num(self.restarts.load(Ordering::Relaxed) as f64)),
            ("actor_give_ups", Json::Num(self.give_ups.load(Ordering::Relaxed) as f64)),
            ("shards", Json::Arr(rows)),
        ])
    }

    fn spawn_health_thread(self: &Arc<Self>) {
        let me = self.clone();
        let period = Duration::from_secs(self.cfg.health_secs.max(1));
        std::thread::Builder::new()
            .name("route-health".to_string())
            .spawn(move || {
                let mut ping = Vec::new();
                wire::encode_request(&wire::RequestFrame::control(wire::OP_PING), &mut ping);
                loop {
                    std::thread::sleep(period);
                    me.health_pass(&ping);
                }
            })
            .expect("spawn health thread");
    }

    /// One health sweep. Closed shards get a liveness ping whose failures
    /// count toward the breaker threshold like request failures do. An
    /// open shard whose backoff has elapsed moves to half-open, and a
    /// single probe decides: success closes the breaker, failure re-opens
    /// it with a doubled (jittered) backoff.
    fn health_pass(&self, ping_frame: &[u8]) {
        for i in 0..self.shards.len() {
            let probe = {
                let mut b = self.shards[i].breaker.lock().unwrap();
                match b.state {
                    BreakerState::Closed => true,
                    BreakerState::Open | BreakerState::HalfOpen => {
                        if Instant::now() >= b.next_probe_at {
                            if b.state == BreakerState::Open {
                                b.state = BreakerState::HalfOpen;
                                self.stats.breaker_half_open.fetch_add(1, Ordering::Relaxed);
                            }
                            true
                        } else {
                            false
                        }
                    }
                }
            };
            if !probe {
                continue;
            }
            if self.ping_shard(i, ping_frame) {
                self.record_success(i);
            } else {
                self.record_failure(i);
            }
        }
    }

    fn ping_shard(&self, idx: usize, ping_frame: &[u8]) -> bool {
        let ok = (|| -> std::io::Result<bool> {
            let mut conn = self.connect(idx)?;
            conn.set_read_timeout(Some(self.cfg.probe_timeout))?;
            conn.write_all(ping_frame)?;
            let reply = wire::read_reply(&mut conn)?;
            Ok(reply.status == wire::STATUS_OK)
        })();
        ok.unwrap_or(false)
    }

    fn spawn_drain_watcher(self: &Arc<Self>) {
        signal::install();
        let me = self.clone();
        std::thread::Builder::new()
            .name("route-drain".to_string())
            .spawn(move || loop {
                std::thread::sleep(Duration::from_millis(50));
                if signal::requested() {
                    me.draining.store(true, Ordering::SeqCst);
                    let deadline = Instant::now() + Duration::from_secs(me.cfg.drain_secs);
                    while me.admission.inflight() > 0 && Instant::now() < deadline {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    println!("idiff route: drained {} shards, exiting", me.shards.len());
                    std::process::exit(0);
                }
            })
            .expect("spawn drain watcher");
    }

    // ----------------------------------------------------- client side --

    /// Answer one JSON request line (no trailing newline on the result).
    pub fn handle_json_line(&self, line: &str) -> String {
        let arrival = Instant::now();
        if line.len() > self.cfg.max_line_bytes {
            let e = format!(
                "request too large ({} bytes > {} max)",
                line.len(),
                self.cfg.max_line_bytes
            );
            return Json::obj(vec![("error", Json::Str(e))]).to_string_compact();
        }
        // Routing peek: op + problem + θ. A line we cannot parse still gets
        // forwarded (to a deterministic shard) so the client receives the
        // engine's canonical error string, not a router-flavored one.
        let parsed = json::parse(line).ok();
        let op = parsed.as_ref().map(|j| j.str_or("op", "").to_string()).unwrap_or_default();
        match op.as_str() {
            "ping" => return Json::obj(vec![("ok", Json::Bool(true))]).to_string_compact(),
            "stats" => return self.aggregate_stats().to_string_compact(),
            _ => {}
        }
        if self.draining.load(Ordering::Relaxed) {
            self.admission.note_rejected();
            return overloaded_json();
        }
        let Some(_slot) = self.admission.admit() else {
            self.admission.note_rejected();
            return overloaded_json();
        };
        // Deadline budget: start the clock at arrival, relay the REMAINING
        // budget so the shard's own enforcement accounts for router time.
        // A malformed member forwards verbatim — the shard answers with
        // the engine's canonical validation error.
        let deadline = parsed
            .as_ref()
            .and_then(|j| j.get("deadline_ms"))
            .and_then(Json::as_f64)
            .filter(|ms| ms.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(ms))
            .and_then(|ms| (ms > 0.0).then(|| arrival + Duration::from_millis(ms as u64)));
        let (problem, theta) = route_identity_json(parsed.as_ref(), &op);
        let rewritten;
        let relay: &str = match (deadline, parsed) {
            (Some(d), Some(mut j)) => {
                let rem = remaining_ms(d);
                if rem == 0 {
                    self.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                    return deadline_json();
                }
                if let Json::Obj(map) = &mut j {
                    map.insert("deadline_ms".to_string(), Json::Num(rem as f64));
                }
                rewritten = j.to_string_compact();
                &rewritten
            }
            _ => line,
        };
        match self.forward_routed(&problem, &theta, deadline, |me, idx| {
            me.forward_json(idx, relay, deadline)
        }) {
            Ok(resp) => resp,
            Err(e) => Json::obj(vec![("error", Json::Str(e))]).to_string_compact(),
        }
    }

    /// Answer one binary request frame (raw header+payload in, raw reply
    /// frame appended to `out`). `deadline_ms` is the header's budget (0 =
    /// none); the clock started at `arrival`.
    fn handle_frame(
        &self,
        hdr: &[u8; wire::REQUEST_HEADER_LEN],
        payload: &[u8],
        deadline_ms: u32,
        arrival: Instant,
        out: &mut Vec<u8>,
    ) {
        out.clear();
        let req = match wire::decode_request(payload, &self.pool) {
            Ok(r) => r,
            Err(e) => {
                // A shard would answer this payload error identically —
                // encode_reply is shared code — so answer locally.
                wire::encode_reply(&Reply::Error(e), out);
                return;
            }
        };
        use super::super::Request;
        let (problem, theta): (String, Vec<f64>) = match &req {
            Request::Ping => {
                wire::encode_reply(&Reply::Pong, out);
                return;
            }
            Request::Stats => {
                wire::encode_reply(&Reply::Text(self.aggregate_stats()), out);
                return;
            }
            Request::Replicate { .. } => {
                // Replication is shard-to-shard; a replica delta has no θ
                // identity to route by and must never transit the router.
                wire::encode_reply(
                    &Reply::Error("replicate frames are shard-to-shard only".to_string()),
                    out,
                );
                return;
            }
            Request::Problems => (String::new(), Vec::new()),
            Request::Solve { problem, theta } | Request::Jacobian { problem, theta } => {
                (problem.clone(), theta.to_vec())
            }
            Request::Derivative { problem, theta, .. } => (problem.clone(), theta.to_vec()),
        };
        if self.draining.load(Ordering::Relaxed) {
            self.admission.note_rejected();
            wire::encode_reply(&Reply::Error(OVERLOADED.to_string()), out);
            return;
        }
        let Some(_slot) = self.admission.admit() else {
            self.admission.note_rejected();
            wire::encode_reply(&Reply::Error(OVERLOADED.to_string()), out);
            return;
        };
        let deadline = (deadline_ms > 0)
            .then(|| arrival + Duration::from_millis(deadline_ms as u64));
        // Rebuild the full raw request frame for verbatim forwarding.
        let mut frame = Vec::with_capacity(hdr.len() + payload.len());
        frame.extend_from_slice(hdr);
        frame.extend_from_slice(payload);
        let mut relayed = Vec::new();
        let res = self.forward_routed(&problem, &theta, deadline, |me, idx| {
            // Patch the header's budget to what is REMAINING before this
            // attempt (never 0 — on the wire, 0 means "no deadline"; a
            // spent budget is caught by forward_routed's expiry gate).
            if let Some(d) = deadline {
                let rem = remaining_ms(d).max(1);
                frame[wire::REQUEST_DEADLINE_OFFSET..wire::REQUEST_DEADLINE_OFFSET + 4]
                    .copy_from_slice(&rem.to_le_bytes());
            }
            me.forward_binary(idx, &frame, &mut relayed, deadline)
        });
        match res {
            Ok(()) => out.extend_from_slice(&relayed),
            Err(e) => wire::encode_reply(&Reply::Error(e), out),
        }
    }

    // ----------------------------------------------------------- serve --

    /// Serve client connections from an already-bound listener through the
    /// supervised actor group. Blocks forever.
    pub fn serve_on(self: Arc<Self>, listener: TcpListener) -> std::io::Result<()> {
        self.spawn_health_thread();
        self.spawn_drain_watcher();
        let mailbox: Arc<Mailbox<TcpStream>> = Mailbox::new(self.cfg.accept_queue);
        let me = self.clone();
        let handler: Arc<dyn Fn(TcpStream) + Send + Sync> = Arc::new(move |stream| {
            me.admission.conn_dequeued();
            let _ = handle_client_conn(&me, stream);
        });
        let _sup = super::actor::supervise(
            "route-conn",
            self.cfg.workers,
            mailbox.clone(),
            handler,
            self.restarts.clone(),
            self.give_ups.clone(),
        );
        for stream in listener.incoming() {
            let stream = stream?;
            self.admission.conn_enqueued();
            if let Err(e) = mailbox.try_send(stream) {
                self.admission.conn_dequeued();
                self.admission.note_rejected();
                shed_connection(e.into_inner());
            }
        }
        Ok(())
    }

    /// Bind `addr` (report the actual bound address — `:0` picks a free
    /// port) and serve.
    pub fn serve(self: Arc<Self>, addr: &str) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        println!(
            "idiff route: listening on {local} ({} shards: {})",
            self.shards.len(),
            self.shard_addrs().join(", ")
        );
        self.serve_on(listener)
    }
}

fn overloaded_json() -> String {
    Json::obj(vec![("error", Json::Str(OVERLOADED.to_string()))]).to_string_compact()
}

fn deadline_json() -> String {
    Json::obj(vec![("error", Json::Str(DEADLINE_EXCEEDED.to_string()))]).to_string_compact()
}

fn expired(deadline: Option<Instant>) -> bool {
    deadline.map_or(false, |d| Instant::now() >= d)
}

/// Whole milliseconds left until `deadline` (0 = already passed).
fn remaining_ms(deadline: Instant) -> u32 {
    deadline
        .saturating_duration_since(Instant::now())
        .as_millis()
        .min(u32::MAX as u128) as u32
}

/// Best-effort reject for a connection shed at the accept queue, before the
/// wire is even known: a JSON error line (binary clients see a framing
/// error and close — still a clean, prompt reject, never a hang).
fn shed_connection(mut stream: TcpStream) {
    let _ = stream.write_all(overloaded_json().as_bytes());
    let _ = stream.write_all(b"\n");
}

/// Routing identity of a parsed JSON request: problem name (with the
/// legacy `ridge_*` aliases folded in) + θ. Unroutable requests map to the
/// empty identity, which the ring still assigns deterministically.
fn route_identity_json(parsed: Option<&Json>, op: &str) -> (String, Vec<f64>) {
    let Some(req) = parsed else { return (String::new(), Vec::new()) };
    let problem = if op.starts_with("ridge_") {
        "ridge".to_string()
    } else {
        req.str_or("problem", "").to_string()
    };
    let theta: Vec<f64> = req
        .get("theta")
        .and_then(Json::as_arr)
        .map(|a| a.iter().map(|x| x.as_f64().unwrap_or(0.0)).collect())
        .unwrap_or_default();
    (problem, theta)
}

fn handle_client_conn(router: &Arc<Router>, stream: TcpStream) -> std::io::Result<()> {
    let _ = stream.set_read_timeout(Some(router.cfg.idle_timeout));
    let _ = stream.set_nodelay(true);
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let first = match reader.fill_buf() {
        Ok([]) => return Ok(()),
        Ok(buf) => buf[0],
        Err(e) if super::super::is_disconnect(&e) => return Ok(()),
        Err(e) => return Err(e),
    };
    if first == wire::MAGIC {
        route_binary_conn(router, reader, writer)
    } else {
        route_json_conn(router, reader, writer)
    }
}

fn route_json_conn(
    router: &Arc<Router>,
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
) -> std::io::Result<()> {
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            Err(e) if super::super::is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let resp = router.handle_json_line(trimmed);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

fn route_binary_conn(
    router: &Arc<Router>,
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
) -> std::io::Result<()> {
    let mut payload = router.pool.take_bytes(4096);
    let mut out = router.pool.take_bytes(4096);
    loop {
        let mut hdr = [0u8; wire::REQUEST_HEADER_LEN];
        match reader.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if super::super::is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        let arrival = Instant::now();
        let (len, deadline_ms) = match wire::parse_request_header(&hdr, router.cfg.max_line_bytes)
        {
            Ok(parsed) => parsed,
            Err(msg) => {
                // Framing violation: same policy as a shard — error
                // frame, then close.
                out.clear();
                wire::encode_reply(&Reply::Error(msg), &mut out);
                let _ = writer.write_all(&out);
                return Ok(());
            }
        };
        payload.resize(len, 0);
        match reader.read_exact(&mut payload[..]) {
            Ok(()) => {}
            Err(e) if super::super::is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        router.handle_frame(&hdr, &payload, deadline_ms, arrival, &mut out);
        writer.write_all(&out)?;
    }
}
