//! Admission control and backpressure for a serving shard (or router).
//!
//! Three bounded resources, each with a lock-free gauge:
//!
//! * **accept queue** — the connection mailbox (`actor::Mailbox`) is
//!   bounded by construction; its depth is mirrored here so the `stats`
//!   op can report it without reaching into the mailbox.
//! * **inflight requests** — data-plane requests (solve / derivative /
//!   jacobian) currently executing. `admit()` hands out an RAII gauge
//!   guard or refuses; control-plane ops (ping/problems/stats) are never
//!   refused — health checks must keep working under overload.
//! * **solve slots** — requests queued for the implicit path's block
//!   solve + factorization (`solve_slot()`). This is the expensive,
//!   latency-heavy queue; when it saturates the server becomes
//!   *mode-aware*: `"mode":"implicit"` requests are rejected with
//!   `{"error":"overloaded"}`, while `"mode":"auto"` requests with a
//!   cached contraction ρ degrade to the solve-free one-step/Neumann
//!   answer instead of queueing (counted in `degraded_one_step`).
//!
//! All limits are runtime-adjustable atomics (`set_max_*`) so tests and
//! operators can tighten them on a live server; `0` means unbounded,
//! which is the default — a standalone `idiff serve` behaves exactly as
//! before unless limits are configured.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

fn limit_of(raw: usize) -> usize {
    if raw == 0 {
        usize::MAX
    } else {
        raw
    }
}

/// RAII inflight-gauge guard: decrements on drop.
pub struct Slot<'a> {
    gauge: &'a AtomicUsize,
}

impl Drop for Slot<'_> {
    fn drop(&mut self) {
        self.gauge.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Shared admission state for one process.
pub struct Admission {
    max_inflight: AtomicUsize,
    max_solve_inflight: AtomicUsize,
    inflight: AtomicUsize,
    solve_inflight: AtomicUsize,
    queue_depth: AtomicUsize,
    rejected: AtomicU64,
    degraded_one_step: AtomicU64,
}

impl Admission {
    /// `0` for either limit means unbounded.
    pub fn new(max_inflight: usize, max_solve_inflight: usize) -> Admission {
        Admission {
            max_inflight: AtomicUsize::new(max_inflight),
            max_solve_inflight: AtomicUsize::new(max_solve_inflight),
            inflight: AtomicUsize::new(0),
            solve_inflight: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
            degraded_one_step: AtomicU64::new(0),
        }
    }

    fn acquire<'a>(&self, gauge: &'a AtomicUsize, max: usize) -> Option<Slot<'a>> {
        let prev = gauge.fetch_add(1, Ordering::Relaxed);
        if prev >= max {
            gauge.fetch_sub(1, Ordering::Relaxed);
            return None;
        }
        Some(Slot { gauge })
    }

    /// Admit one data-plane request, or refuse (caller replies
    /// `{"error":"overloaded"}` and counts `note_rejected`).
    pub fn admit(&self) -> Option<Slot<'_>> {
        let max = limit_of(self.max_inflight.load(Ordering::Relaxed));
        self.acquire(&self.inflight, max)
    }

    /// Claim a slot on the implicit-path solve queue.
    pub fn solve_slot(&self) -> Option<Slot<'_>> {
        let max = limit_of(self.max_solve_inflight.load(Ordering::Relaxed));
        self.acquire(&self.solve_inflight, max)
    }

    /// True when every solve slot is taken — the mode-aware degrade
    /// trigger. Always false when the limit is unbounded.
    pub fn solve_saturated(&self) -> bool {
        let max = limit_of(self.max_solve_inflight.load(Ordering::Relaxed));
        self.solve_inflight.load(Ordering::Relaxed) >= max
    }

    pub fn set_max_inflight(&self, n: usize) {
        self.max_inflight.store(n, Ordering::Relaxed);
    }

    pub fn set_max_solve_inflight(&self, n: usize) {
        self.max_solve_inflight.store(n, Ordering::Relaxed);
    }

    /// Accept-queue depth mirror, maintained by the accept loop.
    pub fn conn_enqueued(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_dequeued(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_degraded(&self) {
        self.degraded_one_step.fetch_add(1, Ordering::Relaxed);
    }

    // ---- gauges / counters for the stats op --------------------------
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn solve_inflight(&self) -> usize {
        self.solve_inflight.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn degraded_one_step(&self) -> u64 {
        self.degraded_one_step.load(Ordering::Relaxed)
    }
}

/// The canonical overload reject, identical on both wires: the JSON wire
/// sends `{"error":"overloaded"}`, the binary wire an error frame whose
/// message is this string.
pub const OVERLOADED: &str = "overloaded";

/// The canonical deadline reject, identical on both wires: a request whose
/// deadline budget ran out — on arrival or at the solve-lane gate — is
/// answered with this typed error instead of queueing past-due work.
pub const DEADLINE_EXCEEDED: &str = "deadline_exceeded";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_limits_are_unbounded() {
        let a = Admission::new(0, 0);
        let slots: Vec<_> = (0..1000).map(|_| a.admit().expect("unbounded")).collect();
        assert_eq!(a.inflight(), 1000);
        assert!(!a.solve_saturated());
        drop(slots);
        assert_eq!(a.inflight(), 0);
    }

    #[test]
    fn inflight_limit_refuses_and_releases() {
        let a = Admission::new(2, 0);
        let s1 = a.admit().unwrap();
        let _s2 = a.admit().unwrap();
        assert!(a.admit().is_none());
        drop(s1);
        assert!(a.admit().is_some());
    }

    #[test]
    fn solve_saturation_tracks_slots() {
        let a = Admission::new(0, 1);
        assert!(!a.solve_saturated());
        let slot = a.solve_slot().unwrap();
        assert!(a.solve_saturated());
        assert!(a.solve_slot().is_none());
        drop(slot);
        assert!(!a.solve_saturated());
        // Limits are live-adjustable.
        a.set_max_solve_inflight(0);
        assert!(!a.solve_saturated());
    }
}
