//! Sharded serving tier: actor runtime, θ-consistent-hash ring, admission
//! control, and the front-end router.
//!
//! Layering (each piece is usable alone; `serve::Server` composes the
//! first three, `idiff route` runs the fourth):
//!
//! * [`actor`] — bounded MPMC mailboxes + supervised restart-on-panic
//!   actor threads. The shard server and the router both run their
//!   connection handling on this runtime instead of the flat
//!   `WorkerPool` accept loop.
//! * [`ring`] — the deterministic consistent-hash ring assigning every
//!   (problem, θ) to exactly one shard. Router forwarding, shard manifest
//!   slicing, and the cluster tests all derive the same assignment from
//!   the same pure function.
//! * [`admit`] — bounded inflight / queue-depth / solve-slot accounting
//!   with the `overloaded` reject and the mode-aware degrade trigger
//!   (saturated solve queue + `"mode":"auto"` + cached ρ ⇒ solve-free
//!   answer instead of queueing).
//! * [`router`] — the `idiff route` process: both client wires unchanged,
//!   ring-position forwarding over pooled upstream connections, circuit-
//!   breaker health tracking with jittered probe backoff, failover to the
//!   replicated ring successor, drain-on-SIGTERM.
//! * [`faults`] — the fault-injection plan (`IDIFF_FAULTS`) used by the
//!   chaos sweep; a relaxed-load no-op when no plan is installed.

pub mod actor;
pub mod admit;
pub mod faults;
pub mod ring;
pub mod router;
