//! Fault injection for chaos testing.
//!
//! A fault *plan* names sites in the serving path and attaches an action to
//! each. Sites are compiled into the real code path (`faults::at("site")`)
//! but cost one relaxed atomic load when no plan is installed, so production
//! binaries pay nothing.
//!
//! Plan grammar (comma-separated rules):
//!
//! ```text
//! site=action[@k]
//! action ::= drop | delay-<ms> | close-mid-frame | panic
//! ```
//!
//! `@k` makes the rule fire on every k-th hit of the site (default: every
//! hit). Example: `IDIFF_FAULTS="shard-reply=close-mid-frame@3,actor=panic@50"`.
//!
//! Actions:
//! - `drop` — the caller discards the in-flight message (router: treat the
//!   forward attempt as failed; shard: swallow the request without replying).
//! - `delay-<ms>` — executed here: the calling thread sleeps, then proceeds.
//! - `close-mid-frame` — the caller writes a partial frame and closes the
//!   connection.
//! - `panic` — executed here: the calling thread panics (exercises the actor
//!   supervisor).
//!
//! The plan comes from the `IDIFF_FAULTS` environment variable (loaded once,
//! on first probe) or programmatically via [`install`] — tests that share a
//! process must use [`install`]/[`clear`] and run their faulted sections
//! sequentially, since the plan is process-global.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once};
use std::time::Duration;

/// What to do when a faulted site is hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Caller drops the in-flight message.
    Drop,
    /// Sleep this many milliseconds (executed inside [`at`]), then proceed.
    Delay(u64),
    /// Caller writes a truncated frame and closes the connection.
    CloseMidFrame,
    /// Panic the calling thread (executed inside [`at`]).
    Panic,
}

/// Shard-side: just after a request frame/line has been read.
pub const SITE_SHARD_REQUEST: &str = "shard-request";
/// Shard-side: just before a reply frame/line is written.
pub const SITE_SHARD_REPLY: &str = "shard-reply";
/// Router-side: just before relaying a request upstream.
pub const SITE_ROUTER_FORWARD: &str = "router-forward";
/// Actor runtime: at the top of every supervised message dispatch.
pub const SITE_ACTOR: &str = "actor";

struct Rule {
    site: String,
    action: Action,
    every: u64,
    hits: u64,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Vec<Rule>> = Mutex::new(Vec::new());
static ENV_LOAD: Once = Once::new();

fn parse_action(spec: &str) -> Result<Action, String> {
    match spec {
        "drop" => Ok(Action::Drop),
        "close-mid-frame" => Ok(Action::CloseMidFrame),
        "panic" => Ok(Action::Panic),
        _ => {
            if let Some(ms) = spec.strip_prefix("delay-") {
                let ms: u64 = ms
                    .parse()
                    .map_err(|_| format!("bad delay milliseconds in fault action `{spec}`"))?;
                Ok(Action::Delay(ms))
            } else {
                Err(format!(
                    "unknown fault action `{spec}` (want drop | delay-<ms> | close-mid-frame | panic)"
                ))
            }
        }
    }
}

fn parse_plan(plan: &str) -> Result<Vec<Rule>, String> {
    let mut rules = Vec::new();
    for part in plan.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site, rest) = part
            .split_once('=')
            .ok_or_else(|| format!("fault rule `{part}` is missing `=`"))?;
        let (action_spec, every) = match rest.split_once('@') {
            Some((a, k)) => {
                let k: u64 = k
                    .parse()
                    .map_err(|_| format!("bad `@every` count in fault rule `{part}`"))?;
                if k == 0 {
                    return Err(format!("`@0` in fault rule `{part}` would never fire"));
                }
                (a, k)
            }
            None => (rest, 1),
        };
        rules.push(Rule {
            site: site.trim().to_string(),
            action: parse_action(action_spec.trim())?,
            every,
            hits: 0,
        });
    }
    Ok(rules)
}

/// Install a fault plan for this process, replacing any previous plan.
pub fn install(plan: &str) -> Result<(), String> {
    let rules = parse_plan(plan)?;
    let active = !rules.is_empty();
    *PLAN.lock().unwrap() = rules;
    ACTIVE.store(active, Ordering::Release);
    Ok(())
}

/// Remove the fault plan; every subsequent [`at`] probe is a no-op.
pub fn clear() {
    ACTIVE.store(false, Ordering::Release);
    PLAN.lock().unwrap().clear();
}

fn ensure_env_loaded() {
    ENV_LOAD.call_once(|| {
        if let Ok(plan) = std::env::var("IDIFF_FAULTS") {
            if let Err(e) = install(&plan) {
                eprintln!("idiff: ignoring IDIFF_FAULTS: {e}");
            }
        }
    });
}

/// Probe a fault site. Returns `Some(Action::Drop)` / `Some(Action::CloseMidFrame)`
/// for the caller to act on; `Delay` sleeps here and `Panic` panics here, so
/// callers only ever see the two message-shaped actions. `None` = no fault.
pub fn at(site: &str) -> Option<Action> {
    ensure_env_loaded();
    if !ACTIVE.load(Ordering::Acquire) {
        return None;
    }
    let fired = {
        let mut plan = PLAN.lock().unwrap();
        let mut fired = None;
        for rule in plan.iter_mut() {
            if rule.site == site {
                rule.hits += 1;
                if rule.hits % rule.every == 0 {
                    fired = Some(rule.action);
                }
                break;
            }
        }
        fired
    };
    match fired {
        Some(Action::Delay(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        Some(Action::Panic) => panic!("injected fault: panic at site `{site}`"),
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_rejects_malformed_rules() {
        assert!(parse_plan("shard-reply=close-mid-frame").is_ok());
        assert!(parse_plan("a=drop,b=delay-25@3,c=panic@7").is_ok());
        assert!(parse_plan("no-equals-sign").is_err());
        assert!(parse_plan("a=explode").is_err());
        assert!(parse_plan("a=delay-xyz").is_err());
        assert!(parse_plan("a=drop@0").is_err());
        assert!(parse_plan("a=drop@two").is_err());
        assert!(parse_plan("").unwrap().is_empty());
    }

    // One test exercises the process-global plan end to end: the global is
    // shared across the test binary's threads, so splitting this into several
    // #[test] fns would race.
    #[test]
    fn install_fire_every_and_clear() {
        // Unset: zero-cost probe.
        clear();
        assert_eq!(at(SITE_SHARD_REQUEST), None);

        // `@3` fires on hits 3, 6, ... only.
        install("shard-request=drop@3").unwrap();
        assert_eq!(at(SITE_SHARD_REQUEST), None);
        assert_eq!(at(SITE_SHARD_REQUEST), None);
        assert_eq!(at(SITE_SHARD_REQUEST), Some(Action::Drop));
        assert_eq!(at(SITE_SHARD_REQUEST), None);
        // Other sites are untouched.
        assert_eq!(at(SITE_ROUTER_FORWARD), None);

        // Delay executes inside `at` and then reports "no action".
        install("router-forward=delay-1").unwrap();
        let t0 = std::time::Instant::now();
        assert_eq!(at(SITE_ROUTER_FORWARD), None);
        assert!(t0.elapsed() >= Duration::from_millis(1));

        // Panic executes inside `at`.
        install("actor=panic").unwrap();
        let caught = std::panic::catch_unwind(|| at(SITE_ACTOR));
        assert!(caught.is_err());

        clear();
        assert_eq!(at(SITE_ACTOR), None);
    }
}
