//! Minimal actor/supervisor runtime for the serving tier.
//!
//! Replaces the flat `util::parallel::WorkerPool` accept loop with the two
//! pieces a shardable server actually needs:
//!
//! * [`Mailbox`] — a bounded MPMC message ring (Mutex + Condvar over a
//!   `VecDeque`). `try_send` **never blocks**: when the ring is full the
//!   message comes straight back as [`SendError::Full`] so the caller can
//!   shed load (`{"error":"overloaded"}`) instead of queueing unboundedly.
//!   That non-blocking contract is what admission control hangs off.
//! * [`supervise`] — N actor threads drain one shared mailbox; each actor
//!   is watched by a supervisor thread that detects a panic via
//!   `JoinHandle::join` and respawns the actor (counted, with a capped
//!   exponential backoff that resets once a respawned actor stays healthy).
//!   A slot that keeps crashing — more than [`STORM_MAX_RESTARTS`] restarts
//!   inside one [`STORM_WINDOW`] — is given up (counted in `give_ups`)
//!   instead of burning a core on a panic loop forever. Pending messages
//!   survive a restart because they live in the shared mailbox; only the
//!   message being processed at the instant of the panic is lost — for the
//!   serve tier that is one TCP connection, which the client sees as a
//!   disconnect and retries.
//!
//! Zero dependencies, std threads only — same discipline as the rest of
//! the crate.

use super::faults;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Why a `try_send` bounced; the message is handed back in both cases.
pub enum SendError<T> {
    /// The ring is at capacity — shed load or retry later.
    Full(T),
    /// The mailbox was closed — no actor will ever drain it again.
    Closed(T),
}

impl<T> SendError<T> {
    pub fn into_inner(self) -> T {
        match self {
            SendError::Full(m) | SendError::Closed(m) => m,
        }
    }
}

impl<T> std::fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SendError::Full(_) => "SendError::Full",
            SendError::Closed(_) => "SendError::Closed",
        })
    }
}

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer message ring.
pub struct Mailbox<T> {
    state: Mutex<State<T>>,
    available: Condvar,
    capacity: usize,
    /// Lock-free depth gauge so `stats` can report queue depth without
    /// contending on the mailbox mutex.
    depth: AtomicUsize,
}

impl<T> Mailbox<T> {
    pub fn new(capacity: usize) -> Arc<Mailbox<T>> {
        Arc::new(Mailbox {
            state: Mutex::new(State { queue: VecDeque::with_capacity(capacity.max(1)), closed: false }),
            available: Condvar::new(),
            capacity: capacity.max(1),
            depth: AtomicUsize::new(0),
        })
    }

    /// Non-blocking send. Full or closed rings hand the message back.
    pub fn try_send(&self, msg: T) -> Result<(), SendError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SendError::Closed(msg));
        }
        if st.queue.len() >= self.capacity {
            return Err(SendError::Full(msg));
        }
        st.queue.push_back(msg);
        self.depth.store(st.queue.len(), Ordering::Relaxed);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Blocking receive. `None` means the mailbox is closed *and* drained —
    /// the actor's clean-exit signal.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(msg) = st.queue.pop_front() {
                self.depth.store(st.queue.len(), Ordering::Relaxed);
                return Some(msg);
            }
            if st.closed {
                return None;
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Close the mailbox: senders get `Closed`, actors drain what is left
    /// and exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    /// Messages currently queued (approximate; lock-free read).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Handle to a supervised actor group. Dropping it detaches the threads
/// (they exit when the mailbox closes); `join` waits for that exit.
pub struct Supervisor {
    threads: Vec<JoinHandle<()>>,
    restarts: Arc<AtomicU64>,
}

impl Supervisor {
    /// Total actor restarts across the group (panics recovered).
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Wait for every supervisor to finish. Only returns after the mailbox
    /// has been closed and drained.
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Restarts one supervisor slot tolerates inside a rolling [`STORM_WINDOW`]
/// before giving up on the slot: past this a crash is deterministic
/// (respawning cannot help) and the loop would only starve healthy actors.
pub const STORM_MAX_RESTARTS: u32 = 30;
/// The rolling window the restart-storm guard counts over.
pub const STORM_WINDOW: Duration = Duration::from_secs(60);
/// Respawn backoff: starts here, doubles per consecutive crash…
const BACKOFF_START: Duration = Duration::from_millis(10);
/// …capped here.
const BACKOFF_CAP: Duration = Duration::from_secs(1);
/// An actor that ran at least this long before panicking was healthy:
/// its slot's backoff resets to [`BACKOFF_START`].
const HEALTHY_RUN: Duration = Duration::from_secs(1);

/// Spawn `actors` supervised actor threads draining `mailbox` with
/// `handler`. Each panic in `handler` is recovered by that actor's
/// supervisor: the restart counter is bumped, the actor thread is
/// respawned after a capped exponential backoff (reset once a respawn
/// stays up), and the shared mailbox keeps feeding it. A slot restarting
/// more than [`STORM_MAX_RESTARTS`] times inside one [`STORM_WINDOW`] is
/// abandoned and counted in `give_ups`. Restarts/give-ups are recorded in
/// the shared counters the server's `stats` op reports.
pub fn supervise<T: Send + 'static>(
    name: &str,
    actors: usize,
    mailbox: Arc<Mailbox<T>>,
    handler: Arc<dyn Fn(T) + Send + Sync>,
    restarts: Arc<AtomicU64>,
    give_ups: Arc<AtomicU64>,
) -> Supervisor {
    let threads = (0..actors.max(1))
        .map(|i| {
            let mb = mailbox.clone();
            let h = handler.clone();
            let r = restarts.clone();
            let g = give_ups.clone();
            let label = format!("{name}-{i}");
            std::thread::Builder::new()
                .name(format!("{label}-sup"))
                .spawn(move || {
                    let mut backoff = BACKOFF_START;
                    let mut window_start = Instant::now();
                    let mut window_restarts = 0u32;
                    loop {
                        let mb2 = mb.clone();
                        let h2 = h.clone();
                        let actor = std::thread::Builder::new()
                            .name(label.clone())
                            .spawn(move || {
                                while let Some(msg) = mb2.recv() {
                                    // Fault probe: `panic` unwinds here and
                                    // exercises this supervisor; the
                                    // message-shaped actions just lose the
                                    // message (the client sees a disconnect).
                                    if faults::at(faults::SITE_ACTOR).is_some() {
                                        continue;
                                    }
                                    h2(msg);
                                }
                            })
                            .expect("spawn actor thread");
                        let started = Instant::now();
                        match actor.join() {
                            // Clean exit: mailbox closed and drained.
                            Ok(()) => break,
                            // Panic: count it, back off, respawn — unless
                            // this slot is crash-storming.
                            Err(_) => {
                                r.fetch_add(1, Ordering::Relaxed);
                                if started.elapsed() >= HEALTHY_RUN {
                                    backoff = BACKOFF_START;
                                }
                                if window_start.elapsed() >= STORM_WINDOW {
                                    window_start = Instant::now();
                                    window_restarts = 0;
                                }
                                window_restarts += 1;
                                if window_restarts > STORM_MAX_RESTARTS {
                                    g.fetch_add(1, Ordering::Relaxed);
                                    eprintln!(
                                        "idiff: actor slot {label} abandoned after \
                                         {window_restarts} restarts inside {STORM_WINDOW:?}"
                                    );
                                    break;
                                }
                                std::thread::sleep(backoff);
                                backoff = (backoff * 2).min(BACKOFF_CAP);
                            }
                        }
                    }
                })
                .expect("spawn supervisor thread")
        })
        .collect();
    Supervisor { threads, restarts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mailbox_bounds_and_sheds() {
        let mb: Arc<Mailbox<u32>> = Mailbox::new(2);
        assert!(mb.try_send(1).is_ok());
        assert!(mb.try_send(2).is_ok());
        match mb.try_send(3) {
            Err(SendError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(mb.depth(), 2);
        assert_eq!(mb.recv(), Some(1));
        assert!(mb.try_send(3).is_ok());
        mb.close();
        match mb.try_send(4) {
            Err(SendError::Closed(4)) => {}
            other => panic!("expected Closed(4), got {other:?}"),
        }
        // Drain continues after close, then signals exit.
        assert_eq!(mb.recv(), Some(2));
        assert_eq!(mb.recv(), Some(3));
        assert_eq!(mb.recv(), None);
    }

    #[test]
    fn supervisor_restarts_panicked_actor_and_keeps_draining() {
        let mb: Arc<Mailbox<u32>> = Mailbox::new(64);
        let processed = Arc::new(AtomicUsize::new(0));
        let restarts = Arc::new(AtomicU64::new(0));
        let give_ups = Arc::new(AtomicU64::new(0));
        let p = processed.clone();
        let sup = supervise(
            "test-actor",
            2,
            mb.clone(),
            Arc::new(move |msg: u32| {
                if msg == 13 {
                    panic!("poison message");
                }
                p.fetch_add(1, Ordering::SeqCst);
            }),
            restarts.clone(),
            give_ups.clone(),
        );
        for i in 0..20 {
            // Blocking-ish send: the ring is larger than the message count.
            mb.try_send(i).unwrap();
        }
        mb.close();
        sup.join();
        // 19 good messages processed, exactly the poison one lost.
        assert_eq!(processed.load(Ordering::SeqCst), 19);
        assert_eq!(restarts.load(Ordering::Relaxed), 1);
        // One panic is far below the storm threshold.
        assert_eq!(give_ups.load(Ordering::Relaxed), 0);
    }
}
