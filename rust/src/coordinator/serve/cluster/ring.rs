//! θ-consistent-hash ring: assigns each (problem, θ) to exactly one shard.
//!
//! The ring is a **pure function** of the member set and the vnode count —
//! no RNG, no process state — so every process in the cluster (router,
//! shards, tests) independently computes the *same* assignment. That is
//! what makes "zero duplicate factorizations cluster-wide" enforceable
//! without any coordination traffic: the router forwards by ring position,
//! and each shard's warm-start loader drops manifest entries it does not
//! own (see `serve::persist`).
//!
//! Design: classic consistent hashing with virtual nodes. Each member `m`
//! contributes `vnodes` points at `fnv1a("idiff-ring" · m · v)`; a key is
//! owned by the first point clockwise from its hash. Removing a member
//! removes only that member's points, so only the keys on its arcs move
//! (≈ 1/N of the keyspace) — the failover/"cold-start re-hash" property
//! the router relies on when a shard dies. Keys are hashed from the
//! *canonical θ bytes* (IEEE-754 bit pattern, little-endian) plus the
//! problem name, exactly the identity `cache::ThetaKey` uses, so ring
//! ownership and cache keying can never disagree.

/// 64-bit FNV-1a. Stable across platforms and processes; no allocation.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Consistent-hash ring over a set of shard ids.
#[derive(Clone, Debug)]
pub struct Ring {
    /// (point hash, owning member), sorted by hash then member so the
    /// ordering is total even under hash collisions.
    points: Vec<(u64, u32)>,
    members: Vec<u32>,
    vnodes: usize,
}

/// Default virtual nodes per member: enough that a 2–8 shard ring is
/// balanced to within a few percent, cheap enough to rebuild on failover.
pub const DEFAULT_VNODES: usize = 64;

impl Ring {
    /// Build a ring over `members` with `vnodes` points per member.
    /// Duplicate member ids are deduplicated; an empty member set yields
    /// an empty ring (`owner` returns `None` — the router's "no healthy
    /// shards" case).
    pub fn new(members: &[u32], vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut ms: Vec<u32> = members.to_vec();
        ms.sort_unstable();
        ms.dedup();
        let mut points = Vec::with_capacity(ms.len() * vnodes);
        for &m in &ms {
            for v in 0..vnodes as u32 {
                let mut buf = [0u8; 18];
                buf[..10].copy_from_slice(b"idiff-ring");
                buf[10..14].copy_from_slice(&m.to_le_bytes());
                buf[14..18].copy_from_slice(&v.to_le_bytes());
                points.push((fnv1a(&buf), m));
            }
        }
        points.sort_unstable();
        Ring { points, members: ms, vnodes }
    }

    /// Canonical routing key for a request: problem name bytes, a 0xff
    /// separator (never valid inside UTF-8), then each θ component's
    /// IEEE-754 bits little-endian. Matches `cache::ThetaKey` identity:
    /// bitwise-equal θ ⇒ same key ⇒ same shard ⇒ one cache entry.
    pub fn route_key(problem: &str, theta: &[f64]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut step = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        for &b in problem.as_bytes() {
            step(b);
        }
        step(0xff);
        for t in theta {
            for b in t.to_bits().to_le_bytes() {
                step(b);
            }
        }
        h
    }

    /// Member owning `key`: the first ring point at or clockwise-after it.
    pub fn owner(&self, key: u64) -> Option<u32> {
        if self.points.is_empty() {
            return None;
        }
        let i = self.points.partition_point(|&(h, _)| h < key);
        let i = if i == self.points.len() { 0 } else { i };
        Some(self.points[i].1)
    }

    /// Shard owning a (problem, θ) request.
    pub fn shard_for(&self, problem: &str, theta: &[f64]) -> Option<u32> {
        self.owner(Self::route_key(problem, theta))
    }

    pub fn members(&self) -> &[u32] {
        &self.members
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<(String, Vec<f64>)> {
        (0..n)
            .map(|i| {
                let theta: Vec<f64> = (0..8).map(|j| 1.0 + i as f64 * 0.01 + j as f64).collect();
                ("ridge".to_string(), theta)
            })
            .collect()
    }

    #[test]
    fn ring_is_deterministic_across_instances() {
        let a = Ring::new(&[0, 1, 2, 3], DEFAULT_VNODES);
        let b = Ring::new(&[3, 2, 1, 0, 2], DEFAULT_VNODES); // order/dup-insensitive
        for (p, t) in keys(500) {
            assert_eq!(a.shard_for(&p, &t), b.shard_for(&p, &t));
        }
    }

    #[test]
    fn removal_moves_only_the_dead_members_keys() {
        let full = Ring::new(&[0, 1, 2, 3], DEFAULT_VNODES);
        let without2 = Ring::new(&[0, 1, 3], DEFAULT_VNODES);
        let mut moved = 0usize;
        let ks = keys(1000);
        for (p, t) in &ks {
            let before = full.shard_for(p, t).unwrap();
            let after = without2.shard_for(p, t).unwrap();
            if before == 2 {
                moved += 1;
                assert_ne!(after, 2);
            } else {
                assert_eq!(before, after, "key owned by a surviving shard moved");
            }
        }
        // ~1/4 of the keyspace belonged to shard 2; allow generous slack.
        assert!(moved > 100 && moved < 450, "moved {moved}/1000 — ring unbalanced");
    }

    #[test]
    fn route_key_matches_bitwise_theta_identity() {
        let t1 = vec![1.0, -0.0, 2.5];
        let t2 = vec![1.0, 0.0, 2.5]; // -0.0 and 0.0 differ bitwise → different keys
        assert_ne!(Ring::route_key("ridge", &t1), Ring::route_key("ridge", &t2));
        assert_eq!(Ring::route_key("ridge", &t1), Ring::route_key("ridge", &t1.clone()));
        assert_ne!(Ring::route_key("ridge", &t1), Ring::route_key("lasso", &t1));
    }

    #[test]
    fn empty_ring_owns_nothing() {
        let r = Ring::new(&[], DEFAULT_VNODES);
        assert!(r.is_empty());
        assert_eq!(r.shard_for("ridge", &[1.0]), None);
    }
}
