//! Hypergradient serving subsystem: the whole optimality-mapping catalog
//! behind one TCP port speaking TWO wire protocols — line-delimited JSON for
//! debuggability and a zero-copy length-prefixed binary frame protocol for
//! the hot path — with request micro-batching onto block solves, a θ-keyed
//! factorization cache, a θ-keyed contraction (ρ) cache, pooled request
//! buffers, manifest persistence for warm restarts, and a supervised actor
//! runtime (bounded connection mailbox, restart-on-panic — no
//! thread-per-connection). The [`cluster`] module shards this engine across
//! processes behind a θ-consistent-hash router with admission control.
//!
//! # Protocol auto-detection
//!
//! Both protocols share one listener. The first byte of a connection picks
//! the protocol for its whole lifetime: binary frames open with the magic
//! byte `0xB1` ([`wire::MAGIC`]), which can never begin a JSON value, so
//! anything else is served as JSON lines. `telnet`/`nc` debugging therefore
//! keeps working unchanged while SDK clients speak frames.
//!
//! # JSON line protocol (one JSON object per line, one reply line each)
//!
//! | request                                                        | reply |
//! |----------------------------------------------------------------|-------|
//! | `{"op":"ping"}`                                                | `{"ok":true}` |
//! | `{"op":"problems"}`                                            | `{"problems":[{"name","desc","dim_x","dim_theta"},…]}` |
//! | `{"op":"stats"}`                                               | serve counters (solves, batches, cache hits, pool hits, …) |
//! | `{"op":"solve","problem":P,"theta":[…]}`                       | `{"x":[…],"cached":bool}` |
//! | `{"op":"hypergrad","problem":P,"theta":[…],"v":[… dim_x]}`     | `{"grad":[… dim_theta],"batched":k,"cached":bool,"mode":m}` |
//! | `{"op":"jvp","problem":P,"theta":[…],"v":[… dim_theta]}`       | `{"jv":[… dim_x],"batched":k,"cached":bool,"mode":m}` |
//! | `{"op":"jacobian","problem":P,"theta":[…]}`                    | `{"jacobian":[[…]…],"cached":bool}` |
//!
//! `"vjp"` is accepted as an alias of `"hypergrad"`; the pre-registry ops
//! `"ridge_hypergrad"`/`"ridge_jacobian"` are kept as aliases onto
//! `problem = "ridge"`. Every failure — malformed JSON, unknown op or
//! problem, wrong-length or non-finite vectors, oversized lines — is a
//! `{"error": "…"}` reply; the connection stays open.
//!
//! # Binary frame protocol
//!
//! Frames carry the same requests with zero intermediate JSON values: f64
//! payloads are read little-endian straight into pooled buffers and written
//! straight back out of result vectors (see [`wire`] for the byte-exact
//! layout). Requests are `[0xB1][version=2][u32 deadline_ms][u32 len]` + a
//! payload of op / mode / precision bytes, `iters`, the problem name, and
//! raw θ / v blocks;
//! replies are `[0xB1][version][status][flags][u32 len]` + mode byte, batch
//! size, a rows×cols f64 block, and an optional JSON text tail (used only by
//! `problems` / `stats`, which stay JSON-shaped on both wires). Both wires
//! answer from literally the same engine path ([`Server::execute`]), so
//! every op × mode × precision combination is bitwise-identical across
//! protocols (asserted by `rust/tests/protocol_equiv.rs`). A well-framed but
//! malformed payload gets an error frame and the connection stays usable; a
//! framing-level violation (bad magic/version, oversized length) gets an
//! error frame followed by a close, since the stream can no longer be
//! delimited safely.
//!
//! Derivative requests accept an optional `"precision"` field
//! (`"f64"` default, or `"mixed"` for f32-inner/f64-refined solves on the
//! cache-miss iterative path). Requests with different precisions never
//! share a batch, and the θ-keyed cache always stores full-precision
//! factorizations, so a cache hit serves f64 quality regardless of the
//! requested policy.
//!
//! They also accept an optional `"mode"` field choosing the derivative
//! mechanism: `"implicit"` (default — the IFT linear solve), `"one-step"`
//! (differentiate a single application of the fixed-point iteration at x*:
//! zero solves, zero factorizations, error O(ρ) in the contraction factor),
//! `"unroll"` (k-term truncated Neumann at x*, error O(ρᵏ); optional
//! `"iters"` sets k), or `"auto"` (a warm θ-cache serves factored implicit;
//! a cold one estimates ρ — served from the θ-keyed ρ-cache when this
//! (problem, θ) has been seen before, power iteration otherwise — and picks
//! the cheapest mode whose error bound meets the policy target). The
//! solve-free modes bypass the factorization cache entirely: they neither
//! read nor populate it. Replies echo the requested mode in `"mode"`
//! (cache hits report `"implicit"`, which is what they served). Requests
//! with different modes (or explicit unroll depths) never share a batch.
//!
//! # Request path
//!
//! Derivative requests are keyed by (problem, θ, op):
//!
//! 1. **Cache hit** — the θ-keyed LRU holds x*(θ) and a dense Cholesky/LU
//!    factorization of A = −∂₁F: the reply costs an O(d²) substitution and
//!    ZERO iterative solves (asserted by tests via the solve counter).
//! 2. **Miss** — the request joins the micro-batch for its key; the batch
//!    leader waits out the batching window (or until `batch_max`), solves
//!    the inner problem once, answers all k members with ONE
//!    `implicit_vjp_multi`/`implicit_jvp_multi` block solve, and populates
//!    the cache so subsequent repeats of θ take path 1.
//!
//! Request θ/v payloads live in recycled [`Pool`] buffers on both wires
//! (hits/misses/recycled surface in the `stats` op), so the steady-state
//! request path allocates nothing on the decode side.
//!
//! # Persistence
//!
//! With a manifest path configured, the θ-factorization cache, the ρ-cache
//! and the catalog fingerprint serialize periodically (and on demand via
//! [`Server::save_manifest`]) to a versioned JSON manifest, atomically
//! (tmp + rename). A rebooted server warm-starts from it: repeat-θ traffic
//! immediately takes the factored path with ZERO new factorizations
//! (asserted by `rust/tests/persist_warm.rs`). A manifest with an unknown
//! format or version produces a clean cold start, never a crash. With
//! `handle_signals` set (the `idiff serve` binary sets it; embedded servers
//! do not), SIGTERM/SIGINT trips a signal-safe latch and a watcher thread
//! writes the manifest once more before exiting — graceful shutdown loses
//! no warm state. A sharded server (`cfg.shard = Some((i, n))`) restores
//! only ring-owned manifest entries, so shard manifests partition cleanly.
//!
//! # Connection runtime and admission
//!
//! Accepted connections enter a bounded [`cluster::actor::Mailbox`] drained
//! by `workers` supervised connection actors: a panicking actor is restarted
//! by its supervisor (`actor_restarts` in `stats`) without dropping the
//! listener, excess connections past `accept_queue` are shed with a prompt
//! `{"error":"overloaded"}`, and a connection idle past `idle_timeout` is
//! closed so it cannot pin an actor. [`cluster::admit::Admission`] bounds
//! the data plane: at most `max_inflight` requests execute at once, at most
//! `max_solve_inflight` of them on the implicit block-solve lane. A
//! saturated solve lane rejects implicit work up front and degrades
//! `"mode":"auto"` requests with a cached contractive ρ to solve-free
//! answers (flagged `"degraded":true`, counted in `degraded_one_step`)
//! instead of queueing them.
//!
//! # Deadlines
//!
//! Every data-plane request may carry an optional deadline budget — the
//! JSON member `"deadline_ms"` or the binary header's u32 deadline field
//! (0 = none on both wires). The budget starts when the request is read;
//! a request whose budget has expired — on arrival, or by the time it
//! would claim a solve slot — is answered `{"error":"deadline_exceeded"}`
//! instead of queueing past-due work (counted in `deadline_exceeded`).
//! The cluster router decrements the budget by its own elapsed time before
//! relaying, so shards always see the *remaining* budget.
//!
//! # Replication
//!
//! A sharded server with `--peers` configured runs a replicator thread:
//! every `replicate_secs` it ships each warm cache entry it *owns* to the
//! shard that would inherit that θ if this shard died (the key's owner on
//! the ring minus self — exactly the router's failover re-hash), over the
//! binary wire's internal `OP_REPLICATE` op. The receiver installs the
//! entries bypassing its ownership filter and WITHOUT counting
//! factorizations (like a manifest restore), so router failover after a
//! shard death lands on a warm replica: the migrated θ-slice is served
//! bitwise-identically with ZERO new factorizations (asserted end-to-end
//! in `rust/tests/cluster.rs`). `replicated_out`/`replicated_in` count
//! shipped/installed entries on both sides.

pub mod batcher;
pub mod cache;
pub mod cluster;
pub mod persist;
pub mod registry;
pub mod wire;

use crate::diff::mode::{DiffMode, ModeDecision, ModePolicy};
use crate::linalg::mat::Mat;
use crate::linalg::op::densify;
use crate::linalg::solve::{counter, SolvePrecision};
use crate::util::json::{self, Json};
use crate::util::pool::{Pool, PoolVec};
use batcher::{BatchKey, BatchOp, Batcher};
use cache::{CacheEntry, FactorCache, RhoCache, ThetaKey};
use cluster::actor::Mailbox;
use cluster::admit::{Admission, DEADLINE_EXCEEDED, OVERLOADED};
use cluster::faults;
use cluster::ring::{Ring, DEFAULT_VNODES};
use registry::{Problem, Registry};
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serve-side knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads handling connections (bounded pool).
    pub workers: usize,
    /// Micro-batching window: how long a batch leader waits for followers.
    pub batch_window: Duration,
    /// Close a batch early once this many requests joined.
    pub batch_max: usize,
    /// θ-keyed factorization cache capacity (entries across all problems).
    pub cache_capacity: usize,
    /// Reject JSON request lines / binary frame payloads longer than this
    /// many bytes.
    pub max_line_bytes: usize,
    /// Close a connection after this long with no request. A connection
    /// holds a pool worker while open, so idle clients must not be allowed
    /// to starve queued connections forever.
    pub idle_timeout: Duration,
    /// Idle buffers the request pool retains per free-list.
    pub pool_max_idle: usize,
    /// Warm-state manifest location; None disables persistence entirely.
    pub manifest_path: Option<PathBuf>,
    /// Seconds between periodic manifest writes (0 = only explicit
    /// [`Server::save_manifest`] calls persist).
    pub persist_secs: u64,
    /// Cluster shard identity as (index, count). `None` = standalone.
    /// A sharded server reports its slot in `stats` and filters its
    /// warm-start manifest to ring-owned entries; it still answers any θ
    /// it is asked (the router re-hashes onto survivors on failover).
    pub shard: Option<(usize, usize)>,
    /// Virtual nodes per shard on the consistent-hash ring. Must match the
    /// router's setting (both default to [`DEFAULT_VNODES`]).
    pub vnodes: usize,
    /// Bounded accept-queue depth; connections past it are shed with a
    /// prompt `overloaded` reject instead of queueing unboundedly.
    pub accept_queue: usize,
    /// Max concurrently executing data-plane requests (0 = unbounded).
    pub max_inflight: usize,
    /// Max requests queued/executing on the implicit block-solve path
    /// (0 = unbounded). When saturated, implicit requests are shed and
    /// `"mode":"auto"` requests with a cached ρ degrade to solve-free
    /// answers.
    pub max_solve_inflight: usize,
    /// Install the SIGTERM/SIGINT latch and write the manifest on shutdown.
    /// Off by default so embedded servers (tests, benches) never touch
    /// process-wide signal state; `idiff serve` turns it on.
    pub handle_signals: bool,
    /// Addresses of every shard in the cluster, index-aligned with shard
    /// ids (`peers[i]` is shard i — including this shard's own address).
    /// Empty disables replication.
    pub peers: Vec<String>,
    /// Seconds between replication passes (0 = replication off even with
    /// peers configured).
    pub replicate_secs: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: crate::util::parallel::default_workers(),
            batch_window: Duration::from_millis(2),
            batch_max: 32,
            cache_capacity: 64,
            max_line_bytes: 1 << 20,
            idle_timeout: Duration::from_secs(30),
            pool_max_idle: 256,
            manifest_path: None,
            persist_secs: 60,
            shard: None,
            vnodes: DEFAULT_VNODES,
            accept_queue: 1024,
            max_inflight: 0,
            max_solve_inflight: 0,
            handle_signals: false,
            peers: Vec::new(),
            replicate_secs: 5,
        }
    }
}

/// Engine counters (all monotonic).
#[derive(Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// Iterative solves issued (block solve of any width counts ONCE),
    /// measured around each compute via the thread-local solve counter.
    pub block_solves: AtomicU64,
    /// Inner problem solves (x*(θ) computations).
    pub inner_solves: AtomicU64,
    /// Requests answered from the θ-keyed factorization cache.
    pub cache_hits: AtomicU64,
    /// Dense factorizations performed (cache population). The solve-free
    /// modes must never bump this — asserted by the integration tests.
    pub factorizations: AtomicU64,
    /// Dense d×d operators materialized while answering derivative
    /// requests (thread-local densify-counter deltas around each compute).
    pub densified: AtomicU64,
    /// Power-iteration contraction estimates actually run (ρ-cache misses
    /// on the solve-free path). Repeat-θ auto traffic must not bump this —
    /// asserted by the ρ-cache tests.
    pub rho_estimates: AtomicU64,
    /// Requests refused because their deadline budget had already expired
    /// (on arrival or at the solve-lane gate).
    pub deadline_exceeded: AtomicU64,
    /// Warm cache entries (factorizations + ρ) shipped to a ring successor
    /// by the replicator thread.
    pub replicated_out: AtomicU64,
    /// Warm cache entries installed from a peer's replica deltas. Replica
    /// installs never count as `factorizations` — same accounting as a
    /// manifest restore.
    pub replicated_in: AtomicU64,
}

/// A decoded, transport-neutral request. Both wire protocols produce this,
/// so they are answered by literally the same engine path; θ and v live in
/// pooled buffers that recycle on drop.
pub enum Request {
    Ping,
    Problems,
    Stats,
    Solve {
        problem: String,
        theta: PoolVec,
    },
    Derivative {
        problem: String,
        theta: PoolVec,
        v: PoolVec,
        op: BatchOp,
        mode: DiffMode,
        precision: SolvePrecision,
        /// Explicit unroll depth (0 = policy-chosen).
        iters: usize,
    },
    Jacobian {
        problem: String,
        theta: PoolVec,
    },
    /// Internal shard→shard warm-state transfer (binary wire only,
    /// `OP_REPLICATE`): a replica-delta document to install.
    Replicate {
        doc: String,
    },
}

/// A transport-neutral reply, rendered to a JSON object ([`reply_to_json`])
/// or a binary frame ([`wire::encode_reply`]).
pub enum Reply {
    Pong,
    /// Control-plane payloads (`problems`, `stats`) stay JSON-shaped on both
    /// wires — they are a debugging surface, not a hot path.
    Text(Json),
    Solution {
        x: Vec<f64>,
        cached: bool,
    },
    Derivative {
        out: Vec<f64>,
        /// JSON reply key: `"grad"` for VJPs, `"jv"` for JVPs.
        out_key: &'static str,
        batched: usize,
        cached: bool,
        /// Served solve-free under admission pressure (saturated solve
        /// queue + `"mode":"auto"` + cached ρ). JSON adds
        /// `"degraded":true`; the binary wire sets
        /// [`wire::FLAG_DEGRADED`].
        degraded: bool,
        mode: &'static str,
    },
    Jacobian {
        jac: Mat,
        cached: bool,
    },
    Error(String),
}

/// The serving engine. `handle` (JSON lines) and `handle_frame` (binary
/// payloads) are the transport-free cores — tests and benches call them
/// directly; [`Server::serve`] is the TCP front speaking both.
pub struct Server {
    registry: Registry,
    batcher: Batcher,
    cache: FactorCache,
    rho_cache: RhoCache,
    pool: Arc<Pool>,
    admission: Admission,
    /// (own shard index, ring over all shard ids) — `None` standalone.
    ring: Option<(usize, Ring)>,
    /// Actor restarts recovered by the connection supervisors.
    restarts: Arc<AtomicU64>,
    /// Actor slots abandoned by the restart-storm guard.
    give_ups: Arc<AtomicU64>,
    pub stats: ServeStats,
    cfg: ServeConfig,
}

impl Server {
    pub fn new(cfg: ServeConfig) -> Server {
        if let Some((i, n)) = cfg.shard {
            assert!(n >= 1 && i < n, "shard index {i} out of range for {n} shards");
        }
        let ring = cfg.shard.map(|(i, n)| {
            let members: Vec<u32> = (0..n as u32).collect();
            (i, Ring::new(&members, cfg.vnodes))
        });
        Server {
            registry: Registry::standard(),
            batcher: Batcher::new(cfg.batch_window, cfg.batch_max),
            cache: FactorCache::new(cfg.cache_capacity),
            rho_cache: RhoCache::new(cfg.cache_capacity),
            pool: Pool::new(cfg.pool_max_idle),
            admission: Admission::new(cfg.max_inflight, cfg.max_solve_inflight),
            ring,
            restarts: Arc::new(AtomicU64::new(0)),
            give_ups: Arc::new(AtomicU64::new(0)),
            stats: ServeStats::default(),
            cfg,
        }
    }

    /// The admission-control state (limits are live-adjustable; tests and
    /// operators tighten them on a running server).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// Does the consistent-hash ring assign this (problem, θ) to THIS
    /// shard? Standalone servers own everything. Used by the warm-start
    /// loader to keep each shard's manifest slice disjoint; requests are
    /// never refused on ownership (failover re-hashes foreign keys here
    /// on purpose).
    pub fn owns(&self, problem: &str, theta: &[f64]) -> bool {
        match &self.ring {
            None => true,
            Some((idx, ring)) => ring.shard_for(problem, theta) == Some(*idx as u32),
        }
    }

    pub fn with_defaults() -> Server {
        Server::new(ServeConfig::default())
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The shared request-buffer pool (clients embedding the engine can
    /// borrow from the same free-lists the wire decoders use).
    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    /// Handle one JSON request line, producing one reply value. Never
    /// panics: internal panics are caught and reported as `{"error": …}`.
    pub fn handle(&self, line: &str) -> Json {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let reply = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.handle_line(line)
        }))
        .unwrap_or_else(|_| Reply::Error("internal: request handler panicked".to_string()));
        if matches!(reply, Reply::Error(_)) {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        reply_to_json(reply)
    }

    fn handle_line(&self, line: &str) -> Reply {
        let arrival = Instant::now();
        if line.len() > self.cfg.max_line_bytes {
            return Reply::Error(format!(
                "request too large ({} bytes > {} max)",
                line.len(),
                self.cfg.max_line_bytes
            ));
        }
        match self.parse_request_json(line) {
            Ok((req, deadline_ms)) => {
                self.execute_with_deadline(req, deadline_of(arrival, deadline_ms))
            }
            Err(e) => Reply::Error(e),
        }
    }

    /// Handle one decoded binary frame payload (everything after the length
    /// prefix). Same panic containment and counter behavior as [`handle`].
    pub fn handle_frame(&self, payload: &[u8]) -> Reply {
        self.handle_frame_deadline(payload, 0)
    }

    /// [`Server::handle_frame`] with the header's deadline budget (0 = no
    /// deadline); the budget starts counting now.
    pub fn handle_frame_deadline(&self, payload: &[u8], deadline_ms: u32) -> Reply {
        let deadline = deadline_of(Instant::now(), deadline_ms);
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let reply = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match wire::decode_request(payload, &self.pool) {
                Ok(req) => self.execute_with_deadline(req, deadline),
                Err(e) => Reply::Error(e),
            }
        }))
        .unwrap_or_else(|_| Reply::Error("internal: request handler panicked".to_string()));
        if matches!(reply, Reply::Error(_)) {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        reply
    }

    /// The protocol-independent engine with no deadline.
    pub fn execute(&self, req: Request) -> Reply {
        self.execute_with_deadline(req, None)
    }

    /// The protocol-independent engine: every wire decodes into a
    /// [`Request`] and is answered from here. A data-plane request whose
    /// deadline has already passed — on arrival, or again at the solve-lane
    /// gate inside the ops — gets the typed `deadline_exceeded` error
    /// instead of queueing past-due work. Control-plane ops ignore
    /// deadlines like they ignore admission: health checks must always
    /// answer.
    pub fn execute_with_deadline(&self, req: Request, deadline: Option<Instant>) -> Reply {
        // Admission: data-plane requests hold an inflight slot for their
        // whole execution; past the limit they are shed with the canonical
        // `overloaded` reject. The control plane (ping/problems/stats) is
        // never refused — the router's health checks and an operator's
        // diagnostics must keep working exactly when the server is busiest.
        let _inflight = match req {
            Request::Ping | Request::Problems | Request::Stats | Request::Replicate { .. } => None,
            _ => {
                // A past-due request is not admitted at all: the typed error
                // is cheaper than any queueing, and the client has already
                // given up on the answer.
                if expired(deadline) {
                    self.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                    return Reply::Error(DEADLINE_EXCEEDED.to_string());
                }
                match self.admission.admit() {
                    Some(slot) => Some(slot),
                    None => {
                        self.admission.note_rejected();
                        return Reply::Error(OVERLOADED.to_string());
                    }
                }
            }
        };
        match req {
            Request::Ping => Reply::Pong,
            Request::Problems => Reply::Text(self.op_problems()),
            Request::Stats => Reply::Text(self.op_stats()),
            Request::Replicate { doc } => match self.apply_replica_delta(&doc) {
                Ok((facts, rho)) => {
                    self.stats.replicated_in.fetch_add(facts + rho, Ordering::Relaxed);
                    Reply::Text(Json::obj(vec![
                        ("replicated", Json::Bool(true)),
                        ("entries", Json::Num(facts as f64)),
                        ("rho", Json::Num(rho as f64)),
                    ]))
                }
                Err(e) => Reply::Error(e),
            },
            Request::Solve { problem, theta } => match self.lookup(&problem, &theta) {
                Ok(p) => self.op_solve(p, &theta),
                Err(e) => Reply::Error(e),
            },
            Request::Derivative { problem, theta, v, op, mode, precision, iters } => {
                match self.lookup(&problem, &theta) {
                    Ok(p) => {
                        self.op_derivative(p, &theta, v, op, mode, precision, iters, deadline)
                    }
                    Err(e) => Reply::Error(e),
                }
            }
            Request::Jacobian { problem, theta } => match self.lookup(&problem, &theta) {
                Ok(p) => self.op_jacobian(p, &theta, deadline),
                Err(e) => Reply::Error(e),
            },
        }
    }

    fn lookup(&self, name: &str, theta: &[f64]) -> Result<&Problem, String> {
        if name.is_empty() {
            return Err("missing 'problem'".to_string());
        }
        let p = self.registry.get(name).ok_or_else(|| {
            let names: Vec<&str> = self.registry.problems().iter().map(|p| p.name).collect();
            format!("unknown problem '{name}' (have: {})", names.join(", "))
        })?;
        if theta.len() != p.dim_theta() {
            return Err(format!(
                "'theta' must have length {}, got {}",
                p.dim_theta(),
                theta.len()
            ));
        }
        p.validate_theta(theta)?;
        Ok(p)
    }

    // ------------------------------------------------------ JSON decode --

    /// Parse one JSON request line into `(request, deadline_ms)` —
    /// `"deadline_ms"` is an optional member on any op (0 = no deadline).
    fn parse_request_json(&self, line: &str) -> Result<(Request, u32), String> {
        let req = json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        let deadline_ms = match req.get("deadline_ms") {
            None => 0u32,
            Some(j) => match j.as_f64() {
                Some(ms) if ms.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&ms) => {
                    ms as u32
                }
                _ => {
                    return Err("'deadline_ms' must be a non-negative integer".to_string());
                }
            },
        };
        let parsed = match req.str_or("op", "") {
            "ping" => Ok(Request::Ping),
            "problems" => Ok(Request::Problems),
            "stats" => Ok(Request::Stats),
            "solve" => Ok(Request::Solve {
                problem: required_problem(&req)?,
                theta: self.json_vec(&req, "theta")?,
            }),
            "hypergrad" | "vjp" => self.json_derivative(&req, BatchOp::Vjp, None),
            "jvp" => self.json_derivative(&req, BatchOp::Jvp, None),
            "jacobian" => Ok(Request::Jacobian {
                problem: required_problem(&req)?,
                theta: self.json_vec(&req, "theta")?,
            }),
            // Pre-registry aliases (PR 0 protocol).
            "ridge_hypergrad" => self.json_derivative(&req, BatchOp::Vjp, Some("ridge")),
            "ridge_jacobian" => Ok(Request::Jacobian {
                problem: "ridge".to_string(),
                theta: self.json_vec(&req, "theta")?,
            }),
            "" => Err("missing 'op'".to_string()),
            other => Err(format!("unknown op '{other}'")),
        };
        parsed.map(|r| (r, deadline_ms))
    }

    fn json_derivative(
        &self,
        req: &Json,
        op: BatchOp,
        forced_problem: Option<&str>,
    ) -> Result<Request, String> {
        let problem = match forced_problem {
            Some(name) => name.to_string(),
            None => required_problem(req)?,
        };
        let theta = self.json_vec(req, "theta")?;
        let v = self.json_vec(req, "v")?;
        let precision = match req.get("precision") {
            None => SolvePrecision::F64,
            Some(j) => j
                .as_str()
                .and_then(SolvePrecision::parse)
                .ok_or_else(|| "'precision' must be \"f64\" or \"mixed\"".to_string())?,
        };
        let mode = match req.get("mode") {
            None => DiffMode::Implicit,
            Some(j) => j.as_str().and_then(DiffMode::parse).ok_or_else(|| {
                "'mode' must be \"implicit\", \"unroll\", \"one-step\" or \"auto\"".to_string()
            })?,
        };
        // Explicit unroll depth (0 = let the policy derive it from ρ).
        let iters = match req.get("iters") {
            None => 0usize,
            Some(j) => match j.as_f64() {
                Some(k) if k.fract() == 0.0 && (1.0..=1e6).contains(&k) => k as usize,
                _ => return Err("'iters' must be a positive integer".to_string()),
            },
        };
        Ok(Request::Derivative { problem, theta, v, op, mode, precision, iters })
    }

    /// Decode a JSON number array into a pooled buffer (length validation
    /// happens in [`Server::lookup`] / `op_derivative`, which know the
    /// problem's dimensions).
    fn json_vec(&self, req: &Json, key: &str) -> Result<PoolVec, String> {
        let arr = req
            .get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("missing '{key}'"))?;
        let mut v = self.pool.take_f64(arr.len());
        for (i, x) in arr.iter().enumerate() {
            match x.as_f64() {
                Some(f) if f.is_finite() => v[i] = f,
                _ => return Err(format!("'{key}[{i}]' is not a finite number")),
            }
        }
        Ok(v)
    }

    // ------------------------------------------------------------- ops --

    fn op_problems(&self) -> Json {
        let rows: Vec<Json> = self
            .registry
            .problems()
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::Str(p.name.to_string())),
                    ("desc", Json::Str(p.describe.to_string())),
                    ("dim_x", Json::Num(p.dim_x() as f64)),
                    ("dim_theta", Json::Num(p.dim_theta() as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("problems", Json::Arr(rows))])
    }

    fn op_stats(&self) -> Json {
        let (batches, coalesced) = self.batcher.stats();
        let (hits, misses, evictions) = self.cache.stats();
        let (rho_hits, rho_misses) = self.rho_cache.stats();
        let pool = self.pool.stats();
        let (shard_id, shard_count) = self.cfg.shard.unwrap_or((0, 1));
        Json::obj(vec![
            ("requests", Json::Num(self.stats.requests.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(self.stats.errors.load(Ordering::Relaxed) as f64)),
            ("block_solves", Json::Num(self.stats.block_solves.load(Ordering::Relaxed) as f64)),
            ("inner_solves", Json::Num(self.stats.inner_solves.load(Ordering::Relaxed) as f64)),
            (
                "factorizations",
                Json::Num(self.stats.factorizations.load(Ordering::Relaxed) as f64),
            ),
            ("densified", Json::Num(self.stats.densified.load(Ordering::Relaxed) as f64)),
            (
                "rho_estimates",
                Json::Num(self.stats.rho_estimates.load(Ordering::Relaxed) as f64),
            ),
            ("batches", Json::Num(batches as f64)),
            ("coalesced_requests", Json::Num(coalesced as f64)),
            ("cache_hits", Json::Num(hits as f64)),
            ("cache_misses", Json::Num(misses as f64)),
            ("cache_evictions", Json::Num(evictions as f64)),
            ("cache_len", Json::Num(self.cache.len() as f64)),
            ("rho_cache_hits", Json::Num(rho_hits as f64)),
            ("rho_cache_misses", Json::Num(rho_misses as f64)),
            ("rho_cache_len", Json::Num(self.rho_cache.len() as f64)),
            ("pool_hits", Json::Num(pool.hits as f64)),
            ("pool_misses", Json::Num(pool.misses as f64)),
            ("pool_recycled", Json::Num(pool.recycled as f64)),
            ("workers", Json::Num(self.cfg.workers as f64)),
            // Cluster / admission fields (identical on both wires — the
            // binary `stats` reply carries this same JSON text).
            ("shard_id", Json::Num(shard_id as f64)),
            ("shard_count", Json::Num(shard_count as f64)),
            ("ring_size", Json::Num(shard_count as f64)),
            ("inflight", Json::Num(self.admission.inflight() as f64)),
            ("solve_inflight", Json::Num(self.admission.solve_inflight() as f64)),
            ("queue_depth", Json::Num(self.admission.queue_depth() as f64)),
            ("batcher_inflight", Json::Num(self.batcher.inflight() as f64)),
            ("rejected", Json::Num(self.admission.rejected() as f64)),
            ("degraded_one_step", Json::Num(self.admission.degraded_one_step() as f64)),
            (
                "deadline_exceeded",
                Json::Num(self.stats.deadline_exceeded.load(Ordering::Relaxed) as f64),
            ),
            (
                "replicated_out",
                Json::Num(self.stats.replicated_out.load(Ordering::Relaxed) as f64),
            ),
            (
                "replicated_in",
                Json::Num(self.stats.replicated_in.load(Ordering::Relaxed) as f64),
            ),
            ("actor_restarts", Json::Num(self.restarts.load(Ordering::Relaxed) as f64)),
            ("actor_give_ups", Json::Num(self.give_ups.load(Ordering::Relaxed) as f64)),
            (
                "catalog_fingerprint",
                Json::Str(format!("{:016x}", self.registry.catalog_fingerprint())),
            ),
        ])
    }

    /// x*(θ) through the cache; the bool reports whether this was a hit
    /// (hits skip the inner solve and the factorization entirely). Problems
    /// past the dense-factorization limit (or singular at this θ) still get
    /// their solution — they just never populate the cache.
    fn cached_solution(&self, p: &Problem, theta: &[f64]) -> (Arc<Vec<f64>>, bool) {
        let key = ThetaKey::new(p.name, theta);
        if let Some(entry) = self.cache.get(&key) {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            return (entry.x_star, true);
        }
        let x_star = Arc::new(p.solve(theta));
        self.stats.inner_solves.fetch_add(1, Ordering::Relaxed);
        if let Some(fact) = p.factorize(&x_star, theta) {
            self.stats.factorizations.fetch_add(1, Ordering::Relaxed);
            let entry = CacheEntry { x_star: x_star.clone(), fact: Arc::new(fact) };
            self.cache.insert(key, entry);
        }
        (x_star, false)
    }

    /// ρ(x*, θ) through the θ-keyed ρ-cache; power iteration only on a
    /// miss (counted in `rho_estimates`).
    fn cached_contraction(&self, p: &Problem, theta: &[f64], x_star: &[f64]) -> f64 {
        let key = ThetaKey::new(p.name, theta);
        if let Some(rho) = self.rho_cache.get(&key) {
            return rho;
        }
        let rho = p.contraction(x_star, theta);
        self.stats.rho_estimates.fetch_add(1, Ordering::Relaxed);
        self.rho_cache.insert(key, rho);
        rho
    }

    fn op_solve(&self, p: &Problem, theta: &[f64]) -> Reply {
        let (x_star, was_hit) = self.cached_solution(p, theta);
        Reply::Solution { x: x_star.as_ref().clone(), cached: was_hit }
    }

    /// The batched derivative path. Implicit/auto on a warm θ → factored
    /// substitution (zero iterative solves). Implicit on a miss →
    /// micro-batch onto ONE block solve under the requested arithmetic
    /// policy. One-step / unroll / auto on a miss → micro-batch onto a
    /// Jacobian-free compute: zero solves, zero factorizations, cache
    /// bypassed by design.
    #[allow(clippy::too_many_arguments)]
    fn op_derivative(
        &self,
        p: &Problem,
        theta: &[f64],
        v: PoolVec,
        op: BatchOp,
        mode: DiffMode,
        precision: SolvePrecision,
        iters: usize,
        deadline: Option<Instant>,
    ) -> Reply {
        let (in_dim, out_key) = match op {
            BatchOp::Vjp => (p.dim_x(), "grad"),
            BatchOp::Jvp => (p.dim_theta(), "jv"),
        };
        if v.len() != in_dim {
            return Reply::Error(format!("'v' must have length {in_dim}, got {}", v.len()));
        }

        // Fast path: prefactored θ. Only implicit and auto look — the
        // explicit solve-free modes bypass the cache by design.
        if matches!(mode, DiffMode::Implicit | DiffMode::Auto) {
            if let Some(entry) = self.cache.get(&ThetaKey::new(p.name, theta)) {
                self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                let vmat = Mat::from_col(&v);
                let before = counter::count();
                let out = match op {
                    BatchOp::Vjp => p.vjp_multi_factored(&entry.fact, &entry.x_star, theta, &vmat),
                    BatchOp::Jvp => p.jvp_multi_factored(&entry.fact, &entry.x_star, theta, &vmat),
                };
                self.stats
                    .block_solves
                    .fetch_add((counter::count() - before) as u64, Ordering::Relaxed);
                return Reply::Derivative {
                    out: out.col(0),
                    out_key,
                    batched: 1,
                    cached: true,
                    mode: "implicit",
                    degraded: false,
                };
            }
        }

        // Mode-aware degradation. When the solve lane is saturated, an
        // `"mode":"auto"` request whose ρ is already cached can be answered
        // solve-free (one-step / unroll) instead of queueing behind the
        // backlog — the closure below re-reads the same cached ρ, so the
        // decision is deterministic. A saturated auto request whose cached ρ
        // demands implicit is rejected here rather than queued; implicit
        // requests reject atomically at `solve_slot()` acquisition below.
        let mut degraded = false;
        if self.admission.solve_saturated() && mode == DiffMode::Auto {
            if let Some(rho) = self.rho_cache.peek(&ThetaKey::new(p.name, theta)) {
                if matches!(ModePolicy::default().select(rho, false), ModeDecision::Implicit) {
                    self.admission.note_rejected();
                    return Reply::Error(OVERLOADED.to_string());
                }
                degraded = true;
                self.admission.note_degraded();
            }
        }

        if mode == DiffMode::Implicit {
            // Deadline gate at the solve lane: a request whose budget ran
            // out while it waited must not claim a solve slot.
            if expired(deadline) {
                self.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                return Reply::Error(DEADLINE_EXCEEDED.to_string());
            }
            // Admission: the implicit path queues onto the solve lane; when
            // that lane is full the request is rejected up front instead of
            // growing an unbounded backlog. The slot guard spans the whole
            // batched solve.
            let _solve_slot = match self.admission.solve_slot() {
                Some(slot) => slot,
                None => {
                    self.admission.note_rejected();
                    return Reply::Error(OVERLOADED.to_string());
                }
            };
            // Batched implicit path: coalesce same-(problem, θ, op,
            // precision) requests into one block solve, then prefactor for
            // future repeats of this θ.
            let key = BatchKey::new(p.name, op, theta, precision);
            let (col, size) = self.batcher.submit(key, v, in_dim, |block| {
                let x_star = p.solve(theta);
                self.stats.inner_solves.fetch_add(1, Ordering::Relaxed);
                let solves_before = counter::count();
                let densify_before = densify::count();
                let (out, rep) = match op {
                    BatchOp::Vjp => p.vjp_multi_prec(&x_star, theta, block, precision),
                    BatchOp::Jvp => p.jvp_multi_prec(&x_star, theta, block, precision),
                };
                self.stats
                    .block_solves
                    .fetch_add((counter::count() - solves_before) as u64, Ordering::Relaxed);
                if !rep.converged {
                    return Err(format!(
                        "linear solve did not converge (residual {:.2e} after {} iterations)",
                        rep.max_residual, rep.iterations
                    ));
                }
                if let Some(fact) = p.factorize(&x_star, theta) {
                    self.stats.factorizations.fetch_add(1, Ordering::Relaxed);
                    self.cache.insert(
                        ThetaKey::new(p.name, theta),
                        CacheEntry { x_star: Arc::new(x_star), fact: Arc::new(fact) },
                    );
                }
                self.stats
                    .densified
                    .fetch_add((densify::count() - densify_before) as u64, Ordering::Relaxed);
                Ok(out)
            });
            return match col {
                Ok(col) => Reply::Derivative {
                    out: col,
                    out_key,
                    batched: size,
                    cached: false,
                    mode: "implicit",
                    degraded: false,
                },
                Err(e) => Reply::Error(e),
            };
        }

        // Solve-free path: one-step / truncated unroll / auto on a cold θ.
        // The leader solves the inner problem once for the whole batch and
        // answers with Jacobian products of the fixed-point map — no linear
        // solve, no factorization, no cache insert (an auto request that
        // resolves to implicit because T barely contracts is the one
        // exception: it pays the solve and prefactors like implicit would).
        let key = BatchKey::with_mode(p.name, op, theta, precision, mode, iters);
        let (col, size) = self.batcher.submit(key, v, in_dim, |block| {
            let x_star = p.solve(theta);
            self.stats.inner_solves.fetch_add(1, Ordering::Relaxed);
            let policy = ModePolicy::default();
            let need_rho =
                mode == DiffMode::Auto || (mode == DiffMode::Unroll && iters == 0);
            let rho =
                if need_rho { self.cached_contraction(p, theta, &x_star) } else { f64::NAN };
            let decision =
                policy.resolve(mode, rho, false, if iters > 0 { Some(iters) } else { None });
            let solves_before = counter::count();
            let densify_before = densify::count();
            let out = match decision {
                ModeDecision::OneStep => match op {
                    BatchOp::Vjp => p.one_step_vjp_multi(&x_star, theta, block),
                    BatchOp::Jvp => p.one_step_jvp_multi(&x_star, theta, block),
                },
                ModeDecision::Unroll(k) => match op {
                    BatchOp::Vjp => p.unroll_vjp_multi(&x_star, theta, block, k),
                    BatchOp::Jvp => p.unroll_jvp_multi(&x_star, theta, block, k),
                },
                ModeDecision::Implicit => {
                    let (out, rep) = match op {
                        BatchOp::Vjp => p.vjp_multi_prec(&x_star, theta, block, precision),
                        BatchOp::Jvp => p.jvp_multi_prec(&x_star, theta, block, precision),
                    };
                    if !rep.converged {
                        return Err(format!(
                            "linear solve did not converge (residual {:.2e} after {} iterations)",
                            rep.max_residual, rep.iterations
                        ));
                    }
                    if let Some(fact) = p.factorize(&x_star, theta) {
                        self.stats.factorizations.fetch_add(1, Ordering::Relaxed);
                        self.cache.insert(
                            ThetaKey::new(p.name, theta),
                            CacheEntry { x_star: Arc::new(x_star), fact: Arc::new(fact) },
                        );
                    }
                    out
                }
            };
            self.stats
                .block_solves
                .fetch_add((counter::count() - solves_before) as u64, Ordering::Relaxed);
            self.stats
                .densified
                .fetch_add((densify::count() - densify_before) as u64, Ordering::Relaxed);
            Ok(out)
        });
        match col {
            Ok(col) => Reply::Derivative {
                out: col,
                out_key,
                batched: size,
                cached: false,
                mode: mode.as_str(),
                degraded,
            },
            Err(e) => Reply::Error(e),
        }
    }

    fn op_jacobian(&self, p: &Problem, theta: &[f64], deadline: Option<Instant>) -> Reply {
        let key = ThetaKey::new(p.name, theta);
        let (jac, was_hit) = if let Some(entry) = self.cache.get(&key) {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            (p.jacobian_factored(&entry.fact, &entry.x_star, theta), true)
        } else {
            // Same deadline gate as the implicit derivative path: past-due
            // work never claims a solve slot (cache hits above are cheap
            // enough to always answer).
            if expired(deadline) {
                self.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                return Reply::Error(DEADLINE_EXCEEDED.to_string());
            }
            // A cold Jacobian rides the solve lane like implicit derivatives
            // do; saturation rejects instead of queueing (cache hits above
            // stay solve-free and are always served).
            let _solve_slot = match self.admission.solve_slot() {
                Some(slot) => slot,
                None => {
                    self.admission.note_rejected();
                    return Reply::Error(OVERLOADED.to_string());
                }
            };
            // One inner solve either way; the factorization decides between
            // the direct and the iterative Jacobian path.
            let x_star = p.solve(theta);
            self.stats.inner_solves.fetch_add(1, Ordering::Relaxed);
            match p.factorize(&x_star, theta) {
                Some(fact) => {
                    self.stats.factorizations.fetch_add(1, Ordering::Relaxed);
                    let entry =
                        CacheEntry { x_star: Arc::new(x_star), fact: Arc::new(fact) };
                    self.cache.insert(key, entry.clone());
                    (p.jacobian_factored(&entry.fact, &entry.x_star, theta), false)
                }
                // Singular A: nothing to cache, but the iterative (GMRES)
                // Jacobian still produces the best least-squares iterate
                // instead of refusing the request.
                None => {
                    let before = counter::count();
                    let jac = p.jacobian(&x_star, theta);
                    self.stats
                        .block_solves
                        .fetch_add((counter::count() - before) as u64, Ordering::Relaxed);
                    (jac, false)
                }
            }
        };
        Reply::Jacobian { jac, cached: was_hit }
    }

    /// Serve connections from an already-bound listener, dispatching each
    /// onto the supervised actor runtime: a bounded mailbox of accepted
    /// connections drained by `cfg.workers` connection actors. A panicking
    /// actor is restarted by its supervisor (counted in `actor_restarts`);
    /// an accept burst past `cfg.accept_queue` is shed with an
    /// `{"error":"overloaded"}` line instead of an unbounded backlog.
    /// Blocks forever (until process exit).
    pub fn serve_on(self: Arc<Self>, listener: TcpListener) -> std::io::Result<()> {
        self.clone().spawn_persist_thread();
        self.clone().spawn_replicator_thread();
        if self.cfg.handle_signals {
            self.clone().spawn_shutdown_watcher();
        }
        let mailbox = Mailbox::new(self.cfg.accept_queue);
        let me = self.clone();
        let handler: Arc<dyn Fn(TcpStream) + Send + Sync> = Arc::new(move |stream| {
            me.admission.conn_dequeued();
            let _ = handle_conn(&me, stream);
        });
        let _sup = cluster::actor::supervise(
            "serve-conn",
            self.cfg.workers,
            mailbox.clone(),
            handler,
            self.restarts.clone(),
            self.give_ups.clone(),
        );
        for stream in listener.incoming() {
            let stream = stream?;
            self.admission.conn_enqueued();
            if let Err(e) = mailbox.try_send(stream) {
                self.admission.conn_dequeued();
                self.admission.note_rejected();
                shed(e.into_inner());
            }
        }
        Ok(())
    }

    /// Install the SIGTERM/SIGINT latch and a watcher thread that writes the
    /// warm-start manifest (when configured) before exiting. Only called
    /// when `cfg.handle_signals` is set — the `idiff serve` binary opts in;
    /// embedded servers (tests, benches) never install process-wide
    /// handlers.
    pub fn spawn_shutdown_watcher(self: Arc<Self>) {
        crate::util::signal::install();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_millis(50));
            if crate::util::signal::requested() {
                if let Some(path) = &self.cfg.manifest_path {
                    match self.save_manifest(path) {
                        Ok(()) => println!(
                            "idiff serve: shutdown manifest written to {}",
                            path.display()
                        ),
                        Err(e) => {
                            eprintln!("idiff serve: shutdown manifest write failed: {e}")
                        }
                    }
                }
                std::process::exit(0);
            }
        });
    }

    /// Start the periodic manifest writer (a no-op unless both a manifest
    /// path and a nonzero interval are configured). `serve_on` calls this;
    /// embedders driving `handle`/`execute` directly can too.
    pub fn spawn_persist_thread(self: Arc<Self>) {
        let Some(path) = self.cfg.manifest_path.clone() else { return };
        if self.cfg.persist_secs == 0 {
            return;
        }
        let period = Duration::from_secs(self.cfg.persist_secs);
        std::thread::spawn(move || loop {
            std::thread::sleep(period);
            if let Err(e) = self.save_manifest(&path) {
                eprintln!("idiff serve: manifest persist failed: {e}");
            }
        });
    }

    /// Start the warm-state replicator (a no-op unless this server is a
    /// shard with `peers` configured and a nonzero interval). Each pass
    /// ships every owned warm entry this shard has not shipped yet to the
    /// shard that would inherit its θ on this shard's death — the key's
    /// owner on the ring *minus self*, which is exactly the re-hash the
    /// router performs on failover. `serve_on` calls this; embedded
    /// sharded servers can too.
    pub fn spawn_replicator_thread(self: Arc<Self>) {
        let Some((idx, _)) = self.cfg.shard else { return };
        if self.cfg.peers.is_empty() || self.cfg.replicate_secs == 0 {
            return;
        }
        let period = Duration::from_secs(self.cfg.replicate_secs);
        std::thread::spawn(move || {
            let mut shipped_facts: HashSet<ThetaKey> = HashSet::new();
            let mut shipped_rho: HashSet<ThetaKey> = HashSet::new();
            loop {
                std::thread::sleep(period);
                self.replicate_once(idx, &mut shipped_facts, &mut shipped_rho);
            }
        });
    }

    /// One replication pass; returns how many entries shipped. One frame
    /// per entry keeps every delta far under `max_line_bytes`; the
    /// shipped-sets make a steady-state pass free (failures stay
    /// un-shipped and retry next pass).
    fn replicate_once(
        &self,
        idx: usize,
        shipped_facts: &mut HashSet<ThetaKey>,
        shipped_rho: &mut HashSet<ThetaKey>,
    ) -> usize {
        let Some((_, ring)) = &self.ring else { return 0 };
        let survivors: Vec<u32> =
            ring.members().iter().copied().filter(|&m| m != idx as u32).collect();
        if survivors.is_empty() {
            return 0;
        }
        // The ring without this shard: where each of our keys would land
        // if we died right now.
        let successors = Ring::new(&survivors, self.cfg.vnodes);
        let mut shipped = 0usize;
        for (key, entry) in self.cache.snapshot() {
            if shipped_facts.contains(&key) || !self.owns(&key.problem, &key.theta()) {
                continue;
            }
            let Some(target) = successors.owner(Ring::route_key(&key.problem, &key.theta()))
            else {
                continue;
            };
            let doc =
                self.replica_delta_doc(&[(key.clone(), entry)], &[], idx).to_string_compact();
            if self.ship_delta(target, &doc) {
                shipped_facts.insert(key);
                self.stats.replicated_out.fetch_add(1, Ordering::Relaxed);
                shipped += 1;
            }
        }
        for (key, rho) in self.rho_cache.snapshot() {
            if shipped_rho.contains(&key) || !self.owns(&key.problem, &key.theta()) {
                continue;
            }
            let Some(target) = successors.owner(Ring::route_key(&key.problem, &key.theta()))
            else {
                continue;
            };
            let doc =
                self.replica_delta_doc(&[], &[(key.clone(), rho)], idx).to_string_compact();
            if self.ship_delta(target, &doc) {
                shipped_rho.insert(key);
                self.stats.replicated_out.fetch_add(1, Ordering::Relaxed);
                shipped += 1;
            }
        }
        shipped
    }

    /// Ship one replica-delta document to peer shard `target` over a
    /// fresh binary-wire connection. Failures are silent by design —
    /// replication is best-effort background work and the next pass
    /// retries anything that did not land.
    fn ship_delta(&self, target: u32, doc: &str) -> bool {
        let Some(addr) = self.cfg.peers.get(target as usize) else { return false };
        let mut frame = Vec::new();
        wire::encode_replicate(doc.as_bytes(), &mut frame);
        let Ok(mut stream) = TcpStream::connect(addr) else { return false };
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
        if stream.write_all(&frame).is_err() {
            return false;
        }
        matches!(wire::read_reply(&mut stream), Ok(reply) if reply.status == wire::STATUS_OK)
    }

    /// Bind `addr` and serve (see [`Server::serve_on`]). Prints the bound
    /// address (not the requested one) so `--addr host:0` callers — the e2e
    /// harness, scripted shard launchers — can parse the ephemeral port.
    pub fn serve(self: Arc<Self>, addr: &str) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        match self.cfg.shard {
            Some((i, n)) => println!(
                "idiff serve: listening on {local} ({} workers, shard {i}/{n})",
                self.cfg.workers
            ),
            None => {
                println!("idiff serve: listening on {local} ({} workers)", self.cfg.workers)
            }
        }
        self.serve_on(listener)
    }
}

/// Render a reply as the JSON line protocol's object shapes.
pub fn reply_to_json(reply: Reply) -> Json {
    match reply {
        Reply::Pong => Json::obj(vec![("ok", Json::Bool(true))]),
        Reply::Text(j) => j,
        Reply::Solution { x, cached } => {
            Json::obj(vec![("x", Json::arr_f64(&x)), ("cached", Json::Bool(cached))])
        }
        Reply::Derivative { out, out_key, batched, cached, mode, degraded } => {
            let mut members = vec![
                (out_key, Json::arr_f64(&out)),
                ("batched", Json::Num(batched as f64)),
                ("cached", Json::Bool(cached)),
                ("mode", Json::Str(mode.to_string())),
            ];
            // Only present when true, so pre-cluster replies stay
            // byte-identical.
            if degraded {
                members.push(("degraded", Json::Bool(true)));
            }
            Json::obj(members)
        }
        Reply::Jacobian { jac, cached } => {
            let rows: Vec<Json> = (0..jac.rows).map(|i| Json::arr_f64(jac.row(i))).collect();
            Json::obj(vec![("jacobian", Json::Arr(rows)), ("cached", Json::Bool(cached))])
        }
        Reply::Error(e) => Json::obj(vec![("error", Json::Str(e))]),
    }
}

/// Absolute deadline for a request that arrived at `arrival` carrying a
/// `deadline_ms` budget (0 = no deadline, the wire default on both
/// protocols).
fn deadline_of(arrival: Instant, deadline_ms: u32) -> Option<Instant> {
    (deadline_ms > 0).then(|| arrival + Duration::from_millis(deadline_ms as u64))
}

fn expired(deadline: Option<Instant>) -> bool {
    deadline.map_or(false, |d| Instant::now() >= d)
}

fn required_problem(req: &Json) -> Result<String, String> {
    let name = req.str_or("problem", "");
    if name.is_empty() {
        return Err("missing 'problem'".to_string());
    }
    Ok(name.to_string())
}

/// Best-effort overload reply for a connection shed at the accept queue.
/// Shedding happens before protocol detection, so the reject is the JSON
/// line; a binary client sees a short read and treats the connection as
/// refused — either way the stream closes immediately.
fn shed(mut stream: TcpStream) {
    let _ = stream.write_all(b"{\"error\":\"overloaded\"}\n");
}

pub(crate) fn is_disconnect(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::UnexpectedEof
    )
}

fn handle_conn(server: &Server, stream: TcpStream) -> std::io::Result<()> {
    // An open connection holds a pool worker; an idle one must hand it back.
    let _ = stream.set_read_timeout(Some(server.cfg.idle_timeout));
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Protocol auto-detection: a binary connection's first byte is the
    // frame magic 0xB1, which no JSON line can start with.
    let first = match reader.fill_buf() {
        Ok([]) => return Ok(()), // EOF before the first byte
        Ok(buf) => buf[0],
        Err(e) if is_disconnect(&e) => return Ok(()),
        Err(e) => return Err(e),
    };
    if first == wire::MAGIC {
        serve_binary_conn(server, reader, writer)
    } else {
        serve_json_conn(server, reader, writer)
    }
}

fn serve_json_conn(
    server: &Server,
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
) -> std::io::Result<()> {
    let mut line = String::new();
    // One pooled reply buffer recycled across every line this connection
    // sends — replies serialize straight into it (no per-reply String).
    let mut out = server.pool.take_bytes(4096);
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            Err(e) if is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match faults::at(faults::SITE_SHARD_REQUEST) {
            Some(faults::Action::Drop) => continue, // swallow: no reply
            Some(faults::Action::CloseMidFrame) => return Ok(()),
            _ => {}
        }
        let resp = server.handle(trimmed);
        out.clear();
        resp.write_compact_bytes(&mut out);
        out.push(b'\n');
        match faults::at(faults::SITE_SHARD_REPLY) {
            Some(faults::Action::Drop) => continue, // reply lost in flight
            Some(faults::Action::CloseMidFrame) => {
                let _ = writer.write_all(&out[..out.len().min(3)]);
                return Ok(());
            }
            _ => {}
        }
        writer.write_all(&out)?;
    }
}

fn serve_binary_conn(
    server: &Server,
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
) -> std::io::Result<()> {
    // One payload buffer and one reply buffer, recycled across every frame
    // this connection ever sends.
    let mut payload = server.pool.take_bytes(4096);
    let mut out = server.pool.take_bytes(4096);
    loop {
        let mut hdr = [0u8; wire::REQUEST_HEADER_LEN];
        match reader.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        let (len, deadline_ms) = match wire::parse_request_header(&hdr, server.cfg.max_line_bytes)
        {
            Ok(parsed) => parsed,
            Err(msg) => {
                // Framing violation: the stream can no longer be delimited.
                // Reply with an error frame, then close.
                server.stats.requests.fetch_add(1, Ordering::Relaxed);
                server.stats.errors.fetch_add(1, Ordering::Relaxed);
                out.clear();
                wire::encode_reply(&Reply::Error(msg), &mut out);
                let _ = writer.write_all(&out);
                return Ok(());
            }
        };
        payload.resize(len, 0);
        match reader.read_exact(&mut payload[..]) {
            Ok(()) => {}
            Err(e) if is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
        match faults::at(faults::SITE_SHARD_REQUEST) {
            Some(faults::Action::Drop) => continue, // swallow: no reply
            Some(faults::Action::CloseMidFrame) => return Ok(()),
            _ => {}
        }
        let reply = server.handle_frame_deadline(&payload, deadline_ms);
        out.clear();
        wire::encode_reply(&reply, &mut out);
        match faults::at(faults::SITE_SHARD_REPLY) {
            Some(faults::Action::Drop) => continue, // reply lost in flight
            Some(faults::Action::CloseMidFrame) => {
                let _ = writer.write_all(&out[..out.len().min(3)]);
                return Ok(());
            }
            _ => {}
        }
        writer.write_all(&out)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::root::implicit_vjp;
    use crate::linalg::solve::LinearSolveConfig;
    use crate::ml::ridge::{RidgeProblem, RidgeRoot};

    fn quiet_cfg() -> ServeConfig {
        // window 0: no deliberate waiting in single-threaded tests
        ServeConfig { batch_window: Duration::from_millis(0), ..ServeConfig::default() }
    }

    #[test]
    fn ping_problems_stats() {
        let s = Server::new(quiet_cfg());
        assert_eq!(s.handle(r#"{"op":"ping"}"#).get("ok"), Some(&Json::Bool(true)));
        let probs = s.handle(r#"{"op":"problems"}"#);
        let arr = probs.get("problems").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 7);
        assert!(arr.iter().any(|p| p.str_or("name", "") == "svm"));
        assert!(arr.iter().any(|p| p.str_or("name", "") == "sparse_logreg"));
        let stats = s.handle(r#"{"op":"stats"}"#);
        assert!(stats.f64_or("requests", -1.0) >= 2.0);
        // The new counters are part of the stats surface.
        for key in ["rho_estimates", "rho_cache_hits", "pool_hits", "pool_recycled"] {
            assert!(stats.get(key).is_some(), "stats missing '{key}'");
        }
    }

    #[test]
    fn errors_are_clean_json() {
        let s = Server::new(quiet_cfg());
        for (req, needle) in [
            ("not json", "bad json"),
            (r#"{"op":"zap"}"#, "unknown op"),
            (r#"{"theta":[1]}"#, "missing 'op'"),
            (r#"{"op":"solve"}"#, "missing 'problem'"),
            (r#"{"op":"solve","problem":"nope","theta":[1]}"#, "unknown problem"),
            (r#"{"op":"solve","problem":"svm","theta":[1,2]}"#, "length 1"),
            (r#"{"op":"solve","problem":"svm","theta":[-1]}"#, "θ > 0"),
            (r#"{"op":"hypergrad","problem":"quad","theta":[1,1,1,1]}"#, "missing 'v'"),
            (r#"{"op":"hypergrad","problem":"quad","theta":[1,1,1,1],"v":[1,2]}"#, "length 6"),
            (r#"{"op":"solve","problem":"lasso","theta":["x"]}"#, "not a finite number"),
        ] {
            let r = s.handle(req);
            let msg = r.get("error").and_then(Json::as_str).unwrap_or_else(|| {
                panic!("expected error for {req}, got {}", r.to_string_compact())
            });
            assert!(msg.contains(needle), "{req}: '{msg}' should contain '{needle}'");
        }
        // oversized line
        let s2 = Server::new(ServeConfig { max_line_bytes: 64, ..quiet_cfg() });
        let big = format!(r#"{{"op":"solve","problem":"ridge","theta":[{}]}}"#, "1.0,".repeat(100));
        assert!(s2.handle(&big).str_or("error", "").contains("too large"));
        let errs = s2.stats.errors.load(Ordering::Relaxed);
        assert_eq!(errs, 1);
    }

    #[test]
    fn hypergrad_matches_direct_implicit_vjp_and_legacy_alias() {
        let s = Server::new(quiet_cfg());
        let theta = vec![1.0; 8];
        let v = vec![1.0; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&v)),
        ]);
        let r = s.handle(&req.to_string_compact());
        let g: Vec<f64> = r
            .get("grad")
            .and_then(Json::as_arr)
            .expect("grad")
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        // ground truth through the library path on the same data
        let (x, y) = crate::data::regression::diabetes_like(64, 8, 7);
        let rp = RidgeProblem::new(x, y);
        let x_star = rp.solve_closed_form_vec(&theta);
        let (truth, _) = implicit_vjp(
            &RidgeRoot(&rp),
            &x_star,
            &theta,
            &v,
            &LinearSolveConfig::default(),
        );
        for i in 0..8 {
            assert!((g[i] - truth[i]).abs() < 1e-7, "{}: {} vs {}", i, g[i], truth[i]);
        }
        // legacy alias answers the same
        let legacy = Json::obj(vec![
            ("op", Json::Str("ridge_hypergrad".into())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&v)),
        ]);
        // (the alias hits the now-populated factorization cache, so this
        // also cross-checks the factored path against the iterative one)
        let r2 = s.handle(&legacy.to_string_compact());
        let g2 = r2.get("grad").and_then(Json::as_arr).expect("legacy grad");
        for i in 0..8 {
            assert!((g2[i].as_f64().unwrap() - g[i]).abs() < 1e-7);
        }
        // jacobian (legacy alias too) matches the closed form
        let jreq = Json::obj(vec![
            ("op", Json::Str("ridge_jacobian".into())),
            ("theta", Json::arr_f64(&theta)),
        ]);
        let jr = s.handle(&jreq.to_string_compact());
        let jac = jr.get("jacobian").and_then(Json::as_arr).expect("jacobian");
        let truth = rp.jacobian_closed_form(&theta);
        for i in 0..8 {
            let row = jac[i].as_arr().unwrap();
            for j in 0..8 {
                assert!((row[j].as_f64().unwrap() - truth.at(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn repeat_theta_is_served_from_cache_with_zero_new_solves() {
        let s = Server::new(quiet_cfg());
        let theta = vec![0.9; 8];
        let v = vec![0.5; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&v)),
        ])
        .to_string_compact();
        let first = s.handle(&req);
        assert_eq!(first.get("cached"), Some(&Json::Bool(false)));
        let solves_after_first = s.stats.block_solves.load(Ordering::Relaxed);
        let inner_after_first = s.stats.inner_solves.load(Ordering::Relaxed);
        assert_eq!(solves_after_first, 1);
        assert_eq!(inner_after_first, 1);
        let second = s.handle(&req);
        assert_eq!(second.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(
            s.stats.block_solves.load(Ordering::Relaxed),
            solves_after_first,
            "repeat-θ must not issue new iterative solves"
        );
        assert_eq!(
            s.stats.inner_solves.load(Ordering::Relaxed),
            inner_after_first,
            "repeat-θ must not re-solve the inner problem"
        );
        // identical answers on both paths
        let a = first.get("grad").and_then(Json::as_arr).unwrap();
        let b = second.get("grad").and_then(Json::as_arr).unwrap();
        for i in 0..8 {
            assert!((a[i].as_f64().unwrap() - b[i].as_f64().unwrap()).abs() < 1e-7);
        }
        assert_eq!(s.stats.cache_hits.load(Ordering::Relaxed), 1);
        // …and the second request's θ/v decode reused pooled buffers.
        assert!(s.pool.stats().hits >= 2, "repeat request must hit the buffer pool");
    }

    /// The tentpole acceptance property: N concurrent hypergrad requests on
    /// one (problem, θ) → exactly ONE block solve, answers identical to the
    /// serial path.
    #[test]
    fn concurrent_hypergrads_coalesce_into_one_block_solve() {
        let n = 6;
        let s = Arc::new(Server::new(ServeConfig {
            batch_window: Duration::from_secs(10), // full batch closes it
            batch_max: n,
            ..ServeConfig::default()
        }));
        let theta = vec![1.1; 8];
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let s = s.clone();
                let theta = theta.clone();
                std::thread::spawn(move || {
                    let mut v = vec![0.0; 8];
                    v[i % 8] = 1.0 + i as f64;
                    let req = Json::obj(vec![
                        ("op", Json::Str("hypergrad".into())),
                        ("problem", Json::Str("ridge".into())),
                        ("theta", Json::arr_f64(&theta)),
                        ("v", Json::arr_f64(&v)),
                    ]);
                    let r = s.handle(&req.to_string_compact());
                    let g: Vec<f64> = r
                        .get("grad")
                        .and_then(Json::as_arr)
                        .unwrap_or_else(|| panic!("no grad: {}", r.to_string_compact()))
                        .iter()
                        .filter_map(Json::as_f64)
                        .collect();
                    let k = r.f64_or("batched", 0.0) as usize;
                    (v, g, k)
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(
            s.stats.block_solves.load(Ordering::Relaxed),
            1,
            "k concurrent hypergrads on one θ must be ONE block solve"
        );
        assert_eq!(s.stats.inner_solves.load(Ordering::Relaxed), 1);
        for (_, _, k) in &results {
            assert_eq!(*k, n, "every member sees the full batch");
        }
        // serial ground truth per member
        let serial = Server::new(quiet_cfg());
        for (v, g, _) in &results {
            let req = Json::obj(vec![
                ("op", Json::Str("hypergrad".into())),
                ("problem", Json::Str("ridge".into())),
                ("theta", Json::arr_f64(&theta)),
                ("v", Json::arr_f64(v)),
            ]);
            let r = serial.handle(&req.to_string_compact());
            let gs = r.get("grad").and_then(Json::as_arr).unwrap();
            for i in 0..8 {
                assert!(
                    (g[i] - gs[i].as_f64().unwrap()).abs() < 1e-7,
                    "batched vs serial mismatch at {i}"
                );
            }
        }
        // …and the batch populated the cache: one more request, zero solves.
        let before = s.stats.block_solves.load(Ordering::Relaxed);
        let req = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta)),
            ("v", Json::arr_f64(&vec![1.0; 8])),
        ]);
        let r = s.handle(&req.to_string_compact());
        assert_eq!(r.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(s.stats.block_solves.load(Ordering::Relaxed), before);
    }

    /// Mixed-precision requests take their own batch, land within refinement
    /// tolerance of the f64 answer, and an invalid policy is a clean error.
    #[test]
    fn precision_field_mixed_matches_f64_and_validates() {
        let s = Server::new(quiet_cfg());
        let bad = s.handle(
            r#"{"op":"hypergrad","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1],"precision":"f16"}"#,
        );
        assert!(bad.str_or("error", "").contains("precision"));
        let theta = vec![0.8; 8];
        let v = vec![0.7; 8];
        let mk = |prec: &str| {
            let mut fields = vec![
                ("op", Json::Str("hypergrad".into())),
                ("problem", Json::Str("ridge".into())),
                ("theta", Json::arr_f64(&theta)),
                ("v", Json::arr_f64(&v)),
            ];
            if !prec.is_empty() {
                fields.push(("precision", Json::Str(prec.into())));
            }
            Json::obj(fields).to_string_compact()
        };
        // mixed first: forces the f32-inner/f64-refined iterative block
        // solve (the cache is still empty), then prefactors in full f64.
        let rm = s.handle(&mk("mixed"));
        assert_eq!(rm.get("cached"), Some(&Json::Bool(false)));
        let gm: Vec<f64> = rm
            .get("grad")
            .and_then(Json::as_arr)
            .expect("mixed grad")
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        // f64 repeat hits the (precision-independent) factorization cache.
        let rf = s.handle(&mk("f64"));
        assert_eq!(rf.get("cached"), Some(&Json::Bool(true)));
        let gf: Vec<f64> = rf
            .get("grad")
            .and_then(Json::as_arr)
            .expect("f64 grad")
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let scale = gf.iter().fold(1.0f64, |m, g| m.max(g.abs()));
        for i in 0..8 {
            assert!(
                (gm[i] - gf[i]).abs() < 1e-6 * scale,
                "{i}: mixed {} vs f64 {}",
                gm[i],
                gf[i]
            );
        }
    }

    /// The `"mode"` field end to end: validation, the solve-free one-step
    /// path (zero iterative solves, zero factorizations, zero dense
    /// materializations, cache bypassed), the O(ρ)/O(ρᵏ) accuracy bounds
    /// against the implicit answer, and auto's cold→solve-free /
    /// warm→factored switching.
    #[test]
    fn mode_field_serves_solve_free_answers_within_contraction_bounds() {
        let s = Server::new(quiet_cfg());
        let bad = s.handle(
            r#"{"op":"hypergrad","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1],"mode":"onestep"}"#,
        );
        assert!(bad.str_or("error", "").contains("mode"));
        let bad_iters = s.handle(
            r#"{"op":"jvp","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1],"mode":"unroll","iters":0.5}"#,
        );
        assert!(bad_iters.str_or("error", "").contains("iters"));

        let theta = vec![1.2; 8];
        let v = vec![0.4; 8];
        let mk = |op: &str, mode: &str, iters: usize| {
            let mut fields = vec![
                ("op", Json::Str(op.into())),
                ("problem", Json::Str("ridge".into())),
                ("theta", Json::arr_f64(&theta)),
                ("v", Json::arr_f64(&v)),
            ];
            if !mode.is_empty() {
                fields.push(("mode", Json::Str(mode.into())));
            }
            if iters > 0 {
                fields.push(("iters", Json::Num(iters as f64)));
            }
            Json::obj(fields).to_string_compact()
        };
        let vec_of = |r: &Json, key: &str| -> Vec<f64> {
            r.get(key)
                .and_then(Json::as_arr)
                .unwrap_or_else(|| panic!("no {key} in {}", r.to_string_compact()))
                .iter()
                .filter_map(Json::as_f64)
                .collect()
        };

        // One-step on a cold θ: Jacobian-free end to end.
        let r_os = s.handle(&mk("hypergrad", "one-step", 0));
        assert_eq!(r_os.get("cached"), Some(&Json::Bool(false)));
        assert_eq!(r_os.str_or("mode", ""), "one-step");
        assert_eq!(vec_of(&r_os, "grad").len(), 8);
        let jv_os = vec_of(&s.handle(&mk("jvp", "one-step", 0)), "jv");
        assert_eq!(s.stats.block_solves.load(Ordering::Relaxed), 0);
        assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), 0);
        assert_eq!(s.stats.densified.load(Ordering::Relaxed), 0);
        assert_eq!(s.cache.len(), 0, "one-step must bypass the θ-cache");
        assert_eq!(s.stats.inner_solves.load(Ordering::Relaxed), 2);

        // Implicit on the same θ: pays the solve, factorizes, warms the cache.
        let jv_imp = vec_of(&s.handle(&mk("jvp", "", 0)), "jv");
        assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), 1);
        let norm = |x: &[f64]| x.iter().map(|a| a * a).sum::<f64>().sqrt();
        let diff = |a: &[f64], b: &[f64]| {
            norm(&a.iter().zip(b).map(|(x, y)| x - y).collect::<Vec<f64>>())
        };
        let p = s.registry.get("ridge").unwrap();
        let x_star = p.solve(&theta);
        let rho = p.contraction(&x_star, &theta);
        assert!(rho > 0.0 && rho < 1.0, "ridge gradient step must contract, rho = {rho}");
        // The Bolte-style bound ‖(J_os − J_imp)v‖ ≤ ρ‖J_imp v‖ (slack for
        // the power-iteration estimate approaching σ_max from below).
        let err_os = diff(&jv_os, &jv_imp);
        assert!(
            err_os <= 1.1 * rho * norm(&jv_imp) + 1e-12,
            "one-step err {err_os} vs rho {rho} · {}",
            norm(&jv_imp)
        );
        // unroll(k) tightens geometrically: err ≤ ρᵏ‖J_imp v‖.
        let jv_u6 = vec_of(&s.handle(&mk("jvp", "unroll", 6)), "jv");
        let err_u6 = diff(&jv_u6, &jv_imp);
        assert!(
            err_u6 <= 1.1 * rho.powi(6) * norm(&jv_imp) + 1e-9,
            "unroll(6) err {err_u6} vs rho^6 bound"
        );
        assert!(err_u6 <= err_os + 1e-12, "unroll(6) must beat one-step");

        // Auto on the now-warm θ serves the factored implicit answer…
        let r_auto = s.handle(&mk("hypergrad", "auto", 0));
        assert_eq!(r_auto.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(r_auto.str_or("mode", ""), "implicit");
        // …and on a cold θ goes one-step: no new solves or factorizations.
        let solves_before = s.stats.block_solves.load(Ordering::Relaxed);
        let facts_before = s.stats.factorizations.load(Ordering::Relaxed);
        let theta2 = vec![0.7; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta2)),
            ("v", Json::arr_f64(&v)),
            ("mode", Json::Str("auto".into())),
        ]);
        let r_cold = s.handle(&req.to_string_compact());
        assert_eq!(r_cold.get("cached"), Some(&Json::Bool(false)));
        assert_eq!(r_cold.str_or("mode", ""), "auto");
        assert_eq!(vec_of(&r_cold, "grad").len(), 8);
        assert_eq!(s.stats.block_solves.load(Ordering::Relaxed), solves_before);
        assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), facts_before);
    }

    /// Repeat (problem, θ) auto-mode requests on a cold factorization cache
    /// must run power iteration exactly ONCE: the ρ-cache absorbs the rest.
    #[test]
    fn repeat_auto_theta_runs_power_iteration_once() {
        let s = Server::new(quiet_cfg());
        let theta = vec![1.3; 8];
        let mk = |i: usize| {
            let mut v = vec![0.0; 8];
            v[i % 8] = 1.0;
            Json::obj(vec![
                ("op", Json::Str("hypergrad".into())),
                ("problem", Json::Str("ridge".into())),
                ("theta", Json::arr_f64(&theta)),
                ("v", Json::arr_f64(&v)),
                ("mode", Json::Str("auto".into())),
            ])
            .to_string_compact()
        };
        for i in 0..4 {
            let r = s.handle(&mk(i));
            assert!(r.get("error").is_none(), "{}", r.to_string_compact());
            // Distinct v's → distinct batches, all solve-free on the cold θ.
            assert_eq!(r.get("cached"), Some(&Json::Bool(false)));
        }
        assert_eq!(
            s.stats.rho_estimates.load(Ordering::Relaxed),
            1,
            "repeat-θ auto must serve ρ from the cache after the first estimate"
        );
        let (rho_hits, rho_misses) = s.rho_cache.stats();
        assert_eq!((rho_hits, rho_misses), (3, 1));
        // A different θ is a genuinely new estimate.
        let theta2 = vec![0.65; 8];
        let req = Json::obj(vec![
            ("op", Json::Str("hypergrad".into())),
            ("problem", Json::Str("ridge".into())),
            ("theta", Json::arr_f64(&theta2)),
            ("v", Json::arr_f64(&vec![1.0; 8])),
            ("mode", Json::Str("auto".into())),
        ]);
        s.handle(&req.to_string_compact());
        assert_eq!(s.stats.rho_estimates.load(Ordering::Relaxed), 2);
        // Factorization cache stayed cold throughout (auto went solve-free).
        assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn jvp_and_solve_round_trip_on_every_problem() {
        let s = Server::new(quiet_cfg());
        for p in s.registry.problems() {
            let theta: Vec<f64> = (0..p.dim_theta()).map(|i| 0.6 + 0.1 * i as f64).collect();
            let sreq = Json::obj(vec![
                ("op", Json::Str("solve".into())),
                ("problem", Json::Str(p.name.into())),
                ("theta", Json::arr_f64(&theta)),
            ]);
            let sr = s.handle(&sreq.to_string_compact());
            let x = sr.get("x").and_then(Json::as_arr).unwrap_or_else(|| {
                panic!("{}: no x in {}", p.name, sr.to_string_compact())
            });
            assert_eq!(x.len(), p.dim_x(), "{}", p.name);
            let v = vec![0.3; p.dim_theta()];
            let jreq = Json::obj(vec![
                ("op", Json::Str("jvp".into())),
                ("problem", Json::Str(p.name.into())),
                ("theta", Json::arr_f64(&theta)),
                ("v", Json::arr_f64(&v)),
            ]);
            let jr = s.handle(&jreq.to_string_compact());
            let jv = jr.get("jv").and_then(Json::as_arr).unwrap_or_else(|| {
                panic!("{}: no jv in {}", p.name, jr.to_string_compact())
            });
            assert_eq!(jv.len(), p.dim_x(), "{}", p.name);
            assert!(jv.iter().all(|x| x.as_f64().unwrap().is_finite()), "{}", p.name);
        }
    }

    /// A data-plane request whose deadline has already passed gets the
    /// typed `deadline_exceeded` error and never touches the solve path;
    /// the control plane ignores deadlines entirely.
    #[test]
    fn expired_deadlines_get_the_typed_error_and_never_solve() {
        let s = Server::new(quiet_cfg());
        assert_eq!(deadline_of(Instant::now(), 0), None, "0 = no deadline");
        assert!(!expired(None));
        let past = Some(Instant::now() - Duration::from_millis(5));
        assert!(expired(past));
        assert!(!expired(Some(Instant::now() + Duration::from_secs(3600))));

        let line = r#"{"op":"hypergrad","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1]}"#;
        let (req, deadline_ms) = s.parse_request_json(line).unwrap();
        assert_eq!(deadline_ms, 0, "no member = no deadline");
        match s.execute_with_deadline(req, past) {
            Reply::Error(e) => assert_eq!(e, DEADLINE_EXCEEDED),
            _ => panic!("expected the typed deadline error"),
        }
        // Past-due cold Jacobians gate at the solve lane too.
        let jline = r#"{"op":"jacobian","problem":"ridge","theta":[1,1,1,1,1,1,1,1]}"#;
        let (jreq, _) = s.parse_request_json(jline).unwrap();
        assert!(matches!(s.execute_with_deadline(jreq, past), Reply::Error(e) if e == DEADLINE_EXCEEDED));
        assert_eq!(s.stats.deadline_exceeded.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.inner_solves.load(Ordering::Relaxed), 0);
        assert_eq!(s.stats.block_solves.load(Ordering::Relaxed), 0);
        assert_eq!(s.stats.factorizations.load(Ordering::Relaxed), 0);
        // Health checks must answer exactly when things are past due.
        assert!(matches!(s.execute_with_deadline(Request::Ping, past), Reply::Pong));
    }

    /// The JSON wire's `"deadline_ms"` member: a generous budget answers
    /// normally, malformed budgets are clean errors, and the new
    /// fault-tolerance counters are part of the stats surface.
    #[test]
    fn deadline_ms_member_parses_and_counters_surface_in_stats() {
        let s = Server::new(quiet_cfg());
        let r = s.handle(
            r#"{"op":"hypergrad","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1],"deadline_ms":60000}"#,
        );
        assert!(r.get("grad").is_some(), "{}", r.to_string_compact());
        for bad in [r#""deadline_ms":-5"#, r#""deadline_ms":1.5"#, r#""deadline_ms":"soon""#] {
            let line = format!(r#"{{"op":"ping",{bad}}}"#);
            let r = s.handle(&line);
            assert!(
                r.str_or("error", "").contains("deadline_ms"),
                "{}",
                r.to_string_compact()
            );
        }
        let stats = s.handle(r#"{"op":"stats"}"#);
        for key in ["deadline_exceeded", "replicated_out", "replicated_in", "actor_give_ups"] {
            assert!(stats.get(key).is_some(), "stats missing '{key}'");
        }
        assert_eq!(stats.f64_or("deadline_exceeded", -1.0), 0.0);
    }
}
