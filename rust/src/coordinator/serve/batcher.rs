//! Request micro-batching: coalesce concurrent derivative requests on the
//! same (problem, θ, op) into ONE multi-RHS block solve.
//!
//! The first request to open a batch becomes its *leader*: it waits up to
//! the batching window (or until the batch is full — whichever comes first)
//! for followers to join, closes the batch, runs the supplied block compute
//! (e.g. `implicit_vjp_multi`, one solve for all k columns), and publishes
//! the n×k result. Followers block on the batch condvar and each read their
//! own column. A panicking compute is caught and surfaced as a per-request
//! error instead of hanging the followers.

use crate::diff::mode::DiffMode;
use crate::linalg::mat::Mat;
use crate::linalg::solve::SolvePrecision;
use crate::util::pool::PoolVec;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Which derivative op a batch coalesces (column dimensions differ).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BatchOp {
    /// Reverse-mode: cotangents of length dim_x → outputs of length dim_theta.
    Vjp,
    /// Forward-mode: directions of length dim_theta → outputs of length dim_x.
    Jvp,
}

/// Coalescing key: requests batch together iff problem, θ bits, op,
/// arithmetic policy AND derivative mode all match (an f64 and a
/// mixed-precision request must not share one block solve; an implicit and
/// a one-step request don't even run the same compute). Explicit-k unroll
/// requests additionally key on k, since the leader's truncation depth is
/// applied to the whole block.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct BatchKey {
    pub problem: String,
    pub op: BatchOp,
    pub precision: SolvePrecision,
    pub mode: DiffMode,
    /// Requested unroll depth (0 = let the policy choose).
    pub iters: usize,
    bits: Vec<u64>,
}

impl BatchKey {
    pub fn new(
        problem: &str,
        op: BatchOp,
        theta: &[f64],
        precision: SolvePrecision,
    ) -> BatchKey {
        BatchKey::with_mode(problem, op, theta, precision, DiffMode::Implicit, 0)
    }

    pub fn with_mode(
        problem: &str,
        op: BatchOp,
        theta: &[f64],
        precision: SolvePrecision,
        mode: DiffMode,
        iters: usize,
    ) -> BatchKey {
        BatchKey {
            problem: problem.to_string(),
            op,
            precision,
            mode,
            iters,
            bits: theta.iter().map(|t| t.to_bits()).collect(),
        }
    }
}

type BatchResult = Result<Mat, String>;

struct BatchState {
    /// Pooled input columns; the leader drops them (returning the buffers)
    /// as soon as the dense block is assembled.
    inputs: Vec<PoolVec>,
    /// Set once the leader has taken the inputs; late arrivals must retry
    /// into a fresh batch.
    closed: bool,
    result: Option<Arc<BatchResult>>,
    /// Final batch size, set at close (so followers can report it).
    size: usize,
}

struct Batch {
    state: Mutex<BatchState>,
    cv: Condvar,
}

impl Batch {
    fn new() -> Batch {
        Batch {
            state: Mutex::new(BatchState {
                inputs: Vec::new(),
                closed: false,
                result: None,
                size: 0,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Decrements the submit-inflight gauge on every exit path (including the
/// catch_unwind-recovered leader panic).
struct InflightGuard<'a>(&'a AtomicU64);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The coalescing front of the serve engine.
pub struct Batcher {
    window: Duration,
    max_batch: usize,
    open: Mutex<HashMap<BatchKey, Arc<Batch>>>,
    batches: AtomicU64,
    coalesced_requests: AtomicU64,
    /// Requests currently inside `submit` (queued in an open batch or
    /// computing). The admission layer reads this as the live depth of
    /// the compute queue.
    inflight: AtomicU64,
}

impl Batcher {
    /// `window`: how long a leader waits for followers; `max_batch`: close
    /// early once this many requests joined. `window = 0` degenerates to
    /// serial per-request solves.
    pub fn new(window: Duration, max_batch: usize) -> Batcher {
        Batcher {
            window,
            max_batch: max_batch.max(1),
            open: Mutex::new(HashMap::new()),
            batches: AtomicU64::new(0),
            coalesced_requests: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
        }
    }

    /// Requests currently inside `submit` (live gauge, not monotonic).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// (batches executed, requests that shared a batch with at least one
    /// other request).
    pub fn stats(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.coalesced_requests.load(Ordering::Relaxed))
    }

    /// Join (or open) the batch for `key`, contributing the column `v`
    /// (length `rows`). Exactly one caller per batch runs `compute` over the
    /// assembled rows×k input block; every caller gets back its own output
    /// column and the batch size. Lock order is `open` before `state`,
    /// never the reverse.
    pub fn submit(
        &self,
        key: BatchKey,
        v: PoolVec,
        rows: usize,
        compute: impl FnOnce(&Mat) -> BatchResult,
    ) -> (Result<Vec<f64>, String>, usize) {
        assert_eq!(v.len(), rows, "batch column length mismatch");
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let _inflight = InflightGuard(&self.inflight);
        // Moved (not cloned) into whichever batch actually admits us — a
        // race with a closing leader retries with the buffer still in hand.
        let mut v = Some(v);
        loop {
            let batch = {
                let mut open = self.open.lock().unwrap();
                open.entry(key.clone()).or_insert_with(|| Arc::new(Batch::new())).clone()
            };
            let my_idx = {
                let mut st = batch.state.lock().unwrap();
                if st.closed {
                    // Raced with the leader closing this batch; retry into a
                    // fresh one.
                    continue;
                }
                st.inputs.push(v.take().expect("column consumed by a closed batch"));
                let idx = st.inputs.len() - 1;
                if st.inputs.len() >= self.max_batch {
                    // Wake a leader waiting out its window.
                    batch.cv.notify_all();
                }
                idx
            };

            let result = if my_idx == 0 {
                self.lead(&key, &batch, compute)
            } else {
                let mut st = batch.state.lock().unwrap();
                while st.result.is_none() {
                    st = batch.cv.wait(st).unwrap();
                }
                st.result.clone().unwrap()
            };

            let size = batch.state.lock().unwrap().size;
            let col = match result.as_ref() {
                Ok(out) => {
                    debug_assert_eq!(out.cols, size);
                    Ok(out.col(my_idx))
                }
                Err(e) => Err(e.clone()),
            };
            return (col, size);
        }
    }

    /// Leader path: wait for followers, close the batch, compute, publish.
    fn lead(
        &self,
        key: &BatchKey,
        batch: &Arc<Batch>,
        compute: impl FnOnce(&Mat) -> BatchResult,
    ) -> Arc<BatchResult> {
        // Phase 1: wait for the window to elapse or the batch to fill.
        let deadline = Instant::now() + self.window;
        {
            let mut st = batch.state.lock().unwrap();
            while st.inputs.len() < self.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = batch.cv.wait_timeout(st, deadline - now).unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        // Phase 2: unlist the batch so new arrivals open a fresh one
        // (open-lock before state-lock, hence the dance).
        {
            let mut open = self.open.lock().unwrap();
            if let Some(cur) = open.get(key) {
                if Arc::ptr_eq(cur, batch) {
                    open.remove(key);
                }
            }
        }
        // Phase 3: close and take the inputs. Anything pushed before this
        // point is in; pushes after see `closed` and retry.
        let inputs = {
            let mut st = batch.state.lock().unwrap();
            st.closed = true;
            st.size = st.inputs.len();
            std::mem::take(&mut st.inputs)
        };
        let k = inputs.len();
        let rows = inputs[0].len();
        let mut block = Mat::zeros(rows, k);
        for (j, col) in inputs.iter().enumerate() {
            block.set_col(j, col);
        }
        // Input buffers go back to the pool before the (possibly long)
        // compute, not after.
        drop(inputs);
        // Phase 4: one block compute for the whole batch; a panic becomes a
        // shared error rather than a hang.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| compute(&block)))
            .unwrap_or_else(|_| Err("internal: batch compute panicked".to_string()));
        let result = Arc::new(result);
        self.batches.fetch_add(1, Ordering::Relaxed);
        if k > 1 {
            self.coalesced_requests.fetch_add(k as u64, Ordering::Relaxed);
        }
        let mut st = batch.state.lock().unwrap();
        st.result = Some(result.clone());
        batch.cv.notify_all();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::Pool;
    use std::sync::atomic::AtomicUsize;

    /// N threads on one key with `max_batch = N`: exactly one compute over
    /// an N-column block, each thread gets its own column back.
    #[test]
    fn coalesces_concurrent_requests_into_one_compute() {
        let n = 6;
        let batcher = Arc::new(Batcher::new(Duration::from_secs(5), n));
        let pool = Pool::new(8);
        let computes = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = batcher.clone();
                let pool = pool.clone();
                let c = computes.clone();
                std::thread::spawn(move || {
                    let key = BatchKey::new("p", BatchOp::Vjp, &[1.0], SolvePrecision::F64);
                    let v = pool.take_f64_copy(&[i as f64; 3]);
                    let (res, size) = b.submit(key, v, 3, |block| {
                        c.fetch_add(1, Ordering::SeqCst);
                        // compute: 2× each column
                        let mut out = Mat::zeros(block.rows, block.cols);
                        for idx in 0..block.data.len() {
                            out.data[idx] = 2.0 * block.data[idx];
                        }
                        Ok(out)
                    });
                    (i, res.unwrap(), size)
                })
            })
            .collect();
        for h in handles {
            let (i, col, size) = h.join().unwrap();
            assert_eq!(size, n);
            assert_eq!(col, vec![2.0 * i as f64; 3]);
        }
        assert_eq!(computes.load(Ordering::SeqCst), 1, "batch must run ONE compute");
        let (batches, coalesced) = batcher.stats();
        assert_eq!(batches, 1);
        assert_eq!(coalesced, n as u64);
    }

    #[test]
    fn different_keys_do_not_coalesce() {
        let batcher = Batcher::new(Duration::from_millis(0), 8);
        let pool = Pool::new(8);
        let (a, sa) = batcher.submit(
            BatchKey::new("p", BatchOp::Vjp, &[1.0], SolvePrecision::F64),
            pool.take_f64_copy(&[1.0]),
            1,
            |b| Ok(b.clone()),
        );
        let (c, sc) = batcher.submit(
            BatchKey::new("p", BatchOp::Jvp, &[1.0], SolvePrecision::F64),
            pool.take_f64_copy(&[2.0]),
            1,
            |b| Ok(b.clone()),
        );
        assert_eq!((a.unwrap(), sa), (vec![1.0], 1));
        assert_eq!((c.unwrap(), sc), (vec![2.0], 1));
        assert_eq!(batcher.stats().0, 2);
        // Same (problem, op, θ, precision) but a different derivative mode
        // or unroll depth opens its own batch.
        let k1 = BatchKey::new("p", BatchOp::Vjp, &[1.0], SolvePrecision::F64);
        let k2 = BatchKey::with_mode(
            "p",
            BatchOp::Vjp,
            &[1.0],
            SolvePrecision::F64,
            DiffMode::OneStep,
            0,
        );
        let k3 = BatchKey::with_mode(
            "p",
            BatchOp::Vjp,
            &[1.0],
            SolvePrecision::F64,
            DiffMode::Unroll,
            8,
        );
        assert_eq!(k1.mode, DiffMode::Implicit);
        assert_ne!(k1, k2);
        assert_ne!(k2, k3);
    }

    #[test]
    fn compute_error_reaches_every_member_and_panic_is_caught() {
        let batcher = Batcher::new(Duration::from_millis(0), 4);
        let pool = Pool::new(8);
        let key = BatchKey::new("p", BatchOp::Vjp, &[2.0], SolvePrecision::F64);
        let (res, _) =
            batcher.submit(key.clone(), pool.take_f64(1), 1, |_| Err("boom".into()));
        assert_eq!(res.unwrap_err(), "boom");
        let (res, _) = batcher.submit(key, pool.take_f64(1), 1, |_| panic!("kaput"));
        assert!(res.unwrap_err().contains("panicked"));
        // The leader returned both input buffers to the pool.
        assert_eq!(pool.stats().recycled, 2);
    }
}
