//! Warm-start persistence: the θ-keyed factorization cache and the ρ-cache
//! serialize to a versioned JSON manifest so a rebooted server answers
//! repeat-θ traffic with ZERO new factorizations.
//!
//! # Manifest format (version 2)
//!
//! ```json
//! {
//!   "format": "idiff-serve-manifest",
//!   "version": 2,
//!   "catalog": [{"name": "...", "dim_x": n, "dim_theta": m}, …],
//!   "entries": [
//!     {"problem": "...", "theta": […], "x_star": […],
//!      "fact": {"kind": "chol", "l": {"rows","cols","data"}}         |
//!              {"kind": "lu", "lu": {…}, "piv": […], "sign": ±1}},
//!     …  // least-recently-used first, so reinsertion reproduces recency
//!   ],
//!   "rho": [{"problem": "...", "theta": […], "rho": r}, …]
//! }
//! ```
//!
//! θ, x* and factor entries ride the exact-f64 JSON round trip
//! (`util::json::fmt_f64`), so a save → load cycle reproduces cache keys
//! and answers bit-for-bit. Mixed-precision factorizations are skipped on
//! save (the cache only stores f64 factors on the serve path, and a cold
//! re-factorization beats persisting f32 state).
//!
//! # Compatibility policy
//!
//! Loading NEVER crashes the server. A manifest with the wrong `format` or
//! `version`, or one whose `catalog` disagrees with the running registry
//! (dims changed, problems renamed), is reported as a clean cold start.
//! Individually stale entries (unknown problem, wrong dims, non-finite or
//! malformed factors) are skipped and counted; everything else restores.
//! Only an unreadable/unparseable file is an `Err` — and callers treat
//! that as a cold start too, it is just worth a louder log line.

use super::cache::{CacheEntry, ThetaKey};
use super::Server;
use crate::linalg::chol::Cholesky;
use crate::linalg::lu::Lu;
use crate::linalg::mat::Mat;
use crate::linalg::solve::Factorization;
use crate::util::json::Json;
use std::path::Path;
use std::sync::Arc;

pub const MANIFEST_FORMAT: &str = "idiff-serve-manifest";
/// Bumped whenever the entry layout changes; older manifests cold-start.
pub const MANIFEST_VERSION: f64 = 2.0;

/// What a manifest load did.
#[derive(Debug, Default)]
pub struct WarmStart {
    /// Factorization-cache entries restored.
    pub factorizations: usize,
    /// ρ-cache entries restored.
    pub rho_entries: usize,
    /// Entries present in the manifest but dropped (stale problem, wrong
    /// dims, malformed factor).
    pub skipped: usize,
    /// `Some(reason)` when the manifest as a whole was rejected and the
    /// server is cold-starting (wrong format/version/catalog).
    pub cold_start: Option<String>,
}

fn mat_json(m: &Mat) -> Json {
    Json::obj(vec![
        ("rows", Json::Num(m.rows as f64)),
        ("cols", Json::Num(m.cols as f64)),
        ("data", Json::arr_f64(&m.data)),
    ])
}

fn mat_from(j: &Json) -> Option<Mat> {
    let rows = j.get("rows")?.as_f64()? as usize;
    let cols = j.get("cols")?.as_f64()? as usize;
    let data = vec_from(j.get("data")?)?;
    if rows.checked_mul(cols)? != data.len() || data.iter().any(|x| !x.is_finite()) {
        return None;
    }
    Some(Mat::from_vec(rows, cols, data))
}

fn vec_from(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

/// Serialize a factorization, or None for kinds that don't persist
/// (mixed-precision factors are rebuilt rather than stored).
fn fact_json(fact: &Factorization) -> Option<Json> {
    match fact {
        Factorization::Chol(c) => Some(Json::obj(vec![
            ("kind", Json::Str("chol".to_string())),
            ("l", mat_json(&c.l)),
        ])),
        Factorization::Lu(lu) => {
            let (mat, piv, sign) = lu.parts();
            Some(Json::obj(vec![
                ("kind", Json::Str("lu".to_string())),
                ("lu", mat_json(mat)),
                ("piv", Json::Arr(piv.iter().map(|&p| Json::Num(p as f64)).collect())),
                ("sign", Json::Num(sign)),
            ]))
        }
        _ => None,
    }
}

fn fact_from(j: &Json) -> Option<Factorization> {
    match j.get("kind")?.as_str()? {
        "chol" => {
            let l = mat_from(j.get("l")?)?;
            if l.rows != l.cols {
                return None;
            }
            Some(Factorization::Chol(Cholesky { l }))
        }
        "lu" => {
            let mat = mat_from(j.get("lu")?)?;
            let piv: Option<Vec<usize>> = j
                .get("piv")?
                .as_arr()?
                .iter()
                .map(|p| {
                    let x = p.as_f64()?;
                    if x.fract() == 0.0 && x >= 0.0 {
                        Some(x as usize)
                    } else {
                        None
                    }
                })
                .collect();
            let sign = j.get("sign")?.as_f64()?;
            Lu::from_parts(mat, piv?, sign).map(Factorization::Lu)
        }
        _ => None,
    }
}

impl Server {
    /// The full warm state as a manifest document.
    pub fn manifest_json(&self) -> Json {
        let entries: Vec<Json> = self
            .cache
            .snapshot()
            .iter()
            .filter_map(|(key, entry)| {
                let fact = fact_json(&entry.fact)?;
                Some(Json::obj(vec![
                    ("problem", Json::Str(key.problem.clone())),
                    ("theta", Json::arr_f64(&key.theta())),
                    ("x_star", Json::arr_f64(&entry.x_star)),
                    ("fact", fact),
                ]))
            })
            .collect();
        let rho: Vec<Json> = self
            .rho_cache
            .snapshot()
            .iter()
            .map(|(key, rho)| {
                Json::obj(vec![
                    ("problem", Json::Str(key.problem.clone())),
                    ("theta", Json::arr_f64(&key.theta())),
                    ("rho", Json::Num(*rho)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::Str(MANIFEST_FORMAT.to_string())),
            ("version", Json::Num(MANIFEST_VERSION)),
            ("catalog", self.registry.catalog_signature()),
            ("entries", Json::Arr(entries)),
            ("rho", Json::Arr(rho)),
        ])
    }

    /// Write the manifest atomically (tmp file + rename), so a crash
    /// mid-write never corrupts the previous good manifest.
    pub fn save_manifest(&self, path: &Path) -> std::io::Result<()> {
        let doc = self.manifest_json().to_string_pretty();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, doc)?;
        std::fs::rename(&tmp, path)
    }

    /// Load a manifest into the live caches. See the module docs for the
    /// compatibility policy; this never panics on any file content.
    pub fn load_manifest(&self, path: &Path) -> Result<WarmStart, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read manifest {}: {e}", path.display()))?;
        let doc = crate::util::json::parse(&text)
            .map_err(|e| format!("cannot parse manifest {}: {e}", path.display()))?;
        let mut warm = WarmStart::default();
        if doc.str_or("format", "") != MANIFEST_FORMAT {
            warm.cold_start = Some("manifest format not recognized".to_string());
            return Ok(warm);
        }
        let version = doc.f64_or("version", -1.0);
        if version != MANIFEST_VERSION {
            warm.cold_start = Some(format!(
                "manifest version {version} (this build reads {MANIFEST_VERSION}); cold start"
            ));
            return Ok(warm);
        }
        if doc.get("catalog") != Some(&self.registry.catalog_signature()) {
            warm.cold_start =
                Some("manifest catalog does not match the running registry".to_string());
            return Ok(warm);
        }
        for entry in doc.get("entries").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            if self.restore_entry(entry).is_some() {
                warm.factorizations += 1;
            } else {
                warm.skipped += 1;
            }
        }
        for entry in doc.get("rho").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            if self.restore_rho(entry).is_some() {
                warm.rho_entries += 1;
            } else {
                warm.skipped += 1;
            }
        }
        Ok(warm)
    }

    fn restore_entry(&self, entry: &Json) -> Option<()> {
        let name = entry.get("problem")?.as_str()?;
        let p = self.registry.get(name)?;
        let theta = vec_from(entry.get("theta")?)?;
        let x_star = vec_from(entry.get("x_star")?)?;
        if theta.len() != p.dim_theta()
            || x_star.len() != p.dim_x()
            || theta.iter().chain(&x_star).any(|x| !x.is_finite())
        {
            return None;
        }
        // Shard manifest slice: a sharded server only warm-starts entries
        // the consistent-hash ring assigns to it (counted as skipped), so
        // N shard manifests partition a standalone manifest cleanly and no
        // factorization is ever duplicated cluster-wide at restore time.
        if !self.owns(name, &theta) {
            return None;
        }
        let fact = fact_from(entry.get("fact")?)?;
        if fact.dim() != p.dim_x() {
            return None;
        }
        self.cache.insert(
            ThetaKey::new(name, &theta),
            CacheEntry { x_star: Arc::new(x_star), fact: Arc::new(fact) },
        );
        Some(())
    }

    fn restore_rho(&self, entry: &Json) -> Option<()> {
        let name = entry.get("problem")?.as_str()?;
        let p = self.registry.get(name)?;
        let theta = vec_from(entry.get("theta")?)?;
        let rho = entry.get("rho")?.as_f64()?;
        if theta.len() != p.dim_theta()
            || theta.iter().any(|x| !x.is_finite())
            || !rho.is_finite()
            || rho < 0.0
        {
            return None;
        }
        // Same ring-ownership slice as factorization entries.
        if !self.owns(name, &theta) {
            return None;
        }
        self.rho_cache.insert(ThetaKey::new(name, &theta), rho);
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ServeConfig, Server};
    use super::*;
    use std::time::Duration;

    fn quiet() -> Server {
        Server::new(ServeConfig {
            batch_window: Duration::from_millis(0),
            ..ServeConfig::default()
        })
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("idiff_persist_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn factorization_round_trips_through_json_bit_exactly() {
        // Cholesky
        let spd = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let fact = Factorization::of_mat(&spd, true).unwrap();
        let back = fact_from(&fact_json(&fact).unwrap()).unwrap();
        match (&fact, &back) {
            (Factorization::Chol(a), Factorization::Chol(b)) => {
                for (x, y) in a.l.data.iter().zip(&b.l.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            _ => panic!("expected Cholesky round trip"),
        }
        // LU of a non-symmetric matrix
        let gen = Mat::from_vec(2, 2, vec![0.0, 2.0, 1.0, 7.0]);
        let fact = Factorization::of_mat(&gen, false).unwrap();
        let j = fact_json(&fact).unwrap();
        let back = fact_from(&j).unwrap();
        match (&fact, &back) {
            (Factorization::Lu(a), Factorization::Lu(b)) => {
                let (am, ap, asg) = a.parts();
                let (bm, bp, bsg) = b.parts();
                assert_eq!(ap, bp);
                assert_eq!(asg, bsg);
                for (x, y) in am.data.iter().zip(&bm.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            _ => panic!("expected LU round trip"),
        }
        // Corrupt pivots are rejected, not trusted.
        let mut bad = j.clone();
        if let Json::Obj(fields) = &mut bad {
            for (k, v) in fields.iter_mut() {
                if k == "piv" {
                    *v = Json::Arr(vec![Json::Num(9.0), Json::Num(0.0)]);
                }
            }
        }
        assert!(fact_from(&bad).is_none());
    }

    #[test]
    fn save_load_reproduces_cache_state() {
        let a = quiet();
        // Warm two problems through the JSON front end.
        let reqs = [
            r#"{"op":"hypergrad","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1]}"#,
            r#"{"op":"hypergrad","problem":"quad","theta":[0.5,0.6,0.7,0.8],"v":[1,1,1,1,1,1]}"#,
        ];
        for r in reqs {
            assert!(a.handle(r).get("error").is_none());
        }
        assert_eq!(a.cache.len(), 2);
        let path = tmp_path("roundtrip");
        a.save_manifest(&path).unwrap();

        let b = quiet();
        let warm = b.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_none(), "{:?}", warm.cold_start);
        assert_eq!(warm.factorizations, 2);
        assert_eq!(warm.skipped, 0);
        assert_eq!(b.cache.len(), 2);
        // Replays are cache hits with zero factorizations on the new server.
        for r in reqs {
            let reply = b.handle(r);
            assert_eq!(reply.get("cached"), Some(&Json::Bool(true)), "{r}");
        }
        use std::sync::atomic::Ordering;
        assert_eq!(b.stats.factorizations.load(Ordering::Relaxed), 0);
        assert_eq!(b.stats.inner_solves.load(Ordering::Relaxed), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_version_or_format_is_a_clean_cold_start() {
        let path = tmp_path("oldversion");
        // A version-1 manifest from a previous build.
        std::fs::write(
            &path,
            r#"{"format":"idiff-serve-manifest","version":1,"entries":[{"junk":true}]}"#,
        )
        .unwrap();
        let s = quiet();
        let warm = s.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_some());
        assert_eq!(warm.factorizations, 0);
        assert!(s.cache.is_empty());
        // Foreign JSON file: also a cold start, not an error.
        std::fs::write(&path, r#"{"hello":"world"}"#).unwrap();
        assert!(s.load_manifest(&path).unwrap().cold_start.is_some());
        // Unparseable garbage: an Err, still no panic, caches untouched.
        std::fs::write(&path, "not json at all {{{").unwrap();
        assert!(s.load_manifest(&path).is_err());
        assert!(s.cache.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_entries_are_skipped_and_counted() {
        let a = quiet();
        let req = r#"{"op":"hypergrad","problem":"ridge","theta":[2,2,2,2,2,2,2,2],"v":[1,1,1,1,1,1,1,1]}"#;
        assert!(a.handle(req).get("error").is_none());
        let mut doc = a.manifest_json();
        // Inject a stale entry for a problem this registry doesn't have.
        if let Json::Obj(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "entries" {
                    if let Json::Arr(entries) = v {
                        let mut fake = entries[0].clone();
                        if let Json::Obj(ef) = &mut fake {
                            for (ek, ev) in ef.iter_mut() {
                                if ek == "problem" {
                                    *ev = Json::Str("retired_problem".to_string());
                                }
                            }
                        }
                        entries.push(fake);
                    }
                }
            }
        }
        let path = tmp_path("stale");
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        let b = quiet();
        let warm = b.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_none());
        assert_eq!(warm.factorizations, 1);
        assert_eq!(warm.skipped, 1);
        assert_eq!(b.cache.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
