//! Warm-start persistence: the θ-keyed factorization cache and the ρ-cache
//! serialize to a versioned JSON manifest so a rebooted server answers
//! repeat-θ traffic with ZERO new factorizations.
//!
//! # Manifest format (version 2)
//!
//! ```json
//! {
//!   "format": "idiff-serve-manifest",
//!   "version": 2,
//!   "catalog": [{"name": "...", "dim_x": n, "dim_theta": m}, …],
//!   "entries": [
//!     {"problem": "...", "theta": […], "x_star": […],
//!      "fact": {"kind": "chol", "l": {"rows","cols","data"}}         |
//!              {"kind": "lu", "lu": {…}, "piv": […], "sign": ±1}},
//!     …  // least-recently-used first, so reinsertion reproduces recency
//!   ],
//!   "rho": [{"problem": "...", "theta": […], "rho": r}, …]
//! }
//! ```
//!
//! θ, x* and factor entries ride the exact-f64 JSON round trip
//! (`util::json::fmt_f64`), so a save → load cycle reproduces cache keys
//! and answers bit-for-bit. Mixed-precision factorizations are skipped on
//! save (the cache only stores f64 factors on the serve path, and a cold
//! re-factorization beats persisting f32 state).
//!
//! # Compatibility policy
//!
//! Loading NEVER crashes the server. A manifest with the wrong `format` or
//! `version`, one whose `catalog` disagrees with the running registry
//! (dims changed, problems renamed), a file of non-JSON garbage (including
//! invalid UTF-8 or a write truncated mid-JSON), or valid JSON of the wrong
//! shape is reported as a clean cold start (`WarmStart::cold_start` names
//! the reason). Individually stale entries (unknown problem, wrong dims,
//! non-finite or malformed factors) are skipped and counted; everything
//! else restores. Only an *unreadable* file (I/O error) is an `Err` — and
//! callers treat that as a cold start too, it is just worth a louder log
//! line.
//!
//! # Replica deltas
//!
//! Sharded servers ship warm state to their ring successor as
//! `idiff-replica-delta` documents over the binary wire's `OP_REPLICATE`
//! op (see [`Server::replica_delta_doc`] / [`Server::apply_replica_delta`]).
//! A delta reuses the manifest entry layout but is installed *bypassing*
//! the ring-ownership filter — a replica holds its predecessor's slice on
//! purpose — and never bumps the `factorizations` counter, exactly like a
//! manifest restore, so cluster-wide factorization counts stay a partition.

use super::cache::{CacheEntry, ThetaKey};
use super::Server;
use crate::linalg::chol::Cholesky;
use crate::linalg::lu::Lu;
use crate::linalg::mat::Mat;
use crate::linalg::solve::Factorization;
use crate::util::json::Json;
use std::path::Path;
use std::sync::Arc;

pub const MANIFEST_FORMAT: &str = "idiff-serve-manifest";
/// Bumped whenever the entry layout changes; older manifests cold-start.
pub const MANIFEST_VERSION: f64 = 2.0;
/// Format tag of shard→shard replica-delta documents (OP_REPLICATE).
pub const REPLICA_FORMAT: &str = "idiff-replica-delta";

/// What a manifest load did.
#[derive(Debug, Default)]
pub struct WarmStart {
    /// Factorization-cache entries restored.
    pub factorizations: usize,
    /// ρ-cache entries restored.
    pub rho_entries: usize,
    /// Entries present in the manifest but dropped (stale problem, wrong
    /// dims, malformed factor).
    pub skipped: usize,
    /// `Some(reason)` when the manifest as a whole was rejected and the
    /// server is cold-starting (wrong format/version/catalog).
    pub cold_start: Option<String>,
}

fn mat_json(m: &Mat) -> Json {
    Json::obj(vec![
        ("rows", Json::Num(m.rows as f64)),
        ("cols", Json::Num(m.cols as f64)),
        ("data", Json::arr_f64(&m.data)),
    ])
}

fn mat_from(j: &Json) -> Option<Mat> {
    let rows = j.get("rows")?.as_f64()? as usize;
    let cols = j.get("cols")?.as_f64()? as usize;
    let data = vec_from(j.get("data")?)?;
    if rows.checked_mul(cols)? != data.len() || data.iter().any(|x| !x.is_finite()) {
        return None;
    }
    Some(Mat::from_vec(rows, cols, data))
}

fn vec_from(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

/// Serialize a factorization, or None for kinds that don't persist
/// (mixed-precision factors are rebuilt rather than stored).
fn fact_json(fact: &Factorization) -> Option<Json> {
    match fact {
        Factorization::Chol(c) => Some(Json::obj(vec![
            ("kind", Json::Str("chol".to_string())),
            ("l", mat_json(&c.l)),
        ])),
        Factorization::Lu(lu) => {
            let (mat, piv, sign) = lu.parts();
            Some(Json::obj(vec![
                ("kind", Json::Str("lu".to_string())),
                ("lu", mat_json(mat)),
                ("piv", Json::Arr(piv.iter().map(|&p| Json::Num(p as f64)).collect())),
                ("sign", Json::Num(sign)),
            ]))
        }
        _ => None,
    }
}

fn fact_from(j: &Json) -> Option<Factorization> {
    match j.get("kind")?.as_str()? {
        "chol" => {
            let l = mat_from(j.get("l")?)?;
            if l.rows != l.cols {
                return None;
            }
            Some(Factorization::Chol(Cholesky { l }))
        }
        "lu" => {
            let mat = mat_from(j.get("lu")?)?;
            let piv: Option<Vec<usize>> = j
                .get("piv")?
                .as_arr()?
                .iter()
                .map(|p| {
                    let x = p.as_f64()?;
                    if x.fract() == 0.0 && x >= 0.0 {
                        Some(x as usize)
                    } else {
                        None
                    }
                })
                .collect();
            let sign = j.get("sign")?.as_f64()?;
            Lu::from_parts(mat, piv?, sign).map(Factorization::Lu)
        }
        _ => None,
    }
}

/// One factorization-cache entry in manifest/replica-delta layout.
fn entry_json(key: &ThetaKey, entry: &CacheEntry) -> Option<Json> {
    let fact = fact_json(&entry.fact)?;
    Some(Json::obj(vec![
        ("problem", Json::Str(key.problem.clone())),
        ("theta", Json::arr_f64(&key.theta())),
        ("x_star", Json::arr_f64(&entry.x_star)),
        ("fact", fact),
    ]))
}

/// One ρ-cache entry in manifest/replica-delta layout.
fn rho_json(key: &ThetaKey, rho: f64) -> Json {
    Json::obj(vec![
        ("problem", Json::Str(key.problem.clone())),
        ("theta", Json::arr_f64(&key.theta())),
        ("rho", Json::Num(rho)),
    ])
}

impl Server {
    /// The full warm state as a manifest document.
    pub fn manifest_json(&self) -> Json {
        let entries: Vec<Json> = self
            .cache
            .snapshot()
            .iter()
            .filter_map(|(key, entry)| entry_json(key, entry))
            .collect();
        let rho: Vec<Json> = self
            .rho_cache
            .snapshot()
            .iter()
            .map(|(key, rho)| rho_json(key, *rho))
            .collect();
        Json::obj(vec![
            ("format", Json::Str(MANIFEST_FORMAT.to_string())),
            ("version", Json::Num(MANIFEST_VERSION)),
            ("catalog", self.registry.catalog_signature()),
            ("entries", Json::Arr(entries)),
            ("rho", Json::Arr(rho)),
        ])
    }

    /// Write the manifest atomically (tmp file + rename), so a crash
    /// mid-write never corrupts the previous good manifest.
    pub fn save_manifest(&self, path: &Path) -> std::io::Result<()> {
        let doc = self.manifest_json().to_string_pretty();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, doc)?;
        std::fs::rename(&tmp, path)
    }

    /// Load a manifest into the live caches. See the module docs for the
    /// compatibility policy; this never panics on any file content. Corrupt
    /// bytes (truncated write, garbage, invalid UTF-8) are a *counted cold
    /// start*, not an `Err` — only failing to read the file at all is.
    pub fn load_manifest(&self, path: &Path) -> Result<WarmStart, String> {
        let raw = std::fs::read(path)
            .map_err(|e| format!("cannot read manifest {}: {e}", path.display()))?;
        let mut warm = WarmStart::default();
        // Invalid UTF-8 can only come from a corrupt file; lossy-decode so
        // it reaches the parser and fails there instead of erroring here.
        let doc = match crate::util::json::parse(&String::from_utf8_lossy(&raw)) {
            Ok(doc) => doc,
            Err(e) => {
                warm.cold_start = Some(format!(
                    "manifest {} is corrupt ({e}); cold start",
                    path.display()
                ));
                return Ok(warm);
            }
        };
        if doc.str_or("format", "") != MANIFEST_FORMAT {
            warm.cold_start = Some("manifest format not recognized".to_string());
            return Ok(warm);
        }
        let version = doc.f64_or("version", -1.0);
        if version != MANIFEST_VERSION {
            warm.cold_start = Some(format!(
                "manifest version {version} (this build reads {MANIFEST_VERSION}); cold start"
            ));
            return Ok(warm);
        }
        if doc.get("catalog") != Some(&self.registry.catalog_signature()) {
            warm.cold_start =
                Some("manifest catalog does not match the running registry".to_string());
            return Ok(warm);
        }
        for entry in doc.get("entries").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            if self.restore_entry(entry, true).is_some() {
                warm.factorizations += 1;
            } else {
                warm.skipped += 1;
            }
        }
        for entry in doc.get("rho").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            if self.restore_rho(entry, true).is_some() {
                warm.rho_entries += 1;
            } else {
                warm.skipped += 1;
            }
        }
        Ok(warm)
    }

    /// Build a replica-delta document carrying the given cache slices to a
    /// ring successor. Layout matches the manifest entries; `from_shard`
    /// identifies the sender for the receiver's logs/stats.
    pub fn replica_delta_doc(
        &self,
        entries: &[(ThetaKey, CacheEntry)],
        rho: &[(ThetaKey, f64)],
        from_shard: usize,
    ) -> Json {
        Json::obj(vec![
            ("format", Json::Str(REPLICA_FORMAT.to_string())),
            ("version", Json::Num(MANIFEST_VERSION)),
            ("from_shard", Json::Num(from_shard as f64)),
            ("catalog", self.registry.catalog_signature()),
            (
                "entries",
                Json::Arr(entries.iter().filter_map(|(k, e)| entry_json(k, e)).collect()),
            ),
            ("rho", Json::Arr(rho.iter().map(|(k, r)| rho_json(k, *r)).collect())),
        ])
    }

    /// Install a replica delta received over OP_REPLICATE. Entries are
    /// installed *without* the ring-ownership filter (a replica holds its
    /// predecessor's slice) and without touching the `factorizations`
    /// counter — identical accounting to a manifest restore. Returns
    /// (factorization entries, ρ entries) installed.
    pub fn apply_replica_delta(&self, doc: &str) -> Result<(u64, u64), String> {
        let doc = crate::util::json::parse(doc).map_err(|e| format!("bad replica delta: {e}"))?;
        if doc.str_or("format", "") != REPLICA_FORMAT {
            return Err("replica delta format not recognized".to_string());
        }
        let version = doc.f64_or("version", -1.0);
        if version != MANIFEST_VERSION {
            return Err(format!(
                "replica delta version {version} (this build reads {MANIFEST_VERSION})"
            ));
        }
        if doc.get("catalog") != Some(&self.registry.catalog_signature()) {
            return Err("replica delta catalog does not match the running registry".to_string());
        }
        let mut facts = 0u64;
        let mut rho = 0u64;
        for entry in doc.get("entries").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            if self.restore_entry(entry, false).is_some() {
                facts += 1;
            }
        }
        for entry in doc.get("rho").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            if self.restore_rho(entry, false).is_some() {
                rho += 1;
            }
        }
        Ok((facts, rho))
    }

    fn restore_entry(&self, entry: &Json, enforce_ownership: bool) -> Option<()> {
        let name = entry.get("problem")?.as_str()?;
        let p = self.registry.get(name)?;
        let theta = vec_from(entry.get("theta")?)?;
        let x_star = vec_from(entry.get("x_star")?)?;
        if theta.len() != p.dim_theta()
            || x_star.len() != p.dim_x()
            || theta.iter().chain(&x_star).any(|x| !x.is_finite())
        {
            return None;
        }
        // Shard manifest slice: a sharded server only warm-starts entries
        // the consistent-hash ring assigns to it (counted as skipped), so
        // N shard manifests partition a standalone manifest cleanly and no
        // factorization is ever duplicated cluster-wide at restore time.
        // Replica deltas install with the filter off: a replica holds its
        // ring predecessor's slice by design.
        if enforce_ownership && !self.owns(name, &theta) {
            return None;
        }
        let fact = fact_from(entry.get("fact")?)?;
        if fact.dim() != p.dim_x() {
            return None;
        }
        self.cache.insert(
            ThetaKey::new(name, &theta),
            CacheEntry { x_star: Arc::new(x_star), fact: Arc::new(fact) },
        );
        Some(())
    }

    fn restore_rho(&self, entry: &Json, enforce_ownership: bool) -> Option<()> {
        let name = entry.get("problem")?.as_str()?;
        let p = self.registry.get(name)?;
        let theta = vec_from(entry.get("theta")?)?;
        let rho = entry.get("rho")?.as_f64()?;
        if theta.len() != p.dim_theta()
            || theta.iter().any(|x| !x.is_finite())
            || !rho.is_finite()
            || rho < 0.0
        {
            return None;
        }
        // Same ring-ownership slice as factorization entries.
        if enforce_ownership && !self.owns(name, &theta) {
            return None;
        }
        self.rho_cache.insert(ThetaKey::new(name, &theta), rho);
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ServeConfig, Server};
    use super::*;
    use std::time::Duration;

    fn quiet() -> Server {
        Server::new(ServeConfig {
            batch_window: Duration::from_millis(0),
            ..ServeConfig::default()
        })
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("idiff_persist_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn factorization_round_trips_through_json_bit_exactly() {
        // Cholesky
        let spd = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let fact = Factorization::of_mat(&spd, true).unwrap();
        let back = fact_from(&fact_json(&fact).unwrap()).unwrap();
        match (&fact, &back) {
            (Factorization::Chol(a), Factorization::Chol(b)) => {
                for (x, y) in a.l.data.iter().zip(&b.l.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            _ => panic!("expected Cholesky round trip"),
        }
        // LU of a non-symmetric matrix
        let gen = Mat::from_vec(2, 2, vec![0.0, 2.0, 1.0, 7.0]);
        let fact = Factorization::of_mat(&gen, false).unwrap();
        let j = fact_json(&fact).unwrap();
        let back = fact_from(&j).unwrap();
        match (&fact, &back) {
            (Factorization::Lu(a), Factorization::Lu(b)) => {
                let (am, ap, asg) = a.parts();
                let (bm, bp, bsg) = b.parts();
                assert_eq!(ap, bp);
                assert_eq!(asg, bsg);
                for (x, y) in am.data.iter().zip(&bm.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            _ => panic!("expected LU round trip"),
        }
        // Corrupt pivots are rejected, not trusted.
        let mut bad = j.clone();
        if let Json::Obj(fields) = &mut bad {
            for (k, v) in fields.iter_mut() {
                if k == "piv" {
                    *v = Json::Arr(vec![Json::Num(9.0), Json::Num(0.0)]);
                }
            }
        }
        assert!(fact_from(&bad).is_none());
    }

    #[test]
    fn save_load_reproduces_cache_state() {
        let a = quiet();
        // Warm two problems through the JSON front end.
        let reqs = [
            r#"{"op":"hypergrad","problem":"ridge","theta":[1,1,1,1,1,1,1,1],"v":[1,1,1,1,1,1,1,1]}"#,
            r#"{"op":"hypergrad","problem":"quad","theta":[0.5,0.6,0.7,0.8],"v":[1,1,1,1,1,1]}"#,
        ];
        for r in reqs {
            assert!(a.handle(r).get("error").is_none());
        }
        assert_eq!(a.cache.len(), 2);
        let path = tmp_path("roundtrip");
        a.save_manifest(&path).unwrap();

        let b = quiet();
        let warm = b.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_none(), "{:?}", warm.cold_start);
        assert_eq!(warm.factorizations, 2);
        assert_eq!(warm.skipped, 0);
        assert_eq!(b.cache.len(), 2);
        // Replays are cache hits with zero factorizations on the new server.
        for r in reqs {
            let reply = b.handle(r);
            assert_eq!(reply.get("cached"), Some(&Json::Bool(true)), "{r}");
        }
        use std::sync::atomic::Ordering;
        assert_eq!(b.stats.factorizations.load(Ordering::Relaxed), 0);
        assert_eq!(b.stats.inner_solves.load(Ordering::Relaxed), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_version_or_format_is_a_clean_cold_start() {
        let path = tmp_path("oldversion");
        // A version-1 manifest from a previous build.
        std::fs::write(
            &path,
            r#"{"format":"idiff-serve-manifest","version":1,"entries":[{"junk":true}]}"#,
        )
        .unwrap();
        let s = quiet();
        let warm = s.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_some());
        assert_eq!(warm.factorizations, 0);
        assert!(s.cache.is_empty());
        // Foreign JSON file: also a cold start, not an error.
        std::fs::write(&path, r#"{"hello":"world"}"#).unwrap();
        assert!(s.load_manifest(&path).unwrap().cold_start.is_some());
        // Unparseable garbage: a counted cold start, no panic, caches untouched.
        std::fs::write(&path, "not json at all {{{").unwrap();
        let warm = s.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_some());
        assert!(s.cache.is_empty());
        // A missing file is the only Err: nothing was read at all.
        let _ = std::fs::remove_file(&path);
        assert!(s.load_manifest(&path).is_err());
    }

    #[test]
    fn replica_delta_installs_foreign_slice_without_counting_factorizations() {
        use std::sync::atomic::Ordering;
        let a = quiet();
        let req = r#"{"op":"hypergrad","problem":"ridge","theta":[3,3,3,3,3,3,3,3],"v":[1,1,1,1,1,1,1,1]}"#;
        assert!(a.handle(req).get("error").is_none());
        let entries = a.cache.snapshot();
        let rho = a.rho_cache.snapshot();
        let doc = a.replica_delta_doc(&entries, &rho, 0).to_string_compact();

        let b = quiet();
        let (facts, _) = b.apply_replica_delta(&doc).unwrap();
        assert_eq!(facts, 1);
        assert_eq!(b.cache.len(), 1);
        // Replicated state serves cache hits with zero local factorizations.
        let reply = b.handle(req);
        assert_eq!(reply.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(b.stats.factorizations.load(Ordering::Relaxed), 0);
        // Wrong format / version / catalog are typed errors, not installs.
        assert!(b.apply_replica_delta(r#"{"format":"nope"}"#).is_err());
        assert!(b.apply_replica_delta("garbage {{{").is_err());
    }

    #[test]
    fn stale_entries_are_skipped_and_counted() {
        let a = quiet();
        let req = r#"{"op":"hypergrad","problem":"ridge","theta":[2,2,2,2,2,2,2,2],"v":[1,1,1,1,1,1,1,1]}"#;
        assert!(a.handle(req).get("error").is_none());
        let mut doc = a.manifest_json();
        // Inject a stale entry for a problem this registry doesn't have.
        if let Json::Obj(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "entries" {
                    if let Json::Arr(entries) = v {
                        let mut fake = entries[0].clone();
                        if let Json::Obj(ef) = &mut fake {
                            for (ek, ev) in ef.iter_mut() {
                                if ek == "problem" {
                                    *ev = Json::Str("retired_problem".to_string());
                                }
                            }
                        }
                        entries.push(fake);
                    }
                }
            }
        }
        let path = tmp_path("stale");
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        let b = quiet();
        let warm = b.load_manifest(&path).unwrap();
        assert!(warm.cold_start.is_none());
        assert_eq!(warm.factorizations, 1);
        assert_eq!(warm.skipped, 1);
        assert_eq!(b.cache.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
