//! Fig. 4 (a–c), Fig. 13 and Fig. 14 — multiclass-SVM hyper-parameter
//! optimization: per-outer-iteration runtime of implicit differentiation vs
//! forward-mode unrolling, across problem sizes, for three inner solvers;
//! plus the reverse-mode memory model (Fig. 13) and the validation-loss
//! parity check (Fig. 14).
//!
//! Default sizes are scaled for the single-core CI box; pass
//! `--sizes 100,250,...,10000 --m 700 --val 200` for the paper's scale.

use crate::data::classification::make_classification;
use crate::linalg::solve::{LinearSolveConfig, LinearSolverKind};
use crate::linalg::vecops;
use crate::mappings::mirror::{KlMirrorDescentFixedPoint, KlSimplexRows};
use crate::mappings::prox_grad::ProjGradFixedPoint;
use crate::ml::svm::MulticlassSvm;
use crate::proj::simplex::RowsSimplexProjection;
use crate::util::bench::{write_figure, Series};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

pub struct SvmSetup {
    pub svm: MulticlassSvm,
    pub x_val: crate::linalg::Mat,
    pub y_val: crate::linalg::Mat,
}

pub fn setup(m: usize, p: usize, k: usize, m_val: usize, seed: u64) -> SvmSetup {
    let mut rng = Rng::new(seed);
    let ds = make_classification(m + m_val, p, k, 0.1, 2.0, &mut rng);
    let y = ds.one_hot();
    let x_tr = crate::data::splits::take_rows(&ds.x, &(0..m).collect::<Vec<_>>());
    let y_tr = crate::data::splits::take_rows(&y, &(0..m).collect::<Vec<_>>());
    let x_val = crate::data::splits::take_rows(&ds.x, &(m..m + m_val).collect::<Vec<_>>());
    let y_val = crate::data::splits::take_rows(&y, &(m..m + m_val).collect::<Vec<_>>());
    SvmSetup { svm: MulticlassSvm::new(x_tr, y_tr), x_val, y_val }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    MirrorDescent,
    ProxGrad,
    Bcd,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffFp {
    Mirror,
    ProjGrad,
}

/// Solve the inner problem at θ with the chosen solver.
pub fn inner_solve(setup: &SvmSetup, solver: Solver, theta: f64, iters: usize) -> Vec<f64> {
    let svm = &setup.svm;
    match solver {
        Solver::MirrorDescent => {
            let geom = KlSimplexRows { m: svm.m(), k: svm.k };
            let cfg = crate::solvers::mirror::MirrorDescentConfig {
                step0: 1.0,
                warmup: 100,
                max_iter: iters,
                tol: 0.0,
            };
            let obj = MulticlassSvm::new(svm.x_tr.clone(), svm.y_tr.clone());
            crate::solvers::mirror::mirror_descent(&obj, &geom, &svm.init(), &[theta], &cfg).0
        }
        Solver::ProxGrad => {
            // projected gradient with simplex rows, step from Lipschitz bound
            let step = svm.pg_step(theta);
            let mut x = svm.init();
            let mut g = vec![0.0; x.len()];
            let mut z = vec![0.0; x.len()];
            let obj = MulticlassSvm::new(svm.x_tr.clone(), svm.y_tr.clone());
            use crate::mappings::objective::Objective;
            for _ in 0..iters {
                obj.grad_x(&x, &[theta], &mut g);
                let y: Vec<f64> = (0..x.len()).map(|i| x[i] - step * g[i]).collect();
                crate::proj::simplex::project_rows_simplex(&y, svm.k, &mut z);
                std::mem::swap(&mut x, &mut z);
            }
            x
        }
        Solver::Bcd => svm.solve_bcd(theta, iters),
    }
}

/// Hypergradient dL/dλ (λ = log θ) via implicit diff through a fixed point,
/// routed through the batched bilevel engine (`hypergrad_fixed_point` → one
/// block solve with k = 1; callers with several outer losses can pass the
/// cotangent block to `bilevel::hypergrad_implicit_multi` and share it).
pub fn hypergrad_implicit(setup: &SvmSetup, fp: DiffFp, x_star: &[f64], theta: f64) -> f64 {
    let svm = &setup.svm;
    let (grad_x, dl_dtheta_direct) = svm.outer_grads(&setup.x_val, &setup.y_val, x_star, theta);
    // Hypergradient precision ~1e-6 suffices for the outer loop; the cap
    // keeps the linear solve a small fraction of the inner-solve cost.
    let cfg = LinearSolveConfig {
        kind: LinearSolverKind::NormalCg,
        tol: 1e-6,
        max_iter: 400,
        gmres_restart: 30,
        ..Default::default()
    };
    let obj = MulticlassSvm::new(svm.x_tr.clone(), svm.y_tr.clone());
    let direct = [dl_dtheta_direct];
    // dL/dθ = (∂x*)ᵀ∇ₓL + ∂L/∂θ(direct); λ-space only after the chain rule.
    let dl_dtheta = match fp {
        DiffFp::Mirror => {
            let t = KlMirrorDescentFixedPoint::new(obj, KlSimplexRows { m: svm.m(), k: svm.k }, 1.0);
            crate::bilevel::hypergrad_fixed_point(t, x_star, &[theta], &grad_x, &direct, &cfg)[0]
        }
        DiffFp::ProjGrad => {
            let eta = svm.pg_step(theta);
            let t = ProjGradFixedPoint::new(obj, RowsSimplexProjection { m: svm.m(), k: svm.k }, eta);
            crate::bilevel::hypergrad_fixed_point(t, x_star, &[theta], &grad_x, &direct, &cfg)[0]
        }
    };
    // chain rule through θ = exp(λ): dL/dλ = dL/dθ · θ
    dl_dtheta * theta
}

/// Hypergradient via forward-mode unrolling of the fixed-point iteration
/// (same iteration count as the solver).
pub fn hypergrad_unroll(setup: &SvmSetup, fp: DiffFp, theta: f64, iters: usize) -> f64 {
    let svm = &setup.svm;
    let obj = MulticlassSvm::new(svm.x_tr.clone(), svm.y_tr.clone());
    let (x_t, dx) = match fp {
        DiffFp::Mirror => {
            let t = KlMirrorDescentFixedPoint::new(obj, KlSimplexRows { m: svm.m(), k: svm.k }, 1.0);
            crate::unroll::unroll_jvp(&t, &svm.init(), &[theta], &[1.0], iters)
        }
        DiffFp::ProjGrad => {
            let eta = svm.pg_step(theta);
            let t = ProjGradFixedPoint::new(obj, RowsSimplexProjection { m: svm.m(), k: svm.k }, eta);
            crate::unroll::unroll_jvp(&t, &svm.init(), &[theta], &[1.0], iters)
        }
    };
    let (grad_x, dl_dtheta_direct) = svm.outer_grads(&setup.x_val, &setup.y_val, &x_t, theta);
    (vecops::dot(&grad_x, &dx) + dl_dtheta_direct) * theta
}

/// One (solver, fixed point) runtime sweep over sizes.
fn runtime_sweep(args: &Args, solver: Solver, fps: &[DiffFp]) -> Json {
    let sizes = args.get_usize_list("sizes", &[50, 100, 200, 400]);
    let m = args.get_usize("m", 140);
    let m_val = args.get_usize("val", 40);
    let k = args.get_usize("k", 5);
    let samples = args.get_usize("samples", 3);
    let inner_iters = args.get_usize(
        "inner-iters",
        match solver {
            Solver::Bcd => 50,
            _ => 250,
        },
    );
    let seed = args.get_u64("seed", 3);

    let mut all_series: Vec<Series> = Vec::new();
    for &fp in fps {
        let fp_name = match fp {
            DiffFp::Mirror => "MD-fp",
            DiffFp::ProjGrad => "PG-fp",
        };
        let mut s_imp = Series::new(&format!("implicit ({fp_name})"));
        let mut s_unr = Series::new(&format!("unroll ({fp_name})"));
        for &p in &sizes {
            let setup_data = setup(m, p, k, m_val, seed);
            let theta = 1.0;
            // implicit: solve + vjp (timed together — one outer iteration)
            let mut times_i = Vec::new();
            let mut times_u = Vec::new();
            for _ in 0..samples {
                let t = Timer::start();
                let x_star = inner_solve(&setup_data, solver, theta, inner_iters);
                let _g = hypergrad_implicit(&setup_data, fp, &x_star, theta);
                times_i.push(t.elapsed_s());
                let t = Timer::start();
                // Unrolling cannot go through BCD (the paper's point in
                // Fig. 4c): it unrolls the differentiable MD/PG solver run to
                // comparable accuracy — 5× the sweeps (paper: 2500 vs 500).
                let unroll_iters =
                    if solver == Solver::Bcd { inner_iters * 5 } else { inner_iters };
                let _g = hypergrad_unroll(&setup_data, fp, theta, unroll_iters);
                times_u.push(t.elapsed_s());
            }
            let mi = crate::util::stats::mean(&times_i);
            let mu = crate::util::stats::mean(&times_u);
            s_imp.push(p as f64, mi, crate::util::stats::ci_half_width(&times_i, 1.645));
            s_unr.push(p as f64, mu, crate::util::stats::ci_half_width(&times_u, 1.645));
            println!(
                "p={p:>6}  implicit {:>10.4}s  unroll {:>10.4}s  ratio {:.2}x",
                mi,
                mu,
                mu / mi.max(1e-12)
            );
        }
        all_series.push(s_imp);
        all_series.push(s_unr);
    }
    let name = match solver {
        Solver::MirrorDescent => "fig4a",
        Solver::ProxGrad => "fig4b",
        Solver::Bcd => "fig4c",
    };
    write_figure(name, &all_series);
    Json::obj(vec![("series", Json::Arr(all_series.iter().map(Series::to_json).collect()))])
}

pub fn run_md(args: &Args) -> Json {
    runtime_sweep(args, Solver::MirrorDescent, &[DiffFp::Mirror])
}
pub fn run_pg(args: &Args) -> Json {
    runtime_sweep(args, Solver::ProxGrad, &[DiffFp::ProjGrad])
}
/// Fig. 4(c): BCD solver, differentiated with BOTH fixed points — the
/// paper's "solver and fixed point can be independently chosen".
pub fn run_bcd(args: &Args) -> Json {
    runtime_sweep(args, Solver::Bcd, &[DiffFp::Mirror, DiffFp::ProjGrad])
}

/// Fig. 13 — reverse-mode unrolling memory vs the 16 GiB device budget.
pub fn run_memory(args: &Args) -> Json {
    let sizes = args.get_usize_list(
        "sizes",
        &[100, 250, 500, 750, 1000, 2000, 3000, 4000, 5000, 7500, 10000],
    );
    let m = args.get_usize("m", 700);
    let k = args.get_usize("k", 5);
    let inner_iters = args.get_usize("inner-iters", 2500);
    let budget: u64 = 16 * (1 << 30);
    let mut s_unroll = Series::new("unroll reverse-mode memory (bytes)");
    let mut s_implicit = Series::new("implicit memory (bytes)");
    let mut rows = Vec::new();
    println!("{:<8} {:>16} {:>16} {:>8}", "p", "unroll bytes", "implicit bytes", "OOM?");
    for &p in &sizes {
        // Unrolling state: dual iterate (m×k) PLUS the primal W (p×k) each
        // iteration participates in — the p-dependence that drives the OOM.
        let state = m * k + p * k;
        let bytes = crate::unroll::reverse_memory_bytes(inner_iters, state, 4);
        let ooms = bytes > budget;
        let implicit_bytes = (state * 4 * 3) as u64; // O(1) iterates + CG workspace
        s_unroll.push(p as f64, bytes as f64, 0.0);
        s_implicit.push(p as f64, implicit_bytes as f64, 0.0);
        println!("{p:<8} {bytes:>16} {implicit_bytes:>16} {:>8}", if ooms { "OOM" } else { "ok" });
        rows.push(Json::obj(vec![
            ("p", Json::Num(p as f64)),
            ("unroll_bytes", Json::Num(bytes as f64)),
            ("implicit_bytes", Json::Num(implicit_bytes as f64)),
            ("oom", Json::Bool(ooms)),
        ]));
    }
    write_figure("fig13", &[s_unroll, s_implicit]);
    Json::obj(vec![("budget_bytes", Json::Num(budget as f64)), ("rows", Json::Arr(rows))])
}

/// Fig. 14 — validation loss at convergence is method-independent.
pub fn run_val_loss(args: &Args) -> Json {
    let sizes = args.get_usize_list("sizes", &[50, 100, 200]);
    let m = args.get_usize("m", 140);
    let m_val = args.get_usize("val", 40);
    let k = args.get_usize("k", 5);
    let outer_iters = args.get_usize("outer-iters", 25);
    let inner_iters = args.get_usize("inner-iters", 300);
    let seed = args.get_u64("seed", 3);
    let mut series = Vec::new();
    for (solver, fp, label) in [
        (Solver::MirrorDescent, DiffFp::Mirror, "MD solver + MD fp (implicit)"),
        (Solver::ProxGrad, DiffFp::ProjGrad, "PG solver + PG fp (implicit)"),
        (Solver::Bcd, DiffFp::ProjGrad, "BCD solver + PG fp (implicit)"),
    ] {
        let mut s = Series::new(label);
        for &p in &sizes {
            let setup_data = setup(m, p, k, m_val, seed);
            let mut lambda = 0.0f64;
            // per-solver iteration budgets for comparable convergence
            let iters = match solver {
                Solver::Bcd => inner_iters / 5,
                Solver::ProxGrad => inner_iters * 10,
                Solver::MirrorDescent => inner_iters,
            };
            let mut outer = crate::bilevel::outer::OuterGd::new(
                args.get_f64("outer-step", 5e-3),
                100,
            );
            for _ in 0..outer_iters {
                let theta = lambda.exp();
                let x_star = inner_solve(&setup_data, solver, theta, iters);
                let g = hypergrad_implicit(&setup_data, fp, &x_star, theta);
                let mut th = [lambda];
                outer.step(&mut th, &[g]);
                lambda = th[0];
            }
            let theta = lambda.exp();
            let x_star = inner_solve(&setup_data, solver, theta, iters);
            let loss = setup_data.svm.outer_loss(&setup_data.x_val, &setup_data.y_val, &x_star, theta);
            println!("{label}: p={p} final val loss {loss:.4} (θ={theta:.4})");
            s.push(p as f64, loss, 0.0);
        }
        series.push(s);
    }
    write_figure("fig14", &series);
    Json::obj(vec![("series", Json::Arr(series.iter().map(Series::to_json).collect()))])
}
