//! Experiment implementations, one module per paper table/figure.
//! Benches and examples call these with their own configs; the CLI
//! dispatches through [`crate::coordinator::registry`].

pub mod distill;
pub mod fig15;
pub mod fig3;
pub mod fig4;
pub mod md_sens;
pub mod table1;
pub mod table2;
pub mod xla_parity;
