//! Fig. 15 — Jacobian error vs solution error on the multiclass SVM
//! (θ = 1), ground truth from a tightly-converged BCD solve + central
//! finite differences (as in the paper's Appendix F.1).

use super::fig4::{setup, Solver};
use crate::diff::spec::FixedPointResidual;
use crate::linalg::mat::Mat;
use crate::linalg::solve::{LinearSolveConfig, LinearSolverKind};
use crate::linalg::vecops;
use crate::mappings::prox_grad::ProjGradFixedPoint;
use crate::ml::svm::MulticlassSvm;
use crate::proj::simplex::RowsSimplexProjection;
use crate::util::bench::{write_figure, Series};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

pub fn run(args: &Args) -> Json {
    let sizes = args.get_usize_list("sizes", &[20, 40, 80]);
    let m = args.get_usize("m", 60);
    let k = args.get_usize("k", 3);
    let seed = args.get_u64("seed", 5);
    let cot_k = args.get_usize("cotangents", 8);
    let theta = 1.0;

    let mut series = Vec::new();
    let mut block_bench = Json::Null;
    let largest = sizes.iter().copied().max().unwrap_or(0);
    for &p in &sizes {
        let sd = setup(m, p, k, 10, seed);
        let svm = &sd.svm;
        // Ground truth: very tight BCD solve + FD Jacobian dx*/dθ.
        let x_star = svm.solve_bcd(theta, 4000);
        let h = 1e-5;
        let xp = svm.solve_bcd(theta + h, 4000);
        let xm = svm.solve_bcd(theta - h, 4000);
        let jac_true: Vec<f64> =
            xp.iter().zip(&xm).map(|(a, b)| (a - b) / (2.0 * h)).collect();

        let mut s = Series::new(&format!("p={p}"));
        let cfg = LinearSolveConfig {
            kind: LinearSolverKind::NormalCg,
            tol: 1e-10,
            max_iter: 4000,
            gmres_restart: 30,
            ..Default::default()
        };
        // PG fixed-point residual for implicit differentiation at this size
        // (stateless across iterates — built once per p, not per grid point).
        let eta = svm.pg_step(theta);
        let obj = MulticlassSvm::new(svm.x_tr.clone(), svm.y_tr.clone());
        let fp = ProjGradFixedPoint::new(obj, RowsSimplexProjection { m: svm.m(), k: svm.k }, eta);
        let res = FixedPointResidual(fp);
        for &iters in &[2usize, 5, 10, 25, 50, 100, 200, 400] {
            let x_hat = super::fig4::inner_solve(&sd, Solver::Bcd, theta, iters);
            let sol_err = vecops::norm2(&vecops::sub(&x_hat, &x_star));
            // implicit Jacobian estimate at x̂ via the PG fixed point,
            // through the batched engine (the scalar-θ Jacobian is the
            // 1-column block A X = B·I₁)
            let (jac_est_m, _) =
                crate::diff::root::implicit_jvp_multi(&res, &x_hat, &[theta], &Mat::eye(1), &cfg);
            let jac_est = jac_est_m.data;
            let jac_err = vecops::norm2(&vecops::sub(&jac_est, &jac_true));
            s.push(sol_err, jac_err, 0.0);
            println!("p={p} iters={iters:<5} sol_err={sol_err:.3e} jac_err={jac_err:.3e}");
        }
        series.push(s);

        // Block-vs-column wall-time on the largest problem (EXPERIMENTS.md
        // §Perf): cot_k cotangents share ONE block solve vs cot_k
        // independent VJP solves — the multi-RHS payoff on this workload.
        if p == largest && cot_k > 0 {
            let d = svm.m() * svm.k;
            let mut rng = Rng::new(seed + 77);
            let cot = Mat::randn(d, cot_k, &mut rng);
            // Untimed warmup so first-call costs (allocator growth, thread
            // spawn, cold caches) don't land on whichever path runs first.
            let _ = crate::diff::root::implicit_vjp_multi(&res, &x_star, &[theta], &cot, &cfg);
            let t0 = Timer::start();
            let (vj_block, _) =
                crate::diff::root::implicit_vjp_multi(&res, &x_star, &[theta], &cot, &cfg);
            let s_block = t0.elapsed_s();
            let t0 = Timer::start();
            let mut vj_cols = Mat::zeros(1, cot_k);
            let mut cc = vec![0.0; d];
            for j in 0..cot_k {
                cot.col_into(j, &mut cc);
                let (vj, _) = crate::diff::root::implicit_vjp(&res, &x_star, &[theta], &cc, &cfg);
                vj_cols.set_col(j, &vj);
            }
            let s_cols = t0.elapsed_s();
            let mut max_diff = 0.0f64;
            let mut max_val = 1.0f64;
            for i in 0..vj_block.data.len() {
                max_diff = max_diff.max((vj_block.data[i] - vj_cols.data[i]).abs());
                max_val = max_val.max(vj_cols.data[i].abs());
            }
            // Path agreement is asserted at 1e-8 on well-conditioned systems
            // by the root.rs/integration tests; here (NormalCg squares the
            // conditioning) record it and warn instead of aborting the
            // whole figure run on an ill-conditioned size.
            let agrees = max_diff <= 1e-8 * max_val;
            if !agrees {
                eprintln!(
                    "fig15 WARNING: block vs column VJP max |Δ| = {max_diff:.3e} \
                     exceeds 1e-8 (κ²-amplified solver tolerance?)"
                );
            }
            let speedup = s_cols / s_block.max(1e-12);
            println!(
                "fig15 p={p}: {cot_k}-cotangent VJP block {s_block:.4}s vs column loop \
                 {s_cols:.4}s ({speedup:.2}x), max |Δ| = {max_diff:.2e}"
            );
            block_bench = Json::obj(vec![
                ("p", Json::Num(p as f64)),
                ("cotangents", Json::Num(cot_k as f64)),
                ("block_s", Json::Num(s_block)),
                ("column_s", Json::Num(s_cols)),
                ("speedup", Json::Num(speedup)),
                ("max_abs_diff", Json::Num(max_diff)),
                ("agrees_1e8", Json::Bool(agrees)),
            ]);
        }
    }
    write_figure("fig15", &series);
    Json::obj(vec![
        ("series", Json::Arr(series.iter().map(Series::to_json).collect())),
        ("vjp_block_bench", block_bench),
    ])
}
