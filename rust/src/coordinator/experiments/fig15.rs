//! Fig. 15 — Jacobian error vs solution error on the multiclass SVM
//! (θ = 1), ground truth from a tightly-converged BCD solve + central
//! finite differences (as in the paper's Appendix F.1).

use super::fig4::{setup, Solver};
use crate::diff::spec::FixedPointResidual;
use crate::linalg::solve::{LinearSolveConfig, LinearSolverKind};
use crate::linalg::vecops;
use crate::mappings::prox_grad::ProjGradFixedPoint;
use crate::ml::svm::MulticlassSvm;
use crate::proj::simplex::RowsSimplexProjection;
use crate::util::bench::{write_figure, Series};
use crate::util::cli::Args;
use crate::util::json::Json;

pub fn run(args: &Args) -> Json {
    let sizes = args.get_usize_list("sizes", &[20, 40, 80]);
    let m = args.get_usize("m", 60);
    let k = args.get_usize("k", 3);
    let seed = args.get_u64("seed", 5);
    let theta = 1.0;

    let mut series = Vec::new();
    for &p in &sizes {
        let sd = setup(m, p, k, 10, seed);
        let svm = &sd.svm;
        // Ground truth: very tight BCD solve + FD Jacobian dx*/dθ.
        let x_star = svm.solve_bcd(theta, 4000);
        let h = 1e-5;
        let xp = svm.solve_bcd(theta + h, 4000);
        let xm = svm.solve_bcd(theta - h, 4000);
        let jac_true: Vec<f64> =
            xp.iter().zip(&xm).map(|(a, b)| (a - b) / (2.0 * h)).collect();

        let mut s = Series::new(&format!("p={p}"));
        let cfg = LinearSolveConfig {
            kind: LinearSolverKind::NormalCg,
            tol: 1e-10,
            max_iter: 4000,
            gmres_restart: 30,
        };
        for &iters in &[2usize, 5, 10, 25, 50, 100, 200, 400] {
            let x_hat = super::fig4::inner_solve(&sd, Solver::Bcd, theta, iters);
            let sol_err = vecops::norm2(&vecops::sub(&x_hat, &x_star));
            // implicit Jacobian estimate at x̂ via the PG fixed point
            let eta = svm.pg_step(theta);
            let obj = MulticlassSvm::new(svm.x_tr.clone(), svm.y_tr.clone());
            let t = ProjGradFixedPoint::new(obj, RowsSimplexProjection { m: svm.m(), k: svm.k }, eta);
            let res = FixedPointResidual(t);
            let (jac_est, _) =
                crate::diff::root::implicit_jvp(&res, &x_hat, &[theta], &[1.0], &cfg);
            let jac_err = vecops::norm2(&vecops::sub(&jac_est, &jac_true));
            s.push(sol_err, jac_err, 0.0);
            println!("p={p} iters={iters:<5} sol_err={sol_err:.3e} jac_err={jac_err:.3e}");
        }
        series.push(s);
    }
    write_figure("fig15", &series);
    Json::obj(vec![("series", Json::Arr(series.iter().map(Series::to_json).collect()))])
}
