//! Fig. 3 — Jacobian estimate error vs iterate error on ridge regression
//! (diabetes-like data), three derivative modes side by side: implicit
//! differentiation, forward-mode unrolling, and Jacobian-free one-step
//! differentiation, overlaid with Theorem 1's bound. Besides the figure
//! series, a per-mode summary at the converged solution (Jacobian error,
//! wall time, estimated contraction factor ρ) is journaled to
//! `BENCH_modes.json` so CI tracks the accuracy/latency trade across PRs
//! (EXPERIMENTS.md §Modes).

use crate::data::regression::diabetes_like;
use crate::diff::mode::ModePolicy;
use crate::diff::one_step::{
    estimate_contraction, neumann_jvp_multi, one_step_jvp_multi, CONTRACTION_POWER_ITERS,
};
use crate::diff::precision;
use crate::diff::root::jacobian_via_root;
use crate::linalg::mat::Mat;
use crate::linalg::vecops;
use crate::mappings::stationary::GradientDescentFixedPoint;
use crate::ml::ridge::{RidgeProblem, RidgeRoot};
use crate::util::bench::{bench, write_figure, BenchConfig, BenchJournal, Series};
use crate::util::cli::Args;
use crate::util::json::Json;

fn fro_err(a: &Mat, b: &Mat) -> f64 {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut s = 0.0;
    for i in 0..a.data.len() {
        let d = a.data[i] - b.data[i];
        s += d * d;
    }
    s.sqrt()
}

pub fn run(args: &Args) -> Json {
    let m = args.get_usize("m", 442);
    let p = args.get_usize("p", 10);
    let seed = args.get_u64("seed", 7);
    let theta_val = args.get_f64("theta", 1.0);

    let (x_mat, y) = diabetes_like(m, p, seed);
    let rp = RidgeProblem::new(x_mat.clone(), y);
    let theta = vec![theta_val; p];
    let x_star = rp.solve_closed_form_vec(&theta);
    let jac_true = rp.jacobian_closed_form(&theta);

    // GD step from the Hessian's Lipschitz bound.
    let lip = rp.gram.fro_norm() + theta_val;
    let step = 1.0 / lip;

    let mut s_implicit = Series::new("implicit");
    let mut s_unroll = Series::new("unroll (forward)");
    let mut s_one_step = Series::new("one-step");
    let mut s_bound = Series::new("theorem-1 bound");
    let consts = precision::ridge_constants(&x_mat, &theta, &x_star);
    let mut bound_pairs = Vec::new();

    // The fixed-point view T(x, θ) = x − η∇f shared by the unroll and
    // one-step estimates, and the identity block for dense Jacobians.
    let fp = GradientDescentFixedPoint { obj: RidgeProblem::new(x_mat.clone(), rp.y.clone()), eta: step };
    let mut eye = Mat::zeros(p, p);
    for j in 0..p {
        let mut e = vec![0.0; p];
        e[j] = 1.0;
        eye.set_col(j, &e);
    }

    let iter_grid: Vec<usize> =
        args.get_usize_list("iters", &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048]);
    let root = RidgeRoot(&rp);
    let mut solves_per_jacobian = 0usize;
    for &t in &iter_grid {
        let x_hat = crate::solvers::gd::gd_fixed_iters(&rp, &vec![0.0; p], &theta, step, t);
        let iter_err = vecops::norm2(&vecops::sub(&x_hat, &x_star));
        // implicit estimate J(x̂, θ): all p basis directions as ONE block solve
        crate::linalg::solve::counter::reset();
        let jac_imp = jacobian_via_root(&root, &x_hat, &theta);
        solves_per_jacobian = crate::linalg::solve::counter::count();
        let mut err_imp = 0.0;
        for i in 0..jac_imp.data.len() {
            let d = jac_imp.data[i] - jac_true.data[i];
            err_imp += d * d;
        }
        let err_imp = err_imp.sqrt();
        // unrolled estimate: forward-mode through t GD iterations, per basis dir
        let mut err_unr = 0.0;
        {
            let mut e = vec![0.0; p];
            for j in 0..p {
                e[j] = 1.0;
                let (_, dx) = crate::unroll::unroll_jvp(&fp, &vec![0.0; p], &theta, &e, t);
                for i in 0..p {
                    let d = dx[i] - jac_true.at(i, j);
                    err_unr += d * d;
                }
                e[j] = 0.0;
            }
        }
        let err_unr = err_unr.sqrt();
        // one-step estimate: differentiate ONE application of T at x̂ —
        // J_os = ∂₂T(x̂, θ); no solve, no tape through the trajectory.
        let jac_os = one_step_jvp_multi(&fp, &x_hat, &theta, &eye);
        let err_os = fro_err(&jac_os, &jac_true);
        s_implicit.push(iter_err, err_imp, 0.0);
        s_unroll.push(iter_err, err_unr, 0.0);
        s_one_step.push(iter_err, err_os, 0.0);
        s_bound.push(iter_err, consts.bound(iter_err), 0.0);
        // Below ~1e-6 the measured Jacobian error is dominated by the CG
        // solve tolerance, not Theorem 1's term — exclude from the check.
        if iter_err > 1e-6 {
            bound_pairs.push(precision::ErrorPair { iterate_err: iter_err, jacobian_err: err_imp });
        }
    }
    // Empirical Theorem-1 check (5% numerical slack).
    let worst = precision::check_bound(&consts, &bound_pairs, 0.05);
    println!("fig3: worst bound ratio = {worst:.4} (must be ≤ 1)");
    println!("fig3: each dense Jacobian ({p} columns) = {solves_per_jacobian} block solve(s)");
    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>14}",
        "iter_err", "implicit", "unroll", "one-step", "bound"
    );
    for i in 0..s_implicit.rows.len() {
        println!(
            "{:<12.3e} {:>14.3e} {:>14.3e} {:>14.3e} {:>14.3e}",
            s_implicit.rows[i].0,
            s_implicit.rows[i].1,
            s_unroll.rows[i].1,
            s_one_step.rows[i].1,
            s_bound.rows[i].1
        );
    }

    // ---- per-mode summary at the converged solution → BENCH_modes.json --
    // Accuracy AND wall time for one dense p-column Jacobian, plus the
    // estimated contraction factor driving `ModePolicy` (EXPERIMENTS.md
    // §Modes defines the row schema).
    let rho = estimate_contraction(&fp, &x_star, &theta, CONTRACTION_POWER_ITERS, 0xf193);
    let k_auto = ModePolicy::default().default_unroll_terms(rho);
    let jac_for = |mode: &str| -> Mat {
        match mode {
            "implicit" => jacobian_via_root(&root, &x_star, &theta),
            "unroll" => neumann_jvp_multi(&fp, &x_star, &theta, &eye, k_auto),
            "one-step" => one_step_jvp_multi(&fp, &x_star, &theta, &eye),
            other => panic!("unknown mode {other}"),
        }
    };
    let bcfg = BenchConfig { warmup_iters: 2, samples: 7, reps_per_sample: 1 };
    let mut journal = BenchJournal::new();
    let mut modes_json = Vec::new();
    println!("fig3: rho = {rho:.4}, policy unroll depth = {k_auto}");
    for mode in ["implicit", "unroll", "one-step"] {
        let meas = bench(&format!("fig3/jacobian/{mode}"), bcfg, || jac_for(mode));
        let err = fro_err(&jac_for(mode), &jac_true);
        println!("fig3: mode {mode:<9} jacobian_err = {err:.3e}");
        journal.record(&meas, None);
        let row = Json::obj(vec![
            ("name", Json::Str(format!("fig3/jacobian_err/{mode}"))),
            ("mode", Json::Str(mode.to_string())),
            ("jacobian_err", Json::Num(err)),
            ("mean_s", Json::Num(meas.mean_s())),
        ]);
        journal.note(row.clone());
        modes_json.push(row);
    }
    journal.note(Json::obj(vec![
        ("name", Json::Str("fig3/contraction".into())),
        ("rho", Json::Num(rho)),
        ("unroll_terms", Json::Num(k_auto as f64)),
    ]));
    journal.write("BENCH_modes.json");

    let series = vec![s_implicit, s_unroll, s_one_step, s_bound];
    write_figure("fig3", &series);
    Json::obj(vec![
        ("worst_bound_ratio", Json::Num(worst)),
        ("solves_per_jacobian", Json::Num(solves_per_jacobian as f64)),
        ("rho", Json::Num(rho)),
        ("unroll_terms", Json::Num(k_auto as f64)),
        ("modes", Json::Arr(modes_json)),
        ("series", Json::Arr(series.iter().map(Series::to_json).collect())),
    ])
}
