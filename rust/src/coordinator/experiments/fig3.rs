//! Fig. 3 — Jacobian estimate error vs iterate error on ridge regression
//! (diabetes-like data), for implicit differentiation vs forward-mode
//! unrolling, overlaid with Theorem 1's bound.

use crate::data::regression::diabetes_like;
use crate::diff::precision;
use crate::diff::root::jacobian_via_root;
use crate::diff::spec::FixedPointResidual;
use crate::linalg::vecops;
use crate::mappings::stationary::GradientDescentFixedPoint;
use crate::ml::ridge::{RidgeProblem, RidgeRoot};
use crate::util::bench::{write_figure, Series};
use crate::util::cli::Args;
use crate::util::json::Json;

pub fn run(args: &Args) -> Json {
    let m = args.get_usize("m", 442);
    let p = args.get_usize("p", 10);
    let seed = args.get_u64("seed", 7);
    let theta_val = args.get_f64("theta", 1.0);

    let (x_mat, y) = diabetes_like(m, p, seed);
    let rp = RidgeProblem::new(x_mat.clone(), y);
    let theta = vec![theta_val; p];
    let x_star = rp.solve_closed_form_vec(&theta);
    let jac_true = rp.jacobian_closed_form(&theta);

    // GD step from the Hessian's Lipschitz bound.
    let lip = rp.gram.fro_norm() + theta_val;
    let step = 1.0 / lip;

    let mut s_implicit = Series::new("implicit");
    let mut s_unroll = Series::new("unroll (forward)");
    let mut s_bound = Series::new("theorem-1 bound");
    let consts = precision::ridge_constants(&x_mat, &theta, &x_star);
    let mut bound_pairs = Vec::new();

    let iter_grid: Vec<usize> =
        args.get_usize_list("iters", &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048]);
    let root = RidgeRoot(&rp);
    let mut solves_per_jacobian = 0usize;
    for &t in &iter_grid {
        let x_hat = crate::solvers::gd::gd_fixed_iters(&rp, &vec![0.0; p], &theta, step, t);
        let iter_err = vecops::norm2(&vecops::sub(&x_hat, &x_star));
        // implicit estimate J(x̂, θ): all p basis directions as ONE block solve
        crate::linalg::solve::counter::reset();
        let jac_imp = jacobian_via_root(&root, &x_hat, &theta);
        solves_per_jacobian = crate::linalg::solve::counter::count();
        let mut err_imp = 0.0;
        for i in 0..jac_imp.data.len() {
            let d = jac_imp.data[i] - jac_true.data[i];
            err_imp += d * d;
        }
        let err_imp = err_imp.sqrt();
        // unrolled estimate: forward-mode through t GD iterations, per basis dir
        let fp = GradientDescentFixedPoint {
            obj: RidgeProblem::new(x_mat.clone(), rp.y.clone()),
            eta: step,
        };
        let res = FixedPointResidual(fp);
        let mut err_unr = 0.0;
        {
            let mut e = vec![0.0; p];
            for j in 0..p {
                e[j] = 1.0;
                let (_, dx) = crate::unroll::unroll_jvp(&res.0, &vec![0.0; p], &theta, &e, t);
                for i in 0..p {
                    let d = dx[i] - jac_true.at(i, j);
                    err_unr += d * d;
                }
                e[j] = 0.0;
            }
        }
        let err_unr = err_unr.sqrt();
        s_implicit.push(iter_err, err_imp, 0.0);
        s_unroll.push(iter_err, err_unr, 0.0);
        s_bound.push(iter_err, consts.bound(iter_err), 0.0);
        // Below ~1e-6 the measured Jacobian error is dominated by the CG
        // solve tolerance, not Theorem 1's term — exclude from the check.
        if iter_err > 1e-6 {
            bound_pairs.push(precision::ErrorPair { iterate_err: iter_err, jacobian_err: err_imp });
        }
    }
    // Empirical Theorem-1 check (5% numerical slack).
    let worst = precision::check_bound(&consts, &bound_pairs, 0.05);
    println!("fig3: worst bound ratio = {worst:.4} (must be ≤ 1)");
    println!("fig3: each dense Jacobian ({p} columns) = {solves_per_jacobian} block solve(s)");
    println!("{:<12} {:>14} {:>14} {:>14}", "iter_err", "implicit", "unroll", "bound");
    for i in 0..s_implicit.rows.len() {
        println!(
            "{:<12.3e} {:>14.3e} {:>14.3e} {:>14.3e}",
            s_implicit.rows[i].0, s_implicit.rows[i].1, s_unroll.rows[i].1, s_bound.rows[i].1
        );
    }
    let series = vec![s_implicit, s_unroll, s_bound];
    write_figure("fig3", &series);
    Json::obj(vec![
        ("worst_bound_ratio", Json::Num(worst)),
        ("solves_per_jacobian", Json::Num(solves_per_jacobian as f64)),
        ("series", Json::Arr(series.iter().map(Series::to_json).collect())),
    ])
}
