//! Table 2 — breast-cancer survival AUC for four methods on the synthetic
//! gene-expression cohort (m=299 with 200/99 split, p genes):
//! L1 logreg, L2 logreg, unsupervised DictL + L2 logreg, task-driven DictL.
//! Protocol follows Appendix F.2: repeated 60/20/20 splits, validation-AUC
//! model selection, test AUC mean ± 95% CI.

use crate::data::gene_expr::make_cohort;
use crate::data::splits::{random_split, take, take_rows};
use crate::linalg::mat::Mat;
use crate::linalg::vecops;
use crate::mappings::objective::Objective;
use crate::ml::dict::{logistic_grads, DictReconstruction};
use crate::ml::metrics::auc;
use crate::prox::{ElasticNetProx, LassoProx, Prox};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// Binary logistic objective over weights w (+ intercept as last coord);
/// θ = [l2reg]. Smooth part for L1 is handled by prox-GD.
struct BinLogistic<'a> {
    x: &'a Mat,
    y: &'a [f64], // 0/1
    l2: f64,
}

impl Objective for BinLogistic<'_> {
    fn dim_x(&self) -> usize {
        self.x.cols + 1
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn value(&self, w: &[f64], _t: &[f64]) -> f64 {
        let (ww, b) = w.split_at(self.x.cols);
        let mut total = 0.0;
        for i in 0..self.x.rows {
            let z = vecops::dot(self.x.row(i), ww) + b[0];
            let y = if self.y[i] > 0.5 { 1.0 } else { -1.0 };
            let t = -y * z;
            total += if t > 30.0 { t } else { (1.0 + t.exp()).ln() };
        }
        total / self.x.rows as f64 + 0.5 * self.l2 * vecops::dot(ww, ww)
    }
    fn grad_x(&self, w: &[f64], _t: &[f64], out: &mut [f64]) {
        let p = self.x.cols;
        let (ww, b) = w.split_at(p);
        out.iter_mut().for_each(|o| *o = 0.0);
        let inv_m = 1.0 / self.x.rows as f64;
        for i in 0..self.x.rows {
            let z = vecops::dot(self.x.row(i), ww) + b[0];
            let y = if self.y[i] > 0.5 { 1.0 } else { -1.0 };
            let s = 1.0 / (1.0 + (y * z).exp());
            let coef = -y * s * inv_m;
            vecops::axpy(coef, self.x.row(i), &mut out[..p]);
            out[p] += coef;
        }
        for j in 0..p {
            out[j] += self.l2 * ww[j];
        }
    }
}

fn scores(x: &Mat, w: &[f64]) -> Vec<f64> {
    let p = x.cols;
    (0..x.rows).map(|i| vecops::dot(x.row(i), &w[..p]) + w[p]).collect()
}

/// L2-regularized logistic regression via GD; returns weights (p+1).
fn fit_l2_logreg(x: &Mat, y: &[f64], l2: f64, iters: usize) -> Vec<f64> {
    let obj = BinLogistic { x, y, l2 };
    let cfg = crate::solvers::gd::GdConfig { step: 1.0, max_iter: iters, tol: 1e-8, backtracking: true };
    crate::solvers::gd::gradient_descent(&obj, &vec![0.0; x.cols + 1], &[0.0], &cfg).0
}

/// L1-regularized logistic regression via prox-GD (intercept unpenalized via
/// group trick: lasso prox applied to weights only).
fn fit_l1_logreg(x: &Mat, y: &[f64], l1: f64, iters: usize) -> Vec<f64> {
    let obj = BinLogistic { x, y, l2: 0.0 };
    let p = x.cols;
    let mut w = vec![0.0; p + 1];
    let mut g = vec![0.0; p + 1];
    let step = 0.5;
    let prox = LassoProx { d: p };
    let mut shrunk = vec![0.0; p];
    for _ in 0..iters {
        obj.grad_x(&w, &[0.0], &mut g);
        for i in 0..=p {
            w[i] -= step * g[i];
        }
        let wslice = w[..p].to_vec();
        prox.prox(&wslice, &[l1], step, &mut shrunk);
        w[..p].copy_from_slice(&shrunk);
    }
    w
}

/// Unsupervised dictionary learning by alternating sparse coding (FISTA,
/// elastic net) and least-squares dictionary updates.
fn fit_dictionary(x: &Mat, k: usize, l1: f64, l2: f64, alternations: usize, rng: &mut Rng) -> (Mat, Mat) {
    let (m, p) = (x.rows, x.cols);
    let mut dict = Mat::randn(k, p, rng);
    // normalize dictionary rows
    for r in 0..k {
        let n = vecops::norm2(dict.row(r)).max(1e-12);
        for v in dict.row_mut(r) {
            *v /= n;
        }
    }
    let mut codes = Mat::zeros(m, k);
    for _ in 0..alternations {
        codes = sparse_codes(x, &dict, l1, l2, 200);
        // dict update: minimize ‖X − Cθ‖² → θ = (CᵀC + εI)⁻¹CᵀX
        let gram = codes.gram().plus_diag(1e-6);
        let ch = crate::linalg::chol::Cholesky::factor(&gram).unwrap();
        let ctx = codes.t_matmul(x);
        dict = ch.solve_mat(&ctx);
        for r in 0..k {
            let n = vecops::norm2(dict.row(r)).max(1e-12);
            for v in dict.row_mut(r) {
                *v /= n;
            }
        }
    }
    (dict, codes)
}

/// Sparse codes for data rows given a dictionary (FISTA on the elastic net).
fn sparse_codes(x: &Mat, dict: &Mat, l1: f64, l2: f64, iters: usize) -> Mat {
    let (m, k) = (x.rows, dict.rows);
    let obj = DictReconstruction { data: x.clone(), k };
    let prox = ElasticNetProx { d: m * k };
    let theta_full: Vec<f64> = dict.data.iter().cloned().chain([l1, l2]).collect();
    let lip = dict.matmul_t(dict).fro_norm().max(1e-9);
    let cfg = crate::solvers::prox_gd::ProxGdConfig {
        step: 1.0 / lip,
        max_iter: iters,
        tol: 1e-9,
        accelerated: true,
    };
    let (codes, _) =
        crate::solvers::prox_gd::prox_gradient_descent(&obj, &prox, &vec![0.0; m * k], &theta_full, &cfg);
    Mat { rows: m, cols: k, data: codes }
}

/// Task-driven dictionary learning: bilevel with implicit diff through the
/// prox-grad fixed point of the sparse-coding problem; Adam on (dict, w, b).
fn fit_task_driven(
    x: &Mat,
    y: &[f64],
    k: usize,
    l1: f64,
    l2: f64,
    ridge_c: f64,
    outer_iters: usize,
    rng: &mut Rng,
) -> (Mat, Vec<f64>, f64) {
    use crate::diff::spec::FixedPointResidual;
    use crate::mappings::prox_grad::ProxGradFixedPoint;
    let (m, p) = (x.rows, x.cols);
    let (mut dict, _) = fit_dictionary(x, k, l1, l2, 2, rng);
    let mut w = vec![0.0; k];
    let mut b = 0.0;
    let n_dict = k * p;
    let mut adam = crate::bilevel::outer::Adam::new(0.02, n_dict + k + 1);
    for _ in 0..outer_iters {
        let codes = sparse_codes(x, &dict, l1, l2, 150);
        // outer loss grads
        let (gc, gw, gb) = logistic_grads(&codes, &w, b, y, ridge_c);
        // hypergradient w.r.t. the dictionary through the fixed point
        let obj = DictReconstruction { data: x.clone(), k };
        let prox = ElasticNetProx { d: m * k };
        let lip = dict.matmul_t(&dict).fro_norm().max(1e-9);
        let fp = ProxGradFixedPoint::new(obj, prox, 1.0 / lip);
        let res = FixedPointResidual(fp);
        let theta_full: Vec<f64> = dict.data.iter().cloned().chain([l1, l2]).collect();
        let cfg = crate::linalg::solve::LinearSolveConfig {
            kind: crate::linalg::solve::LinearSolverKind::NormalCg,
            tol: 1e-6,
            max_iter: 400,
            gmres_restart: 30,
            ..Default::default()
        };
        // One-column cotangent block through the batched engine (a future
        // multi-head outer loss shares this single block solve).
        let (hg_full_m, _) = crate::diff::root::implicit_vjp_multi(
            &res,
            &codes.data,
            &theta_full,
            &Mat::from_col(&gc.data),
            &cfg,
        );
        let hg_full = hg_full_m.data;
        // assemble the parameter gradient (dict block + head block)
        let mut grad = vec![0.0; n_dict + k + 1];
        grad[..n_dict].copy_from_slice(&hg_full[..n_dict]);
        grad[n_dict..n_dict + k].copy_from_slice(&gw);
        grad[n_dict + k] = gb;
        let mut params: Vec<f64> = dict.data.iter().cloned().chain(w.iter().cloned()).chain([b]).collect();
        adam.step(&mut params, &grad);
        dict.data.copy_from_slice(&params[..n_dict]);
        w.copy_from_slice(&params[n_dict..n_dict + k]);
        b = params[n_dict + k];
    }
    (dict, w, b)
}

pub fn run(args: &Args) -> Json {
    let p = args.get_usize("p", 300);
    let n_splits = args.get_usize("splits", 4);
    let k = args.get_usize("dict-k", 10);
    let outer_iters = args.get_usize("outer-iters", 15);
    let seed = args.get_u64("seed", 13);
    let cohort = make_cohort(200, 99, p, p / 20, seed);
    let m = cohort.x.rows;

    let l1_grid = [0.001, 0.01, 0.05];
    let l2_grid = [0.001, 0.01, 0.1];

    let mut results: Vec<Vec<f64>> = vec![Vec::new(); 4]; // per-method test AUCs
    let mut rng = Rng::new(seed + 100);
    for split_id in 0..n_splits {
        let sp = random_split(m, 0.6, 0.2, &mut rng);
        let xtr = take_rows(&cohort.x, &sp.train);
        let ytr = take(&cohort.labels, &sp.train);
        let xva = take_rows(&cohort.x, &sp.val);
        let yva = take(&cohort.labels, &sp.val);
        let xte = take_rows(&cohort.x, &sp.test);
        let yte = take(&cohort.labels, &sp.test);

        // Method 1: L1 logreg
        let mut best = (0.0, Vec::new());
        for &l1 in &l1_grid {
            let w = fit_l1_logreg(&xtr, &ytr, l1, 300);
            let a = auc(&scores(&xva, &w), &yva);
            if a >= best.0 {
                best = (a, w);
            }
        }
        results[0].push(auc(&scores(&xte, &best.1), &yte));

        // Method 2: L2 logreg
        let mut best = (0.0, Vec::new());
        for &l2 in &l2_grid {
            let w = fit_l2_logreg(&xtr, &ytr, l2, 300);
            let a = auc(&scores(&xva, &w), &yva);
            if a >= best.0 {
                best = (a, w);
            }
        }
        results[1].push(auc(&scores(&xte, &best.1), &yte));

        // Method 3: unsupervised DictL + L2 logreg on codes
        let (dict, _) = fit_dictionary(&xtr, k, 0.05, 0.01, 3, &mut rng);
        let ctr = sparse_codes(&xtr, &dict, 0.05, 0.01, 200);
        let cte = sparse_codes(&xte, &dict, 0.05, 0.01, 200);
        let cva = sparse_codes(&xva, &dict, 0.05, 0.01, 200);
        let mut best = (0.0, Vec::new());
        for &l2 in &l2_grid {
            let w = fit_l2_logreg(&ctr, &ytr, l2, 400);
            let a = auc(&scores(&cva, &w), &yva);
            if a >= best.0 {
                best = (a, w);
            }
        }
        results[2].push(auc(&scores(&cte, &best.1), &yte));

        // Method 4: task-driven DictL (bilevel, implicit diff)
        let (dict, w, b) = fit_task_driven(&xtr, &ytr, k, 0.05, 0.01, 0.01, outer_iters, &mut rng);
        let cte = sparse_codes(&xte, &dict, 0.05, 0.01, 200);
        let s: Vec<f64> = (0..cte.rows).map(|i| vecops::dot(cte.row(i), &w) + b).collect();
        results[3].push(auc(&s, &yte));

        println!(
            "split {split_id}: L1 {:.3} | L2 {:.3} | DictL+L2 {:.3} | TaskDictL {:.3}",
            results[0][split_id], results[1][split_id], results[2][split_id], results[3][split_id]
        );
    }

    let names = ["L1 logreg", "L2 logreg", "DictL + L2 logreg", "Task-driven DictL"];
    let mut tbl = Table::new(&["Method", "AUC (%)"]);
    let mut rows = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let mean = crate::util::stats::mean(&results[i]) * 100.0;
        let ci = crate::util::stats::ci_half_width(&results[i], 1.96) * 100.0;
        tbl.row_strs(&[name, &format!("{mean:.1} ± {ci:.1}")]);
        rows.push(Json::obj(vec![
            ("method", Json::Str(name.to_string())),
            ("auc_mean", Json::Num(mean)),
            ("auc_ci95", Json::Num(ci)),
        ]));
    }
    tbl.print();
    Json::obj(vec![("rows", Json::Arr(rows))])
}
