//! Table 1 coverage — instantiate every optimality-mapping row on a small
//! problem and verify its implicit Jacobian against finite differences of an
//! exact solver. This is the executable form of the paper's catalog table.

use crate::diff::root::jacobian_via_root;
use crate::diff::spec::{FixedPointResidual, RootMap};
use crate::linalg::Mat;
use crate::mappings::kkt::{solve_eq_qp, QpKktMapping};
use crate::mappings::mirror::{KlMirrorDescentFixedPoint, KlSimplexRows};
use crate::mappings::newton::NewtonFixedPoint;
use crate::mappings::objective::QuadObjective;
use crate::mappings::prox_grad::{BlockProxGradFixedPoint, ProjGradFixedPoint, ProxGradFixedPoint};
use crate::mappings::stationary::StationaryMapping;
use crate::prox::LassoProx;
use crate::proj::simplex::SimplexProjection;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;

fn quad(d: usize, n: usize, seed: u64) -> QuadObjective {
    let mut rng = Rng::new(seed);
    QuadObjective {
        q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0),
        r: Mat::randn(d, n, &mut rng),
        c: rng.normal_vec(d),
    }
}

/// Max |implicit − FD| over the Jacobian of a root map whose solution is
/// produced by `solver`.
fn check_root<M: RootMap>(
    m: &M,
    solver: impl Fn(&[f64]) -> Vec<f64>,
    theta: &[f64],
    fd_h: f64,
) -> f64 {
    let x_star = solver(theta);
    let jac = jacobian_via_root(m, &x_star, theta);
    let mut max_err = 0.0f64;
    for j in 0..theta.len() {
        let mut tp = theta.to_vec();
        tp[j] += fd_h;
        let xp = solver(&tp);
        let mut tm = theta.to_vec();
        tm[j] -= fd_h;
        let xm = solver(&tm);
        for i in 0..x_star.len() {
            let fd = (xp[i] - xm[i]) / (2.0 * fd_h);
            max_err = max_err.max((jac.at(i, j) - fd).abs());
        }
    }
    max_err
}

pub fn run(_args: &Args) -> Json {
    let mut tbl = Table::new(&["mapping (Table 1 row)", "max |J_implicit − J_fd|", "pass"]);
    let mut rows = Vec::new();
    let tol = 2e-4;
    let record = |name: &str, err: f64, tbl: &mut Table, rows: &mut Vec<Json>| {
        let pass = err < tol;
        tbl.row_strs(&[name, &format!("{err:.2e}"), if pass { "✓" } else { "✗" }]);
        rows.push(Json::obj(vec![
            ("mapping", Json::Str(name.to_string())),
            ("max_err", Json::Num(err)),
            ("pass", Json::Bool(pass)),
        ]));
        assert!(pass, "{name}: Jacobian mismatch {err}");
    };

    // 1. Stationary (Eq. 4): quadratic, exact solve.
    {
        let obj = quad(4, 2, 1);
        let q = obj.q.clone();
        let r = obj.r.clone();
        let c = obj.c.clone();
        let solver = move |theta: &[f64]| {
            let ch = crate::linalg::chol::Cholesky::factor(&q).unwrap();
            let rt = r.matvec(theta);
            let rhs: Vec<f64> = rt.iter().zip(&c).map(|(a, b)| -(a + b)).collect();
            ch.solve(&rhs)
        };
        let m = StationaryMapping::new(obj);
        let err = check_root(&m, solver, &[0.4, -0.2], 1e-6);
        record("stationary (4)", err, &mut tbl, &mut rows);
    }
    // 2. KKT (Eq. 6): equality-constrained QP.
    {
        let mut rng = Rng::new(2);
        let q = Mat::randn(5, 3, &mut rng).gram().plus_diag(1.0);
        let e = Mat::randn(1, 3, &mut rng);
        let mapping = QpKktMapping { q: q.clone(), e: e.clone(), m: Mat::zeros(0, 3) };
        let solver = move |theta: &[f64]| {
            let (z, nu) = solve_eq_qp(&q, &e, &theta[..3], &theta[3..4]);
            z.into_iter().chain(nu).collect()
        };
        let theta = [0.3, -0.1, 0.5, 0.2];
        let err = check_root(&mapping, solver, &theta, 1e-6);
        record("KKT (6)", err, &mut tbl, &mut rows);
    }
    // 3. Proximal gradient (Eq. 7): lasso on a quadratic.
    {
        let obj = quad(5, 1, 3);
        let fp = ProxGradFixedPoint::new(obj, LassoProx { d: 5 }, 0.05);
        let res = FixedPointResidual(fp);
        let solver = |theta: &[f64]| {
            let obj = quad(5, 1, 3);
            let prox = LassoProx { d: 5 };
            let cfg = crate::solvers::prox_gd::ProxGdConfig {
                step: 0.05,
                max_iter: 60_000,
                tol: 1e-14,
                accelerated: false,
            };
            crate::solvers::prox_gd::prox_gradient_descent(&obj, &prox, &vec![0.0; 5], theta, &cfg).0
        };
        let err = check_root(&res, solver, &[0.3, 0.25], 1e-5);
        record("proximal gradient (7)", err, &mut tbl, &mut rows);
    }
    // 4. Projected gradient (Eq. 9): simplex-constrained quadratic.
    {
        let fp = ProjGradFixedPoint::new(quad(4, 1, 4), SimplexProjection { d: 4 }, 0.05);
        let res = FixedPointResidual(fp);
        let solver = |theta: &[f64]| {
            let obj = quad(4, 1, 4);
            use crate::mappings::objective::Objective;
            let mut x = vec![0.25; 4];
            let mut g = vec![0.0; 4];
            for _ in 0..40_000 {
                obj.grad_x(&x, theta, &mut g);
                let y: Vec<f64> = (0..4).map(|i| x[i] - 0.05 * g[i]).collect();
                let mut z = vec![0.0; 4];
                crate::proj::simplex::project_simplex(&y, &mut z);
                x = z;
            }
            x
        };
        let err = check_root(&res, solver, &[0.2], 1e-5);
        record("projected gradient (9)", err, &mut tbl, &mut rows);
    }
    // 5. Mirror descent (13): KL simplex.
    {
        let fp = KlMirrorDescentFixedPoint::new(quad(4, 1, 5), KlSimplexRows { m: 1, k: 4 }, 0.3);
        let res = FixedPointResidual(fp);
        let solver = |theta: &[f64]| {
            let obj = quad(4, 1, 5);
            let geom = KlSimplexRows { m: 1, k: 4 };
            let cfg = crate::solvers::mirror::MirrorDescentConfig {
                step0: 0.3,
                warmup: 100_000,
                max_iter: 100_000,
                tol: 1e-15,
            };
            crate::solvers::mirror::mirror_descent(&obj, &geom, &vec![0.25; 4], theta, &cfg).0
        };
        let err = check_root(&res, solver, &[0.2], 1e-5);
        record("mirror descent (13)", err, &mut tbl, &mut rows);
    }
    // 6. Newton (14) on the stationary mapping of a quadratic.
    {
        let newton = NewtonFixedPoint::new(StationaryMapping::new(quad(4, 2, 6)), 1.0);
        let res = FixedPointResidual(newton);
        let solver = |theta: &[f64]| {
            let obj = quad(4, 2, 6);
            let ch = crate::linalg::chol::Cholesky::factor(&obj.q).unwrap();
            let rt = obj.r.matvec(theta);
            let rhs: Vec<f64> = rt.iter().zip(&obj.c).map(|(a, b)| -(a + b)).collect();
            ch.solve(&rhs)
        };
        let err = check_root(&res, solver, &[0.1, 0.6], 1e-6);
        record("Newton (14)", err, &mut tbl, &mut rows);
    }
    // 7. Block proximal gradient (15): two blocks, same lasso.
    {
        let fp = BlockProxGradFixedPoint {
            obj: quad(6, 1, 7),
            prox: LassoProx { d: 6 },
            blocks: vec![(0, 3, 0.04), (3, 6, 0.04)],
        };
        let res = FixedPointResidual(fp);
        let solver = |theta: &[f64]| {
            let obj = quad(6, 1, 7);
            let prox = LassoProx { d: 6 };
            let cfg = crate::solvers::prox_gd::ProxGdConfig {
                step: 0.04,
                max_iter: 80_000,
                tol: 1e-14,
                accelerated: false,
            };
            crate::solvers::prox_gd::prox_gradient_descent(&obj, &prox, &vec![0.0; 6], theta, &cfg).0
        };
        let err = check_root(&res, solver, &[0.2, 0.2], 1e-5);
        record("block proximal gradient (15)", err, &mut tbl, &mut rows);
    }
    // 8. Conic programming (18): jacobian products validated against FD at a
    //    generic point (full LP pipeline exercised in unit tests).
    {
        let mut rng = Rng::new(8);
        let map = crate::mappings::conic::ConicResidualMap { e: Mat::randn(3, 2, &mut rng) };
        let x = rng.normal_vec(map.dim_x());
        let theta = rng.normal_vec(map.dim_theta());
        let v = rng.normal_vec(map.dim_x());
        let mut jv = vec![0.0; map.dim_x()];
        map.jvp_x(&x, &theta, &v, &mut jv);
        let fd = crate::ad::num_grad::jvp_fd(|xx| map.eval_vec(xx, &theta), &x, &v, 1e-7);
        let err = jv.iter().zip(&fd).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        record("conic residual map (18)", err, &mut tbl, &mut rows);
    }

    tbl.print();
    Json::obj(vec![("rows", Json::Arr(rows))])
}
