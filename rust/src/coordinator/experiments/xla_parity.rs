//! XLA runtime parity — load the AOT-compiled JAX/Pallas ridge oracle and
//! verify it produces the same F/JVP values and the same implicit Jacobian
//! as the native Rust oracle (the three-layer composition check).
//!
//! The problem data is exported by `python/compile/aot.py` into
//! `artifacts/ridge_data.json` so both sides operate on identical inputs.

use crate::diff::root::jacobian_via_root;
use crate::diff::spec::RootMap;
use crate::linalg::Mat;
use crate::ml::ridge::{RidgeProblem, RidgeRoot};
use crate::runtime::{artifacts_dir, XlaRidgeRoot, XlaRuntime};
use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Load the shared ridge problem the artifacts were compiled against.
pub fn load_shared_problem(dir: &std::path::Path) -> anyhow::Result<RidgeProblem> {
    let text = std::fs::read_to_string(dir.join("ridge_data.json"))?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("ridge_data: {e}"))?;
    let m = doc.usize_or("m", 0);
    let d = doc.usize_or("d", 0);
    let x: Vec<f64> = doc
        .get("x")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default();
    let y: Vec<f64> = doc
        .get("y")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default();
    anyhow::ensure!(x.len() == m * d && y.len() == m, "ridge_data shape mismatch");
    Ok(RidgeProblem::new(Mat::from_vec(m, d, x), y))
}

fn rel_max_err(a: &[f64], b: &[f64]) -> f64 {
    let scale = b.iter().fold(1e-12f64, |m, &v| m.max(v.abs()));
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max)
        / scale
}

pub fn run(_args: &Args) -> Json {
    let dir = artifacts_dir();
    let rt = match XlaRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("xla parity SKIPPED: {e:#} (run `make artifacts` first)");
            return Json::obj(vec![("skipped", Json::Bool(true))]);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let rp = match load_shared_problem(&dir) {
        Ok(rp) => rp,
        Err(e) => {
            println!("xla parity SKIPPED: {e:#}");
            return Json::obj(vec![("skipped", Json::Bool(true))]);
        }
    };
    let d = rp.dim();
    let native = RidgeRoot(&rp);
    let oracle = XlaRidgeRoot { rt: &rt, d, design: rp.x.data.clone(), targets: rp.y.clone() };

    let theta = vec![1.5; d];
    let x_star = rp.solve_closed_form_vec(&theta);

    // F parity at a generic (non-root) point — at x* both sides are ≈0 and
    // the relative metric would divide by noise.
    let x_generic: Vec<f64> = x_star.iter().map(|v| v + 1.0).collect();
    let f_native = native.eval_vec(&x_generic, &theta);
    let f_xla = oracle.eval_vec(&x_generic, &theta);
    let max_f = rel_max_err(&f_xla, &f_native);
    // JVP parity
    let v: Vec<f64> = (0..d).map(|i| (i as f64 * 0.37).sin()).collect();
    let mut jn = vec![0.0; d];
    native.jvp_x(&x_star, &theta, &v, &mut jn);
    let mut jx = vec![0.0; d];
    oracle.jvp_x(&x_star, &theta, &v, &mut jx);
    let max_jvp = rel_max_err(&jx, &jn);
    // Implicit Jacobian through BOTH oracles
    let jac_native = jacobian_via_root(&native, &x_star, &theta);
    let jac_xla = jacobian_via_root(&oracle, &x_star, &theta);
    let max_jac = rel_max_err(&jac_xla.data, &jac_native.data);
    println!("rel max |F_native − F_xla|      = {max_f:.3e}");
    println!("rel max |JVP_native − JVP_xla|  = {max_jvp:.3e}");
    println!("rel max |Jac_native − Jac_xla|  = {max_jac:.3e}");
    // f32 artifacts → parity at f32 precision.
    let ok = max_f < 1e-3 && max_jvp < 1e-3 && max_jac < 1e-3;
    println!("xla parity: {}", if ok { "OK" } else { "FAILED" });
    Json::obj(vec![
        ("max_f_err", Json::Num(max_f)),
        ("max_jvp_err", Json::Num(max_jvp)),
        ("max_jac_err", Json::Num(max_jac)),
        ("ok", Json::Bool(ok)),
    ])
}
