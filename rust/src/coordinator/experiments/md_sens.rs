//! Figs. 6 & 17 — molecular-dynamics position sensitivity ∂x*(θ) w.r.t. the
//! small-particle diameter. Implicit forward-mode (BiCGSTAB on the Hessian
//! system, as the paper does) converges; forward-mode unrolling through the
//! discontinuous FIRE optimizer does not.

use crate::diff::spec::RootMap;
use crate::linalg::op::FnOp;
use crate::linalg::solve::{self, LinearSolveConfig, LinearSolverKind};
use crate::linalg::vecops;
use crate::md::{random_packing, MdForceRoot, SoftSphereSystem};
use crate::solvers::fire::FireConfig;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Implicit sensitivity dx*/dθ via BiCGSTAB with a small Tikhonov shift
/// (the Hessian is singular along rigid translations).
pub fn implicit_sensitivity(sys: &SoftSphereSystem, x_star: &[f64], theta: f64) -> Vec<f64> {
    let root = MdForceRoot(sys);
    let d = sys.dim();
    let mut b = vec![0.0; d];
    root.jvp_theta(x_star, &[theta], &[1.0], &mut b);
    let reg = 1e-8;
    let op = FnOp::sym(
        d,
        |v: &[f64], y: &mut [f64]| {
            sys.hessian_vp(x_star, theta, v, y);
            for i in 0..d {
                y[i] += reg * v[i];
            }
        },
        |v: &[f64], y: &mut [f64]| {
            sys.hessian_vp(x_star, theta, v, y);
            for i in 0..d {
                y[i] += reg * v[i];
            }
        },
    );
    let mut dx = vec![0.0; d];
    let cfg = LinearSolveConfig {
        kind: LinearSolverKind::BiCgStab,
        tol: 1e-9,
        max_iter: 4000,
        gmres_restart: 50,
        ..Default::default()
    };
    solve::solve(&op, &b, &mut dx, &cfg);
    dx
}

/// Forward-mode unrolling through FIRE: propagate tangents through the
/// velocity-Verlet updates and the (discontinuous) mixing/reset logic.
pub fn unrolled_sensitivity(
    sys: &SoftSphereSystem,
    x0: &[f64],
    theta: f64,
    cfg: &FireConfig,
) -> Vec<f64> {
    let d = sys.dim();
    let mut x = x0.to_vec();
    let mut v = vec![0.0; d];
    let mut dx = vec![0.0; d];
    let mut dv = vec![0.0; d];
    let mut f = vec![0.0; d];
    let mut df = vec![0.0; d];
    let mut hv = vec![0.0; d];
    let mut ft = vec![0.0; d];
    let mut dt = cfg.dt_start;
    let mut alpha = cfg.alpha_start;
    let mut n_pos = 0usize;
    let compute_df = |x: &[f64], dx: &[f64], hv: &mut [f64], ft: &mut [f64], df: &mut [f64]| {
        // dF = −H dx + ∂F/∂θ
        sys.hessian_vp(x, theta, dx, hv);
        sys.force_theta_jvp(x, theta, ft);
        for i in 0..df.len() {
            df[i] = -hv[i] + ft[i];
        }
    };
    sys.forces(&x, theta, &mut f);
    compute_df(&x, &dx, &mut hv, &mut ft, &mut df);
    for _ in 0..cfg.max_iter {
        for i in 0..d {
            v[i] += dt * f[i];
            dv[i] += dt * df[i];
            x[i] += dt * v[i];
            dx[i] += dt * dv[i];
        }
        sys.forces(&x, theta, &mut f);
        compute_df(&x, &dx, &mut hv, &mut ft, &mut df);
        let p = vecops::dot(&f, &v);
        let fnorm = vecops::norm2(&f).max(1e-300);
        let vnorm = vecops::norm2(&v);
        if p > 0.0 {
            // differentiate v ← (1−α)v + α|v| f/|f|
            let dvnorm = if vnorm > 1e-300 { vecops::dot(&v, &dv) / vnorm } else { 0.0 };
            let dfnorm = vecops::dot(&f, &df) / fnorm;
            for i in 0..d {
                let unit_f = f[i] / fnorm;
                let dunit_f = df[i] / fnorm - f[i] * dfnorm / (fnorm * fnorm);
                dv[i] = (1.0 - alpha) * dv[i] + alpha * (dvnorm * unit_f + vnorm * dunit_f);
                v[i] = (1.0 - alpha) * v[i] + alpha * vnorm * unit_f;
            }
            n_pos += 1;
            if n_pos > cfg.n_min {
                dt = (dt * cfg.f_inc).min(cfg.dt_max);
                alpha *= cfg.f_alpha;
            }
        } else {
            v.iter_mut().for_each(|vi| *vi = 0.0);
            dv.iter_mut().for_each(|vi| *vi = 0.0); // the discontinuity
            dt *= cfg.f_dec;
            alpha = cfg.alpha_start;
            n_pos = 0;
        }
        // NOTE: no early exit — the paper unrolls a fixed-length
        // lax.fori_loop, and it is precisely the post-convergence steps
        // (f → 0, so d(f/‖f‖) ~ df/‖f‖ blows up in the velocity mixing)
        // that make unrolled FIRE sensitivities diverge (Fig. 17).
    }
    dx
}

pub fn run(args: &Args) -> Json {
    let n_particles = args.get_usize("particles", 32);
    let n_seeds = args.get_usize("seeds", 8);
    let theta = args.get_f64("theta", 0.6);
    let seed0 = args.get_u64("seed", 21);
    // box sized for ~50% packing fraction
    let area: f64 = (n_particles as f64 / 2.0)
        * (std::f64::consts::PI / 4.0)
        * (1.0 + theta * theta);
    let box_side = (area / 1.25).sqrt();

    let mut rows = Vec::new();
    let mut imp_norms = Vec::new();
    let mut unr_norms = Vec::new();
    let mut n_unroll_diverged = 0;
    for s in 0..n_seeds {
        let sys = SoftSphereSystem::new(n_particles, box_side);
        let mut rng = Rng::new(seed0 + s as u64);
        let x0 = random_packing(n_particles, &mut rng);
        let cfg = FireConfig { max_iter: 6000, force_tol: 1e-10, ..Default::default() };
        let x_star = sys.relax(&x0, theta, &cfg);
        let dx_imp = implicit_sensitivity(&sys, &x_star, theta);
        let n_imp = vecops::norm1(&dx_imp);
        let dx_unr = unrolled_sensitivity(&sys, &x0, theta, &cfg);
        let n_unr = vecops::norm1(&dx_unr);
        let diverged = !n_unr.is_finite() || n_unr > 100.0 * n_imp.max(1e-12);
        if diverged {
            n_unroll_diverged += 1;
        }
        println!(
            "seed {s}: ‖∂x‖₁ implicit {n_imp:.4e}  unrolled {n_unr:.4e}{}",
            if diverged { "  (diverged)" } else { "" }
        );
        imp_norms.push(n_imp);
        unr_norms.push(n_unr);
        rows.push(Json::obj(vec![
            ("seed", Json::Num(s as f64)),
            ("implicit_l1", Json::Num(n_imp)),
            ("unrolled_l1", Json::Num(n_unr)),
            ("unrolled_diverged", Json::Bool(diverged)),
        ]));
    }
    println!(
        "fig17: unrolled diverged on {n_unroll_diverged}/{n_seeds} seeds (paper: most seeds fail to converge)"
    );
    Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("implicit_l1_mean", Json::Num(crate::util::stats::mean(&imp_norms))),
        ("n_unroll_diverged", Json::Num(n_unroll_diverged as f64)),
        ("n_seeds", Json::Num(n_seeds as f64)),
    ])
}
