//! Dataset distillation (paper §4.2, Figs. 5 & 16) — bi-level problem on the
//! synthetic digits set. Inner: ℓ2-regularized multiclass logistic regression
//! trained on the k distilled images θ; outer: training-set loss of the inner
//! solution. Implicit differentiation (stationary mapping + CG) vs
//! reverse-mode unrolling of the GD fixed point — the paper reports implicit
//! being ~4× faster per outer step at equal quality.

use crate::data::digits;
use crate::diff::spec::FixedPointResidual;
use crate::linalg::solve::{LinearSolveConfig, LinearSolverKind};
use crate::mappings::stationary::{GradientDescentFixedPoint, StationaryMapping};
use crate::ml::logreg::{mean_ce_grad, mean_ce_loss, DistillInnerObjective};
use crate::solvers::gd::{gradient_descent, GdConfig};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

pub struct DistillSetup {
    pub train: digits::DigitsDataset,
    pub obj: DistillInnerObjective,
    pub p: usize,
    pub k: usize,
}

pub fn make_setup(m_train: usize, seed: u64) -> DistillSetup {
    let mut rng = Rng::new(seed);
    let train = digits::make_digits(m_train, 0.3, &mut rng);
    let p = digits::PIXELS;
    let k = 10;
    DistillSetup { train, obj: DistillInnerObjective { p, k, l2reg: 1e-3 }, p, k }
}

/// One implicit outer step: inner solve (GD + backtracking), hypergradient
/// via the stationary mapping (CG on the inner Hessian). Returns
/// (outer loss, hypergrad, inner x*).
pub fn outer_step_implicit(
    s: &DistillSetup,
    theta: &[f64],
    inner_cfg: &GdConfig,
    w_init: &[f64],
) -> (f64, Vec<f64>, Vec<f64>) {
    let (w_star, _tr) = gradient_descent(&s.obj, w_init, theta, inner_cfg);
    let loss = mean_ce_loss(&w_star, &s.train.x, &s.train.labels, s.k);
    let mut grad_w = vec![0.0; s.p * s.k];
    mean_ce_grad(&w_star, &s.train.x, &s.train.labels, s.k, &mut grad_w);
    let mapping = StationaryMapping::new(DistillInnerObjective { p: s.p, k: s.k, l2reg: s.obj.l2reg });
    let cfg = LinearSolveConfig {
        kind: LinearSolverKind::Cg,
        tol: 1e-7,
        max_iter: 300,
        gmres_restart: 30,
        ..Default::default()
    };
    let (hg, _) = crate::diff::root::implicit_vjp(&mapping, &w_star, theta, &grad_w, &cfg);
    (loss, hg, w_star)
}

/// One unrolled outer step: reverse-mode through `iters` fixed-step GD
/// iterations (stores the trajectory — the memory cost of unrolling).
pub fn outer_step_unroll(
    s: &DistillSetup,
    theta: &[f64],
    step: f64,
    iters: usize,
    w_init: &[f64],
) -> (f64, Vec<f64>) {
    let fp = GradientDescentFixedPoint {
        obj: DistillInnerObjective { p: s.p, k: s.k, l2reg: s.obj.l2reg },
        eta: step,
    };
    let res = FixedPointResidual(fp);
    let w_t = crate::unroll::unroll_solve(&res.0, w_init, theta, iters);
    let loss = mean_ce_loss(&w_t, &s.train.x, &s.train.labels, s.k);
    let mut grad_w = vec![0.0; s.p * s.k];
    mean_ce_grad(&w_t, &s.train.x, &s.train.labels, s.k, &mut grad_w);
    let (_x, hg) = crate::unroll::unroll_vjp(&res.0, w_init, theta, &grad_w, iters);
    (loss, hg)
}

pub fn run(args: &Args) -> Json {
    let m_train = args.get_usize("m", 300);
    let outer_iters = args.get_usize("outer-iters", 10);
    let inner_iters = args.get_usize("inner-iters", 60);
    let seed = args.get_u64("seed", 11);
    let s = make_setup(m_train, seed);
    let d_theta = s.k * s.p;

    // θ initialized at small noise (the paper learns images from scratch).
    let mut rng = Rng::new(seed + 1);
    let mut theta: Vec<f64> = (0..d_theta).map(|_| 0.01 * rng.normal()).collect();
    let inner_cfg = GdConfig { step: 1.0, max_iter: inner_iters, tol: 1e-9, backtracking: true };
    let mut outer = crate::bilevel::outer::Momentum::new(args.get_f64("outer-step", 0.05), 0.9, d_theta);

    // --- implicit-diff outer loop (timed) ---
    let t_imp = Timer::start();
    let mut losses = Vec::new();
    let mut w_star = vec![0.0; s.p * s.k];
    for it in 0..outer_iters {
        let (loss, hg, w) = outer_step_implicit(&s, &theta, &inner_cfg, &w_star);
        w_star = w; // warm start the next inner solve
        outer.step(&mut theta, &hg);
        losses.push(loss);
        println!("[distill implicit] outer {it:>3}: train loss {loss:.4}");
    }
    let time_implicit = t_imp.elapsed_s();

    // --- unrolled outer loop on the same budget (timed) ---
    let step = 0.5; // fixed inner step for the unrolled variant
    let mut theta_u: Vec<f64> = (0..d_theta).map(|_| 0.01 * rng.normal()).collect();
    let mut outer_u = crate::bilevel::outer::Momentum::new(args.get_f64("outer-step", 0.05), 0.9, d_theta);
    let t_unr = Timer::start();
    let mut losses_u = Vec::new();
    for it in 0..outer_iters {
        let (loss, hg) = outer_step_unroll(&s, &theta_u, step, inner_iters, &vec![0.0; s.p * s.k]);
        outer_u.step(&mut theta_u, &hg);
        losses_u.push(loss);
        println!("[distill unroll  ] outer {it:>3}: train loss {loss:.4}");
    }
    let time_unroll = t_unr.elapsed_s();

    let speedup = time_unroll / time_implicit.max(1e-12);
    println!(
        "distill: implicit {:.2}s vs unrolled {:.2}s per {} outer iters → {:.2}× (paper: 4×)",
        time_implicit, time_unroll, outer_iters, speedup
    );

    // Dump distilled images (Fig. 5) as ASCII into results/.
    let mut art = String::new();
    for c in 0..s.k.min(3) {
        art.push_str(&format!("--- distilled class {c} ---\n"));
        art.push_str(&digits::ascii_render(&theta[c * s.p..(c + 1) * s.p]));
    }
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/fig5_distilled.txt", &art);

    Json::obj(vec![
        ("time_implicit_s", Json::Num(time_implicit)),
        ("time_unroll_s", Json::Num(time_unroll)),
        ("speedup", Json::Num(speedup)),
        ("loss_curve_implicit", Json::arr_f64(&losses)),
        ("loss_curve_unroll", Json::arr_f64(&losses_u)),
        ("final_loss_implicit", Json::Num(*losses.last().unwrap_or(&f64::NAN))),
        ("final_loss_unroll", Json::Num(*losses_u.last().unwrap_or(&f64::NAN))),
    ])
}
