//! Norm-ball projections (ℓ1, ℓ2, ℓ∞) with radius parameter θ — paper
//! Appendix C.1 "Norm balls".

use super::Projection;
use crate::linalg::vecops;

/// ℓ2 ball {x : ‖x‖₂ ≤ θ}.
pub struct L2BallProjection {
    pub d: usize,
}

impl Projection for L2BallProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let r = t[0];
        let n = vecops::norm2(y);
        if n <= r {
            out.copy_from_slice(y);
        } else {
            let s = r / n;
            for i in 0..y.len() {
                out[i] = s * y[i];
            }
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let r = t[0];
        let n = vecops::norm2(y);
        if n <= r {
            out.copy_from_slice(v);
        } else {
            // J = (r/n)(I − ŷŷᵀ)
            let s = r / n;
            let yv = vecops::dot(y, v) / (n * n);
            for i in 0..y.len() {
                out[i] = s * (v[i] - yv * y[i]);
            }
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out); // symmetric
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let n = vecops::norm2(y);
        if n <= t[0] {
            out.iter_mut().for_each(|o| *o = 0.0);
        } else {
            for i in 0..y.len() {
                out[i] = v[0] * y[i] / n;
            }
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let n = vecops::norm2(y);
        out[0] = if n <= t[0] { 0.0 } else { vecops::dot(y, u) / n };
    }
}

/// ℓ∞ ball {x : ‖x‖∞ ≤ θ} = clip(y, −θ, θ).
pub struct LInfBallProjection {
    pub d: usize,
}

impl Projection for LInfBallProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let r = t[0];
        for i in 0..y.len() {
            out[i] = y[i].clamp(-r, r);
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let r = t[0];
        for i in 0..y.len() {
            out[i] = if y[i].abs() < r { v[i] } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out);
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let r = t[0];
        for i in 0..y.len() {
            out[i] = if y[i] >= r {
                v[0]
            } else if y[i] <= -r {
                -v[0]
            } else {
                0.0
            };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let r = t[0];
        out[0] = 0.0;
        for i in 0..y.len() {
            if y[i] >= r {
                out[0] += u[i];
            } else if y[i] <= -r {
                out[0] -= u[i];
            }
        }
    }
}

/// ℓ1 ball {x : ‖x‖₁ ≤ θ}: reduces to a simplex-type thresholding of |y|
/// (paper C.1; Duchi et al. [33]).
pub struct L1BallProjection {
    pub d: usize,
}

/// Project y onto the ℓ1 ball of radius r. Returns (projection, τ, support).
pub fn project_l1_ball(y: &[f64], r: f64) -> (Vec<f64>, f64, Vec<bool>) {
    let d = y.len();
    if vecops::norm1(y) <= r {
        return (y.to_vec(), 0.0, vec![true; d]);
    }
    // Threshold τ: Σ (|y_i| − τ)₊ = r, found by sorting |y| descending.
    let mut a: Vec<f64> = y.iter().map(|x| x.abs()).collect();
    a.sort_by(|p, q| q.partial_cmp(p).unwrap());
    let mut css = 0.0;
    let mut tau = 0.0;
    for i in 0..d {
        css += a[i];
        let t = (css - r) / (i + 1) as f64;
        if a[i] - t > 0.0 {
            tau = t;
        }
    }
    let mut out = vec![0.0; d];
    let mut support = vec![false; d];
    for i in 0..d {
        let m = y[i].abs() - tau;
        if m > 0.0 {
            out[i] = y[i].signum() * m;
            support[i] = true;
        }
    }
    (out, tau, support)
}

impl Projection for L1BallProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let (p, _, _) = project_l1_ball(y, t[0]);
        out.copy_from_slice(&p);
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        if vecops::norm1(y) <= t[0] {
            out.copy_from_slice(v);
            return;
        }
        let (_, _, s) = project_l1_ball(y, t[0]);
        // J_ij = 1{i∈S}(δ_ij − sign(y_i)sign(y_j)/|S|)
        let nnz = s.iter().filter(|&&b| b).count().max(1) as f64;
        let mut signed_mean = 0.0;
        for i in 0..y.len() {
            if s[i] {
                signed_mean += y[i].signum() * v[i];
            }
        }
        signed_mean /= nnz;
        for i in 0..y.len() {
            out[i] = if s[i] { v[i] - y[i].signum() * signed_mean } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out); // symmetric
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        if vecops::norm1(y) <= t[0] {
            out.iter_mut().for_each(|o| *o = 0.0);
            return;
        }
        let (_, _, s) = project_l1_ball(y, t[0]);
        let nnz = s.iter().filter(|&&b| b).count().max(1) as f64;
        for i in 0..y.len() {
            out[i] = if s[i] { v[0] * y[i].signum() / nnz } else { 0.0 };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        if vecops::norm1(y) <= t[0] {
            out[0] = 0.0;
            return;
        }
        let (_, _, s) = project_l1_ball(y, t[0]);
        let nnz = s.iter().filter(|&&b| b).count().max(1) as f64;
        out[0] = 0.0;
        for i in 0..y.len() {
            if s[i] {
                out[0] += u[i] * y[i].signum() / nnz;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::proptests;
    use crate::util::rng::Rng;

    #[test]
    fn l2_ball_properties() {
        let p = L2BallProjection { d: 7 };
        let theta = [1.5];
        proptests::check_idempotent(&p, &theta, 1, 1e-9);
        proptests::check_nonexpansive(&p, &theta, 2);
        proptests::check_jacobian_products(&p, &theta, 3, 1e-6);
    }

    #[test]
    fn l2_feasibility() {
        let p = L2BallProjection { d: 5 };
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let y = rng.normal_vec(5);
            let z = p.project_vec(&y, &[0.8]);
            assert!(vecops::norm2(&z) <= 0.8 + 1e-12);
        }
    }

    #[test]
    fn linf_ball_properties() {
        let p = LInfBallProjection { d: 6 };
        let theta = [0.7];
        proptests::check_idempotent(&p, &theta, 5, 1e-12);
        proptests::check_nonexpansive(&p, &theta, 6);
        proptests::check_jacobian_products(&p, &theta, 7, 1e-6);
    }

    #[test]
    fn l1_ball_feasibility_and_properties() {
        let p = L1BallProjection { d: 8 };
        let theta = [1.0];
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            let y = rng.normal_vec(8);
            let z = p.project_vec(&y, &theta);
            assert!(vecops::norm1(&z) <= 1.0 + 1e-9);
        }
        proptests::check_idempotent(&p, &theta, 9, 1e-9);
        proptests::check_nonexpansive(&p, &theta, 10);
        proptests::check_jacobian_products(&p, &theta, 11, 1e-5);
    }

    #[test]
    fn l1_interior_identity() {
        let p = L1BallProjection { d: 3 };
        let y = [0.1, -0.2, 0.05];
        let z = p.project_vec(&y, &[1.0]);
        assert_eq!(z, y.to_vec());
        let mut jt = [0.0];
        p.vjp_theta(&y, &[1.0], &[1.0, 1.0, 1.0], &mut jt);
        assert_eq!(jt[0], 0.0);
    }

    #[test]
    fn l2_theta_jacobians_match_fd() {
        let p = L2BallProjection { d: 4 };
        let mut rng = Rng::new(12);
        let y: Vec<f64> = rng.normal_vec(4).iter().map(|x| x * 3.0).collect();
        let theta = [1.0];
        let mut jt = vec![0.0; 4];
        p.jvp_theta(&y, &theta, &[1.0], &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|t| p.project_vec(&y, t), &theta, &[1.0], 1e-7);
        for i in 0..4 {
            assert!((jt[i] - fd[i]).abs() < 1e-6);
        }
    }
}
