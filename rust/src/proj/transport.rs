//! KL projection onto the transportation polytope via Sinkhorn — paper
//! Appendix C.1 "Transportation and Birkhoff polytopes".
//!
//! Given a score matrix y ∈ R^{p×q} and marginals (r, c), the KL projection
//! is P = diag(e^a) ⊙ e^y ⊙ diag(e^b) with potentials (a, b) scaled so that
//! P1 = r and Pᵀ1 = c — computed with Sinkhorn [Cuturi 28]. The input-side
//! Jacobian products are obtained by *implicit differentiation of the
//! potentials* (the paper's "F may itself be implicitly defined" case):
//! the marginal-residual system is solved with a dense symmetric factor
//! (gauge-fixed by pinning b_q = 0).

use super::Projection;
use crate::linalg::lu::Lu;
use crate::linalg::mat::Mat;

/// Result of a Sinkhorn solve.
pub struct SinkhornResult {
    /// Transport plan p×q (row-major).
    pub plan: Vec<f64>,
    /// Row potentials a ∈ R^p (log-domain).
    pub a: Vec<f64>,
    /// Column potentials b ∈ R^q.
    pub b: Vec<f64>,
    pub iterations: usize,
    pub marginal_err: f64,
}

/// Log-domain Sinkhorn: match marginals r (len p) and c (len q).
pub fn sinkhorn(y: &[f64], p: usize, q: usize, r: &[f64], c: &[f64], tol: f64, max_iter: usize) -> SinkhornResult {
    assert_eq!(y.len(), p * q);
    let mut a = vec![0.0; p];
    let mut b = vec![0.0; q];
    let mut it = 0;
    let mut err = f64::INFINITY;
    while it < max_iter {
        // a_i = log r_i − log Σ_j exp(y_ij + b_j)
        for i in 0..p {
            let row = &y[i * q..(i + 1) * q];
            let m = (0..q).map(|j| row[j] + b[j]).fold(f64::NEG_INFINITY, f64::max);
            let lse = m + (0..q).map(|j| (row[j] + b[j] - m).exp()).sum::<f64>().ln();
            a[i] = r[i].ln() - lse;
        }
        // b_j = log c_j − log Σ_i exp(y_ij + a_i)
        for j in 0..q {
            let m = (0..p).map(|i| y[i * q + j] + a[i]).fold(f64::NEG_INFINITY, f64::max);
            let lse = m + (0..p).map(|i| (y[i * q + j] + a[i] - m).exp()).sum::<f64>().ln();
            b[j] = c[j].ln() - lse;
        }
        it += 1;
        // Row-marginal error (columns are exact after the b update).
        err = 0.0;
        for i in 0..p {
            let mut s = 0.0;
            for j in 0..q {
                s += (a[i] + y[i * q + j] + b[j]).exp();
            }
            err = err.max((s - r[i]).abs());
        }
        if err < tol {
            break;
        }
    }
    let mut plan = vec![0.0; p * q];
    for i in 0..p {
        for j in 0..q {
            plan[i * q + j] = (a[i] + y[i * q + j] + b[j]).exp();
        }
    }
    SinkhornResult { plan, a, b, iterations: it, marginal_err: err }
}

/// KL projection onto the transportation polytope as a [`Projection`].
/// θ = (r ‖ c) marginals; y is the (flattened) score matrix.
pub struct TransportProjection {
    pub p: usize,
    pub q: usize,
    pub tol: f64,
    pub max_iter: usize,
}

impl TransportProjection {
    pub fn new(p: usize, q: usize) -> Self {
        TransportProjection { p, q, tol: 1e-12, max_iter: 5000 }
    }

    /// Gauge-fixed potential system: M = [[diag(P1), P],[Pᵀ, diag(Pᵀ1)]]
    /// with the last row/column dropped (b_q pinned). Symmetric.
    fn potential_factor(&self, plan: &[f64]) -> Lu {
        let (p, q) = (self.p, self.q);
        let n = p + q - 1;
        let mut m = Mat::zeros(n, n);
        for i in 0..p {
            let mut rs = 0.0;
            for j in 0..q {
                rs += plan[i * q + j];
                if j < q - 1 {
                    *m.at_mut(i, p + j) = plan[i * q + j];
                    *m.at_mut(p + j, i) = plan[i * q + j];
                }
            }
            *m.at_mut(i, i) = rs;
        }
        for j in 0..q - 1 {
            let mut cs = 0.0;
            for i in 0..p {
                cs += plan[i * q + j];
            }
            *m.at_mut(p + j, p + j) = cs;
        }
        Lu::factor(&m).expect("potential system must be non-singular")
    }

    /// rhs entries for a direction V: (Σ_j P_ij V_ij; Σ_i P_ij V_ij) gauge-fixed.
    fn marginal_weighted(&self, plan: &[f64], v: &[f64]) -> Vec<f64> {
        let (p, q) = (self.p, self.q);
        let mut out = vec![0.0; p + q - 1];
        for i in 0..p {
            for j in 0..q {
                let pv = plan[i * q + j] * v[i * q + j];
                out[i] += pv;
                if j < q - 1 {
                    out[p + j] += pv;
                }
            }
        }
        out
    }
}

impl Projection for TransportProjection {
    fn dim(&self) -> usize {
        self.p * self.q
    }
    fn dim_theta(&self) -> usize {
        self.p + self.q
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let (r, c) = t.split_at(self.p);
        let res = sinkhorn(y, self.p, self.q, r, c, self.tol, self.max_iter);
        out.copy_from_slice(&res.plan);
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let (r, c) = t.split_at(self.p);
        let res = sinkhorn(y, self.p, self.q, r, c, self.tol, self.max_iter);
        let lu = self.potential_factor(&res.plan);
        // Implicit diff of marginal residuals: M (da;db) = −N V.
        let mut rhs = self.marginal_weighted(&res.plan, v);
        for x in rhs.iter_mut() {
            *x = -*x;
        }
        let dab = lu.solve(&rhs);
        let (p, q) = (self.p, self.q);
        for i in 0..p {
            for j in 0..q {
                let db = if j < q - 1 { dab[p + j] } else { 0.0 };
                out[i * q + j] = res.plan[i * q + j] * (v[i * q + j] + dab[i] + db);
            }
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let (r, c) = t.split_at(self.p);
        let res = sinkhorn(y, self.p, self.q, r, c, self.tol, self.max_iter);
        let lu = self.potential_factor(&res.plan);
        // g = N (u ⊙ P) marginals; w = M⁻¹ g (M symmetric); vjp = P⊙u − P⊙(w_i+w_j).
        let g = self.marginal_weighted(&res.plan, u);
        let w = lu.solve(&g);
        let (p, q) = (self.p, self.q);
        for i in 0..p {
            for j in 0..q {
                let wj = if j < q - 1 { w[p + j] } else { 0.0 };
                out[i * q + j] = res.plan[i * q + j] * (u[i * q + j] - w[i] - wj);
            }
        }
    }
}

/// Birkhoff polytope (doubly stochastic matrices): uniform marginals 1/d.
pub fn birkhoff_marginals(d: usize) -> Vec<f64> {
    vec![1.0 / d as f64; 2 * d]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn uniform_theta(p: usize, q: usize) -> Vec<f64> {
        let mut t = vec![1.0 / p as f64; p];
        t.extend(vec![1.0 / q as f64; q]);
        t
    }

    #[test]
    fn sinkhorn_matches_marginals() {
        let mut rng = Rng::new(1);
        let (p, q) = (4, 6);
        let y = rng.normal_vec(p * q);
        let t = uniform_theta(p, q);
        let (r, c) = t.split_at(p);
        let res = sinkhorn(&y, p, q, r, c, 1e-12, 5000);
        for i in 0..p {
            let s: f64 = (0..q).map(|j| res.plan[i * q + j]).sum();
            assert!((s - r[i]).abs() < 1e-10, "row {i}: {s}");
        }
        for j in 0..q {
            let s: f64 = (0..p).map(|i| res.plan[i * q + j]).sum();
            assert!((s - c[j]).abs() < 1e-10, "col {j}: {s}");
        }
    }

    #[test]
    fn jvp_matches_fd() {
        let mut rng = Rng::new(2);
        let (p, q) = (3, 4);
        let proj = TransportProjection::new(p, q);
        let t = uniform_theta(p, q);
        let y = rng.normal_vec(p * q);
        let v = rng.normal_vec(p * q);
        let mut jv = vec![0.0; p * q];
        proj.jvp_y(&y, &t, &v, &mut jv);
        let fd = crate::ad::num_grad::jvp_fd(|yy| proj.project_vec(yy, &t), &y, &v, 1e-6);
        for i in 0..p * q {
            assert!((jv[i] - fd[i]).abs() < 1e-6, "i={i}: {} vs {}", jv[i], fd[i]);
        }
    }

    #[test]
    fn vjp_adjoint_identity() {
        let mut rng = Rng::new(3);
        let (p, q) = (3, 3);
        let proj = TransportProjection::new(p, q);
        let t = uniform_theta(p, q);
        let y = rng.normal_vec(p * q);
        let v = rng.normal_vec(p * q);
        let u = rng.normal_vec(p * q);
        let mut jv = vec![0.0; p * q];
        let mut vj = vec![0.0; p * q];
        proj.jvp_y(&y, &t, &v, &mut jv);
        proj.vjp_y(&y, &t, &u, &mut vj);
        let lhs: f64 = u.iter().zip(&jv).map(|(a, b)| a * b).sum();
        let rhs: f64 = vj.iter().zip(&v).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
    }

    #[test]
    fn birkhoff_is_doubly_stochastic() {
        let mut rng = Rng::new(4);
        let d = 5;
        let proj = TransportProjection::new(d, d);
        let t = birkhoff_marginals(d);
        let y = rng.normal_vec(d * d);
        let plan = proj.project_vec(&y, &t);
        for i in 0..d {
            let rs: f64 = (0..d).map(|j| plan[i * d + j]).sum();
            assert!((rs - 1.0 / d as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn plan_is_nonnegative() {
        let mut rng = Rng::new(5);
        let proj = TransportProjection::new(4, 4);
        let t = uniform_theta(4, 4);
        let y = rng.normal_vec(16);
        let plan = proj.project_vec(&y, &t);
        assert!(plan.iter().all(|&x| x > 0.0));
    }
}
