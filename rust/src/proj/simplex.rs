//! Projections onto the probability simplex — paper Appendix C.1.
//!
//! - Euclidean: exact O(d log d) sort-based algorithm [Michelot 63; Duchi 33;
//!   Condat 26]. Jacobian = diag(s) − ssᵀ/‖s‖₁ over the support indicator s
//!   [Martins & Astudillo 62] — both JVP and VJP are the same symmetric
//!   centering-on-support operator.
//! - KL (Bregman): row softmax, Jacobian diag(p) − ppᵀ.
//!
//! Row-wise variants over m×k matrices serve the multiclass-SVM experiment
//! (projection of each dual row onto △^k).

use super::Projection;

/// Euclidean projection of y onto △^d = {x ≥ 0, Σx = 1}.
pub fn project_simplex(y: &[f64], out: &mut [f64]) {
    let d = y.len();
    debug_assert_eq!(out.len(), d);
    // Sort descending, find threshold τ with Σ(yᵢ − τ)₊ = 1.
    let mut u = y.to_vec();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut tau = 0.0;
    let mut k = 0;
    for i in 0..d {
        css += u[i];
        let t = (css - 1.0) / (i + 1) as f64;
        if u[i] - t > 0.0 {
            tau = t;
            k = i + 1;
        }
    }
    debug_assert!(k > 0);
    let _ = k;
    for i in 0..d {
        out[i] = (y[i] - tau).max(0.0);
    }
}

/// Support indicator of the projection (1 where the output is positive).
pub fn simplex_support(proj: &[f64]) -> Vec<bool> {
    proj.iter().map(|&p| p > 0.0).collect()
}

/// The simplex-projection Jacobian product: Jv = s⊙(v − mean_S(v)), where S
/// is the support of the projection. Symmetric, so JVP = VJP.
pub fn simplex_jacobian_product(proj: &[f64], v: &[f64], out: &mut [f64]) {
    let mut sum = 0.0;
    let mut nnz = 0usize;
    for i in 0..proj.len() {
        if proj[i] > 0.0 {
            sum += v[i];
            nnz += 1;
        }
    }
    let mean = if nnz > 0 { sum / nnz as f64 } else { 0.0 };
    for i in 0..proj.len() {
        out[i] = if proj[i] > 0.0 { v[i] - mean } else { 0.0 };
    }
}

/// KL projection onto the simplex = softmax. Returns p.
pub fn softmax(y: &[f64], out: &mut [f64]) {
    let m = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for i in 0..y.len() {
        out[i] = (y[i] - m).exp();
        z += out[i];
    }
    for o in out.iter_mut() {
        *o /= z;
    }
}

/// Softmax Jacobian product: Jv = p⊙(v − ⟨p, v⟩). Symmetric.
pub fn softmax_jacobian_product(p: &[f64], v: &[f64], out: &mut [f64]) {
    let pv: f64 = p.iter().zip(v).map(|(&a, &b)| a * b).sum();
    for i in 0..p.len() {
        out[i] = p[i] * (v[i] - pv);
    }
}

/// Euclidean simplex projection as a [`Projection`] (no parameters).
pub struct SimplexProjection {
    pub d: usize,
}

impl Projection for SimplexProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        0
    }
    fn project(&self, y: &[f64], _theta: &[f64], out: &mut [f64]) {
        project_simplex(y, out);
    }
    fn jvp_y(&self, y: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        let mut p = vec![0.0; self.d];
        project_simplex(y, &mut p);
        simplex_jacobian_product(&p, v, out);
    }
    fn vjp_y(&self, y: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, theta, u, out); // symmetric Jacobian
    }
}

/// KL (softmax) projection as a [`Projection`].
pub struct KlSimplexProjection {
    pub d: usize,
}

impl Projection for KlSimplexProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        0
    }
    fn project(&self, y: &[f64], _theta: &[f64], out: &mut [f64]) {
        softmax(y, out);
    }
    fn jvp_y(&self, y: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        let mut p = vec![0.0; self.d];
        softmax(y, &mut p);
        softmax_jacobian_product(&p, v, out);
    }
    fn vjp_y(&self, y: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, theta, u, out);
    }
}

/// Product of m simplices △^k (row-wise projection of a flattened m×k
/// matrix) as a [`Projection`] — the multiclass-SVM dual feasible set.
pub struct RowsSimplexProjection {
    pub m: usize,
    pub k: usize,
}

impl Projection for RowsSimplexProjection {
    fn dim(&self) -> usize {
        self.m * self.k
    }
    fn dim_theta(&self) -> usize {
        0
    }
    fn project(&self, y: &[f64], _theta: &[f64], out: &mut [f64]) {
        project_rows_simplex(y, self.k, out);
    }
    fn jvp_y(&self, y: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        let mut p = vec![0.0; y.len()];
        project_rows_simplex(y, self.k, &mut p);
        rows_simplex_jacobian_product(&p, self.k, v, out);
    }
    fn vjp_y(&self, y: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, theta, u, out); // block-diagonal symmetric
    }
}

/// Row-wise Euclidean simplex projection of an m×k matrix (flattened
/// row-major) — the multiclass-SVM dual feasible set C = △^k × ... × △^k.
pub fn project_rows_simplex(y: &[f64], k: usize, out: &mut [f64]) {
    debug_assert_eq!(y.len() % k, 0);
    for (yrow, orow) in y.chunks_exact(k).zip(out.chunks_exact_mut(k)) {
        project_simplex(yrow, orow);
    }
}

/// Row-wise simplex Jacobian product given the projected rows.
pub fn rows_simplex_jacobian_product(proj: &[f64], k: usize, v: &[f64], out: &mut [f64]) {
    for ((prow, vrow), orow) in proj
        .chunks_exact(k)
        .zip(v.chunks_exact(k))
        .zip(out.chunks_exact_mut(k))
    {
        simplex_jacobian_product(prow, vrow, orow);
    }
}

/// Row-wise softmax of an m×k matrix.
pub fn softmax_rows(y: &[f64], k: usize, out: &mut [f64]) {
    for (yrow, orow) in y.chunks_exact(k).zip(out.chunks_exact_mut(k)) {
        softmax(yrow, orow);
    }
}

/// Row-wise softmax Jacobian product given the softmax rows.
pub fn rows_softmax_jacobian_product(p: &[f64], k: usize, v: &[f64], out: &mut [f64]) {
    for ((prow, vrow), orow) in
        p.chunks_exact(k).zip(v.chunks_exact(k)).zip(out.chunks_exact_mut(k))
    {
        softmax_jacobian_product(prow, vrow, orow);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::proptests;
    use crate::util::rng::Rng;

    #[test]
    fn projection_is_feasible() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let y = rng.normal_vec(8);
            let mut p = vec![0.0; 8];
            project_simplex(&y, &mut p);
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-10, "sum={sum}");
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn already_feasible_is_fixed() {
        let y = [0.2, 0.3, 0.5];
        let mut p = vec![0.0; 3];
        project_simplex(&y, &mut p);
        for i in 0..3 {
            assert!((p[i] - y[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn extreme_point() {
        let y = [10.0, 0.0, 0.0];
        let mut p = vec![0.0; 3];
        project_simplex(&y, &mut p);
        assert!((p[0] - 1.0).abs() < 1e-12);
        assert_eq!(p[1], 0.0);
    }

    #[test]
    fn properties_euclidean() {
        let p = SimplexProjection { d: 10 };
        proptests::check_idempotent(&p, &[], 11, 1e-9);
        proptests::check_nonexpansive(&p, &[], 12);
    }

    #[test]
    fn jacobian_product_matches_fd_interior() {
        // At generic points the support is locally constant → FD valid.
        let p = SimplexProjection { d: 6 };
        proptests::check_jacobian_products(&p, &[], 13, 1e-5);
    }

    #[test]
    fn softmax_properties() {
        let p = KlSimplexProjection { d: 7 };
        let mut rng = Rng::new(2);
        let y = rng.normal_vec(7);
        let mut s = vec![0.0; 7];
        softmax(&y, &mut s);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(s.iter().all(|&x| x > 0.0));
        proptests::check_jacobian_products(&p, &[], 14, 1e-6);
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let y = [1.0, 2.0, 3.0];
        let ys = [11.0, 12.0, 13.0];
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        softmax(&y, &mut a);
        softmax(&ys, &mut b);
        for i in 0..3 {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn rowwise_matches_per_row() {
        let mut rng = Rng::new(3);
        let k = 4;
        let y = rng.normal_vec(3 * k);
        let mut p = vec![0.0; 3 * k];
        project_rows_simplex(&y, k, &mut p);
        for r in 0..3 {
            let mut expected = vec![0.0; k];
            project_simplex(&y[r * k..(r + 1) * k], &mut expected);
            assert_eq!(&p[r * k..(r + 1) * k], &expected[..]);
        }
    }

    #[test]
    fn jacobian_annihilates_ones_on_support() {
        // J·1 = 0 since moving all coords equally keeps the projection fixed.
        let mut rng = Rng::new(4);
        let y = rng.normal_vec(9);
        let mut p = vec![0.0; 9];
        project_simplex(&y, &mut p);
        let ones = vec![1.0; 9];
        let mut jp = vec![0.0; 9];
        simplex_jacobian_product(&p, &ones, &mut jp);
        for i in 0..9 {
            assert!(jp[i].abs() < 1e-12);
        }
    }
}
