//! Projection catalog — paper Appendix C.1.
//!
//! Each projection provides `project` plus analytic JVP/VJPs (the paper's
//! "Jacobian products of projections"), exercised by the projected-gradient
//! and mirror-descent fixed points in [`crate::mappings`]. Every Jacobian
//! product is unit-tested against finite differences, and property-tested
//! for idempotency / feasibility / non-expansiveness.

pub mod affine;
pub mod balls;
pub mod box_section;
pub mod boxes;
pub mod order_simplex;
pub mod simplex;
pub mod transport;

use crate::ad::num_grad;

/// A parametric projection y ↦ proj_C(θ)(y).
pub trait Projection {
    /// Ambient dimension of y.
    fn dim(&self) -> usize;
    /// Dimension of the set parameter θ (0 for fixed sets).
    fn dim_theta(&self) -> usize;

    /// out = proj(y, θ).
    fn project(&self, y: &[f64], theta: &[f64], out: &mut [f64]);

    /// out = ∂_y proj(y, θ) · v.
    fn jvp_y(&self, y: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|yy| self.project_vec(yy, theta), y, v, 1e-6);
        out.copy_from_slice(&r);
    }
    /// out = ∂_θ proj(y, θ) · v.
    fn jvp_theta(&self, y: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        if self.dim_theta() == 0 {
            out.iter_mut().for_each(|o| *o = 0.0);
            return;
        }
        let r = num_grad::jvp_fd(|tt| self.project_vec(y, tt), theta, v, 1e-6);
        out.copy_from_slice(&r);
    }
    /// out = ∂_y proj(y, θ)ᵀ · u.
    fn vjp_y(&self, y: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|yy| self.project_vec(yy, theta), y, u, 1e-6);
        out.copy_from_slice(&r);
    }
    /// out = ∂_θ proj(y, θ)ᵀ · u.
    fn vjp_theta(&self, y: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        if self.dim_theta() == 0 {
            out.iter_mut().for_each(|o| *o = 0.0);
            return;
        }
        let r = num_grad::vjp_fd(|tt| self.project_vec(y, tt), theta, u, 1e-6);
        out.copy_from_slice(&r);
    }

    fn project_vec(&self, y: &[f64], theta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.project(y, theta, &mut out);
        out
    }
}

#[cfg(test)]
pub(crate) mod proptests {
    //! Shared property checks every projection must satisfy.
    use super::Projection;
    use crate::linalg::vecops;
    use crate::util::rng::Rng;

    /// proj(proj(y)) = proj(y) (idempotency).
    pub fn check_idempotent<P: Projection>(p: &P, theta: &[f64], seed: u64, tol: f64) {
        let mut rng = Rng::new(seed);
        for _ in 0..50 {
            let y = rng.normal_vec(p.dim());
            let z = p.project_vec(&y, theta);
            let zz = p.project_vec(&z, theta);
            assert!(vecops::rel_err(&zz, &z) < tol, "not idempotent");
        }
    }

    /// ‖proj(a) − proj(b)‖ ≤ ‖a − b‖ (1-Lipschitz / non-expansive).
    pub fn check_nonexpansive<P: Projection>(p: &P, theta: &[f64], seed: u64) {
        let mut rng = Rng::new(seed);
        for _ in 0..50 {
            let a = rng.normal_vec(p.dim());
            let b = rng.normal_vec(p.dim());
            let pa = p.project_vec(&a, theta);
            let pb = p.project_vec(&b, theta);
            let num = vecops::norm2(&vecops::sub(&pa, &pb));
            let den = vecops::norm2(&vecops::sub(&a, &b));
            assert!(num <= den + 1e-9, "expansive: {num} > {den}");
        }
    }

    /// Analytic JVP/VJP match finite differences at generic points.
    pub fn check_jacobian_products<P: Projection>(p: &P, theta: &[f64], seed: u64, tol: f64) {
        use crate::ad::num_grad;
        let mut rng = Rng::new(seed);
        for _ in 0..20 {
            let y = rng.normal_vec(p.dim());
            let v = rng.normal_vec(p.dim());
            let mut jv = vec![0.0; p.dim()];
            p.jvp_y(&y, theta, &v, &mut jv);
            let jv_fd = num_grad::jvp_fd(|yy| p.project_vec(yy, theta), &y, &v, 1e-7);
            for i in 0..p.dim() {
                assert!(
                    (jv[i] - jv_fd[i]).abs() < tol,
                    "jvp mismatch at {i}: {} vs {}",
                    jv[i],
                    jv_fd[i]
                );
            }
            let u = rng.normal_vec(p.dim());
            let mut vj = vec![0.0; p.dim()];
            p.vjp_y(&y, theta, &u, &mut vj);
            let vj_fd = num_grad::vjp_fd(|yy| p.project_vec(yy, theta), &y, &u, 1e-7);
            for i in 0..p.dim() {
                assert!(
                    (vj[i] - vj_fd[i]).abs() < tol,
                    "vjp mismatch at {i}: {} vs {}",
                    vj[i],
                    vj_fd[i]
                );
            }
        }
    }
}
