//! Order-simplex / isotonic projection — paper Appendix C.1 "Order simplex".
//!
//! Euclidean projection onto the monotone cone {x : x₁ ≥ x₂ ≥ … ≥ x_d}
//! solved exactly by the Pool Adjacent Violators (PAV) algorithm in O(d);
//! optional upper/lower caps θ = (top, bottom) clip the result into the
//! order simplex {θ₁ ≥ x₁ ≥ … ≥ x_d ≥ θ₂}. The Jacobian averages within
//! pooled blocks [Djolonga & Krause 31; Blondel et al. 18].

use super::Projection;

/// Isotonic regression (decreasing): argmin ‖x − y‖² s.t. x₁ ≥ … ≥ x_d.
/// Returns the solution and the pooled-block partition (start indices).
pub fn pav_decreasing(y: &[f64]) -> (Vec<f64>, Vec<usize>) {
    let d = y.len();
    // Blocks as (value-sum, count), maintained as a stack.
    let mut sums: Vec<f64> = Vec::with_capacity(d);
    let mut counts: Vec<usize> = Vec::with_capacity(d);
    for &yi in y {
        sums.push(yi);
        counts.push(1);
        // Merge while mean of last block exceeds the one before it
        // (decreasing constraint violated if later mean > earlier mean).
        while sums.len() > 1 {
            let n = sums.len();
            let mean_last = sums[n - 1] / counts[n - 1] as f64;
            let mean_prev = sums[n - 2] / counts[n - 2] as f64;
            if mean_last > mean_prev {
                let s = sums.pop().unwrap();
                let c = counts.pop().unwrap();
                *sums.last_mut().unwrap() += s;
                *counts.last_mut().unwrap() += c;
            } else {
                break;
            }
        }
    }
    let mut out = Vec::with_capacity(d);
    let mut starts = Vec::with_capacity(sums.len());
    let mut idx = 0;
    for (s, c) in sums.iter().zip(&counts) {
        starts.push(idx);
        let mean = s / *c as f64;
        for _ in 0..*c {
            out.push(mean);
        }
        idx += c;
    }
    (out, starts)
}

/// Jacobian product of the isotonic projection: average v within each
/// pooled block (symmetric projection matrix → JVP = VJP).
pub fn pav_jacobian_product(starts: &[usize], d: usize, v: &[f64], out: &mut [f64]) {
    let mut ends = starts[1..].to_vec();
    ends.push(d);
    for (s, e) in starts.iter().zip(&ends) {
        let n = (e - s) as f64;
        let mean: f64 = v[*s..*e].iter().sum::<f64>() / n;
        for o in out[*s..*e].iter_mut() {
            *o = mean;
        }
    }
}

/// Order-simplex projection with caps θ = (top, bottom): first isotonic,
/// then clip (valid because clipping a monotone vector preserves order and
/// the composition equals the exact projection for separable chains [14]).
pub struct OrderSimplexProjection {
    pub d: usize,
}

impl Projection for OrderSimplexProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        2
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let (top, bottom) = (t[0], t[1]);
        let (iso, _) = pav_decreasing(y);
        for i in 0..y.len() {
            out[i] = iso[i].clamp(bottom, top);
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let (top, bottom) = (t[0], t[1]);
        let (iso, starts) = pav_decreasing(y);
        let mut block = vec![0.0; y.len()];
        pav_jacobian_product(&starts, y.len(), v, &mut block);
        for i in 0..y.len() {
            out[i] = if iso[i] > bottom && iso[i] < top { block[i] } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out); // block-averaging is symmetric
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let (top, bottom) = (t[0], t[1]);
        let (iso, _) = pav_decreasing(y);
        for i in 0..y.len() {
            out[i] = if iso[i] >= top {
                v[0]
            } else if iso[i] <= bottom {
                v[1]
            } else {
                0.0
            };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let (top, bottom) = (t[0], t[1]);
        let (iso, _) = pav_decreasing(y);
        out[0] = 0.0;
        out[1] = 0.0;
        for i in 0..y.len() {
            if iso[i] >= top {
                out[0] += u[i];
            } else if iso[i] <= bottom {
                out[1] += u[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::proptests;
    use crate::util::rng::Rng;

    #[test]
    fn pav_output_is_decreasing() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let y = rng.normal_vec(12);
            let (x, _) = pav_decreasing(&y);
            for w in x.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn pav_fixes_feasible_input() {
        let y = [5.0, 3.0, 3.0, 1.0, -2.0];
        let (x, starts) = pav_decreasing(&y);
        assert_eq!(x, y.to_vec());
        assert_eq!(starts.len(), 5);
    }

    #[test]
    fn pav_pools_violations() {
        let y = [1.0, 2.0]; // increasing → pooled to mean
        let (x, starts) = pav_decreasing(&y);
        assert_eq!(x, vec![1.5, 1.5]);
        assert_eq!(starts, vec![0]);
    }

    #[test]
    fn pav_preserves_mean() {
        let mut rng = Rng::new(2);
        let y = rng.normal_vec(20);
        let (x, _) = pav_decreasing(&y);
        let my: f64 = y.iter().sum();
        let mx: f64 = x.iter().sum();
        assert!((my - mx).abs() < 1e-10);
    }

    #[test]
    fn projection_properties() {
        let p = OrderSimplexProjection { d: 9 };
        let theta = [2.0, -2.0];
        proptests::check_idempotent(&p, &theta, 3, 1e-9);
        proptests::check_nonexpansive(&p, &theta, 4);
        proptests::check_jacobian_products(&p, &theta, 5, 1e-5);
    }

    #[test]
    fn feasibility_with_caps() {
        let p = OrderSimplexProjection { d: 7 };
        let theta = [1.0, 0.0];
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            let y = rng.normal_vec(7);
            let z = p.project_vec(&y, &theta);
            assert!(z[0] <= 1.0 + 1e-12);
            assert!(z[6] >= -1e-12);
            for w in z.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn jacobian_block_structure() {
        // y = [1, 2] pools into one block; J = [[.5,.5],[.5,.5]] on free block.
        let p = OrderSimplexProjection { d: 2 };
        let theta = [10.0, -10.0];
        let mut jv = vec![0.0; 2];
        p.jvp_y(&[1.0, 2.0], &theta, &[1.0, 0.0], &mut jv);
        assert!((jv[0] - 0.5).abs() < 1e-12);
        assert!((jv[1] - 0.5).abs() < 1e-12);
    }
}
