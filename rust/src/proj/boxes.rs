//! Box-like sets: non-negative orthant, box constraints, hyperplanes and
//! half-spaces — paper Appendix C.1.

use super::Projection;
use crate::linalg::vecops;

/// Non-negative orthant R^d₊: proj = ReLU; KL projection = exp.
pub struct NonNegProjection {
    pub d: usize,
}

impl Projection for NonNegProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        0
    }
    fn project(&self, y: &[f64], _t: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            out[i] = y[i].max(0.0);
        }
    }
    fn jvp_y(&self, y: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            out[i] = if y[i] > 0.0 { v[i] } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out);
    }
}

/// KL projection onto R^d₊ is elementwise exp (paper C.1).
pub fn kl_project_nonneg(y: &[f64], out: &mut [f64]) {
    for i in 0..y.len() {
        out[i] = y[i].exp();
    }
}

/// Box [θ₁, θ₂]^d with θ ∈ R² (shared bounds; the paper's box constraint).
pub struct BoxProjection {
    pub d: usize,
}

impl Projection for BoxProjection {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        2
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let (lo, hi) = (t[0], t[1]);
        for i in 0..y.len() {
            out[i] = y[i].clamp(lo, hi);
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let (lo, hi) = (t[0], t[1]);
        for i in 0..y.len() {
            out[i] = if y[i] > lo && y[i] < hi { v[i] } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out);
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let (lo, hi) = (t[0], t[1]);
        for i in 0..y.len() {
            out[i] = if y[i] <= lo {
                v[0]
            } else if y[i] >= hi {
                v[1]
            } else {
                0.0
            };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let (lo, hi) = (t[0], t[1]);
        out[0] = 0.0;
        out[1] = 0.0;
        for i in 0..y.len() {
            if y[i] <= lo {
                out[0] += u[i];
            } else if y[i] >= hi {
                out[1] += u[i];
            }
        }
    }
}

/// Hyperplane {x : aᵀx = b}, θ = b (the offset; `a` is fixed per instance).
pub struct HyperplaneProjection {
    pub a: Vec<f64>,
}

impl Projection for HyperplaneProjection {
    fn dim(&self) -> usize {
        self.a.len()
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let b = t[0];
        let c = (vecops::dot(&self.a, y) - b) / vecops::dot(&self.a, &self.a);
        for i in 0..y.len() {
            out[i] = y[i] - c * self.a[i];
        }
    }
    fn jvp_y(&self, _y: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
        // J = I − aaᵀ/‖a‖² (constant)
        let c = vecops::dot(&self.a, v) / vecops::dot(&self.a, &self.a);
        for i in 0..v.len() {
            out[i] = v[i] - c * self.a[i];
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out); // symmetric
    }
    fn jvp_theta(&self, _y: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
        let na2 = vecops::dot(&self.a, &self.a);
        for i in 0..self.a.len() {
            out[i] = v[0] * self.a[i] / na2;
        }
    }
    fn vjp_theta(&self, _y: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
        out[0] = vecops::dot(&self.a, u) / vecops::dot(&self.a, &self.a);
    }
}

/// Half-space {x : aᵀx ≤ b}, θ = b.
pub struct HalfSpaceProjection {
    pub a: Vec<f64>,
}

impl Projection for HalfSpaceProjection {
    fn dim(&self) -> usize {
        self.a.len()
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let b = t[0];
        let viol = (vecops::dot(&self.a, y) - b).max(0.0);
        let c = viol / vecops::dot(&self.a, &self.a);
        for i in 0..y.len() {
            out[i] = y[i] - c * self.a[i];
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let active = vecops::dot(&self.a, y) - t[0] > 0.0;
        if active {
            let c = vecops::dot(&self.a, v) / vecops::dot(&self.a, &self.a);
            for i in 0..v.len() {
                out[i] = v[i] - c * self.a[i];
            }
        } else {
            out.copy_from_slice(v);
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out);
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let active = vecops::dot(&self.a, y) - t[0] > 0.0;
        let na2 = vecops::dot(&self.a, &self.a);
        for i in 0..self.a.len() {
            out[i] = if active { v[0] * self.a[i] / na2 } else { 0.0 };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let active = vecops::dot(&self.a, y) - t[0] > 0.0;
        out[0] = if active {
            vecops::dot(&self.a, u) / vecops::dot(&self.a, &self.a)
        } else {
            0.0
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::proptests;
    use crate::util::rng::Rng;

    #[test]
    fn nonneg_properties() {
        let p = NonNegProjection { d: 8 };
        proptests::check_idempotent(&p, &[], 1, 1e-12);
        proptests::check_nonexpansive(&p, &[], 2);
        proptests::check_jacobian_products(&p, &[], 3, 1e-6);
    }

    #[test]
    fn box_feasible_and_jacobians() {
        let p = BoxProjection { d: 6 };
        let theta = [-0.5, 0.5];
        proptests::check_idempotent(&p, &theta, 4, 1e-12);
        proptests::check_nonexpansive(&p, &theta, 5);
        proptests::check_jacobian_products(&p, &theta, 6, 1e-6);
        // θ-side Jacobian vs FD
        let mut rng = Rng::new(7);
        let y = rng.normal_vec(6);
        let v = [1.0, 0.0];
        let mut jt = vec![0.0; 6];
        p.jvp_theta(&y, &theta, &v, &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|t| p.project_vec(&y, t), &theta, &v, 1e-7);
        for i in 0..6 {
            assert!((jt[i] - fd[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn hyperplane_exact_and_consistent() {
        let p = HyperplaneProjection { a: vec![1.0, 2.0, -1.0] };
        let theta = [0.7];
        let mut rng = Rng::new(8);
        let y = rng.normal_vec(3);
        let z = p.project_vec(&y, &theta);
        assert!((vecops::dot(&p.a, &z) - 0.7).abs() < 1e-12);
        proptests::check_idempotent(&p, &theta, 9, 1e-9);
        proptests::check_nonexpansive(&p, &theta, 10);
        proptests::check_jacobian_products(&p, &theta, 11, 1e-6);
    }

    #[test]
    fn halfspace_inactive_is_identity() {
        let p = HalfSpaceProjection { a: vec![1.0, 0.0] };
        let theta = [5.0];
        let y = [1.0, 2.0];
        let z = p.project_vec(&y, &theta);
        assert_eq!(z, y.to_vec());
        proptests::check_nonexpansive(&p, &theta, 12);
        proptests::check_jacobian_products(&p, &theta, 13, 1e-6);
    }

    #[test]
    fn halfspace_active_projects_to_boundary() {
        let p = HalfSpaceProjection { a: vec![1.0, 1.0] };
        let theta = [0.0];
        let y = [2.0, 2.0];
        let z = p.project_vec(&y, &theta);
        assert!((vecops::dot(&p.a, &z)).abs() < 1e-12);
    }

    #[test]
    fn kl_nonneg_is_exp() {
        let mut out = vec![0.0; 2];
        kl_project_nonneg(&[0.0, 1.0], &mut out);
        assert!((out[0] - 1.0).abs() < 1e-12);
        assert!((out[1] - 1.0f64.exp()).abs() < 1e-12);
    }
}
