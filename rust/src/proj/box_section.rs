//! Box-section projection — paper Appendix C.1 "Box sections".
//!
//! Projects onto C(θ) = {z : α ≤ z ≤ β, wᵀz = c}. This is a
//! singly-constrained bounded QP whose solution is the dual-primal map
//! z_i = clip(w_i x + y_i, α_i, β_i) where the scalar dual x*(y, c) is the
//! root of F(x) = L(x)ᵀw − c, found by bisection. The gradient of x* uses
//! the paper's 1-D formula ∇x* = Bᵀ/A, and ∂z follows by chain rule —
//! an in-crate example of a projection that is *itself* implicitly defined.

use super::Projection;

/// Fixed bounds and weights; θ = c (the linear-constraint level).
pub struct BoxSectionProjection {
    pub alpha: Vec<f64>,
    pub beta: Vec<f64>,
    pub w: Vec<f64>,
}

impl BoxSectionProjection {
    pub fn new(alpha: Vec<f64>, beta: Vec<f64>, w: Vec<f64>) -> Self {
        assert_eq!(alpha.len(), beta.len());
        assert_eq!(alpha.len(), w.len());
        assert!(alpha.iter().zip(&beta).all(|(a, b)| a <= b));
        assert!(w.iter().all(|&wi| wi != 0.0), "weights must be nonzero");
        BoxSectionProjection { alpha, beta, w }
    }

    fn l(&self, x: f64, y: &[f64], out: &mut [f64]) {
        for i in 0..y.len() {
            out[i] = (self.w[i] * x + y[i]).clamp(self.alpha[i], self.beta[i]);
        }
    }

    /// F(x) = L(x)ᵀ w − c, monotone non-decreasing in x.
    fn f_dual(&self, x: f64, y: &[f64], c: f64) -> f64 {
        let mut s = 0.0;
        for i in 0..y.len() {
            s += (self.w[i] * x + y[i]).clamp(self.alpha[i], self.beta[i]) * self.w[i];
        }
        s - c
    }

    /// Solve the scalar dual by bisection.
    fn solve_dual(&self, y: &[f64], c: f64) -> f64 {
        let (mut lo, mut hi) = (-1.0, 1.0);
        let mut grow = 0;
        while self.f_dual(lo, y, c) > 0.0 && grow < 80 {
            lo *= 2.0;
            grow += 1;
        }
        grow = 0;
        while self.f_dual(hi, y, c) < 0.0 && grow < 80 {
            hi *= 2.0;
            grow += 1;
        }
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if self.f_dual(mid, y, c) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Active-set mask: coordinates strictly inside (α_i, β_i).
    fn interior_mask(&self, x: f64, y: &[f64]) -> Vec<bool> {
        (0..y.len())
            .map(|i| {
                let z = self.w[i] * x + y[i];
                z > self.alpha[i] && z < self.beta[i]
            })
            .collect()
    }
}

impl Projection for BoxSectionProjection {
    fn dim(&self) -> usize {
        self.w.len()
    }
    fn dim_theta(&self) -> usize {
        1 // θ = c
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let x = self.solve_dual(y, t[0]);
        self.l(x, y, out);
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let x = self.solve_dual(y, t[0]);
        let m = self.interior_mask(x, y);
        // A = ∂F/∂x = Σ_{i interior} w_i², ∂F/∂y_j = w_j 1{j interior}.
        let a: f64 = (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * self.w[i]).sum();
        let dfy: f64 = (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * v[i]).sum();
        let dx = if a > 0.0 { -dfy / a } else { 0.0 };
        for i in 0..y.len() {
            out[i] = if m[i] { self.w[i] * dx + v[i] } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let x = self.solve_dual(y, t[0]);
        let m = self.interior_mask(x, y);
        let a: f64 = (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * self.w[i]).sum();
        // Jᵀu: J = D(I + w dxᵀ) structure; by symmetry of the projection
        // Jacobian (Euclidean projection onto a convex set evaluated a.e.),
        // J = D − (D w)(D w)ᵀ/a where D = diag(mask). Compute directly:
        let wu: f64 = (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * u[i]).sum();
        for i in 0..y.len() {
            out[i] = if m[i] { u[i] - self.w[i] * wu / a } else { 0.0 };
        }
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], v: &[f64], out: &mut [f64]) {
        let x = self.solve_dual(y, t[0]);
        let m = self.interior_mask(x, y);
        let a: f64 = (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * self.w[i]).sum();
        let dx = if a > 0.0 { v[0] / a } else { 0.0 };
        for i in 0..y.len() {
            out[i] = if m[i] { self.w[i] * dx } else { 0.0 };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        let x = self.solve_dual(y, t[0]);
        let m = self.interior_mask(x, y);
        let a: f64 = (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * self.w[i]).sum();
        out[0] = if a > 0.0 {
            (0..y.len()).filter(|&i| m[i]).map(|i| self.w[i] * u[i]).sum::<f64>() / a
        } else {
            0.0
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::proptests;
    use crate::util::rng::Rng;

    fn make(d: usize) -> BoxSectionProjection {
        BoxSectionProjection::new(vec![-1.0; d], vec![1.0; d], vec![1.0; d])
    }

    #[test]
    fn feasibility() {
        let p = make(6);
        let t = [0.5];
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let y = rng.normal_vec(6);
            let z = p.project_vec(&y, &t);
            let s: f64 = z.iter().sum();
            assert!((s - 0.5).abs() < 1e-8, "sum={s}");
            assert!(z.iter().all(|&zi| (-1.0 - 1e-9..=1.0 + 1e-9).contains(&zi)));
        }
    }

    #[test]
    fn simplex_special_case() {
        // α=0, β=1, w=1, c=1 is exactly the probability simplex.
        let p = BoxSectionProjection::new(vec![0.0; 5], vec![1.0; 5], vec![1.0; 5]);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let y = rng.normal_vec(5);
            let z = p.project_vec(&y, &[1.0]);
            let mut expected = vec![0.0; 5];
            crate::proj::simplex::project_simplex(&y, &mut expected);
            for i in 0..5 {
                assert!((z[i] - expected[i]).abs() < 1e-7, "{} vs {}", z[i], expected[i]);
            }
        }
    }

    #[test]
    fn properties_and_jacobians() {
        let p = make(5);
        let t = [0.3];
        proptests::check_idempotent(&p, &t, 3, 1e-7);
        proptests::check_nonexpansive(&p, &t, 4);
        proptests::check_jacobian_products(&p, &t, 5, 1e-5);
    }

    #[test]
    fn theta_jacobian_matches_fd() {
        let p = make(5);
        let t = [0.3];
        let mut rng = Rng::new(6);
        let y = rng.normal_vec(5);
        let mut jt = vec![0.0; 5];
        p.jvp_theta(&y, &t, &[1.0], &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|tt| p.project_vec(&y, tt), &t, &[1.0], 1e-6);
        for i in 0..5 {
            assert!((jt[i] - fd[i]).abs() < 1e-5, "{} vs {}", jt[i], fd[i]);
        }
    }

    #[test]
    fn weighted_version() {
        let p = BoxSectionProjection::new(vec![-2.0; 4], vec![2.0; 4], vec![1.0, 2.0, -1.0, 0.5]);
        let t = [0.7];
        let mut rng = Rng::new(7);
        let y = rng.normal_vec(4);
        let z = p.project_vec(&y, &t);
        let s: f64 = z.iter().zip(&p.w).map(|(zi, wi)| zi * wi).sum();
        assert!((s - 0.7).abs() < 1e-8);
        proptests::check_jacobian_products(&p, &t, 8, 1e-5);
    }
}
