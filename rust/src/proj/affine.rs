//! Projection onto an affine set {x : Ax = b} — paper Appendix C.1.
//!
//! proj(y, b) = y − Aᵀ(AAᵀ)⁻¹(Ay − b). The Gram factor AAᵀ is Cholesky-
//! factored once at construction (the paper's "practical implementation can
//! pre-compute a factorization").

use super::Projection;
use crate::linalg::chol::Cholesky;
use crate::linalg::mat::Mat;

pub struct AffineProjection {
    pub a: Mat,
    chol: Cholesky,
}

impl AffineProjection {
    /// A must be full row-rank p×d with p < d.
    pub fn new(a: Mat) -> AffineProjection {
        let gram = a.matmul_t(&a); // AAᵀ (p×p)
        let chol = Cholesky::factor(&gram).expect("A must have full row rank");
        AffineProjection { a, chol }
    }

    fn correct(&self, residual: &[f64], out_sub: &mut [f64]) {
        // out_sub −= Aᵀ(AAᵀ)⁻¹ residual
        let w = self.chol.solve(residual);
        let atw = self.a.matvec_t(&w);
        for i in 0..out_sub.len() {
            out_sub[i] -= atw[i];
        }
    }
}

impl Projection for AffineProjection {
    fn dim(&self) -> usize {
        self.a.cols
    }
    fn dim_theta(&self) -> usize {
        self.a.rows // θ = b
    }
    fn project(&self, y: &[f64], t: &[f64], out: &mut [f64]) {
        let mut r = self.a.matvec(y);
        for i in 0..r.len() {
            r[i] -= t[i];
        }
        out.copy_from_slice(y);
        self.correct(&r, out);
    }
    fn jvp_y(&self, _y: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
        // J = I − Aᵀ(AAᵀ)⁻¹A (constant, symmetric)
        let r = self.a.matvec(v);
        out.copy_from_slice(v);
        self.correct(&r, out);
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, u, out);
    }
    fn jvp_theta(&self, _y: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
        // ∂proj/∂b = Aᵀ(AAᵀ)⁻¹
        let w = self.chol.solve(v);
        let atw = self.a.matvec_t(&w);
        out.copy_from_slice(&atw);
    }
    fn vjp_theta(&self, _y: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
        // (Aᵀ(AAᵀ)⁻¹)ᵀ u = (AAᵀ)⁻¹ A u
        let au = self.a.matvec(u);
        let w = self.chol.solve(&au);
        out.copy_from_slice(&w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops;
    use crate::proj::proptests;
    use crate::util::rng::Rng;

    fn make(seed: u64) -> (AffineProjection, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Mat::randn(2, 6, &mut rng);
        let b = rng.normal_vec(2);
        (AffineProjection::new(a), b)
    }

    #[test]
    fn projection_is_feasible() {
        let (p, b) = make(1);
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let y = rng.normal_vec(6);
            let z = p.project_vec(&y, &b);
            let az = p.a.matvec(&z);
            for i in 0..2 {
                assert!((az[i] - b[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn properties() {
        let (p, b) = make(3);
        proptests::check_idempotent(&p, &b, 4, 1e-9);
        proptests::check_nonexpansive(&p, &b, 5);
        proptests::check_jacobian_products(&p, &b, 6, 1e-5);
    }

    #[test]
    fn theta_jacobians_match_fd() {
        let (p, b) = make(7);
        let mut rng = Rng::new(8);
        let y = rng.normal_vec(6);
        let v = rng.normal_vec(2);
        let mut jt = vec![0.0; 6];
        p.jvp_theta(&y, &b, &v, &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|t| p.project_vec(&y, t), &b, &v, 1e-7);
        for i in 0..6 {
            assert!((jt[i] - fd[i]).abs() < 1e-6);
        }
        // adjoint identity ⟨u, ∂θ proj v⟩ = ⟨∂θ projᵀ u, v⟩
        let u = rng.normal_vec(6);
        let mut vjt = vec![0.0; 2];
        p.vjp_theta(&y, &b, &u, &mut vjt);
        let lhs = vecops::dot(&u, &jt);
        let rhs = vecops::dot(&vjt, &v);
        assert!((lhs - rhs).abs() < 1e-8);
    }

    #[test]
    fn minimal_distance_property() {
        // The projection is the closest feasible point: any other feasible
        // point is at least as far from y.
        let (p, b) = make(9);
        let mut rng = Rng::new(10);
        let y = rng.normal_vec(6);
        let z = p.project_vec(&y, &b);
        for _ in 0..20 {
            let w = rng.normal_vec(6);
            let w_feas = p.project_vec(&w, &b);
            let dz = vecops::norm2(&vecops::sub(&z, &y));
            let dw = vecops::norm2(&vecops::sub(&w_feas, &y));
            assert!(dz <= dw + 1e-9);
        }
    }
}
