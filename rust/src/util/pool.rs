//! Buffer pooling for the serve hot path.
//!
//! Every derivative request used to allocate fresh `Vec<f64>`s for θ and v
//! (and a scratch byte buffer per binary frame). At one-step / factored-cache
//! latencies the allocator shows up in the profile, so the serve engine
//! recycles buffers through a [`Pool`]: `take_*` hands out a cleared buffer
//! (reusing a previously returned allocation when one is idle), and the RAII
//! wrappers return the allocation on drop. Hit/miss/recycle counters surface
//! through the serve `stats` op.
//!
//! Buffers above [`MAX_POOLED_LEN`] elements are dropped instead of pooled so
//! a single oversized request cannot pin megabytes in the idle list forever.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Largest buffer (in elements) the idle lists will retain.
pub const MAX_POOLED_LEN: usize = 1 << 20;

/// Pool counter snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// `take_*` calls served from an idle buffer.
    pub hits: u64,
    /// `take_*` calls that had to allocate.
    pub misses: u64,
    /// Buffers returned to an idle list on drop.
    pub recycled: u64,
}

/// Shared free-lists of `f64` and byte buffers.
pub struct Pool {
    f64s: Mutex<Vec<Vec<f64>>>,
    bytes: Mutex<Vec<Vec<u8>>>,
    /// Idle buffers retained per list; extras are dropped on return.
    max_idle: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
}

impl Pool {
    pub fn new(max_idle: usize) -> Arc<Pool> {
        Arc::new(Pool {
            f64s: Mutex::new(Vec::new()),
            bytes: Mutex::new(Vec::new()),
            max_idle: max_idle.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        })
    }

    /// A zeroed `f64` buffer of exactly `len` elements.
    pub fn take_f64(self: &Arc<Self>, len: usize) -> PoolVec {
        let mut buf = match self.f64s.lock().unwrap().pop() {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        buf.clear();
        buf.resize(len, 0.0);
        PoolVec { buf, home: Arc::clone(self) }
    }

    /// A buffer pre-filled with a copy of `src`.
    pub fn take_f64_copy(self: &Arc<Self>, src: &[f64]) -> PoolVec {
        let mut buf = self.take_f64(src.len());
        buf.copy_from_slice(src);
        buf
    }

    /// An empty byte buffer with at least `cap` bytes of capacity.
    pub fn take_bytes(self: &Arc<Self>, cap: usize) -> PoolBytes {
        let mut buf = match self.bytes.lock().unwrap().pop() {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        buf.clear();
        buf.reserve(cap);
        PoolBytes { buf, home: Arc::clone(self) }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
        }
    }

    fn put_f64(&self, buf: Vec<f64>) {
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_LEN {
            return;
        }
        let mut list = self.f64s.lock().unwrap();
        if list.len() < self.max_idle {
            list.push(buf);
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn put_bytes(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_LEN {
            return;
        }
        let mut list = self.bytes.lock().unwrap();
        if list.len() < self.max_idle {
            list.push(buf);
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A pooled `Vec<f64>`; derefs to the vector (and through it to `&[f64]`),
/// returns its allocation to the pool on drop.
pub struct PoolVec {
    buf: Vec<f64>,
    home: Arc<Pool>,
}

impl Deref for PoolVec {
    type Target = Vec<f64>;
    fn deref(&self) -> &Vec<f64> {
        &self.buf
    }
}

impl DerefMut for PoolVec {
    fn deref_mut(&mut self) -> &mut Vec<f64> {
        &mut self.buf
    }
}

impl Drop for PoolVec {
    fn drop(&mut self) {
        self.home.put_f64(std::mem::take(&mut self.buf));
    }
}

impl std::fmt::Debug for PoolVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.buf.fmt(f)
    }
}

/// A pooled `Vec<u8>` (frame payload / reply scratch); same contract as
/// [`PoolVec`].
pub struct PoolBytes {
    buf: Vec<u8>,
    home: Arc<Pool>,
}

impl Deref for PoolBytes {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl DerefMut for PoolBytes {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for PoolBytes {
    fn drop(&mut self) {
        self.home.put_bytes(std::mem::take(&mut self.buf));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_recycled_and_counted() {
        let pool = Pool::new(4);
        {
            let mut a = pool.take_f64(3);
            a[0] = 7.0;
            assert_eq!(&a[..], &[7.0, 0.0, 0.0]);
        } // returned
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.recycled), (0, 1, 1));
        {
            // Reuses the returned allocation, zeroed and resized.
            let b = pool.take_f64(2);
            assert_eq!(&b[..], &[0.0, 0.0]);
        }
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.recycled), (1, 1, 2));
    }

    #[test]
    fn idle_list_is_bounded_and_oversized_buffers_are_dropped() {
        let pool = Pool::new(2);
        let taken: Vec<PoolVec> = (0..5).map(|_| pool.take_f64(1)).collect();
        drop(taken); // only max_idle of the 5 survive
        assert_eq!(pool.stats().recycled, 2);
        drop(pool.take_f64(MAX_POOLED_LEN + 1));
        assert_eq!(pool.stats().recycled, 2, "oversized buffer must not be pooled");
    }

    #[test]
    fn take_f64_copy_and_bytes_round_trip() {
        let pool = Pool::new(4);
        let v = pool.take_f64_copy(&[1.5, -2.5]);
        assert_eq!(&v[..], &[1.5, -2.5]);
        let mut b = pool.take_bytes(16);
        b.extend_from_slice(b"abc");
        assert_eq!(&b[..], b"abc");
        drop(v);
        drop(b);
        let again = pool.take_bytes(1);
        assert!(again.is_empty(), "recycled byte buffer must come back cleared");
    }
}
