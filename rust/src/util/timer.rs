//! Wall-clock timing helpers used by the bench harness and coordinator.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    /// Elapsed milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeit_returns_value() {
        let (v, dt) = timeit(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn duration_formats() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-6).ends_with("µs"));
        assert!(fmt_duration(2.5e-3).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }
}
