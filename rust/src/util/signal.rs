//! Minimal zero-dependency SIGTERM/SIGINT latch for graceful shutdown.
//!
//! The crate links no libc wrapper, so the handler is registered through
//! the C `signal(2)` symbol directly. The handler itself does the only
//! async-signal-safe thing possible: it sets a static `AtomicBool`.
//! Consumers (`Server::spawn_shutdown_watcher`, the router's drain
//! watcher) poll [`requested`] from an ordinary thread and run the actual
//! shutdown work — manifest write, drain, exit — in normal code.
//!
//! On non-Unix targets `install` is a no-op and [`requested`] only ever
//! fires via [`request`] (the programmatic path tests use).

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;

    extern "C" {
        /// `sighandler_t signal(int signum, sighandler_t handler)` —
        /// present on every Unix libc this crate targets.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(_sig: i32) {
        super::SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
            signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Register the SIGTERM/SIGINT latch. Idempotent; safe to call from every
/// subsystem that wants shutdown notice.
pub fn install() {
    imp::install();
}

/// Has a shutdown been requested (signal received or [`request`] called)?
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Programmatic shutdown request — same latch the signal handler sets.
pub fn request() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latch_sets_and_install_is_safe() {
        install();
        install(); // idempotent
        // NOTE: not asserting `!requested()` first — another test in the
        // process could legitimately have requested shutdown.
        request();
        assert!(requested());
    }
}
