//! Tiny CLI argument parser (clap is not available offline).
//!
//! Grammar: `program <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--sizes 100,250,500`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse_from(items.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // note: a trailing non-dashed token after a bare --flag would be
        // consumed as its value (greedy grammar), so --verbose goes last.
        let a = parse(&["run", "--exp", "fig3", "--seed=7", "extra", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("exp"), Some("fig3"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_usize("n", 5), 5);
        assert_eq!(a.get_f64("lr", 0.1), 0.1);
        assert!(!a.flag("x"));
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn usize_list() {
        let a = parse(&["x", "--sizes", "100,250,500"]);
        assert_eq!(a.get_usize_list("sizes", &[1]), vec![100, 250, 500]);
        assert_eq!(a.get_usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["x", "--lr", "-0.5"]);
        // "-0.5" does not start with "--" so it is consumed as a value.
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }
}
