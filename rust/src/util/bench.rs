//! Wall-clock micro-benchmark harness (criterion is not available offline).
//!
//! `Bench` runs warmup iterations, then measures a configurable number of
//! samples and reports mean ± CI plus median. `Series` accumulates
//! (x, mean, ci) rows for figure regeneration and can be dumped as CSV and
//! JSON into `results/`.

use super::stats;
use super::timer::{fmt_duration, Timer};
use crate::util::json::Json;
use std::hint::black_box as bb;

/// Re-export of `std::hint::black_box` so benches don't depend on nightly.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Configuration for one measurement.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Inner repetitions per sample (for very fast functions).
    pub reps_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 10, reps_per_sample: 1 }
    }
}

/// Result of one measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-sample seconds (already divided by reps_per_sample).
    pub samples_s: Vec<f64>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples_s)
    }
    /// 90% CI half-width (matches the paper's Fig. 4 error bars).
    pub fn ci90_s(&self) -> f64 {
        stats::ci_half_width(&self.samples_s, 1.645)
    }
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10} (median {:>10}, n={})",
            self.name,
            fmt_duration(self.mean_s()),
            fmt_duration(self.ci90_s()),
            fmt_duration(self.median_s()),
            self.samples_s.len()
        )
    }
}

/// Run one benchmark.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        bb(f());
    }
    let mut samples = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t = Timer::start();
        for _ in 0..cfg.reps_per_sample {
            bb(f());
        }
        samples.push(t.elapsed_s() / cfg.reps_per_sample.max(1) as f64);
    }
    let m = Measurement { name: name.to_string(), samples_s: samples };
    println!("{}", m.report());
    m
}

/// Machine-readable benchmark journal — one row per measurement (name,
/// mean_s, median_s, ci90_s, and GFLOP/s when the caller supplies a flop
/// count), written as JSON so the perf trajectory is tracked across PRs
/// (`BENCH_linalg.json`, see EXPERIMENTS.md §Perf) instead of only printed.
#[derive(Default)]
pub struct BenchJournal {
    rows: Vec<Json>,
}

impl BenchJournal {
    pub fn new() -> BenchJournal {
        BenchJournal::default()
    }

    /// Record a measurement; pass the operation's flop count to get GFLOP/s.
    pub fn record(&mut self, m: &Measurement, flops: Option<f64>) {
        let mut pairs = vec![
            ("name", Json::Str(m.name.clone())),
            ("mean_s", Json::Num(m.mean_s())),
            ("median_s", Json::Num(m.median_s())),
            ("ci90_s", Json::Num(m.ci90_s())),
        ];
        if let Some(fl) = flops {
            pairs.push(("gflops", Json::Num(fl / m.mean_s().max(1e-30) / 1e9)));
        }
        self.rows.push(Json::obj(pairs));
    }

    /// Append a free-form row (e.g. a speedup summary).
    pub fn note(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// Write the journal to `path` (e.g. `BENCH_linalg.json`).
    pub fn write(&self, path: &str) {
        let json = Json::obj(vec![("results", Json::Arr(self.rows.clone()))]);
        match std::fs::write(path, json.to_string_pretty()) {
            Ok(()) => println!("[bench] wrote {path}"),
            Err(e) => eprintln!("[bench] FAILED to write {path}: {e}"),
        }
    }
}

/// A labelled series of (x, value, ci) rows — one paper curve.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub label: String,
    pub rows: Vec<(f64, f64, f64)>,
}

impl Series {
    pub fn new(label: &str) -> Series {
        Series { label: label.to_string(), rows: Vec::new() }
    }
    pub fn push(&mut self, x: f64, y: f64, ci: f64) {
        self.rows.push((x, y, ci));
    }
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("x", Json::arr_f64(&self.rows.iter().map(|r| r.0).collect::<Vec<_>>())),
            ("y", Json::arr_f64(&self.rows.iter().map(|r| r.1).collect::<Vec<_>>())),
            ("ci", Json::arr_f64(&self.rows.iter().map(|r| r.2).collect::<Vec<_>>())),
        ])
    }
}

/// Write a set of series (one figure) to `results/<name>.json` and
/// `results/<name>.csv`, creating the directory if needed.
pub fn write_figure(name: &str, series: &[Series]) {
    let _ = std::fs::create_dir_all("results");
    let json = Json::obj(vec![
        ("figure", Json::Str(name.to_string())),
        ("series", Json::Arr(series.iter().map(Series::to_json).collect())),
    ]);
    let _ = std::fs::write(format!("results/{name}.json"), json.to_string_pretty());
    let mut csv = String::from("label,x,y,ci\n");
    for s in series {
        for (x, y, ci) in &s.rows {
            csv.push_str(&format!("{},{},{},{}\n", s.label, x, y, ci));
        }
    }
    let _ = std::fs::write(format!("results/{name}.csv"), csv);
    println!("[results] wrote results/{name}.json and .csv");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup_iters: 1, samples: 3, reps_per_sample: 2 };
        let m = bench("noop-sum", cfg, || (0..1000u64).sum::<u64>());
        assert_eq!(m.samples_s.len(), 3);
        assert!(m.mean_s() >= 0.0);
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn journal_writes_parseable_json() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 2, reps_per_sample: 1 };
        let m = bench("journal-probe", cfg, || (0..100u64).sum::<u64>());
        let mut j = BenchJournal::new();
        j.record(&m, Some(200.0));
        j.record(&m, None);
        j.note(Json::obj(vec![("name", Json::Str("note".into())), ("speedup", Json::Num(2.0))]));
        let dir = std::env::temp_dir().join("idiff_bench_journal_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_test.json");
        let path_str = path.to_str().unwrap();
        j.write(path_str);
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let rows = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].str_or("name", ""), "journal-probe");
        assert!(rows[0].get("gflops").is_some());
        assert!(rows[1].get("gflops").is_none());
        assert!(rows[0].f64_or("mean_s", -1.0) >= 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn series_json_shape() {
        let mut s = Series::new("implicit");
        s.push(100.0, 0.5, 0.01);
        s.push(200.0, 0.7, 0.02);
        let j = s.to_json();
        assert_eq!(j.get("label").unwrap().as_str(), Some("implicit"));
        assert_eq!(j.get("x").unwrap().as_arr().unwrap().len(), 2);
    }
}
