//! Wall-clock micro-benchmark harness (criterion is not available offline).
//!
//! `Bench` runs warmup iterations, then measures a configurable number of
//! samples and reports mean ± CI plus median. `Series` accumulates
//! (x, mean, ci) rows for figure regeneration and can be dumped as CSV and
//! JSON into `results/`.

use super::stats;
use super::timer::{fmt_duration, Timer};
use crate::util::json::Json;
use std::hint::black_box as bb;

/// Re-export of `std::hint::black_box` so benches don't depend on nightly.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Configuration for one measurement.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Inner repetitions per sample (for very fast functions).
    pub reps_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 10, reps_per_sample: 1 }
    }
}

/// Result of one measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-sample seconds (already divided by reps_per_sample).
    pub samples_s: Vec<f64>,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples_s)
    }
    /// 90% CI half-width (matches the paper's Fig. 4 error bars).
    pub fn ci90_s(&self) -> f64 {
        stats::ci_half_width(&self.samples_s, 1.645)
    }
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10} (median {:>10}, n={})",
            self.name,
            fmt_duration(self.mean_s()),
            fmt_duration(self.ci90_s()),
            fmt_duration(self.median_s()),
            self.samples_s.len()
        )
    }
}

/// Run one benchmark.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        bb(f());
    }
    let mut samples = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t = Timer::start();
        for _ in 0..cfg.reps_per_sample {
            bb(f());
        }
        samples.push(t.elapsed_s() / cfg.reps_per_sample.max(1) as f64);
    }
    let m = Measurement { name: name.to_string(), samples_s: samples };
    println!("{}", m.report());
    m
}

/// A labelled series of (x, value, ci) rows — one paper curve.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub label: String,
    pub rows: Vec<(f64, f64, f64)>,
}

impl Series {
    pub fn new(label: &str) -> Series {
        Series { label: label.to_string(), rows: Vec::new() }
    }
    pub fn push(&mut self, x: f64, y: f64, ci: f64) {
        self.rows.push((x, y, ci));
    }
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("x", Json::arr_f64(&self.rows.iter().map(|r| r.0).collect::<Vec<_>>())),
            ("y", Json::arr_f64(&self.rows.iter().map(|r| r.1).collect::<Vec<_>>())),
            ("ci", Json::arr_f64(&self.rows.iter().map(|r| r.2).collect::<Vec<_>>())),
        ])
    }
}

/// Write a set of series (one figure) to `results/<name>.json` and
/// `results/<name>.csv`, creating the directory if needed.
pub fn write_figure(name: &str, series: &[Series]) {
    let _ = std::fs::create_dir_all("results");
    let json = Json::obj(vec![
        ("figure", Json::Str(name.to_string())),
        ("series", Json::Arr(series.iter().map(Series::to_json).collect())),
    ]);
    let _ = std::fs::write(format!("results/{name}.json"), json.to_string_pretty());
    let mut csv = String::from("label,x,y,ci\n");
    for s in series {
        for (x, y, ci) in &s.rows {
            csv.push_str(&format!("{},{},{},{}\n", s.label, x, y, ci));
        }
    }
    let _ = std::fs::write(format!("results/{name}.csv"), csv);
    println!("[results] wrote results/{name}.json and .csv");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup_iters: 1, samples: 3, reps_per_sample: 2 };
        let m = bench("noop-sum", cfg, || (0..1000u64).sum::<u64>());
        assert_eq!(m.samples_s.len(), 3);
        assert!(m.mean_s() >= 0.0);
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn series_json_shape() {
        let mut s = Series::new("implicit");
        s.push(100.0, 0.5, 0.01);
        s.push(200.0, 0.7, 0.02);
        let j = s.to_json();
        assert_eq!(j.get("label").unwrap().as_str(), Some("implicit"));
        assert_eq!(j.get("x").unwrap().as_arr().unwrap().len(), 2);
    }
}
