//! Minimal JSON substrate (no `serde` available offline).
//!
//! Supports the full JSON grammar needed by the artifact manifest, experiment
//! configs and result files: objects, arrays, strings (with escapes), numbers,
//! booleans and null. The writer emits deterministic, pretty or compact text.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic, which keeps result files diff-able.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Fetch `key` as f64 with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    /// Compact serialization straight into a byte buffer. The JSON wire's
    /// reply path writes into a pooled `PoolBytes` with this — one reply
    /// buffer recycled across a connection's lifetime instead of a fresh
    /// `String` per reply. Byte-identical to `to_string_compact()`.
    pub fn write_compact_bytes(&self, out: &mut Vec<u8>) {
        match self {
            Json::Null => out.extend_from_slice(b"null"),
            Json::Bool(b) => {
                out.extend_from_slice(if *b { b"true" as &[u8] } else { b"false" })
            }
            Json::Num(x) => {
                if x.is_finite() {
                    out.extend_from_slice(fmt_f64(*x).as_bytes());
                } else {
                    out.extend_from_slice(b"null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped_bytes(out, s),
            Json::Arr(a) => {
                out.push(b'[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(b',');
                    }
                    v.write_compact_bytes(out);
                }
                out.push(b']');
            }
            Json::Obj(m) => {
                out.push(b'{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(b',');
                    }
                    write_escaped_bytes(out, k);
                    out.push(b':');
                    v.write_compact_bytes(out);
                }
                out.push(b'}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&fmt_f64(*x));
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Shortest decimal rendering of a finite f64 that parses back to exactly
/// the same bits: the shorter of Rust's `{}` and `{:e}` forms (both emit the
/// minimal round-trip digit string; `{}` never uses an exponent, so 1e300
/// would be 301 characters without the `{:e}` candidate, while `{:e}` pads
/// small values like `4e0`). Both forms are valid JSON numbers, `-0.0`
/// included (`-0`), so serve manifests and protocol replies are bit-stable
/// across a write/parse round trip.
pub fn fmt_f64(x: f64) -> String {
    debug_assert!(x.is_finite());
    let plain = format!("{x}");
    let exp = format!("{x:e}");
    if exp.len() < plain.len() {
        exp
    } else {
        plain
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_escaped_bytes(out: &mut Vec<u8>, s: &str) {
    out.push(b'"');
    for c in s.chars() {
        match c {
            '"' => out.extend_from_slice(b"\\\""),
            '\\' => out.extend_from_slice(b"\\\\"),
            '\n' => out.extend_from_slice(b"\\n"),
            '\r' => out.extend_from_slice(b"\\r"),
            '\t' => out.extend_from_slice(b"\\t"),
            c if (c as u32) < 0x20 => {
                out.extend_from_slice(format!("\\u{:04x}", c as u32).as_bytes());
            }
            c => {
                let mut buf = [0u8; 4];
                out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
            }
        }
    }
    out.push(b'"');
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj(vec![
            ("name", Json::Str("fig3".into())),
            ("n", Json::Num(42.0)),
            ("xs", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let j = Json::obj(vec![("a", Json::Arr(vec![Json::obj(vec![("b", Json::Num(1.0))])]))]);
        let back = parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"c\"A""#).unwrap();
        assert_eq!(v, Json::Str("a\nb\t\"c\"A".into()));
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": [true, null]}], "c": "d"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_arr()
                .unwrap()[0],
            Json::Bool(true)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn accessors_with_defaults() {
        let v = parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.usize_or("n", 0), 3);
        assert_eq!(v.usize_or("missing", 7), 7);
        assert_eq!(v.str_or("s", "d"), "x");
        assert_eq!(v.str_or("missing", "d"), "d");
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("héllo ∆ 日本".into());
        assert_eq!(parse(&j.to_string_compact()).unwrap(), j);
    }

    /// Property: every finite f64 survives emit → parse with the exact same
    /// bit pattern (the manifest warm-start and the binary↔JSON protocol
    /// equivalence sweep both rest on this).
    #[test]
    fn f64_round_trips_bit_exactly() {
        let mut rng = crate::util::rng::Rng::new(0xf64);
        let mut cases: Vec<f64> = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.1,
            1.0 / 3.0,
            f64::MAX,
            f64::MIN,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
            1e15,
            1e16,
            -1e300,
            123456789.123456789,
            2.0 + 1e-9,
        ];
        for _ in 0..2000 {
            // Random bit patterns cover subnormals and extreme exponents.
            let x = f64::from_bits(rng.next_u64());
            if x.is_finite() {
                cases.push(x);
            }
            // Well-scaled values cover the common serving range.
            let exp = rng.below(601) as i32 - 300;
            cases.push((rng.uniform() - 0.5) * 10f64.powi(exp));
        }
        for x in cases {
            let s = Json::Num(x).to_string_compact();
            let back = parse(&s)
                .unwrap_or_else(|e| panic!("{x:?} emitted as {s}, which failed to parse: {e}"))
                .as_f64()
                .unwrap();
            assert_eq!(
                back.to_bits(),
                x.to_bits(),
                "{x:?} emitted as {s} parsed back to {back:?}"
            );
        }
        // Non-finite values still degrade to null (JSON has no NaN/Inf).
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
    }

    #[test]
    fn byte_writer_matches_string_writer() {
        let doc = Json::obj(vec![
            ("grad", Json::arr_f64(&[1.5, -0.0, 2.0 + 1e-9, 1e300, f64::NAN])),
            ("cached", Json::Bool(true)),
            ("mode", Json::Str("one-step".to_string())),
            ("weird \"key\"\n\t\u{1}", Json::Null),
            ("nested", Json::Arr(vec![Json::obj(vec![("k", Json::Num(0.25))]), Json::Arr(vec![])])),
            ("unicode", Json::Str("θ→∂".to_string())),
        ]);
        let mut bytes = Vec::new();
        doc.write_compact_bytes(&mut bytes);
        assert_eq!(bytes, doc.to_string_compact().into_bytes());
        // And the buffer appends rather than clobbers (callers clear it).
        doc.write_compact_bytes(&mut bytes);
        assert_eq!(bytes.len(), 2 * doc.to_string_compact().len());
    }
}
