//! Scoped thread-pool substrate (tokio/rayon unavailable offline).
//!
//! Provides `parallel_for_each` — split a work list across worker threads with
//! captured closures — used by the coordinator to fan experiments out — and
//! [`WorkerPool`], a bounded long-lived general-purpose pool (the serve
//! subsystem's connection dispatch moved to the supervised
//! `coordinator::serve::cluster::actor` runtime, which restarts panicked
//! workers). On a single-core box both degrade gracefully to (nearly)
//! serial execution.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every i in 0..n across `workers` threads (work-stealing via
/// an atomic counter). Results are collected in index order.
pub fn parallel_map<T: Send>(n: usize, workers: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker did not produce a result"))
        .collect()
}

/// Fire-and-collect variant without results.
pub fn parallel_for_each(n: usize, workers: usize, f: impl Fn(usize) + Sync) {
    let _ = parallel_map(n, workers, |i| {
        f(i);
    });
}

/// Split `data` into `chunk_len`-sized mutable chunks and run
/// `f(chunk_index, chunk)` across `workers` threads. Chunks are distributed
/// round-robin, so equal-sized chunks give balanced work without locking:
/// the mutable borrow is split up-front by `chunks_mut`, each thread owns its
/// disjoint set of chunks. This is the substrate under the parallel GEMM /
/// GEMV kernels in `linalg::mat` (row panels of the output are disjoint).
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    workers: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let chunk_len = chunk_len.max(1);
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let workers = workers.max(1).min(n_chunks.max(1));
    if workers <= 1 {
        for (i, ch) in data.chunks_mut(chunk_len).enumerate() {
            f(i, ch);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, ch) in data.chunks_mut(chunk_len).enumerate() {
        buckets[i % workers].push((i, ch));
    }
    let fref = &f;
    std::thread::scope(|scope| {
        for bucket in buckets {
            scope.spawn(move || {
                for (i, ch) in bucket {
                    fref(i, ch);
                }
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    /// Jobs queued or currently executing (for `wait_idle`).
    in_flight: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes workers when a job arrives or shutdown is requested.
    work_cv: Condvar,
    /// Wakes `wait_idle` when `in_flight` reaches zero.
    idle_cv: Condvar,
}

/// A bounded pool of long-lived worker threads with a FIFO job queue — the
/// substrate under the serve subsystem's connection handling (at most
/// `workers` requests execute concurrently; excess connections queue instead
/// of spawning unbounded threads). Dropping the pool drains the queue, then
/// joins the workers.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            if let Some(job) = st.jobs.pop_front() {
                                break job;
                            }
                            if st.shutdown {
                                return;
                            }
                            st = shared.work_cv.wait(st).unwrap();
                        }
                    };
                    // A panicking job must not kill the worker or leak the
                    // in_flight count (that would strand queued jobs and
                    // deadlock wait_idle).
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    let mut st = shared.state.lock().unwrap();
                    st.in_flight -= 1;
                    if st.in_flight == 0 {
                        shared.idle_cv.notify_all();
                    }
                })
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue a job. Returns false (dropping the job) after shutdown began.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return false;
        }
        st.jobs.push_back(Box::new(job));
        st.in_flight += 1;
        self.shared.work_cv.notify_one();
        true
    }

    /// Jobs queued or executing right now.
    pub fn in_flight(&self) -> usize {
        self.shared.state.lock().unwrap().in_flight
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.in_flight > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs_bounded() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let counter = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let (c, p, l) = (counter.clone(), peak.clone(), live.clone());
            assert!(pool.submit(move || {
                let now = l.fetch_add(1, Ordering::SeqCst) + 1;
                p.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(200));
                l.fetch_sub(1, Ordering::SeqCst);
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
        assert!(peak.load(Ordering::SeqCst) <= 3, "pool exceeded its bound");
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = WorkerPool::new(1);
        let done = Arc::new(AtomicUsize::new(0));
        pool.submit(|| panic!("job panic must not kill the worker"));
        let d = done.clone();
        pool.submit(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn pool_drop_drains_queue_then_joins() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..20 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop drains the queue before joining.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }


    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn covers_all_work_items() {
        let counter = AtomicU64::new(0);
        parallel_for_each(1000, 8, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn zero_items_ok() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_serial() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn chunks_mut_covers_all_elements_once() {
        for &(len, chunk, workers) in
            &[(100usize, 7usize, 4usize), (64, 64, 3), (5, 100, 8), (0, 4, 2), (33, 1, 2)]
        {
            let mut data = vec![0u64; len];
            parallel_chunks_mut(&mut data, chunk, workers, |ci, ch| {
                for (off, v) in ch.iter_mut().enumerate() {
                    *v += (ci * chunk + off) as u64 + 1;
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as u64 + 1, "element {i} written wrong/twice");
            }
        }
    }
}
