//! Scoped thread-pool substrate (tokio/rayon unavailable offline).
//!
//! Provides `parallel_for_each` — split a work list across worker threads with
//! captured closures — used by the coordinator to fan experiments out. On a
//! single-core box this degrades gracefully to (nearly) serial execution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every i in 0..n across `workers` threads (work-stealing via
/// an atomic counter). Results are collected in index order.
pub fn parallel_map<T: Send>(n: usize, workers: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker did not produce a result"))
        .collect()
}

/// Fire-and-collect variant without results.
pub fn parallel_for_each(n: usize, workers: usize, f: impl Fn(usize) + Sync) {
    let _ = parallel_map(n, workers, |i| {
        f(i);
    });
}

/// Split `data` into `chunk_len`-sized mutable chunks and run
/// `f(chunk_index, chunk)` across `workers` threads. Chunks are distributed
/// round-robin, so equal-sized chunks give balanced work without locking:
/// the mutable borrow is split up-front by `chunks_mut`, each thread owns its
/// disjoint set of chunks. This is the substrate under the parallel GEMM /
/// GEMV kernels in `linalg::mat` (row panels of the output are disjoint).
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    workers: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let chunk_len = chunk_len.max(1);
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let workers = workers.max(1).min(n_chunks.max(1));
    if workers <= 1 {
        for (i, ch) in data.chunks_mut(chunk_len).enumerate() {
            f(i, ch);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, ch) in data.chunks_mut(chunk_len).enumerate() {
        buckets[i % workers].push((i, ch));
    }
    let fref = &f;
    std::thread::scope(|scope| {
        for bucket in buckets {
            scope.spawn(move || {
                for (i, ch) in bucket {
                    fref(i, ch);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn covers_all_work_items() {
        let counter = AtomicU64::new(0);
        parallel_for_each(1000, 8, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn zero_items_ok() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_serial() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn chunks_mut_covers_all_elements_once() {
        for &(len, chunk, workers) in
            &[(100usize, 7usize, 4usize), (64, 64, 3), (5, 100, 8), (0, 4, 2), (33, 1, 2)]
        {
            let mut data = vec![0u64; len];
            parallel_chunks_mut(&mut data, chunk, workers, |ci, ch| {
                for (off, v) in ch.iter_mut().enumerate() {
                    *v += (ci * chunk + off) as u64 + 1;
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as u64 + 1, "element {i} written wrong/twice");
            }
        }
    }
}
