//! Mini property-based-testing kit (proptest is not available offline),
//! plus the crate's ONE finite-difference referee.
//!
//! `Gen<T>` generators produce random values from an `Rng`; `check` runs a
//! property over many cases and, on failure, performs greedy shrinking (for
//! the built-in numeric/vector generators) before panicking with the minimal
//! counter-example found.
//!
//! [`fd_jvp`] / [`fd_jvp_central`] are the single central-difference
//! implementation every derivative test in the crate compares against
//! (`ad::num_grad` delegates here, and the grad_check / mode sweeps call it
//! directly), so implicit, unrolled and one-step modes are all refereed with
//! identical FD tolerances.

use super::rng::Rng;

// ------------------------------------------------ finite differences --

/// Plain central-difference JVP: (f(x + hv) − f(x − hv)) / 2h. This is the
/// shared implementation behind `ad::num_grad::jvp_fd`; prefer [`fd_jvp`]
/// in tests of piecewise-smooth mappings.
pub fn fd_jvp_central(f: impl Fn(&[f64]) -> Vec<f64>, x: &[f64], v: &[f64], h: f64) -> Vec<f64> {
    let xp: Vec<f64> = x.iter().zip(v).map(|(&xi, &vi)| xi + h * vi).collect();
    let xm: Vec<f64> = x.iter().zip(v).map(|(&xi, &vi)| xi - h * vi).collect();
    let fp = f(&xp);
    let fm = f(&xm);
    fp.iter().zip(&fm).map(|(&a, &b)| (a - b) / (2.0 * h)).collect()
}

/// Kink-aware central-difference JVP: refuses to answer at kinks. If the
/// forward difference (f(x+hv) − f(x))/h and the backward difference
/// (f(x) − f(x−hv))/h disagree by more than `kink_tol` relative to the
/// larger one-sided slope, the segment [x − hv, x + hv] straddles a
/// non-smooth point and the draw should be skipped (`None`) rather than
/// compared against a meaningless central difference.
///
/// Tolerance coupling used by the sweeps: a derivative jump smaller than
/// half the comparison tolerance cannot fail the check (central
/// differencing averages the two sides), and a larger one flags the draw —
/// so callers pass `kink_tol = 0.5 * fd_tol`.
pub fn fd_jvp(
    f: impl Fn(&[f64]) -> Vec<f64>,
    x: &[f64],
    v: &[f64],
    h: f64,
    kink_tol: f64,
) -> Option<Vec<f64>> {
    let f0 = f(x);
    let xp: Vec<f64> = x.iter().zip(v).map(|(&xi, &vi)| xi + h * vi).collect();
    let xm: Vec<f64> = x.iter().zip(v).map(|(&xi, &vi)| xi - h * vi).collect();
    let fp = f(&xp);
    let fm = f(&xm);
    let mut scale = 1.0f64;
    let mut max_gap = 0.0f64;
    let mut central = vec![0.0; f0.len()];
    for i in 0..f0.len() {
        let fwd = (fp[i] - f0[i]) / h;
        let bwd = (f0[i] - fm[i]) / h;
        central[i] = (fp[i] - fm[i]) / (2.0 * h);
        scale = scale.max(fwd.abs()).max(bwd.abs());
        max_gap = max_gap.max((fwd - bwd).abs());
    }
    if max_gap > kink_tol * scale {
        return None; // kink between x−hv and x+hv
    }
    Some(central)
}

/// A generator of values of type T.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    /// Produce "smaller" candidate values for shrinking (may be empty).
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + std::fmt::Debug + 'static> Gen<T> {
    pub fn new(gen: impl Fn(&mut Rng) -> T + 'static) -> Gen<T> {
        Gen { gen: Box::new(gen), shrink: Box::new(|_| Vec::new()) }
    }

    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Gen<T> {
        self.shrink = Box::new(shrink);
        self
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    /// Map the generated value (loses shrinking).
    pub fn map<U: Clone + std::fmt::Debug + 'static>(
        self,
        f: impl Fn(T) -> U + 'static,
    ) -> Gen<U> {
        Gen::new(move |r| f((self.gen)(r)))
    }
}

/// f64 in [lo, hi), shrinks toward lo and 0.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |r| r.uniform_in(lo, hi)).with_shrink(move |&x| {
        let mut c = Vec::new();
        if x != 0.0 && lo <= 0.0 && 0.0 < hi {
            c.push(0.0);
        }
        let halved = lo + (x - lo) / 2.0;
        if (halved - x).abs() > 1e-12 {
            c.push(halved);
        }
        c
    })
}

/// usize in [lo, hi), shrinks toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo < hi);
    Gen::new(move |r| lo + r.below(hi - lo)).with_shrink(move |&x| {
        let mut c = Vec::new();
        if x > lo {
            c.push(lo);
            c.push(lo + (x - lo) / 2);
        }
        c.dedup();
        c
    })
}

/// Vector of n iid standard normals (n drawn in [nlo, nhi)).
/// Shrinks by halving length and zeroing entries.
pub fn normal_vec(nlo: usize, nhi: usize) -> Gen<Vec<f64>> {
    assert!(nlo < nhi);
    Gen::new(move |r| {
        let n = nlo + r.below(nhi - nlo);
        r.normal_vec(n)
    })
    .with_shrink(move |v| {
        let mut c = Vec::new();
        if v.len() > nlo.max(1) {
            c.push(v[..v.len() / 2].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            c.push(vec![0.0; v.len()]);
        }
        c
    })
}

/// Pair generator (no shrinking across components).
pub fn pair<A, B>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)>
where
    A: Clone + std::fmt::Debug + 'static,
    B: Clone + std::fmt::Debug + 'static,
{
    Gen::new(move |r| (a.sample(r), b.sample(r)))
}

/// Run `prop` on `cases` random inputs; on failure shrink greedily and panic
/// with the smallest failing input.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if prop(&input) {
            continue;
        }
        // Greedy shrink.
        let mut current = input;
        let mut improved = true;
        let mut steps = 0;
        while improved && steps < 200 {
            improved = false;
            for cand in (gen.shrink)(&current) {
                if !prop(&cand) {
                    current = cand;
                    improved = true;
                    steps += 1;
                    break;
                }
            }
        }
        panic!(
            "property '{name}' failed at case {case} (seed {seed}).\n\
             minimal counter-example after {steps} shrink steps:\n{current:#?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("abs-nonneg", 1, 200, &f64_in(-10.0, 10.0), |&x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics() {
        check("always-false", 1, 10, &usize_in(0, 5), |_| false);
    }

    #[test]
    #[should_panic]
    fn shrinks_toward_zero() {
        // Fails for any x > 0.5; minimal shrink halves toward lo = 0.
        check("lt-half", 2, 500, &f64_in(0.0, 1.0), |&x| x <= 0.5);
    }

    #[test]
    fn vec_generator_in_bounds() {
        let g = normal_vec(1, 16);
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = g.sample(&mut r);
            assert!((1..16).contains(&v.len()));
        }
    }

    #[test]
    fn pair_generator() {
        let g = pair(usize_in(0, 4), f64_in(0.0, 1.0));
        let mut r = Rng::new(4);
        let (a, b) = g.sample(&mut r);
        assert!(a < 4);
        assert!((0.0..1.0).contains(&b));
    }

    #[test]
    fn fd_jvp_smooth_matches_central() {
        let f = |x: &[f64]| vec![x[0] * x[0] - x[1], x[1].exp()];
        let x = [0.7, -0.3];
        let v = [1.0, 2.0];
        let kk = fd_jvp(f, &x, &v, 1e-6, 1e-4).expect("smooth point must not be flagged");
        let cc = fd_jvp_central(f, &x, &v, 1e-6);
        for i in 0..2 {
            assert_eq!(kk[i], cc[i], "kink-aware central must equal the plain one");
        }
        assert!((kk[0] - (2.0 * 0.7 - 2.0)).abs() < 1e-7);
    }

    #[test]
    fn fd_jvp_flags_kinks() {
        // |x| straddled at the origin: forward slope +1, backward −1.
        let f = |x: &[f64]| vec![x[0].abs()];
        assert!(fd_jvp(f, &[0.0], &[1.0], 1e-6, 1e-4).is_none());
        // Away from the kink the one-sided slopes agree.
        assert!(fd_jvp(f, &[0.5], &[1.0], 1e-6, 1e-4).is_some());
    }
}
