//! ASCII table printer for bench/experiment reports (paper-style rows).

/// A simple column-aligned table.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &widths {
                out.push('+');
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(cell);
                out.push_str(&" ".repeat(widths[c] - cell.chars().count() + 1));
            }
            out.push_str("|\n");
        };
        sep(&mut out);
        line(&mut out, &self.header);
        sep(&mut out);
        for r in &self.rows {
            line(&mut out, r);
        }
        sep(&mut out);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "auc"]);
        t.row_strs(&["L1 logreg", "71.6 ± 2.0"]);
        t.row_strs(&["Task-driven DictL", "73.2 ± 2.1"]);
        let s = t.render();
        assert!(s.contains("| method"));
        assert!(s.contains("Task-driven DictL"));
        // all lines same length
        let lens: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only one"]);
    }
}
