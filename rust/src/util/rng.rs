//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! `Rng` is xoshiro256++ seeded through SplitMix64, the standard construction
//! recommended by the xoshiro authors. It provides the distributions the rest
//! of the crate needs: uniforms, standard normals (Box–Muller with caching),
//! permutations, subset sampling and categorical draws.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Identical seeds yield identical
    /// streams on every platform (used for reproducible experiments).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent child stream (for per-task seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to stay unbiased.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n64 = n as u64;
        let zone = u64::MAX - (u64::MAX % n64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (caches the second draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.cached_normal = Some(r * s);
            return r * c;
        }
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of iid U[0,1).
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.uniform()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k ≤ n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }

    /// Categorical draw from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical weights must have positive sum");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(13);
        let c = r.choose(50, 10);
        assert_eq!(c.len(), 10);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
