//! Self-contained utility substrates.
//!
//! The build environment has no network access to crates.io, so substrates
//! that a framework would normally pull in as dependencies (PRNG, JSON,
//! CLI parsing, bench harness, property testing, thread pool) are implemented
//! here from scratch, each with its own tests.

pub mod bench;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod pool;
pub mod rng;
pub mod signal;
pub mod stats;
pub mod table;
pub mod testkit;
pub mod timer;
