//! Small statistics helpers: mean, std, confidence intervals, quantiles.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Normal-approximation confidence half-width. `z` = 1.645 for 90%,
/// 1.96 for 95% (the paper uses both: Fig. 4 → 90%, Table 2 → 95%).
pub fn ci_half_width(xs: &[f64], z: f64) -> f64 {
    z * sem(xs)
}

/// Quantile by linear interpolation on the sorted copy, q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = pos - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(sem(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = ci_half_width(&[1.0, 2.0, 3.0, 4.0], 1.96);
        let many: Vec<f64> = (0..100).map(|i| 1.0 + (i % 4) as f64).collect();
        let b = ci_half_width(&many, 1.96);
        assert!(b < a);
    }
}
