//! Restarted GMRES(m) [Saad & Schultz, 75] for general systems — the paper's
//! named alternative to BiCGSTAB for non-symmetric A.

use super::op::LinOp;
use super::solve::SolveReport;
use super::vecops::{axpy, dot, norm2};

/// Solve A x = b with GMRES restarted every `restart` iterations.
pub fn gmres(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iter: usize,
    restart: usize,
) -> SolveReport {
    let d = a.dim();
    let m = restart.max(1).min(d);
    let bnorm = norm2(b).max(1e-30);
    let mut total_iters = 0;

    let mut r = vec![0.0; d];
    loop {
        // r = b − A x
        a.apply(x, &mut r);
        for i in 0..d {
            r[i] = b[i] - r[i];
        }
        let beta = norm2(&r);
        let res = beta / bnorm;
        if res <= tol {
            return SolveReport { iterations: total_iters, residual: res, converged: true };
        }
        if total_iters >= max_iter {
            return SolveReport { iterations: total_iters, residual: res, converged: false };
        }

        // Arnoldi with modified Gram–Schmidt.
        let mut v: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
        v.push(r.iter().map(|&ri| ri / beta).collect());
        let mut h = vec![vec![0.0; m]; m + 1]; // (m+1) x m Hessenberg
        // Givens rotation accumulators.
        let mut cs = vec![0.0; m];
        let mut sn = vec![0.0; m];
        let mut g = vec![0.0; m + 1];
        g[0] = beta;

        let mut k_used = 0;
        for k in 0..m {
            if total_iters >= max_iter {
                break;
            }
            total_iters += 1;
            let mut w = vec![0.0; d];
            a.apply(&v[k], &mut w);
            for (j, vj) in v.iter().enumerate().take(k + 1) {
                h[j][k] = dot(&w, vj);
                axpy(-h[j][k], vj, &mut w);
            }
            h[k + 1][k] = norm2(&w);
            // Apply previous Givens rotations to the new column.
            for j in 0..k {
                let tmp = cs[j] * h[j][k] + sn[j] * h[j + 1][k];
                h[j + 1][k] = -sn[j] * h[j][k] + cs[j] * h[j + 1][k];
                h[j][k] = tmp;
            }
            // New rotation to eliminate h[k+1][k].
            let denom = (h[k][k] * h[k][k] + h[k + 1][k] * h[k + 1][k]).sqrt();
            if denom < 1e-300 {
                k_used = k + 1;
                break;
            }
            cs[k] = h[k][k] / denom;
            sn[k] = h[k + 1][k] / denom;
            h[k][k] = denom;
            h[k + 1][k] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            k_used = k + 1;
            let rel = g[k + 1].abs() / bnorm;
            if rel <= tol {
                break;
            }
            if h[k + 1][k].abs() > 0.0 && k + 1 < m {
                // next basis vector (w already orthogonalized)
                let hnext = norm2(&w);
                if hnext < 1e-300 {
                    break;
                }
                v.push(w.iter().map(|&wi| wi / hnext).collect());
                h[k + 1][k] = 0.0; // already rotated away
            } else if k + 1 < m {
                let hnext = norm2(&w);
                if hnext < 1e-300 {
                    break;
                }
                v.push(w.iter().map(|&wi| wi / hnext).collect());
            }
        }

        // Back-substitute y from the triangular H and update x.
        let k = k_used;
        if k == 0 {
            return SolveReport { iterations: total_iters, residual: res, converged: false };
        }
        let mut y = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = g[i];
            for j in i + 1..k {
                s -= h[i][j] * y[j];
            }
            y[i] = if h[i][i].abs() > 1e-300 { s / h[i][i] } else { 0.0 };
        }
        for (j, yj) in y.iter().enumerate() {
            axpy(*yj, &v[j], x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::linalg::op::DenseOp;
    use crate::util::rng::Rng;

    #[test]
    fn solves_nonsymmetric_system() {
        let mut rng = Rng::new(1);
        let n = 30;
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += n as f64;
        }
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; n];
        let rep = gmres(&DenseOp::new(&a), &b, &mut x, 1e-11, 600, 20);
        assert!(rep.converged, "{rep:?}");
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "i={i} {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn full_krylov_is_exact() {
        let mut rng = Rng::new(2);
        let n = 12;
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += 4.0;
        }
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; n];
        let rep = gmres(&DenseOp::new(&a), &b, &mut x, 1e-10, 5 * n, n);
        assert!(rep.converged, "{rep:?}");
    }

    #[test]
    fn small_restart_still_converges() {
        let mut rng = Rng::new(3);
        let n = 20;
        let a = Mat::randn(n, n, &mut rng).gram().plus_diag(2.0);
        let b = rng.normal_vec(n);
        let mut x = vec![0.0; n];
        let rep = gmres(&DenseOp::new(&a), &b, &mut x, 1e-9, 2000, 5);
        assert!(rep.converged, "{rep:?}");
        let mut ax = vec![0.0; n];
        a.matvec_into(&x, &mut ax);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-6);
        }
    }
}
