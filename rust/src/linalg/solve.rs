//! Linear-solver dispatch — the paper's solver-choice policy in §2.1:
//! CG when A is symmetric PSD; GMRES or BiCGSTAB otherwise; optionally the
//! normal equation A Aᵀ u = A v via CG (the `jax.linear_transpose` trick);
//! a least-squares fallback for (near-)singular systems; and a dense
//! direct path ([`Factorization`]: Cholesky for symmetric A, pivoted LU
//! otherwise) that materializes A with one block product and amortizes the
//! O(d³) factor across any number of right-hand sides — the substrate of
//! the serve subsystem's θ-keyed factorization cache.

use super::bicgstab::bicgstab;
use super::cg::{block_cg, block_cg_mixed, cg, cg_mixed};
use super::chol::{Cholesky, CholeskyF32};
use super::gmres::gmres;
use super::lu::{Lu, LuF32};
use super::mat::Mat;
use super::op::{AAtOp, LinOp, TransposedOp};
use super::vecops::norm2;

/// Which iterative method to use for the implicit-diff linear system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinearSolverKind {
    /// Conjugate gradient (requires symmetric A).
    Cg,
    /// BiCGSTAB (general A).
    BiCgStab,
    /// Restarted GMRES (general A).
    Gmres,
    /// CG on the normal equations A Aᵀ u = b (general A; least-squares-like).
    NormalCg,
    /// Dense direct solve: materialize A (one block product), factor
    /// (Cholesky if symmetric, else pivoted LU), substitute. Falls back to
    /// GMRES when the factorization fails. O(d³) — small/repeat systems.
    Direct,
    /// Pick automatically: CG if `op.is_symmetric()`, BiCGSTAB otherwise.
    Auto,
}

/// Arithmetic policy for the implicit-diff linear solves (tentpole 3 of the
/// kernel-layer rebuild): either pure f64 everywhere, or f32 inner work
/// (factorization storage + substitution, Krylov block state) wrapped in f64
/// iterative refinement. Mixed precision is an *accuracy-preserving*
/// optimization: every mixed path re-measures residuals in f64 and falls
/// back to (or polishes with) the f64 method, so converged results satisfy
/// the same tolerance — the `diff::precision` Theorem-1 bound check applies
/// unchanged. Methods without a mixed kernel (GMRES, BiCGSTAB) ignore the
/// policy and run f64.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolvePrecision {
    /// Pure double precision (the default).
    F64,
    /// f32 factorizations / f32-state CG inner solves with f64 iterative
    /// refinement and an f64 finishing pass.
    MixedF32,
}

impl Default for SolvePrecision {
    fn default() -> Self {
        SolvePrecision::F64
    }
}

impl SolvePrecision {
    /// Wire name used by the serve protocol ("precision" request field).
    pub fn name(&self) -> &'static str {
        match self {
            SolvePrecision::F64 => "f64",
            SolvePrecision::MixedF32 => "mixed",
        }
    }

    /// Parse the serve-protocol wire name (accepts a couple of aliases).
    pub fn parse(s: &str) -> Option<SolvePrecision> {
        match s {
            "f64" | "double" => Some(SolvePrecision::F64),
            "mixed" | "mixed_f32" | "f32" => Some(SolvePrecision::MixedF32),
            _ => None,
        }
    }
}

/// A dense factorization of a (square) operator: the direct-solve
/// counterpart of the matrix-free iterative paths. Solves through a
/// `Factorization` do NOT pass through [`solve`]/[`solve_block`] and are
/// not counted by [`counter`] — which is exactly what lets the serve
/// cache assert "repeat-θ requests issue zero new solves".
#[derive(Clone, Debug)]
pub enum Factorization {
    /// A = L Lᵀ (symmetric positive definite A).
    Chol(Cholesky),
    /// P A = L U (general A).
    Lu(Lu),
    /// f32 Cholesky factor + the f64 matrix it came from, for iterative
    /// refinement (substitute in f32, correct residuals in f64).
    CholMixed(CholeskyF32, Mat),
    /// f32 LU factor + the f64 matrix, refined the same way.
    LuMixed(LuF32, Mat),
}

/// Refinement loop shared by the mixed factorization paths: start from the
/// f32 substitution, then repeatedly solve the f64 residual through the same
/// f32 factor. Each round multiplies the error by O(ε_f32·κ); we stop at
/// f64 roundoff, stagnation, or [`REFINE_MAX`] rounds — for the dense
/// systems the direct path handles, 2–3 rounds reach ~1e-15 backward error.
const REFINE_MAX: usize = 8;
const REFINE_TOL: f64 = 1e-14;

fn refine(
    residual: impl Fn(&[f64], &mut [f64]),
    subst: impl Fn(&[f64]) -> Vec<f64>,
    b: &[f64],
) -> Vec<f64> {
    let mut x = subst(b);
    let bnorm = norm2(b).max(1e-30);
    let mut ax = vec![0.0; b.len()];
    let mut r = vec![0.0; b.len()];
    let mut prev = f64::INFINITY;
    for _ in 0..REFINE_MAX {
        residual(&x, &mut ax);
        for i in 0..b.len() {
            r[i] = b[i] - ax[i];
        }
        let rel = norm2(&r) / bnorm;
        if rel <= REFINE_TOL || rel >= 0.5 * prev {
            break;
        }
        prev = rel;
        let e = subst(&r);
        for i in 0..x.len() {
            x[i] += e[i];
        }
    }
    x
}

impl Factorization {
    /// Factor a dense matrix. Tries Cholesky when `symmetric`, falling back
    /// to LU if A is indefinite; None only if A is numerically singular.
    pub fn of_mat(a: &Mat, symmetric: bool) -> Option<Factorization> {
        Factorization::of_mat_prec(a, symmetric, SolvePrecision::F64)
    }

    /// Precision-aware factorization. Mixed: factor in f32 (half the
    /// flops/traffic of the f64 factorization), keep A for f64 refinement;
    /// falls back to the f64 factorization when f32 cannot represent the
    /// problem (pivot/diagonal underflow at single precision).
    pub fn of_mat_prec(
        a: &Mat,
        symmetric: bool,
        precision: SolvePrecision,
    ) -> Option<Factorization> {
        if precision == SolvePrecision::MixedF32 {
            if symmetric {
                if let Some(ch) = CholeskyF32::factor(a) {
                    return Some(Factorization::CholMixed(ch, a.clone()));
                }
            }
            if let Some(lu) = LuF32::factor(a) {
                return Some(Factorization::LuMixed(lu, a.clone()));
            }
            // fall through to f64
        }
        if symmetric {
            if let Some(ch) = Cholesky::factor(a) {
                return Some(Factorization::Chol(ch));
            }
        }
        Lu::factor(a).map(Factorization::Lu)
    }

    /// Materialize `a` (one block product via [`LinOp::to_dense`]) and
    /// factor it.
    pub fn of_op(a: &dyn LinOp) -> Option<Factorization> {
        Factorization::of_mat(&a.to_dense(), a.is_symmetric())
    }

    /// Precision-aware [`Factorization::of_op`].
    pub fn of_op_prec(a: &dyn LinOp, precision: SolvePrecision) -> Option<Factorization> {
        Factorization::of_mat_prec(&a.to_dense(), a.is_symmetric(), precision)
    }

    pub fn dim(&self) -> usize {
        match self {
            Factorization::Chol(ch) => ch.l.rows,
            Factorization::Lu(lu) => lu.dim(),
            Factorization::CholMixed(ch, _) => ch.dim(),
            Factorization::LuMixed(lu, _) => lu.dim(),
        }
    }

    /// The precision tier this factorization runs at.
    pub fn precision(&self) -> SolvePrecision {
        match self {
            Factorization::Chol(_) | Factorization::Lu(_) => SolvePrecision::F64,
            Factorization::CholMixed(..) | Factorization::LuMixed(..) => SolvePrecision::MixedF32,
        }
    }

    /// Solve A x = b by substitution (mixed variants: f32 substitution +
    /// f64 iterative refinement).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        match self {
            Factorization::Chol(ch) => ch.solve(b),
            Factorization::Lu(lu) => lu.solve(b),
            Factorization::CholMixed(ch, a) => {
                refine(|x, ax| a.matvec_into(x, ax), |r| ch.solve(r), b)
            }
            Factorization::LuMixed(lu, a) => {
                refine(|x, ax| a.matvec_into(x, ax), |r| lu.solve(r), b)
            }
        }
    }

    /// Solve Aᵀ x = b (the VJP-side system; Cholesky is symmetric so this
    /// is the same substitution).
    pub fn solve_t(&self, b: &[f64]) -> Vec<f64> {
        match self {
            Factorization::Chol(ch) => ch.solve(b),
            Factorization::Lu(lu) => lu.solve_t(b),
            Factorization::CholMixed(ch, a) => {
                refine(|x, ax| a.matvec_into(x, ax), |r| ch.solve(r), b)
            }
            Factorization::LuMixed(lu, a) => {
                refine(|x, ax| a.matvec_t_into(x, ax), |r| lu.solve_t(r), b)
            }
        }
    }

    /// Solve A X = B for a block of right-hand sides.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        match self {
            Factorization::Chol(ch) => ch.solve_mat(b),
            Factorization::Lu(lu) => lu.solve_mat(b),
            _ => self.solve_cols(b, false),
        }
    }

    /// Solve Aᵀ X = B for a block of right-hand sides.
    pub fn solve_t_mat(&self, b: &Mat) -> Mat {
        match self {
            Factorization::Chol(ch) => ch.solve_mat(b),
            Factorization::Lu(lu) => lu.solve_t_mat(b),
            _ => self.solve_cols(b, true),
        }
    }

    /// Column loop for the mixed block paths (each column refines
    /// independently; the factor is shared).
    fn solve_cols(&self, b: &Mat, transpose: bool) -> Mat {
        let mut out = Mat::zeros(b.rows, b.cols);
        for j in 0..b.cols {
            let col = if transpose { self.solve_t(&b.col(j)) } else { self.solve(&b.col(j)) };
            out.set_col(j, &col);
        }
        out
    }
}

/// Solver configuration shared by all methods.
#[derive(Clone, Copy, Debug)]
pub struct LinearSolveConfig {
    pub kind: LinearSolverKind,
    pub tol: f64,
    pub max_iter: usize,
    pub gmres_restart: usize,
    /// Arithmetic policy: [`SolvePrecision::F64`] (default) or f32-inner /
    /// f64-refined mixed precision on the CG and Direct paths.
    pub precision: SolvePrecision,
}

impl Default for LinearSolveConfig {
    fn default() -> Self {
        LinearSolveConfig {
            kind: LinearSolverKind::Auto,
            tol: 1e-10,
            max_iter: 2500,
            gmres_restart: 30,
            precision: SolvePrecision::F64,
        }
    }
}

impl LinearSolveConfig {
    pub fn with_kind(kind: LinearSolverKind) -> Self {
        LinearSolveConfig { kind, ..Default::default() }
    }

    pub fn with_precision(self, precision: SolvePrecision) -> Self {
        LinearSolveConfig { precision, ..self }
    }
}

/// Outcome of an iterative solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveReport {
    pub iterations: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Outcome of a multi-RHS block solve.
#[derive(Clone, Copy, Debug)]
pub struct BlockSolveReport {
    pub iterations: usize,
    /// Worst relative residual across the block's columns.
    pub max_residual: f64,
    pub converged: bool,
    /// Number of right-hand sides solved together.
    pub rhs: usize,
}

/// Thread-local counter of `solve`/`solve_block` entries on this thread. A
/// block solve over k right-hand sides counts ONCE — this is what lets tests
/// assert that dense Jacobian assembly issues a single block solve instead
/// of d column solves. Note: solves a mapping performs internally (e.g. the
/// Newton fixed point's inner Jacobian solves inside its JVP/VJP) also pass
/// through `solve` and are counted, so count-based assertions only hold for
/// mappings whose Jacobian products are solve-free.
pub mod counter {
    use std::cell::Cell;
    thread_local! {
        static SOLVES: Cell<usize> = Cell::new(0);
    }
    pub(super) fn bump() {
        SOLVES.with(|c| c.set(c.get() + 1));
    }
    /// `solve`/`solve_block` calls on this thread since the last [`reset`].
    pub fn count() -> usize {
        SOLVES.with(|c| c.get())
    }
    pub fn reset() {
        SOLVES.with(|c| c.set(0));
    }
}

fn resolve(kind: LinearSolverKind, a: &dyn LinOp) -> LinearSolverKind {
    match kind {
        LinearSolverKind::Auto => {
            if a.is_symmetric() {
                LinearSolverKind::Cg
            } else {
                LinearSolverKind::BiCgStab
            }
        }
        k => k,
    }
}

/// Solve A x = b in-place in `x` (initial guess on entry).
pub fn solve(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &LinearSolveConfig) -> SolveReport {
    counter::bump();
    let mixed = cfg.precision == SolvePrecision::MixedF32;
    match resolve(cfg.kind, a) {
        LinearSolverKind::Cg if mixed => cg_mixed(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::Cg => cg(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::BiCgStab => bicgstab(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::Gmres => gmres(a, b, x, cfg.tol, cfg.max_iter, cfg.gmres_restart),
        LinearSolverKind::NormalCg => {
            // Solve A x = b via x = Aᵀ u where A Aᵀ u = b.
            let aat = AAtOp::new(a);
            let mut u = vec![0.0; b.len()];
            let rep = if mixed {
                cg_mixed(&aat, b, &mut u, cfg.tol, cfg.max_iter)
            } else {
                cg(&aat, b, &mut u, cfg.tol, cfg.max_iter)
            };
            a.apply_t(&u, x);
            rep
        }
        LinearSolverKind::Direct => match Factorization::of_op_prec(a, cfg.precision) {
            Some(f) => {
                x.copy_from_slice(&f.solve(b));
                direct_report(a, b, x, cfg.tol)
            }
            // Singular factorization: GMRES still produces a least-squares-
            // flavored iterate instead of aborting the request.
            None => gmres(a, b, x, cfg.tol, cfg.max_iter, cfg.gmres_restart),
        },
        LinearSolverKind::Auto => unreachable!(),
    }
}

/// Tolerance a direct solve is judged against: honor a looser requested
/// tolerance, but never flag a roundoff-level residual as divergence when
/// the caller asked for tighter than substitution can deliver.
fn direct_tol(cfg_tol: f64) -> f64 {
    cfg_tol.max(1e-8)
}

/// Report for a direct solve: one "iteration", true relative residual.
fn direct_report(a: &dyn LinOp, b: &[f64], x: &[f64], cfg_tol: f64) -> SolveReport {
    let mut ax = vec![0.0; b.len()];
    a.apply(x, &mut ax);
    let mut rsq = 0.0;
    let mut bsq = 0.0;
    for i in 0..b.len() {
        let d = ax[i] - b[i];
        rsq += d * d;
        bsq += b[i] * b[i];
    }
    let residual = (rsq / bsq.max(1e-300)).sqrt();
    SolveReport { iterations: 1, residual, converged: residual <= direct_tol(cfg_tol) }
}

/// Solve Aᵀ x = b (the VJP-side system of §2.1: first solve Aᵀ u = v).
pub fn solve_t(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &LinearSolveConfig) -> SolveReport {
    let at = TransposedOp(a);
    solve(&at, b, x, cfg)
}

/// Solve A X = B for a block of right-hand sides (columns of B), sharing
/// work across the block wherever the method allows: CG runs the batched
/// [`block_cg`] (one block operator application per iteration), NormalCg
/// runs block-CG on A Aᵀ followed by one block transpose product, and
/// GMRES/BiCGSTAB fall back to a blocked per-column dispatch behind the same
/// entry point (each column needs its own Krylov basis). Counts as ONE solve
/// in [`counter`].
pub fn solve_block(
    a: &dyn LinOp,
    b: &Mat,
    x: &mut Mat,
    cfg: &LinearSolveConfig,
) -> BlockSolveReport {
    counter::bump();
    let kind = resolve(cfg.kind, a);
    let mixed = cfg.precision == SolvePrecision::MixedF32;
    match kind {
        LinearSolverKind::Cg if mixed => block_cg_mixed(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::Cg => block_cg(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::NormalCg => {
            let aat = AAtOp::new(a);
            let mut u = Mat::zeros(b.rows, b.cols);
            let rep = if mixed {
                block_cg_mixed(&aat, b, &mut u, cfg.tol, cfg.max_iter)
            } else {
                block_cg(&aat, b, &mut u, cfg.tol, cfg.max_iter)
            };
            a.apply_t_block(&u, x);
            rep
        }
        LinearSolverKind::Direct => match Factorization::of_op_prec(a, cfg.precision) {
            Some(f) => {
                // Factor once, substitute k times — the whole point of the
                // direct block path.
                let sol = f.solve_mat(b);
                x.data.copy_from_slice(&sol.data);
                let mut ax = Mat::zeros(b.rows, b.cols);
                a.apply_block(x, &mut ax);
                let mut max_res = 0.0f64;
                for j in 0..b.cols {
                    let mut rsq = 0.0;
                    let mut bsq = 0.0;
                    for i in 0..b.rows {
                        let d = ax.at(i, j) - b.at(i, j);
                        rsq += d * d;
                        bsq += b.at(i, j) * b.at(i, j);
                    }
                    max_res = max_res.max((rsq / bsq.max(1e-300)).sqrt());
                }
                BlockSolveReport {
                    iterations: 1,
                    max_residual: max_res,
                    converged: max_res <= direct_tol(cfg.tol),
                    rhs: b.cols,
                }
            }
            None => {
                let mut iterations = 0;
                let mut max_res = 0.0f64;
                let mut all = true;
                let mut bc = vec![0.0; a.dim()];
                let mut xc = vec![0.0; a.dim()];
                for j in 0..b.cols {
                    b.col_into(j, &mut bc);
                    x.col_into(j, &mut xc);
                    let rep = gmres(a, &bc, &mut xc, cfg.tol, cfg.max_iter, cfg.gmres_restart);
                    x.set_col(j, &xc);
                    iterations = iterations.max(rep.iterations);
                    max_res = max_res.max(rep.residual);
                    all &= rep.converged;
                }
                BlockSolveReport { iterations, max_residual: max_res, converged: all, rhs: b.cols }
            }
        },
        LinearSolverKind::Gmres | LinearSolverKind::BiCgStab => {
            let d = a.dim();
            let k = b.cols;
            let mut bc = vec![0.0; d];
            let mut xc = vec![0.0; d];
            let mut iterations = 0;
            let mut max_res = 0.0f64;
            let mut all = true;
            for j in 0..k {
                b.col_into(j, &mut bc);
                x.col_into(j, &mut xc);
                let rep = match kind {
                    LinearSolverKind::Gmres => {
                        gmres(a, &bc, &mut xc, cfg.tol, cfg.max_iter, cfg.gmres_restart)
                    }
                    _ => bicgstab(a, &bc, &mut xc, cfg.tol, cfg.max_iter),
                };
                x.set_col(j, &xc);
                iterations = iterations.max(rep.iterations);
                max_res = max_res.max(rep.residual);
                all &= rep.converged;
            }
            BlockSolveReport { iterations, max_residual: max_res, converged: all, rhs: k }
        }
        LinearSolverKind::Auto => unreachable!(),
    }
}

/// Solve Aᵀ X = B for a block of right-hand sides — the multi-cotangent
/// VJP-side system.
pub fn solve_t_block(
    a: &dyn LinOp,
    b: &Mat,
    x: &mut Mat,
    cfg: &LinearSolveConfig,
) -> BlockSolveReport {
    let at = TransposedOp(a);
    solve_block(&at, b, x, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::linalg::op::DenseOp;
    use crate::util::rng::Rng;

    fn check_solution(a: &Mat, b: &[f64], x: &[f64], tol: f64) {
        let ax = a.matvec(x);
        for i in 0..b.len() {
            assert!((ax[i] - b[i]).abs() < tol, "residual at {i}: {} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn auto_uses_cg_for_symmetric() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(10, 10, &mut rng).gram().plus_diag(1.0);
        let b = rng.normal_vec(10);
        let mut x = vec![0.0; 10];
        let rep = solve(&DenseOp::symmetric(&a), &b, &mut x, &LinearSolveConfig::default());
        assert!(rep.converged);
        check_solution(&a, &b, &x, 1e-6);
    }

    #[test]
    fn all_kinds_agree_on_spd() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(14, 14, &mut rng).gram().plus_diag(2.0);
        let b = rng.normal_vec(14);
        for kind in [
            LinearSolverKind::Cg,
            LinearSolverKind::BiCgStab,
            LinearSolverKind::Gmres,
            LinearSolverKind::NormalCg,
            LinearSolverKind::Direct,
        ] {
            let mut x = vec![0.0; 14];
            let cfg = LinearSolveConfig {
                kind,
                tol: 1e-11,
                max_iter: 4000,
                gmres_restart: 14,
                ..Default::default()
            };
            let rep = solve(&DenseOp::symmetric(&a), &b, &mut x, &cfg);
            assert!(rep.converged, "{kind:?} failed: {rep:?}");
            check_solution(&a, &b, &x, 1e-5);
        }
    }

    #[test]
    fn block_solve_all_kinds_match_column_solves() {
        let mut rng = Rng::new(4);
        let n = 12;
        let k = 4;
        let a = Mat::randn(n, n, &mut rng).gram().plus_diag(2.0);
        let b = Mat::randn(n, k, &mut rng);
        for kind in [
            LinearSolverKind::Cg,
            LinearSolverKind::BiCgStab,
            LinearSolverKind::Gmres,
            LinearSolverKind::NormalCg,
            LinearSolverKind::Direct,
        ] {
            let cfg = LinearSolveConfig {
                kind,
                tol: 1e-11,
                max_iter: 4000,
                gmres_restart: n,
                ..Default::default()
            };
            let op = DenseOp::symmetric(&a);
            let mut x_block = Mat::zeros(n, k);
            let rep = solve_block(&op, &b, &mut x_block, &cfg);
            assert!(rep.converged, "{kind:?}: {rep:?}");
            let mut bc = vec![0.0; n];
            for j in 0..k {
                b.col_into(j, &mut bc);
                let mut xc = vec![0.0; n];
                let rep_j = solve(&op, &bc, &mut xc, &cfg);
                assert!(rep_j.converged, "{kind:?} col {j}");
                for i in 0..n {
                    assert!(
                        (x_block.at(i, j) - xc[i]).abs() < 1e-6,
                        "{kind:?} ({i},{j}): {} vs {}",
                        x_block.at(i, j),
                        xc[i]
                    );
                }
            }
        }
    }

    #[test]
    fn block_transpose_solve_matches_scalar() {
        let mut rng = Rng::new(5);
        let n = 10;
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += 6.0;
        }
        let b = Mat::randn(n, 3, &mut rng);
        let cfg = LinearSolveConfig::default();
        let op = DenseOp::new(&a);
        let mut x_block = Mat::zeros(n, 3);
        let rep = solve_t_block(&op, &b, &mut x_block, &cfg);
        assert!(rep.converged, "{rep:?}");
        let mut bc = vec![0.0; n];
        for j in 0..3 {
            b.col_into(j, &mut bc);
            let mut xc = vec![0.0; n];
            solve_t(&op, &bc, &mut xc, &cfg);
            for i in 0..n {
                assert!((x_block.at(i, j) - xc[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn counter_counts_block_solves_once() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(6, 6, &mut rng).gram().plus_diag(1.0);
        let b = Mat::randn(6, 5, &mut rng);
        let op = DenseOp::symmetric(&a);
        counter::reset();
        let mut x = Mat::zeros(6, 5);
        solve_block(&op, &b, &mut x, &LinearSolveConfig::default());
        assert_eq!(counter::count(), 1, "block solve must count once");
        let mut xc = vec![0.0; 6];
        let bc = b.col(0);
        solve(&op, &bc, &mut xc, &LinearSolveConfig::default());
        solve_t(&op, &bc, &mut xc, &LinearSolveConfig::default());
        assert_eq!(counter::count(), 3);
    }

    #[test]
    fn factorization_solves_without_counting() {
        // Cholesky branch on an SPD matrix, LU branch on a general one; and
        // crucially, Factorization substitutions never bump the solve
        // counter — the property the serve cache's "zero new solves on
        // repeat θ" assertion rests on.
        let mut rng = Rng::new(7);
        let n = 9;
        let spd = Mat::randn(n + 2, n, &mut rng).gram().plus_diag(0.5);
        let gen = {
            let mut g = Mat::randn(n, n, &mut rng);
            for i in 0..n {
                *g.at_mut(i, i) += 4.0;
            }
            g
        };
        counter::reset();
        let fs = Factorization::of_mat(&spd, true).unwrap();
        assert!(matches!(fs, Factorization::Chol(_)));
        let fg = Factorization::of_mat(&gen, false).unwrap();
        assert!(matches!(fg, Factorization::Lu(_)));
        assert_eq!(fs.dim(), n);
        let b = rng.normal_vec(n);
        for (a, f) in [(&spd, &fs), (&gen, &fg)] {
            let x = f.solve(&b);
            check_solution(a, &b, &x, 1e-8);
            // Aᵀ x = b
            let xt = f.solve_t(&b);
            let atx = a.matvec_t(&xt);
            for i in 0..n {
                assert!((atx[i] - b[i]).abs() < 1e-8);
            }
        }
        let bm = Mat::randn(n, 3, &mut rng);
        let xm = fg.solve_mat(&bm);
        let axm = gen.matmul(&xm);
        let xtm = fg.solve_t_mat(&bm);
        let atxm = gen.transpose().matmul(&xtm);
        for i in 0..bm.data.len() {
            assert!((axm.data[i] - bm.data[i]).abs() < 1e-8);
            assert!((atxm.data[i] - bm.data[i]).abs() < 1e-8);
        }
        assert_eq!(counter::count(), 0, "factored substitutions must not count as solves");
        // of_op materializes through the block product and factors the same
        // matrix.
        let f2 = Factorization::of_op(&DenseOp::symmetric(&spd)).unwrap();
        let x2 = f2.solve(&b);
        check_solution(&spd, &b, &x2, 1e-8);
        // Direct kind goes through `solve` and therefore DOES count.
        let mut xd = vec![0.0; n];
        let cfg = LinearSolveConfig::with_kind(LinearSolverKind::Direct);
        let rep = solve(&DenseOp::new(&gen), &b, &mut xd, &cfg);
        assert!(rep.converged, "{rep:?}");
        check_solution(&gen, &b, &xd, 1e-7);
        assert_eq!(counter::count(), 1);
        // Singular matrix: factorization refuses…
        let sing = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Factorization::of_mat(&sing, false).is_none());
    }

    #[test]
    fn mixed_precision_matches_f64_within_refinement_tolerance() {
        let mut rng = Rng::new(8);
        let n = 18;
        let a = Mat::randn(n + 4, n, &mut rng).gram().plus_diag(0.5);
        let b = rng.normal_vec(n);
        let op = DenseOp::symmetric(&a);
        for kind in [LinearSolverKind::Cg, LinearSolverKind::NormalCg, LinearSolverKind::Direct] {
            let f64_cfg = LinearSolveConfig {
                kind,
                tol: 1e-11,
                max_iter: 4000,
                gmres_restart: n,
                ..Default::default()
            };
            let mixed_cfg = f64_cfg.with_precision(SolvePrecision::MixedF32);
            let mut x64 = vec![0.0; n];
            let rep64 = solve(&op, &b, &mut x64, &f64_cfg);
            let mut xm = vec![0.0; n];
            let repm = solve(&op, &b, &mut xm, &mixed_cfg);
            assert!(rep64.converged && repm.converged, "{kind:?}: {rep64:?} vs {repm:?}");
            for i in 0..n {
                assert!(
                    (x64[i] - xm[i]).abs() < 1e-6,
                    "{kind:?} i={i}: {} vs {}",
                    x64[i],
                    xm[i]
                );
            }
        }
        // Mixed factorization: f32 factor + f64 refinement lands at f64-level
        // backward error, and the variant advertises its tier.
        let f = Factorization::of_mat_prec(&a, true, SolvePrecision::MixedF32).unwrap();
        assert!(matches!(f, Factorization::CholMixed(..)));
        assert_eq!(f.precision(), SolvePrecision::MixedF32);
        let x = f.solve(&b);
        check_solution(&a, &b, &x, 1e-7);
        let xt = f.solve_t(&b);
        check_solution(&a, &b, &xt, 1e-7);
        let bm = Mat::randn(n, 3, &mut rng);
        let xm = f.solve_mat(&bm);
        let axm = a.matmul(&xm);
        for i in 0..bm.data.len() {
            assert!((axm.data[i] - bm.data[i]).abs() < 1e-6);
        }
        // General (non-SPD) matrix takes the LuMixed variant.
        let mut g = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *g.at_mut(i, i) += 5.0;
        }
        let fg = Factorization::of_mat_prec(&g, false, SolvePrecision::MixedF32).unwrap();
        assert!(matches!(fg, Factorization::LuMixed(..)));
        let xg = fg.solve(&b);
        let axg = g.matvec(&xg);
        for i in 0..n {
            assert!((axg[i] - b[i]).abs() < 1e-6);
        }
        let xgt = fg.solve_t(&b);
        let atxg = g.matvec_t(&xgt);
        for i in 0..n {
            assert!((atxg[i] - b[i]).abs() < 1e-6);
        }
        // Wire names round-trip for the serve protocol.
        assert_eq!(SolvePrecision::parse("mixed"), Some(SolvePrecision::MixedF32));
        assert_eq!(SolvePrecision::parse(SolvePrecision::F64.name()), Some(SolvePrecision::F64));
        assert_eq!(SolvePrecision::parse("bogus"), None);
    }

    #[test]
    fn transpose_solve() {
        let mut rng = Rng::new(3);
        let n = 9;
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += 5.0;
        }
        let b = rng.normal_vec(n);
        let mut x = vec![0.0; n];
        let rep = solve_t(&DenseOp::new(&a), &b, &mut x, &LinearSolveConfig::default());
        assert!(rep.converged);
        let atx = a.matvec_t(&x);
        for i in 0..n {
            assert!((atx[i] - b[i]).abs() < 1e-6);
        }
    }
}
