//! Linear-solver dispatch — the paper's solver-choice policy in §2.1:
//! CG when A is symmetric PSD; GMRES or BiCGSTAB otherwise; optionally the
//! normal equation A Aᵀ u = A v via CG (the `jax.linear_transpose` trick);
//! and a least-squares fallback for (near-)singular systems.

use super::bicgstab::bicgstab;
use super::cg::cg;
use super::gmres::gmres;
use super::op::{AAtOp, LinOp, TransposedOp};

/// Which iterative method to use for the implicit-diff linear system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinearSolverKind {
    /// Conjugate gradient (requires symmetric A).
    Cg,
    /// BiCGSTAB (general A).
    BiCgStab,
    /// Restarted GMRES (general A).
    Gmres,
    /// CG on the normal equations A Aᵀ u = b (general A; least-squares-like).
    NormalCg,
    /// Pick automatically: CG if `op.is_symmetric()`, BiCGSTAB otherwise.
    Auto,
}

/// Solver configuration shared by all methods.
#[derive(Clone, Copy, Debug)]
pub struct LinearSolveConfig {
    pub kind: LinearSolverKind,
    pub tol: f64,
    pub max_iter: usize,
    pub gmres_restart: usize,
}

impl Default for LinearSolveConfig {
    fn default() -> Self {
        LinearSolveConfig { kind: LinearSolverKind::Auto, tol: 1e-10, max_iter: 2500, gmres_restart: 30 }
    }
}

impl LinearSolveConfig {
    pub fn with_kind(kind: LinearSolverKind) -> Self {
        LinearSolveConfig { kind, ..Default::default() }
    }
}

/// Outcome of an iterative solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveReport {
    pub iterations: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Solve A x = b in-place in `x` (initial guess on entry).
pub fn solve(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &LinearSolveConfig) -> SolveReport {
    let kind = match cfg.kind {
        LinearSolverKind::Auto => {
            if a.is_symmetric() {
                LinearSolverKind::Cg
            } else {
                LinearSolverKind::BiCgStab
            }
        }
        k => k,
    };
    match kind {
        LinearSolverKind::Cg => cg(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::BiCgStab => bicgstab(a, b, x, cfg.tol, cfg.max_iter),
        LinearSolverKind::Gmres => gmres(a, b, x, cfg.tol, cfg.max_iter, cfg.gmres_restart),
        LinearSolverKind::NormalCg => {
            // Solve A x = b via x = Aᵀ u where A Aᵀ u = b.
            let aat = AAtOp::new(a);
            let mut u = vec![0.0; b.len()];
            let rep = cg(&aat, b, &mut u, cfg.tol, cfg.max_iter);
            a.apply_t(&u, x);
            rep
        }
        LinearSolverKind::Auto => unreachable!(),
    }
}

/// Solve Aᵀ x = b (the VJP-side system of §2.1: first solve Aᵀ u = v).
pub fn solve_t(a: &dyn LinOp, b: &[f64], x: &mut [f64], cfg: &LinearSolveConfig) -> SolveReport {
    let at = TransposedOp(a);
    solve(&at, b, x, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::linalg::op::DenseOp;
    use crate::util::rng::Rng;

    fn check_solution(a: &Mat, b: &[f64], x: &[f64], tol: f64) {
        let ax = a.matvec(x);
        for i in 0..b.len() {
            assert!((ax[i] - b[i]).abs() < tol, "residual at {i}: {} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn auto_uses_cg_for_symmetric() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(10, 10, &mut rng).gram().plus_diag(1.0);
        let b = rng.normal_vec(10);
        let mut x = vec![0.0; 10];
        let rep = solve(&DenseOp::symmetric(&a), &b, &mut x, &LinearSolveConfig::default());
        assert!(rep.converged);
        check_solution(&a, &b, &x, 1e-6);
    }

    #[test]
    fn all_kinds_agree_on_spd() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(14, 14, &mut rng).gram().plus_diag(2.0);
        let b = rng.normal_vec(14);
        for kind in [
            LinearSolverKind::Cg,
            LinearSolverKind::BiCgStab,
            LinearSolverKind::Gmres,
            LinearSolverKind::NormalCg,
        ] {
            let mut x = vec![0.0; 14];
            let cfg = LinearSolveConfig { kind, tol: 1e-11, max_iter: 4000, gmres_restart: 14 };
            let rep = solve(&DenseOp::symmetric(&a), &b, &mut x, &cfg);
            assert!(rep.converged, "{kind:?} failed: {rep:?}");
            check_solution(&a, &b, &x, 1e-5);
        }
    }

    #[test]
    fn transpose_solve() {
        let mut rng = Rng::new(3);
        let n = 9;
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += 5.0;
        }
        let b = rng.normal_vec(n);
        let mut x = vec![0.0; n];
        let rep = solve_t(&DenseOp::new(&a), &b, &mut x, &LinearSolveConfig::default());
        assert!(rep.converged);
        let atx = a.matvec_t(&x);
        for i in 0..n {
            assert!((atx[i] - b[i]).abs() < 1e-6);
        }
    }
}
