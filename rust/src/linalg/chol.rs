//! Dense Cholesky factorization + solves, for small SPD systems (KKT blocks,
//! affine-projection Gram matrices, ridge closed forms).

use super::mat::Mat;

/// Lower-triangular Cholesky factor of an SPD matrix.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factor A = L Lᵀ. Returns None if A is not (numerically) positive
    /// definite.
    pub fn factor(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.at(i, j);
                for k in 0..j {
                    s -= l.at(i, k) * l.at(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    *l.at_mut(i, j) = s.sqrt();
                } else {
                    *l.at_mut(i, j) = s / l.at(j, j);
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve A x = b via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
        // Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l.at(k, i) * x[k];
            }
            x[i] = s / self.l.at(i, i);
        }
        x
    }

    /// Solve for multiple right-hand sides (columns of B).
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows, b.cols);
        for j in 0..b.cols {
            let col = self.solve(&b.col(j));
            for i in 0..b.rows {
                *out.at_mut(i, j) = col[i];
            }
        }
        out
    }

    /// log det(A) = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Single-precision Cholesky factor: the inner engine of the mixed-precision
/// direct path. The factorization and both substitutions run entirely in
/// f32 (half the memory traffic of [`Cholesky`], and the part an iterative
/// refinement loop amortizes), while the API stays f64-in/f64-out so the
/// f64 refinement driver in `linalg::solve` can wrap it transparently.
#[derive(Clone, Debug)]
pub struct CholeskyF32 {
    /// Lower-triangular factor, n×n row-major, f32 storage.
    l: Vec<f32>,
    n: usize,
}

impl CholeskyF32 {
    /// Factor A = L Lᵀ in f32. Returns None if A (rounded to f32) is not
    /// numerically positive definite — which the caller treats as "mixed
    /// precision unavailable, use f64".
    pub fn factor(a: &Mat) -> Option<CholeskyF32> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.at(i, j) as f32;
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if !(s > 0.0) || !s.is_finite() {
                        return None;
                    }
                    l[i * n + j] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Some(CholeskyF32 { l, n })
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Solve A x ≈ b with f32 substitution (forward L y = b, back Lᵀ x = y).
    /// The result carries O(ε_f32·κ) error — callers refine in f64.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let mut s = b[i] as f32;
            for k in 0..i {
                s -= self.l[i * n + k] * y[k];
            }
            y[i] = s / self.l[i * n + i];
        }
        let mut x = vec![0.0f32; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        x.iter().map(|&v| v as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn f32_factor_solves_to_single_precision() {
        let mut rng = Rng::new(11);
        let n = 20;
        let a = Mat::randn(n + 5, n, &mut rng).gram().plus_diag(1.0);
        let ch = CholeskyF32::factor(&a).unwrap();
        assert_eq!(ch.dim(), n);
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        // f32 accuracy only — the refinement loop upstream tightens this.
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-2, "i={i}: {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn f32_factor_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(CholeskyF32::factor(&a).is_none());
    }

    #[test]
    fn factor_and_solve() {
        let mut rng = Rng::new(1);
        let n = 15;
        let a = Mat::randn(n + 3, n, &mut rng).gram().plus_diag(0.1);
        let ch = Cholesky::factor(&a).unwrap();
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, −1
        assert!(Cholesky::factor(&a).is_none());
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(8, 6, &mut rng).gram().plus_diag(0.5);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l.matmul_t(&ch.l);
        for i in 0..a.data.len() {
            assert!((rec.data[i] - a.data[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn logdet_matches_identity() {
        let ch = Cholesky::factor(&Mat::eye(5)).unwrap();
        assert!(ch.logdet().abs() < 1e-12);
    }

    #[test]
    fn multi_rhs() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(10, 7, &mut rng).gram().plus_diag(1.0);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Mat::randn(7, 3, &mut rng);
        let x = ch.solve_mat(&b);
        let ax = a.matmul(&x);
        for i in 0..b.data.len() {
            assert!((ax.data[i] - b.data[i]).abs() < 1e-8);
        }
    }
}
