//! BiCGSTAB [van der Vorst, 81] for general (non-symmetric) systems.
//! Used by the molecular-dynamics sensitivity experiment (paper §4.4 uses
//! BiCGSTAB for the tangent linear solve).

use super::op::LinOp;
use super::solve::SolveReport;
use super::vecops::{axpy, dot, norm2};

/// Solve A x = b with BiCGSTAB. `x` holds the initial guess on entry.
pub fn bicgstab(a: &dyn LinOp, b: &[f64], x: &mut [f64], tol: f64, max_iter: usize) -> SolveReport {
    let d = a.dim();
    let bnorm = norm2(b).max(1e-30);

    let mut r = vec![0.0; d];
    a.apply(x, &mut r);
    for i in 0..d {
        r[i] = b[i] - r[i];
    }
    let r0 = r.clone(); // shadow residual
    let mut rho = 1.0;
    let mut alpha = 1.0;
    let mut omega = 1.0;
    let mut v = vec![0.0; d];
    let mut p = vec![0.0; d];
    let mut s = vec![0.0; d];
    let mut t = vec![0.0; d];

    for it in 0..max_iter {
        let res = norm2(&r) / bnorm;
        if res <= tol {
            return SolveReport { iterations: it, residual: res, converged: true };
        }
        let rho_new = dot(&r0, &r);
        if rho_new.abs() < 1e-300 {
            return SolveReport { iterations: it, residual: res, converged: false };
        }
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        // p = r + beta (p − omega v)
        for i in 0..d {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        a.apply(&p, &mut v);
        let r0v = dot(&r0, &v);
        if r0v.abs() < 1e-300 {
            return SolveReport { iterations: it, residual: res, converged: false };
        }
        alpha = rho / r0v;
        for i in 0..d {
            s[i] = r[i] - alpha * v[i];
        }
        if norm2(&s) / bnorm <= tol {
            axpy(alpha, &p, x);
            return SolveReport { iterations: it + 1, residual: norm2(&s) / bnorm, converged: true };
        }
        a.apply(&s, &mut t);
        let tt = dot(&t, &t);
        if tt.abs() < 1e-300 {
            return SolveReport { iterations: it, residual: res, converged: false };
        }
        omega = dot(&t, &s) / tt;
        axpy(alpha, &p, x);
        axpy(omega, &s, x);
        for i in 0..d {
            r[i] = s[i] - omega * t[i];
        }
        if omega.abs() < 1e-300 {
            return SolveReport { iterations: it + 1, residual: norm2(&r) / bnorm, converged: false };
        }
    }
    let res = norm2(&r) / bnorm;
    SolveReport { iterations: max_iter, residual: res, converged: res <= tol }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::linalg::op::DenseOp;
    use crate::util::rng::Rng;

    #[test]
    fn solves_nonsymmetric_system() {
        let mut rng = Rng::new(1);
        let n = 25;
        // Diagonally dominant non-symmetric matrix.
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += n as f64;
        }
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; n];
        let rep = bicgstab(&DenseOp::new(&a), &b, &mut x, 1e-12, 500);
        assert!(rep.converged, "{rep:?}");
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn also_handles_spd() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(12, 12, &mut rng).gram().plus_diag(0.5);
        let x_true = rng.normal_vec(12);
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; 12];
        let rep = bicgstab(&DenseOp::new(&a), &b, &mut x, 1e-12, 300);
        assert!(rep.converged);
        for i in 0..12 {
            assert!((x[i] - x_true[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_rhs_immediate() {
        let a = Mat::eye(5);
        let b = vec![0.0; 5];
        let mut x = vec![0.0; 5];
        let rep = bicgstab(&DenseOp::new(&a), &b, &mut x, 1e-12, 10);
        assert!(rep.converged);
        assert_eq!(rep.iterations, 0);
    }
}
