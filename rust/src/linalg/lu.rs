//! Dense LU with partial pivoting, for small general (non-SPD) systems —
//! dense Jacobian assembly (paper Eq. 2 with explicit A), KKT systems and the
//! Newton fixed point's inner solve.

use super::mat::Mat;

/// LU factorization with partial pivoting: P A = L U.
#[derive(Clone, Debug)]
pub struct Lu {
    /// Packed LU factors (unit lower + upper).
    lu: Mat,
    /// Row permutation.
    piv: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

impl Lu {
    /// Factor A. Returns None if A is numerically singular.
    pub fn factor(a: &Mat) -> Option<Lu> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // Pivot selection.
            let mut pmax = lu.at(k, k).abs();
            let mut prow = k;
            for i in k + 1..n {
                let v = lu.at(i, k).abs();
                if v > pmax {
                    pmax = v;
                    prow = i;
                }
            }
            if pmax < 1e-300 {
                return None;
            }
            if prow != k {
                for j in 0..n {
                    let t = lu.at(k, j);
                    *lu.at_mut(k, j) = lu.at(prow, j);
                    *lu.at_mut(prow, j) = t;
                }
                piv.swap(k, prow);
                sign = -sign;
            }
            let pivot = lu.at(k, k);
            for i in k + 1..n {
                let m = lu.at(i, k) / pivot;
                *lu.at_mut(i, k) = m;
                if m != 0.0 {
                    for j in k + 1..n {
                        *lu.at_mut(i, j) -= m * lu.at(k, j);
                    }
                }
            }
        }
        Some(Lu { lu, piv, sign })
    }

    /// Dimension of the factored (square) matrix.
    pub fn dim(&self) -> usize {
        self.lu.rows
    }

    /// (packed LU factors, row permutation, permutation sign) — the serve
    /// manifest's serialization surface.
    pub fn parts(&self) -> (&Mat, &[usize], f64) {
        (&self.lu, &self.piv, self.sign)
    }

    /// Rebuild a factorization from [`Lu::parts`] output (manifest
    /// warm-start). Returns None unless the shapes form a square matrix with
    /// a valid permutation vector and sign — a corrupt manifest entry must
    /// degrade to a cache miss, never a panic in `solve`.
    pub fn from_parts(lu: Mat, piv: Vec<usize>, sign: f64) -> Option<Lu> {
        let n = lu.rows;
        if lu.cols != n || piv.len() != n || piv.iter().any(|&p| p >= n) {
            return None;
        }
        if sign != 1.0 && sign != -1.0 {
            return None;
        }
        Some(Lu { lu, piv, sign })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        // Apply permutation then forward-substitute (unit lower).
        let mut y: Vec<f64> = (0..n).map(|i| b[self.piv[i]]).collect();
        for i in 1..n {
            let mut s = y[i];
            for k in 0..i {
                s -= self.lu.at(i, k) * y[k];
            }
            y[i] = s;
        }
        // Back-substitute (upper).
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.lu.at(i, k) * y[k];
            }
            y[i] = s / self.lu.at(i, i);
        }
        y
    }

    /// Solve A X = B column-wise.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows, b.cols);
        for j in 0..b.cols {
            let col = self.solve(&b.col(j));
            for i in 0..b.rows {
                *out.at_mut(i, j) = col[i];
            }
        }
        out
    }

    /// Solve Aᵀ x = b (for VJPs: the paper solves Aᵀu = v).
    pub fn solve_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows;
        // Uᵀ y = b (forward, Uᵀ is lower).
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.lu.at(k, i) * y[k];
            }
            y[i] = s / self.lu.at(i, i);
        }
        // Lᵀ z = y (backward, unit diagonal).
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.lu.at(k, i) * y[k];
            }
            y[i] = s;
        }
        // Undo permutation: x[piv[i]] = z[i].
        let mut x = vec![0.0; n];
        for i in 0..n {
            x[self.piv[i]] = y[i];
        }
        x
    }

    /// Solve Aᵀ X = B column-wise (the block version of [`Lu::solve_t`],
    /// used by the factored multi-cotangent VJP path).
    pub fn solve_t_mat(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows, b.cols);
        for j in 0..b.cols {
            let col = self.solve_t(&b.col(j));
            for i in 0..b.rows {
                *out.at_mut(i, j) = col[i];
            }
        }
        out
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows {
            d *= self.lu.at(i, i);
        }
        d
    }
}

/// Single-precision LU with partial pivoting: the general-matrix engine of
/// the mixed-precision direct path (see [`super::chol::CholeskyF32`] for the
/// SPD counterpart). Factors and substitutes in f32; f64-in/f64-out API so
/// the refinement driver in `linalg::solve` wraps it transparently.
#[derive(Clone, Debug)]
pub struct LuF32 {
    /// Packed LU factors (unit lower + upper), n×n row-major, f32 storage.
    lu: Vec<f32>,
    piv: Vec<usize>,
    n: usize,
}

impl LuF32 {
    /// Factor A (rounded to f32). Returns None when a pivot underflows in
    /// f32 — the caller treats that as "mixed precision unavailable".
    pub fn factor(a: &Mat) -> Option<LuF32> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut lu: Vec<f32> = a.data.iter().map(|&v| v as f32).collect();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            let mut pmax = lu[k * n + k].abs();
            let mut prow = k;
            for i in k + 1..n {
                let v = lu[i * n + k].abs();
                if v > pmax {
                    pmax = v;
                    prow = i;
                }
            }
            if !(pmax > 1e-30) || !pmax.is_finite() {
                return None;
            }
            if prow != k {
                for j in 0..n {
                    lu.swap(k * n + j, prow * n + j);
                }
                piv.swap(k, prow);
            }
            let pivot = lu[k * n + k];
            for i in k + 1..n {
                let m = lu[i * n + k] / pivot;
                lu[i * n + k] = m;
                if m != 0.0 {
                    for j in k + 1..n {
                        lu[i * n + j] -= m * lu[k * n + j];
                    }
                }
            }
        }
        Some(LuF32 { lu, piv, n })
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Solve A x ≈ b (f32 substitution; refine in f64 upstream).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut y: Vec<f32> = (0..n).map(|i| b[self.piv[i]] as f32).collect();
        for i in 1..n {
            let mut s = y[i];
            for k in 0..i {
                s -= self.lu[i * n + k] * y[k];
            }
            y[i] = s;
        }
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.lu[i * n + k] * y[k];
            }
            y[i] = s / self.lu[i * n + i];
        }
        y.iter().map(|&v| v as f64).collect()
    }

    /// Solve Aᵀ x ≈ b (f32 substitution, mirroring [`Lu::solve_t`]).
    pub fn solve_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let mut s = b[i] as f32;
            for k in 0..i {
                s -= self.lu[k * n + i] * y[k];
            }
            y[i] = s / self.lu[i * n + i];
        }
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.lu[k * n + i] * y[k];
            }
            y[i] = s;
        }
        let mut x = vec![0.0; n];
        for i in 0..n {
            x[self.piv[i]] = y[i] as f64;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn f32_lu_solves_to_single_precision() {
        let mut rng = Rng::new(21);
        let n = 16;
        let mut a = Mat::randn(n, n, &mut rng);
        for i in 0..n {
            *a.at_mut(i, i) += 4.0;
        }
        let lu = LuF32::factor(&a).unwrap();
        assert_eq!(lu.dim(), n);
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let x = lu.solve(&b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-2, "i={i}");
        }
        let bt = a.matvec_t(&x_true);
        let xt = lu.solve_t(&bt);
        for i in 0..n {
            assert!((xt[i] - x_true[i]).abs() < 1e-2, "t i={i}");
        }
    }

    #[test]
    fn f32_lu_rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(LuF32::factor(&a).is_none());
    }

    #[test]
    fn solve_general_system() {
        let mut rng = Rng::new(1);
        let n = 12;
        let a = Mat::randn(n, n, &mut rng);
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn solve_transposed_system() {
        let mut rng = Rng::new(2);
        let n = 10;
        let a = Mat::randn(n, n, &mut rng);
        let x_true = rng.normal_vec(n);
        let b = a.matvec_t(&x_true);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve_t(&b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn transposed_multi_rhs() {
        let mut rng = Rng::new(4);
        let n = 8;
        let a = Mat::randn(n, n, &mut rng);
        let lu = Lu::factor(&a).unwrap();
        let b = Mat::randn(n, 3, &mut rng);
        let x = lu.solve_t_mat(&b);
        // AᵀX = B
        let atx = a.transpose().matmul(&x);
        for i in 0..b.data.len() {
            assert!((atx.data[i] - b.data[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::factor(&a).is_none());
    }

    #[test]
    fn determinant() {
        let a = Mat::from_vec(2, 2, vec![3.0, 1.0, 4.0, 2.0]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.det() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }
}
