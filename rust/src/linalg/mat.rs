//! Dense row-major matrix with BLAS-like kernels (gemm/gemv/syrk).
//!
//! The gemm is a packed, register-blocked microkernel: B is repacked once
//! into NR-wide column panels, A row panels are packed into contiguous
//! MR×KC scratch, and an MR×NR micro-tile of C is accumulated in registers.
//! Large products are parallelized over row panels of C via
//! [`crate::util::parallel::parallel_chunks_mut`] (disjoint chunks, no
//! locking, no unsafe). This is the crate's single biggest hot spot (SVM
//! objective, logistic regression, Gram matrices, block solves), so it gets
//! perf attention in EXPERIMENTS.md §Perf.

use super::vecops;
use crate::util::parallel;
use crate::util::rng::Rng;

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Copy column j into a caller buffer (multi-RHS blocks store one
    /// right-hand side per column).
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = self.data[i * self.cols + j];
        }
    }

    /// Overwrite column j with `vals`.
    pub fn set_col(&mut self, j: usize, vals: &[f64]) {
        assert_eq!(vals.len(), self.rows);
        let c = self.cols;
        for i in 0..self.rows {
            self.data[i * c + j] = vals[i];
        }
    }

    /// A single vector as a d×1 block (one-column multi-RHS).
    pub fn from_col(v: &[f64]) -> Mat {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into caller buffer. Parallelized over row chunks when the
    /// matrix is large enough to amortize thread spawn.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let n = self.cols;
        let workers = gemv_workers(self.rows, n);
        if workers <= 1 {
            for i in 0..self.rows {
                y[i] = vecops::dot(self.row(i), x);
            }
            return;
        }
        let rows_per = ((self.rows + workers * 2 - 1) / (workers * 2)).max(1);
        let data = &self.data;
        parallel::parallel_chunks_mut(y, rows_per, workers, |ci, ychunk| {
            let r0 = ci * rows_per;
            for (off, yi) in ychunk.iter_mut().enumerate() {
                let i = r0 + off;
                *yi = vecops::dot(&data[i * n..(i + 1) * n], x);
            }
        });
    }

    /// y = Aᵀ x (allocating).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// y = Aᵀ x into caller buffer — row-major friendly (axpy over rows).
    /// Parallelized over disjoint output-column stripes for large matrices.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        let n = self.cols;
        let workers = gemv_workers(self.rows, n);
        if workers <= 1 {
            y.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..self.rows {
                vecops::axpy(x[i], self.row(i), y);
            }
            return;
        }
        let cols_per = ((n + workers * 2 - 1) / (workers * 2)).max(1);
        let data = &self.data;
        let rows = self.rows;
        parallel::parallel_chunks_mut(y, cols_per, workers, |ci, ychunk| {
            let c0 = ci * cols_per;
            let w = ychunk.len();
            ychunk.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..rows {
                let xi = x[i];
                if xi != 0.0 {
                    vecops::axpy(xi, &data[i * n + c0..i * n + c0 + w], ychunk);
                }
            }
        });
    }

    /// C = A · B via the packed parallel gemm.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm_acc(self, b, &mut c);
        c
    }

    /// C = A · B into a caller-provided C (overwritten). The allocation-free
    /// entry point used by block solves and `LinOp::apply_block`.
    pub fn matmul_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        assert_eq!(c.rows, self.rows, "gemm output rows mismatch");
        assert_eq!(c.cols, b.cols, "gemm output cols mismatch");
        c.data.iter_mut().for_each(|v| *v = 0.0);
        gemm_acc(self, b, c);
    }

    /// C = Aᵀ · B without materializing Aᵀ.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.cols, b.cols);
        self.t_matmul_into(b, &mut c);
        c
    }

    /// C = Aᵀ · B into a caller-provided C (overwritten). Parallelized over
    /// disjoint row panels of C (columns of A) for large products.
    pub fn t_matmul_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.rows, b.rows, "tgemm shape mismatch");
        let (m, n, p) = (self.cols, b.cols, self.rows);
        assert_eq!(c.rows, m, "tgemm output rows mismatch");
        assert_eq!(c.cols, n, "tgemm output cols mismatch");
        c.data.iter_mut().for_each(|v| *v = 0.0);
        let workers = gemm_workers(m, n, p);
        if workers <= 1 {
            for k in 0..p {
                let arow = self.row(k);
                let brow = b.row(k);
                for i in 0..m {
                    let aki = arow[i];
                    if aki != 0.0 {
                        vecops::axpy(aki, brow, c.row_mut(i));
                    }
                }
            }
            return;
        }
        let rows_per = ((m + workers * 2 - 1) / (workers * 2)).max(1);
        let adata = &self.data;
        let bdata = &b.data;
        parallel::parallel_chunks_mut(&mut c.data, rows_per * n, workers, |ci, cchunk| {
            let i0 = ci * rows_per;
            let rows = cchunk.len() / n;
            for k in 0..p {
                let arow = &adata[k * m..(k + 1) * m];
                let brow = &bdata[k * n..(k + 1) * n];
                for i in 0..rows {
                    let aki = arow[i0 + i];
                    if aki != 0.0 {
                        vecops::axpy(aki, brow, &mut cchunk[i * n..(i + 1) * n]);
                    }
                }
            }
        });
    }

    /// C = A · Bᵀ without materializing Bᵀ. Parallelized over row panels.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "gemm_t shape mismatch");
        let (m, n, p) = (self.rows, b.rows, self.cols);
        let mut c = Mat::zeros(m, n);
        let workers = gemm_workers(m, n, p);
        if workers <= 1 {
            for i in 0..m {
                for j in 0..n {
                    c.data[i * n + j] = vecops::dot(self.row(i), b.row(j));
                }
            }
            return c;
        }
        let rows_per = ((m + workers * 2 - 1) / (workers * 2)).max(1);
        let adata = &self.data;
        parallel::parallel_chunks_mut(&mut c.data, rows_per * n, workers, |ci, cchunk| {
            let i0 = ci * rows_per;
            let rows = cchunk.len() / n;
            for i in 0..rows {
                let arow = &adata[(i0 + i) * p..(i0 + i + 1) * p];
                for j in 0..n {
                    cchunk[i * n + j] = vecops::dot(arow, b.row(j));
                }
            }
        });
        c
    }

    /// Gram matrix AᵀA (symmetric rank-k update).
    pub fn gram(&self) -> Mat {
        self.t_matmul(self)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::norm2(&self.data)
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        vecops::axpy(alpha, &other.data, &mut self.data);
    }

    /// A + alpha * I (square only).
    pub fn plus_diag(&self, alpha: f64) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out.data[i * self.cols + i] += alpha;
        }
        out
    }
}

/// Micro-tile rows (register-blocked rows of C held in accumulators).
const MR: usize = 4;
/// Micro-tile columns.
const NR: usize = 4;
/// k-blocking depth: one packed A panel is MR×KC ≈ 8 KiB, L1-resident.
const KC: usize = 256;
/// Parallelize a gemm only when it has enough flops to amortize spawning
/// scoped threads (~2·100³).
const GEMM_PAR_FLOPS: f64 = 2e6;
/// Below this flop count (~2·25³) the packed kernel's scratch allocation and
/// pack passes cost more than they save — use the allocation-free fallback.
const GEMM_PACK_FLOPS: f64 = 32768.0;
/// Parallelize a gemv only past ~1M matrix elements.
const GEMV_PAR_ELEMS: usize = 1 << 20;

fn gemm_workers(m: usize, n: usize, p: usize) -> usize {
    if 2.0 * m as f64 * n as f64 * p as f64 >= GEMM_PAR_FLOPS {
        parallel::default_workers()
    } else {
        1
    }
}

fn gemv_workers(rows: usize, cols: usize) -> usize {
    if rows.saturating_mul(cols) >= GEMV_PAR_ELEMS {
        parallel::default_workers()
    } else {
        1
    }
}

/// Pack B (p×n) into NR-wide column panels, k-major within a panel:
/// `bpack[(jb·p + k)·NR + c] = B[k][jb·NR + c]`, zero-padded in the last
/// panel. One pass over B (O(pn), negligible next to the O(mpn) flops) buys
/// unit-stride loads in the microkernel for every row panel of C.
fn pack_b(b: &Mat, bpack: &mut Vec<f64>) {
    let (p, n) = (b.rows, b.cols);
    let nb = (n + NR - 1) / NR;
    bpack.clear();
    bpack.resize(nb * p * NR, 0.0);
    for jb in 0..nb {
        let j0 = jb * NR;
        let w = NR.min(n - j0);
        let base = jb * p * NR;
        for k in 0..p {
            let dst = base + k * NR;
            bpack[dst..dst + w].copy_from_slice(&b.data[k * n + j0..k * n + j0 + w]);
        }
    }
}

/// MR×NR register-blocked microkernel: acc += apanel·bpanel over kc steps.
/// apanel is k-major MR-wide, bpanel is k-major NR-wide; the constant-bound
/// inner loops unroll into MR·NR independent accumulators.
#[inline(always)]
fn micro_kernel(apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    for (ak, bk) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        for r in 0..MR {
            let a = ak[r];
            for c in 0..NR {
                acc[r][c] += a * bk[c];
            }
        }
    }
}

/// Accumulate one row panel of C (rows i0..i0+rows, given as the mutable
/// slice `cchunk`) against all of packed B.
fn gemm_chunk(a: &Mat, bpack: &[f64], n: usize, i0: usize, cchunk: &mut [f64]) {
    let p = a.cols;
    let rows = cchunk.len() / n;
    let nb = (n + NR - 1) / NR;
    let mut apack = vec![0.0; MR * KC];
    for k0 in (0..p).step_by(KC) {
        let kc = KC.min(p - k0);
        let mut ib = 0;
        while ib < rows {
            let mr = MR.min(rows - ib);
            // Pack A rows i0+ib..+mr over columns k0..k0+kc (k-major,
            // zero-padding the missing micro-tile rows).
            for r in 0..MR {
                if r < mr {
                    let arow = &a.data[(i0 + ib + r) * p + k0..(i0 + ib + r) * p + k0 + kc];
                    for (k, &v) in arow.iter().enumerate() {
                        apack[k * MR + r] = v;
                    }
                } else {
                    for k in 0..kc {
                        apack[k * MR + r] = 0.0;
                    }
                }
            }
            for jb in 0..nb {
                let j0 = jb * NR;
                let w = NR.min(n - j0);
                let bpanel = &bpack[(jb * p + k0) * NR..(jb * p + k0 + kc) * NR];
                let mut acc = [[0.0f64; NR]; MR];
                micro_kernel(&apack[..kc * MR], bpanel, &mut acc);
                for r in 0..mr {
                    let crow = &mut cchunk[(ib + r) * n + j0..(ib + r) * n + j0 + w];
                    for (cv, av) in crow.iter_mut().zip(acc[r].iter()) {
                        *cv += *av;
                    }
                }
            }
            ib += mr;
        }
    }
}

/// C += A · B — packed, register-blocked gemm, parallelized over disjoint
/// row panels of C when the product is large enough to amortize thread
/// spawn. Exact same contraction order per element as the naive triple loop
/// up to floating-point reassociation within a micro-tile.
pub fn gemm_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, p, n) = (a.rows, a.cols, b.cols);
    assert_eq!(p, b.rows, "gemm shape mismatch");
    assert_eq!(c.rows, m, "gemm output rows mismatch");
    assert_eq!(c.cols, n, "gemm output cols mismatch");
    if m == 0 || n == 0 || p == 0 {
        return;
    }
    // Tiny products (e.g. the p×p ridge blocks inside per-iteration block-CG
    // operator applications) skip packing entirely: the allocation + pack
    // pass costs more than it saves below this size. This is the seed's
    // allocation-free i-k-j axpy kernel.
    if 2.0 * m as f64 * n as f64 * p as f64 < GEMM_PACK_FLOPS {
        for i in 0..m {
            let (arow, crow) = (i * p, i * n);
            for k in 0..p {
                let aik = a.data[arow + k];
                if aik != 0.0 {
                    vecops::axpy(aik, &b.data[k * n..(k + 1) * n], &mut c.data[crow..crow + n]);
                }
            }
        }
        return;
    }
    let mut bpack = Vec::new();
    pack_b(b, &mut bpack);
    let workers = gemm_workers(m, n, p);
    if workers <= 1 {
        gemm_chunk(a, &bpack, n, 0, &mut c.data);
        return;
    }
    // MR-aligned row panels, ≥2 per worker for load balance.
    let target = (m + workers * 2 - 1) / (workers * 2);
    let rows_per = ((target + MR - 1) / MR * MR).max(MR);
    parallel::parallel_chunks_mut(&mut c.data, rows_per * n, workers, |ci, cchunk| {
        gemm_chunk(a, &bpack, n, ci * rows_per, cchunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, p, n) in &[(3usize, 4usize, 5usize), (17, 33, 9), (64, 65, 66), (1, 7, 1)] {
            let a = Mat::randn(m, p, &mut rng);
            let b = Mat::randn(p, n, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            for i in 0..c.data.len() {
                assert!((c.data[i] - c0.data[i]).abs() < 1e-9);
            }
        }
    }

    /// Packed parallel gemm property test: every non-multiple-of-tile shape,
    /// degenerate 1×n / n×1 products, KC-straddling depths, and shapes big
    /// enough to cross the parallel threshold must all match the naive
    /// triple loop.
    #[test]
    fn packed_gemm_matches_naive_on_awkward_shapes() {
        let mut rng = Rng::new(7);
        let shapes: &[(usize, usize, usize)] = &[
            (1, 300, 1),   // single row × single col (allocation-free fallback)
            (300, 1, 5),   // rank-1 outer product (fallback)
            (1, 1, 1),
            (5, 3, 1),     // single output column (fallback)
            (1, 9, 13),    // single output row (fallback)
            (13, 11, 17),  // nothing divides MR/NR (fallback)
            (1, 2000, 9),  // packed: single-row micro-tile, KC straddles, partial NR
            (601, 28, 1),  // packed: single output column, MR-remainder panel
            (7, 515, 9),   // packed: depth straddles two KC blocks
            (130, 120, 110), // crosses GEMM_PAR_FLOPS → parallel row panels
            (257, 64, 66), // parallel with MR-remainder last panel
        ];
        for &(m, p, n) in shapes {
            let a = Mat::randn(m, p, &mut rng);
            let b = Mat::randn(p, n, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            let scale = (p as f64).sqrt();
            for i in 0..c.data.len() {
                assert!(
                    (c.data[i] - c0.data[i]).abs() < 1e-10 * scale.max(1.0) * 10.0,
                    "shape ({m},{p},{n}) element {i}: {} vs {}",
                    c.data[i],
                    c0.data[i]
                );
            }
        }
    }

    #[test]
    fn matmul_into_overwrites_stale_output() {
        let mut rng = Rng::new(8);
        let a = Mat::randn(9, 6, &mut rng);
        let b = Mat::randn(6, 4, &mut rng);
        let mut c = Mat::from_fn(9, 4, |i, j| (i + j) as f64); // stale garbage
        a.matmul_into(&b, &mut c);
        let c0 = naive_matmul(&a, &b);
        for i in 0..c.data.len() {
            assert!((c.data[i] - c0.data[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_transpose_products_match_serial() {
        // Shapes past the parallel threshold for t_matmul / matmul_t.
        let mut rng = Rng::new(9);
        let a = Mat::randn(120, 115, &mut rng);
        let b = Mat::randn(120, 105, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = naive_matmul(&a.transpose(), &b);
        for i in 0..c1.data.len() {
            assert!((c1.data[i] - c2.data[i]).abs() < 1e-9);
        }
        let d = Mat::randn(110, 115, &mut rng);
        let e1 = a.matmul_t(&d);
        let e2 = naive_matmul(&a, &d.transpose());
        for i in 0..e1.data.len() {
            assert!((e1.data[i] - e2.data[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn col_helpers_roundtrip() {
        let mut rng = Rng::new(10);
        let mut m = Mat::randn(7, 3, &mut rng);
        let mut buf = vec![0.0; 7];
        m.col_into(1, &mut buf);
        assert_eq!(buf, m.col(1));
        let vals: Vec<f64> = (0..7).map(|i| i as f64).collect();
        m.set_col(2, &vals);
        assert_eq!(m.col(2), vals);
        let c = Mat::from_col(&vals);
        assert_eq!((c.rows, c.cols), (7, 1));
        assert_eq!(c.col(0), vals);
    }

    #[test]
    fn transpose_matmuls_consistent() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(13, 7, &mut rng);
        let b = Mat::randn(13, 5, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        for i in 0..c1.data.len() {
            assert!((c1.data[i] - c2.data[i]).abs() < 1e-10);
        }
        let d = Mat::randn(4, 7, &mut rng);
        let e1 = a.matmul_t(&d);
        let e2 = a.matmul(&d.transpose());
        for i in 0..e1.data.len() {
            assert!((e1.data[i] - e2.data[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn matvecs() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(10, 6, &mut rng);
        let g = a.gram();
        assert_eq!(g.rows, 6);
        for i in 0..6 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..6 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn eye_and_plus_diag() {
        let i3 = Mat::eye(3);
        let x = [1.0, 2.0, 3.0];
        assert_eq!(i3.matvec(&x), x.to_vec());
        let shifted = Mat::zeros(2, 2).plus_diag(5.0);
        assert_eq!(shifted.at(0, 0), 5.0);
        assert_eq!(shifted.at(0, 1), 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(5, 8, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }
}
