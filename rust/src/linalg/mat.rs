//! Dense row-major matrix with BLAS-like kernels (gemm/gemv/syrk).
//!
//! The gemm is a packed, register-blocked microkernel: B is repacked once
//! into NR-wide column panels, A row panels are packed into contiguous
//! MR×KC scratch, and an MR×NR micro-tile of C is accumulated in registers.
//! The microkernel is selected at runtime: on x86-64 with AVX2+FMA the
//! 8×4 / 4×8 vector kernels (`unsafe` intrinsics behind
//! `is_x86_feature_detected!`) compete with the portable 4×4 scalar kernel
//! in a one-time autotune pass over a small (kernel × KC) candidate grid;
//! the winner is cached process-wide ([`gemm_config`]). Large products are
//! parallelized over row panels of C via
//! [`crate::util::parallel::parallel_chunks_mut`] (disjoint chunks, no
//! locking). `t_matmul`/`gram` route through the same packed kernels by
//! packing Aᵀ panels in place (no transpose materialization). This is the
//! crate's single biggest hot spot (SVM objective, logistic regression,
//! Gram matrices, block solves), so it gets perf attention in
//! EXPERIMENTS.md §Perf and §Kernels.

use super::vecops;
use crate::util::parallel;
use crate::util::rng::Rng;
use std::sync::OnceLock;

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Copy column j into a caller buffer (multi-RHS blocks store one
    /// right-hand side per column).
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = self.data[i * self.cols + j];
        }
    }

    /// Overwrite column j with `vals`.
    pub fn set_col(&mut self, j: usize, vals: &[f64]) {
        assert_eq!(vals.len(), self.rows);
        let c = self.cols;
        for i in 0..self.rows {
            self.data[i * c + j] = vals[i];
        }
    }

    /// A single vector as a d×1 block (one-column multi-RHS).
    pub fn from_col(v: &[f64]) -> Mat {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into caller buffer. Parallelized over row chunks when the
    /// matrix is large enough to amortize thread spawn. Worker rows use the
    /// serial dot (no nested thread spawn inside a parallel region).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let n = self.cols;
        let workers = gemv_workers(self.rows, n);
        if workers <= 1 {
            for i in 0..self.rows {
                y[i] = vecops::dot(self.row(i), x);
            }
            return;
        }
        let rows_per = ((self.rows + workers * 2 - 1) / (workers * 2)).max(1);
        let data = &self.data;
        parallel::parallel_chunks_mut(y, rows_per, workers, |ci, ychunk| {
            let r0 = ci * rows_per;
            for (off, yi) in ychunk.iter_mut().enumerate() {
                let i = r0 + off;
                *yi = vecops::dot_serial(&data[i * n..(i + 1) * n], x);
            }
        });
    }

    /// y = Aᵀ x (allocating).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// y = Aᵀ x into caller buffer — row-major friendly (axpy over rows).
    /// Parallelized over disjoint output-column stripes for large matrices.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        let n = self.cols;
        let workers = gemv_workers(self.rows, n);
        if workers <= 1 {
            y.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..self.rows {
                vecops::axpy(x[i], self.row(i), y);
            }
            return;
        }
        let cols_per = ((n + workers * 2 - 1) / (workers * 2)).max(1);
        let data = &self.data;
        let rows = self.rows;
        parallel::parallel_chunks_mut(y, cols_per, workers, |ci, ychunk| {
            let c0 = ci * cols_per;
            let w = ychunk.len();
            ychunk.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..rows {
                let xi = x[i];
                if xi != 0.0 {
                    vecops::axpy_serial(xi, &data[i * n + c0..i * n + c0 + w], ychunk);
                }
            }
        });
    }

    /// C = A · B via the packed parallel gemm.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm_acc(self, b, &mut c);
        c
    }

    /// C = A · B into a caller-provided C (overwritten). The allocation-free
    /// entry point used by block solves and `LinOp::apply_block`.
    pub fn matmul_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        assert_eq!(c.rows, self.rows, "gemm output rows mismatch");
        assert_eq!(c.cols, b.cols, "gemm output cols mismatch");
        c.data.iter_mut().for_each(|v| *v = 0.0);
        gemm_acc(self, b, c);
    }

    /// C = A · B with a forced kernel configuration (bench/test hook: lets
    /// the perf harness pit the autotuned SIMD kernel against the scalar
    /// one on the same shapes). Always takes the packed path.
    pub fn matmul_cfg(&self, b: &Mat, cfg: GemmConfig) -> Mat {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        if self.rows == 0 || b.cols == 0 || self.cols == 0 {
            return c;
        }
        gemm_packed(self, b, &mut c, false, cfg);
        c
    }

    /// C = Aᵀ · B without materializing Aᵀ.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.cols, b.cols);
        self.t_matmul_into(b, &mut c);
        c
    }

    /// C = Aᵀ · B into a caller-provided C (overwritten). Routed through the
    /// packed (SIMD) gemm for non-tiny products — the A panels are packed
    /// straight from the transposed access pattern, so Aᵀ is never
    /// materialized. Tiny products keep the allocation-free axpy loop.
    pub fn t_matmul_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.rows, b.rows, "tgemm shape mismatch");
        let (m, n, p) = (self.cols, b.cols, self.rows);
        assert_eq!(c.rows, m, "tgemm output rows mismatch");
        assert_eq!(c.cols, n, "tgemm output cols mismatch");
        c.data.iter_mut().for_each(|v| *v = 0.0);
        if m == 0 || n == 0 || p == 0 {
            return;
        }
        if 2.0 * m as f64 * n as f64 * p as f64 >= GEMM_PACK_FLOPS {
            gemm_packed(self, b, c, true, gemm_config());
            return;
        }
        for k in 0..p {
            let arow = self.row(k);
            let brow = b.row(k);
            for i in 0..m {
                let aki = arow[i];
                if aki != 0.0 {
                    vecops::axpy(aki, brow, c.row_mut(i));
                }
            }
        }
    }

    /// C = A · Bᵀ without materializing Bᵀ. Parallelized over row panels.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "gemm_t shape mismatch");
        let (m, n, p) = (self.rows, b.rows, self.cols);
        let mut c = Mat::zeros(m, n);
        let workers = gemm_workers(m, n, p);
        if workers <= 1 {
            for i in 0..m {
                for j in 0..n {
                    c.data[i * n + j] = vecops::dot(self.row(i), b.row(j));
                }
            }
            return c;
        }
        let rows_per = ((m + workers * 2 - 1) / (workers * 2)).max(1);
        let adata = &self.data;
        parallel::parallel_chunks_mut(&mut c.data, rows_per * n, workers, |ci, cchunk| {
            let i0 = ci * rows_per;
            let rows = cchunk.len() / n;
            for i in 0..rows {
                let arow = &adata[(i0 + i) * p..(i0 + i + 1) * p];
                for j in 0..n {
                    cchunk[i * n + j] = vecops::dot_serial(arow, b.row(j));
                }
            }
        });
        c
    }

    /// Gram matrix AᵀA (symmetric rank-k update).
    pub fn gram(&self) -> Mat {
        self.t_matmul(self)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::norm2(&self.data)
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        vecops::axpy(alpha, &other.data, &mut self.data);
    }

    /// A + alpha * I (square only).
    pub fn plus_diag(&self, alpha: f64) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out.data[i * self.cols + i] += alpha;
        }
        out
    }
}

/// Parallelize a gemm only when it has enough flops to amortize spawning
/// scoped threads (~2·100³).
const GEMM_PAR_FLOPS: f64 = 2e6;
/// Below this flop count (~2·25³) the packed kernel's scratch allocation and
/// pack passes cost more than they save — use the allocation-free fallback.
const GEMM_PACK_FLOPS: f64 = 32768.0;
/// Parallelize a gemv only past ~1M matrix elements.
const GEMV_PAR_ELEMS: usize = 1 << 20;

fn gemm_workers(m: usize, n: usize, p: usize) -> usize {
    if 2.0 * m as f64 * n as f64 * p as f64 >= GEMM_PAR_FLOPS {
        parallel::default_workers()
    } else {
        1
    }
}

fn gemv_workers(rows: usize, cols: usize) -> usize {
    if rows.saturating_mul(cols) >= GEMV_PAR_ELEMS {
        parallel::default_workers()
    } else {
        1
    }
}

// ---------------------------------------------------------------------------
// Runtime-selected microkernel + autotuner
// ---------------------------------------------------------------------------

/// Which register-blocked microkernel the packed gemm runs. The AVX2
/// variants only exist on x86-64 and are only ever *selected* when
/// `is_x86_feature_detected!` confirms avx2+fma at runtime, which is what
/// makes the `unsafe` `#[target_feature]` calls sound.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable 4×4 scalar kernel (the pre-SIMD kernel; compiler-vectorized).
    Scalar4x4,
    /// AVX2+FMA 8×4: eight ymm accumulators, broadcast-A · load-B fmadd.
    #[cfg(target_arch = "x86_64")]
    Avx2_8x4,
    /// AVX2+FMA 4×8: 4 rows × two ymm column halves (wider B reuse).
    #[cfg(target_arch = "x86_64")]
    Avx2_4x8,
}

impl KernelKind {
    pub fn mr(self) -> usize {
        match self {
            KernelKind::Scalar4x4 => 4,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_8x4 => 8,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_4x8 => 4,
        }
    }

    pub fn nr(self) -> usize {
        match self {
            KernelKind::Scalar4x4 => 4,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_8x4 => 4,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_4x8 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar4x4 => "scalar-4x4",
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_8x4 => "avx2-8x4",
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_4x8 => "avx2-4x8",
        }
    }

    /// acc (mr·nr row-major) = apanel · bpanel over kc depth steps. apanel is
    /// k-major mr-wide, bpanel k-major nr-wide; acc is overwritten.
    #[inline]
    fn run(self, apanel: &[f64], bpanel: &[f64], kc: usize, acc: &mut [f64]) {
        match self {
            KernelKind::Scalar4x4 => mk_scalar_4x4(apanel, bpanel, kc, acc),
            // SAFETY: these variants are only constructed after
            // `is_x86_feature_detected!("avx2")` && `("fma")` returned true
            // (see `kernel_candidates` / `parse_kernel_name`).
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_8x4 => unsafe { mk_avx2_8x4(apanel, bpanel, kc, acc) },
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2_4x8 => unsafe { mk_avx2_4x8(apanel, bpanel, kc, acc) },
        }
    }
}

/// The (kernel, MR, NR, KC) tuple the packed gemm runs with. MR/NR are
/// redundant with the kernel but kept explicit so callers (benches, CI logs)
/// can report the tile without matching on the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmConfig {
    pub kernel: KernelKind,
    pub mr: usize,
    pub nr: usize,
    pub kc: usize,
}

impl GemmConfig {
    pub fn of(kernel: KernelKind, kc: usize) -> GemmConfig {
        GemmConfig { kernel, mr: kernel.mr(), nr: kernel.nr(), kc: kc.max(1) }
    }

    /// The portable scalar config (bench baseline).
    pub fn scalar() -> GemmConfig {
        GemmConfig::of(KernelKind::Scalar4x4, 256)
    }
}

impl std::fmt::Display for GemmConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} MR={} NR={} KC={}", self.kernel.name(), self.mr, self.nr, self.kc)
    }
}

/// The SIMD capability tier the running CPU supports (for CI/bench logs).
pub fn simd_tier() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return "avx2+fma";
        }
    }
    "scalar"
}

fn kernel_candidates() -> Vec<KernelKind> {
    #[allow(unused_mut)]
    let mut ks = vec![KernelKind::Scalar4x4];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            ks.push(KernelKind::Avx2_8x4);
            ks.push(KernelKind::Avx2_4x8);
        }
    }
    ks
}

/// Map `IDIFF_GEMM_KERNEL` to a kernel, refusing SIMD names the CPU cannot
/// run (so a stale env var cannot cause an unsound dispatch).
fn parse_kernel_name(name: &str) -> Option<KernelKind> {
    kernel_candidates().into_iter().find(|k| k.name() == name)
}

static GEMM_CONFIG: OnceLock<GemmConfig> = OnceLock::new();

/// KC depths the autotuner tries (packed A panel = MR·KC·8 bytes; all three
/// keep the panel L1/L2-resident).
pub const AUTOTUNE_KCS: [usize; 3] = [128, 256, 512];
/// Problem edge for the autotune probe (~2·160³ = 8 Mflop per rep — big
/// enough to rank kernels, small enough that first use pays < ~100 ms once).
const AUTOTUNE_N: usize = 160;

/// The process-wide gemm configuration: autotuned on first use over the
/// (available kernels × [`AUTOTUNE_KCS`]) grid, overridable via the
/// `IDIFF_GEMM_KERNEL` (= a [`KernelKind::name`]) and `IDIFF_GEMM_KC` env
/// vars for A/B runs.
pub fn gemm_config() -> GemmConfig {
    *GEMM_CONFIG.get_or_init(autotune)
}

fn autotune() -> GemmConfig {
    let env_kc = std::env::var("IDIFF_GEMM_KC").ok().and_then(|s| s.parse::<usize>().ok());
    if let Ok(name) = std::env::var("IDIFF_GEMM_KERNEL") {
        if let Some(kernel) = parse_kernel_name(&name) {
            return GemmConfig::of(kernel, env_kc.unwrap_or(256));
        }
    }
    let n = AUTOTUNE_N;
    // Deterministic fill — the autotuner must not perturb any user RNG.
    let a = Mat::from_fn(n, n, |i, j| ((i * 31 + j * 7) % 16) as f64 * 0.0625 - 0.5);
    let b = Mat::from_fn(n, n, |i, j| ((i * 13 + j * 29) % 16) as f64 * 0.0625 - 0.5);
    let mut c = Mat::zeros(n, n);
    let mut best: Option<(GemmConfig, f64)> = None;
    for kernel in kernel_candidates() {
        for &kc in &AUTOTUNE_KCS {
            if let Some(forced) = env_kc {
                if kc != forced {
                    continue;
                }
            }
            let cfg = GemmConfig::of(kernel, kc);
            let mut bpack = Vec::new();
            pack_b(&b, cfg.nr, &mut bpack);
            // One warmup rep, then best-of-2 (min filters scheduler noise).
            let mut min_s = f64::INFINITY;
            for rep in 0..3 {
                c.data.iter_mut().for_each(|v| *v = 0.0);
                let t = std::time::Instant::now();
                gemm_chunk(&a, false, &bpack, n, 0, &mut c.data, cfg);
                let dt = t.elapsed().as_secs_f64();
                if rep > 0 {
                    min_s = min_s.min(dt);
                }
            }
            if best.map_or(true, |(_, t)| min_s < t) {
                best = Some((cfg, min_s));
            }
        }
    }
    best.map(|(cfg, _)| cfg).unwrap_or_else(GemmConfig::scalar)
}

// ---------------------------------------------------------------------------
// Microkernels
// ---------------------------------------------------------------------------

/// Portable 4×4 kernel: the constant-bound loops unroll into 16 independent
/// accumulators the compiler keeps in registers (and auto-vectorizes where
/// the target allows).
fn mk_scalar_4x4(apanel: &[f64], bpanel: &[f64], kc: usize, acc: &mut [f64]) {
    debug_assert!(apanel.len() >= kc * 4 && bpanel.len() >= kc * 4 && acc.len() >= 16);
    let mut t = [[0.0f64; 4]; 4];
    for (ak, bk) in apanel[..kc * 4].chunks_exact(4).zip(bpanel[..kc * 4].chunks_exact(4)) {
        for r in 0..4 {
            let a = ak[r];
            for c in 0..4 {
                t[r][c] += a * bk[c];
            }
        }
    }
    for r in 0..4 {
        acc[r * 4..r * 4 + 4].copy_from_slice(&t[r]);
    }
}

/// AVX2+FMA 8×4 kernel: one ymm per C row (8 accumulators), A broadcast,
/// B loaded once per k step.
///
/// # Safety
/// Caller must have verified avx2+fma via `is_x86_feature_detected!`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mk_avx2_8x4(apanel: &[f64], bpanel: &[f64], kc: usize, acc: &mut [f64]) {
    use std::arch::x86_64::*;
    debug_assert!(apanel.len() >= kc * 8 && bpanel.len() >= kc * 4 && acc.len() >= 32);
    let mut c: [__m256d; 8] = [_mm256_setzero_pd(); 8];
    let mut ap = apanel.as_ptr();
    let mut bp = bpanel.as_ptr();
    for _ in 0..kc {
        let bk = _mm256_loadu_pd(bp);
        for r in 0..8 {
            c[r] = _mm256_fmadd_pd(_mm256_set1_pd(*ap.add(r)), bk, c[r]);
        }
        ap = ap.add(8);
        bp = bp.add(4);
    }
    for r in 0..8 {
        _mm256_storeu_pd(acc.as_mut_ptr().add(r * 4), c[r]);
    }
}

/// AVX2+FMA 4×8 kernel: 4 C rows × two ymm column halves (8 accumulators,
/// each B load reused across 4 rows).
///
/// # Safety
/// Caller must have verified avx2+fma via `is_x86_feature_detected!`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mk_avx2_4x8(apanel: &[f64], bpanel: &[f64], kc: usize, acc: &mut [f64]) {
    use std::arch::x86_64::*;
    debug_assert!(apanel.len() >= kc * 4 && bpanel.len() >= kc * 8 && acc.len() >= 32);
    let mut c: [[__m256d; 2]; 4] = [[_mm256_setzero_pd(); 2]; 4];
    let mut ap = apanel.as_ptr();
    let mut bp = bpanel.as_ptr();
    for _ in 0..kc {
        let b0 = _mm256_loadu_pd(bp);
        let b1 = _mm256_loadu_pd(bp.add(4));
        for r in 0..4 {
            let a = _mm256_set1_pd(*ap.add(r));
            c[r][0] = _mm256_fmadd_pd(a, b0, c[r][0]);
            c[r][1] = _mm256_fmadd_pd(a, b1, c[r][1]);
        }
        ap = ap.add(4);
        bp = bp.add(8);
    }
    for r in 0..4 {
        _mm256_storeu_pd(acc.as_mut_ptr().add(r * 8), c[r][0]);
        _mm256_storeu_pd(acc.as_mut_ptr().add(r * 8 + 4), c[r][1]);
    }
}

// ---------------------------------------------------------------------------
// Packed gemm driver
// ---------------------------------------------------------------------------

/// Pack B (p×n) into `nr`-wide column panels, k-major within a panel:
/// `bpack[(jb·p + k)·nr + c] = B[k][jb·nr + c]`, zero-padded in the last
/// panel. One pass over B (O(pn), negligible next to the O(mpn) flops) buys
/// unit-stride loads in the microkernel for every row panel of C.
fn pack_b(b: &Mat, nr: usize, bpack: &mut Vec<f64>) {
    let (p, n) = (b.rows, b.cols);
    let nb = (n + nr - 1) / nr;
    bpack.clear();
    bpack.resize(nb * p * nr, 0.0);
    for jb in 0..nb {
        let j0 = jb * nr;
        let w = nr.min(n - j0);
        let base = jb * p * nr;
        for k in 0..p {
            let dst = base + k * nr;
            bpack[dst..dst + w].copy_from_slice(&b.data[k * n + j0..k * n + j0 + w]);
        }
    }
}

/// Accumulate one row panel of C (rows i0..i0+rows, given as the mutable
/// slice `cchunk`) against all of packed B. When `trans_a` is set, `a` holds
/// the *transpose* of the logical A (p×m physical for an m×p logical A) and
/// the pack reads it column-wise — Aᵀ·B without materializing Aᵀ.
fn gemm_chunk(a: &Mat, trans_a: bool, bpack: &[f64], n: usize, i0: usize, cchunk: &mut [f64], cfg: GemmConfig) {
    let (mr, nr, kcb) = (cfg.mr, cfg.nr, cfg.kc);
    let p = if trans_a { a.rows } else { a.cols };
    let rows = cchunk.len() / n;
    let nb = (n + nr - 1) / nr;
    let mut apack = vec![0.0; mr * kcb];
    let mut acc = vec![0.0; mr * nr];
    for k0 in (0..p).step_by(kcb) {
        let kc = kcb.min(p - k0);
        let mut ib = 0;
        while ib < rows {
            let mrv = mr.min(rows - ib);
            // Pack A rows i0+ib..+mrv over depth k0..k0+kc (k-major,
            // zero-padding the missing micro-tile rows).
            for r in 0..mr {
                if r < mrv {
                    let i = i0 + ib + r;
                    if trans_a {
                        for k in 0..kc {
                            apack[k * mr + r] = a.data[(k0 + k) * a.cols + i];
                        }
                    } else {
                        let arow = &a.data[i * p + k0..i * p + k0 + kc];
                        for (k, &v) in arow.iter().enumerate() {
                            apack[k * mr + r] = v;
                        }
                    }
                } else {
                    for k in 0..kc {
                        apack[k * mr + r] = 0.0;
                    }
                }
            }
            for jb in 0..nb {
                let j0 = jb * nr;
                let w = nr.min(n - j0);
                let bpanel = &bpack[(jb * p + k0) * nr..(jb * p + k0 + kc) * nr];
                cfg.kernel.run(&apack[..kc * mr], bpanel, kc, &mut acc);
                for r in 0..mrv {
                    let crow = &mut cchunk[(ib + r) * n + j0..(ib + r) * n + j0 + w];
                    for (cv, av) in crow.iter_mut().zip(acc[r * nr..r * nr + w].iter()) {
                        *cv += *av;
                    }
                }
            }
            ib += mrv;
        }
    }
}

/// C += A·B (or Aᵀ·B when `trans_a`) through the packed kernel, parallelized
/// over MR-aligned row panels of C past the flop threshold.
fn gemm_packed(a: &Mat, b: &Mat, c: &mut Mat, trans_a: bool, cfg: GemmConfig) {
    let (m, p) = if trans_a { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let n = b.cols;
    let mut bpack = Vec::new();
    pack_b(b, cfg.nr, &mut bpack);
    let workers = gemm_workers(m, n, p);
    if workers <= 1 {
        gemm_chunk(a, trans_a, &bpack, n, 0, &mut c.data, cfg);
        return;
    }
    // MR-aligned row panels, ≥2 per worker for load balance.
    let target = (m + workers * 2 - 1) / (workers * 2);
    let rows_per = ((target + cfg.mr - 1) / cfg.mr * cfg.mr).max(cfg.mr);
    parallel::parallel_chunks_mut(&mut c.data, rows_per * n, workers, |ci, cchunk| {
        gemm_chunk(a, trans_a, &bpack, n, ci * rows_per, cchunk, cfg);
    });
}

/// C += A · B — packed, register-blocked, autotuned (SIMD where available)
/// gemm, parallelized over disjoint row panels of C when the product is
/// large enough to amortize thread spawn. Exact same contraction order per
/// element as the naive triple loop up to floating-point reassociation
/// within a micro-tile.
pub fn gemm_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, p, n) = (a.rows, a.cols, b.cols);
    assert_eq!(p, b.rows, "gemm shape mismatch");
    assert_eq!(c.rows, m, "gemm output rows mismatch");
    assert_eq!(c.cols, n, "gemm output cols mismatch");
    if m == 0 || n == 0 || p == 0 {
        return;
    }
    // Tiny products (e.g. the p×p ridge blocks inside per-iteration block-CG
    // operator applications) skip packing entirely: the allocation + pack
    // pass costs more than it saves below this size. This is the seed's
    // allocation-free i-k-j axpy kernel.
    if 2.0 * m as f64 * n as f64 * p as f64 < GEMM_PACK_FLOPS {
        for i in 0..m {
            let (arow, crow) = (i * p, i * n);
            for k in 0..p {
                let aik = a.data[arow + k];
                if aik != 0.0 {
                    vecops::axpy(aik, &b.data[k * n..(k + 1) * n], &mut c.data[crow..crow + n]);
                }
            }
        }
        return;
    }
    gemm_packed(a, b, c, false, gemm_config());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, p, n) in &[(3usize, 4usize, 5usize), (17, 33, 9), (64, 65, 66), (1, 7, 1)] {
            let a = Mat::randn(m, p, &mut rng);
            let b = Mat::randn(p, n, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            for i in 0..c.data.len() {
                assert!((c.data[i] - c0.data[i]).abs() < 1e-9);
            }
        }
    }

    /// Packed parallel gemm property test: every non-multiple-of-tile shape,
    /// degenerate 1×n / n×1 products, KC-straddling depths, and shapes big
    /// enough to cross the parallel threshold must all match the naive
    /// triple loop.
    #[test]
    fn packed_gemm_matches_naive_on_awkward_shapes() {
        let mut rng = Rng::new(7);
        let shapes: &[(usize, usize, usize)] = &[
            (1, 300, 1),   // single row × single col (allocation-free fallback)
            (300, 1, 5),   // rank-1 outer product (fallback)
            (1, 1, 1),
            (5, 3, 1),     // single output column (fallback)
            (1, 9, 13),    // single output row (fallback)
            (13, 11, 17),  // nothing divides MR/NR (fallback)
            (1, 2000, 9),  // packed: single-row micro-tile, KC straddles, partial NR
            (601, 28, 1),  // packed: single output column, MR-remainder panel
            (7, 515, 9),   // packed: depth straddles two KC blocks
            (130, 120, 110), // crosses GEMM_PAR_FLOPS → parallel row panels
            (257, 64, 66), // parallel with MR-remainder last panel
        ];
        for &(m, p, n) in shapes {
            let a = Mat::randn(m, p, &mut rng);
            let b = Mat::randn(p, n, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            let scale = (p as f64).sqrt();
            for i in 0..c.data.len() {
                assert!(
                    (c.data[i] - c0.data[i]).abs() < 1e-10 * scale.max(1.0) * 10.0,
                    "shape ({m},{p},{n}) element {i}: {} vs {}",
                    c.data[i],
                    c0.data[i]
                );
            }
        }
    }

    /// Every *available* kernel (scalar everywhere; the AVX2 pair on CPUs
    /// that have it) must agree with the naive loop on tail-heavy shapes,
    /// across every autotune KC — the SIMD paths are not allowed to diverge
    /// from the scalar semantics.
    #[test]
    fn every_kernel_candidate_matches_naive() {
        let mut rng = Rng::new(21);
        let shapes: &[(usize, usize, usize)] =
            &[(1, 1, 1), (3, 5, 2), (9, 130, 11), (33, 257, 17), (70, 70, 70)];
        for kernel in kernel_candidates() {
            for &kc in &AUTOTUNE_KCS {
                let cfg = GemmConfig::of(kernel, kc);
                for &(m, p, n) in shapes {
                    let a = Mat::randn(m, p, &mut rng);
                    let b = Mat::randn(p, n, &mut rng);
                    let c = a.matmul_cfg(&b, cfg);
                    let c0 = naive_matmul(&a, &b);
                    for i in 0..c.data.len() {
                        assert!(
                            (c.data[i] - c0.data[i]).abs() < 1e-9,
                            "kernel {} kc={} shape ({m},{p},{n}) el {i}: {} vs {}",
                            kernel.name(),
                            kc,
                            c.data[i],
                            c0.data[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn autotuner_returns_consistent_config() {
        let cfg = gemm_config();
        assert_eq!(cfg.mr, cfg.kernel.mr());
        assert_eq!(cfg.nr, cfg.kernel.nr());
        assert!(AUTOTUNE_KCS.contains(&cfg.kc) || std::env::var("IDIFF_GEMM_KC").is_ok());
        // Second call returns the cached winner.
        assert_eq!(gemm_config(), cfg);
        assert!(!simd_tier().is_empty());
        assert!(!format!("{cfg}").is_empty());
    }

    #[test]
    fn matmul_into_overwrites_stale_output() {
        let mut rng = Rng::new(8);
        let a = Mat::randn(9, 6, &mut rng);
        let b = Mat::randn(6, 4, &mut rng);
        let mut c = Mat::from_fn(9, 4, |i, j| (i + j) as f64); // stale garbage
        a.matmul_into(&b, &mut c);
        let c0 = naive_matmul(&a, &b);
        for i in 0..c.data.len() {
            assert!((c.data[i] - c0.data[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_transpose_products_match_serial() {
        // Shapes past the parallel threshold for t_matmul / matmul_t.
        let mut rng = Rng::new(9);
        let a = Mat::randn(120, 115, &mut rng);
        let b = Mat::randn(120, 105, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = naive_matmul(&a.transpose(), &b);
        for i in 0..c1.data.len() {
            assert!((c1.data[i] - c2.data[i]).abs() < 1e-9);
        }
        let d = Mat::randn(110, 115, &mut rng);
        let e1 = a.matmul_t(&d);
        let e2 = naive_matmul(&a, &d.transpose());
        for i in 0..e1.data.len() {
            assert!((e1.data[i] - e2.data[i]).abs() < 1e-9);
        }
    }

    /// The packed trans-A path (t_matmul past the pack threshold) on
    /// mid-size, tile-unaligned shapes.
    #[test]
    fn packed_t_matmul_matches_naive_on_awkward_shapes() {
        let mut rng = Rng::new(22);
        for &(p, m, n) in &[(37usize, 13usize, 9usize), (64, 31, 7), (130, 65, 33)] {
            let a = Mat::randn(p, m, &mut rng); // logical Aᵀ is m×p
            let b = Mat::randn(p, n, &mut rng);
            let c1 = a.t_matmul(&b);
            let c2 = naive_matmul(&a.transpose(), &b);
            for i in 0..c1.data.len() {
                assert!(
                    (c1.data[i] - c2.data[i]).abs() < 1e-9,
                    "t_matmul ({p},{m},{n}) el {i}"
                );
            }
        }
    }

    #[test]
    fn col_helpers_roundtrip() {
        let mut rng = Rng::new(10);
        let mut m = Mat::randn(7, 3, &mut rng);
        let mut buf = vec![0.0; 7];
        m.col_into(1, &mut buf);
        assert_eq!(buf, m.col(1));
        let vals: Vec<f64> = (0..7).map(|i| i as f64).collect();
        m.set_col(2, &vals);
        assert_eq!(m.col(2), vals);
        let c = Mat::from_col(&vals);
        assert_eq!((c.rows, c.cols), (7, 1));
        assert_eq!(c.col(0), vals);
    }

    #[test]
    fn transpose_matmuls_consistent() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(13, 7, &mut rng);
        let b = Mat::randn(13, 5, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        for i in 0..c1.data.len() {
            assert!((c1.data[i] - c2.data[i]).abs() < 1e-10);
        }
        let d = Mat::randn(4, 7, &mut rng);
        let e1 = a.matmul_t(&d);
        let e2 = a.matmul(&d.transpose());
        for i in 0..e1.data.len() {
            assert!((e1.data[i] - e2.data[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn matvecs() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(10, 6, &mut rng);
        let g = a.gram();
        assert_eq!(g.rows, 6);
        for i in 0..6 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..6 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn eye_and_plus_diag() {
        let i3 = Mat::eye(3);
        let x = [1.0, 2.0, 3.0];
        assert_eq!(i3.matvec(&x), x.to_vec());
        let shifted = Mat::zeros(2, 2).plus_diag(5.0);
        assert_eq!(shifted.at(0, 0), 5.0);
        assert_eq!(shifted.at(0, 1), 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(5, 8, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }
}
