//! Dense row-major matrix with BLAS-like kernels (gemm/gemv/syrk).
//!
//! The gemm uses i-k-j loop order with a blocked variant for larger sizes —
//! cache-friendly without unsafe code. This is the crate's single biggest
//! hot spot (SVM objective, logistic regression, Gram matrices), so it gets
//! perf attention in EXPERIMENTS.md §Perf.

use super::vecops;
use crate::util::rng::Rng;

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into caller buffer.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = vecops::dot(self.row(i), x);
        }
    }

    /// y = Aᵀ x (allocating).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// y = Aᵀ x into caller buffer — row-major friendly (axpy over rows).
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            vecops::axpy(x[i], self.row(i), y);
        }
    }

    /// C = A · B. Blocked i-k-j gemm.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm_acc(self, b, &mut c);
        c
    }

    /// C = Aᵀ · B without materializing Aᵀ.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "tgemm shape mismatch");
        let (m, n, p) = (self.cols, b.cols, self.rows);
        let mut c = Mat::zeros(m, n);
        for k in 0..p {
            let arow = self.row(k);
            let brow = b.row(k);
            for i in 0..m {
                let aki = arow[i];
                if aki != 0.0 {
                    vecops::axpy(aki, brow, c.row_mut(i));
                }
            }
        }
        c
    }

    /// C = A · Bᵀ without materializing Bᵀ.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "gemm_t shape mismatch");
        let mut c = Mat::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            for j in 0..b.rows {
                c.data[i * b.rows + j] = vecops::dot(self.row(i), b.row(j));
            }
        }
        c
    }

    /// Gram matrix AᵀA (symmetric rank-k update).
    pub fn gram(&self) -> Mat {
        self.t_matmul(self)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::norm2(&self.data)
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        vecops::axpy(alpha, &other.data, &mut self.data);
    }

    /// A + alpha * I (square only).
    pub fn plus_diag(&self, alpha: f64) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out.data[i * self.cols + i] += alpha;
        }
        out
    }
}

/// C += A · B, blocked over k then i for cache locality (i-k-j order: the
/// inner loop is a unit-stride axpy over a row of B and a row of C).
fn gemm_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, p, n) = (a.rows, a.cols, b.cols);
    const KB: usize = 64;
    for k0 in (0..p).step_by(KB) {
        let kend = (k0 + KB).min(p);
        for i in 0..m {
            let arow = a.row(i);
            let crow = &mut c.data[i * n..(i + 1) * n];
            for k in k0..kend {
                let aik = arow[k];
                if aik != 0.0 {
                    let brow = &b.data[k * n..(k + 1) * n];
                    vecops::axpy(aik, brow, crow);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, p, n) in &[(3usize, 4usize, 5usize), (17, 33, 9), (64, 65, 66), (1, 7, 1)] {
            let a = Mat::randn(m, p, &mut rng);
            let b = Mat::randn(p, n, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            for i in 0..c.data.len() {
                assert!((c.data[i] - c0.data[i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn transpose_matmuls_consistent() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(13, 7, &mut rng);
        let b = Mat::randn(13, 5, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        for i in 0..c1.data.len() {
            assert!((c1.data[i] - c2.data[i]).abs() < 1e-10);
        }
        let d = Mat::randn(4, 7, &mut rng);
        let e1 = a.matmul_t(&d);
        let e2 = a.matmul(&d.transpose());
        for i in 0..e1.data.len() {
            assert!((e1.data[i] - e2.data[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn matvecs() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(10, 6, &mut rng);
        let g = a.gram();
        assert_eq!(g.rows, 6);
        for i in 0..6 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..6 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn eye_and_plus_diag() {
        let i3 = Mat::eye(3);
        let x = [1.0, 2.0, 3.0];
        assert_eq!(i3.matvec(&x), x.to_vec());
        let shifted = Mat::zeros(2, 2).plus_diag(5.0);
        assert_eq!(shifted.at(0, 0), 5.0);
        assert_eq!(shifted.at(0, 1), 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(5, 8, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }
}
