//! Vector kernels used on every hot path. Free functions over `&[f64]` keep
//! the call sites allocation-free; the `_into` variants write to caller
//! buffers (hoisted out of solver loops during the perf pass).
//!
//! `dot`/`axpy`/`norm2` parallelize across worker threads past
//! [`PAR_LEN`] elements (large-d sparse/logreg vectors); the `_serial`
//! variants are for callers already inside a parallel region (the gemv/gemm
//! row-panel workers) where nested thread spawn would thrash.

use crate::util::parallel;

/// Length above which `dot`/`axpy`/`norm2` split across worker threads.
/// Below it, thread spawn costs more than the arithmetic saves.
pub const PAR_LEN: usize = 1 << 16;

fn vec_workers(n: usize) -> usize {
    if n >= PAR_LEN {
        parallel::default_workers()
    } else {
        1
    }
}

/// Dot product (unrolled by 4 for ILP; on the perf-critical path).
/// Splits across threads past [`PAR_LEN`] elements.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let workers = vec_workers(a.len());
    if workers <= 1 {
        return dot_serial(a, b);
    }
    let n = a.len();
    // Chunk bounds stay 4-aligned so each partial keeps the serial kernel's
    // unroll pattern; partials reduce in index order (deterministic result
    // for a fixed worker count).
    let chunk = (((n + workers - 1) / workers + 3) / 4 * 4).max(4);
    let n_chunks = (n + chunk - 1) / chunk;
    let mut partials = vec![0.0f64; n_chunks];
    parallel::parallel_chunks_mut(&mut partials, 1, workers, |ci, p| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(n);
        p[0] = dot_serial(&a[lo..hi], &b[lo..hi]);
    });
    partials.iter().sum()
}

/// Single-threaded dot — call sites already inside a parallel region.
#[inline]
pub fn dot_serial(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm — overflow/underflow safe.
///
/// Fast path: `dot(a, a).sqrt()` whenever the squared sum stays comfortably
/// inside the normal f64 range. For extreme vectors (entries near 1e±200,
/// where squaring overflows to inf or underflows to 0 — which would silently
/// break CG/GMRES relative-residual checks) fall back to a LAPACK
/// `dnrm2`-style scale-then-sum accumulation. The fast path inherits the
/// parallel dot; the dnrm2 fallback stays serial (its running `scale`
/// rescaling is order-dependent).
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    let s = dot(a, a);
    if sq_norm_reliable(s) {
        return s.sqrt();
    }
    norm2_scaled(a)
}

/// Whether a squared sum is inside the range where `sqrt` is safe (no
/// under/overflow happened while squaring). Outside it, callers holding the
/// original vector should re-measure with [`norm2`] — this is the single
/// guard window shared by `norm2` and the CG residual checks.
#[inline]
pub fn sq_norm_reliable(sq: f64) -> bool {
    sq > 1e-280 && sq < 1e280
}

/// dnrm2-style accumulation: track `scale = max |a_i|` and the sum of
/// squares of entries divided by `scale`, so the result is `scale·√ssq`
/// without ever forming an over/underflowing square.
fn norm2_scaled(a: &[f64]) -> f64 {
    let mut scale = 0.0f64;
    let mut ssq = 0.0f64;
    for &x in a {
        if x.is_nan() {
            return f64::NAN;
        }
        let ax = x.abs();
        if ax == f64::INFINITY {
            return f64::INFINITY;
        }
        if ax == 0.0 {
            continue;
        }
        if scale < ax {
            let r = scale / ax;
            ssq = 1.0 + ssq * r * r;
            scale = ax;
        } else {
            let r = ax / scale;
            ssq += r * r;
        }
    }
    scale * ssq.sqrt()
}

/// L1 norm.
#[inline]
pub fn norm1(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// Infinity norm.
#[inline]
pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, &x| m.max(x.abs()))
}

/// y += alpha * x. Splits across threads past [`PAR_LEN`] elements
/// (bitwise identical to the serial path — each element is touched once).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let workers = vec_workers(x.len());
    if workers <= 1 {
        axpy_serial(alpha, x, y);
        return;
    }
    let n = y.len();
    let chunk = ((n + workers - 1) / workers).max(1);
    parallel::parallel_chunks_mut(y, chunk, workers, |ci, ych| {
        let lo = ci * chunk;
        axpy_serial(alpha, &x[lo..lo + ych.len()], ych);
    });
}

/// Single-threaded axpy — call sites already inside a parallel region.
#[inline]
pub fn axpy_serial(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * x + beta * y
#[inline]
pub fn axpby(alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = alpha * x[i] + beta * y[i];
    }
}

/// x *= alpha
#[inline]
pub fn scale(x: &mut [f64], alpha: f64) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = a - b
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// out = a + b
#[inline]
pub fn add_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// a - b as a fresh vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.len()];
    sub_into(a, b, &mut out);
    out
}

/// a + b as a fresh vector.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.len()];
    add_into(a, b, &mut out);
    out
}

/// alpha * a as a fresh vector.
pub fn scaled(a: &[f64], alpha: f64) -> Vec<f64> {
    a.iter().map(|&x| alpha * x).collect()
}

/// Relative L2 distance ‖a−b‖/max(1, ‖b‖).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    let mut num = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        num += d * d;
    }
    num.sqrt() / norm2(b).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    /// Regression: the threaded dot/axpy/norm2 paths agree with the serial
    /// kernels on vectors past the parallel threshold (axpy bitwise; dot to
    /// reassociation-level relative error), and again on threshold-straddling
    /// lengths.
    #[test]
    fn parallel_vec_kernels_match_serial() {
        for &n in &[PAR_LEN - 1, PAR_LEN, PAR_LEN + 7, 3 * PAR_LEN + 5] {
            let a: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 97) as f64 * 0.03 - 1.4).collect();
            let b: Vec<f64> = (0..n).map(|i| ((i * 17 + 5) % 89) as f64 * 0.02 - 0.9).collect();
            let d_par = dot(&a, &b);
            let d_ser = dot_serial(&a, &b);
            let denom = d_ser.abs().max(1.0);
            assert!(
                (d_par - d_ser).abs() / denom < 1e-12,
                "n={n}: parallel dot {d_par} vs serial {d_ser}"
            );
            let n_par = norm2(&a);
            let n_ser = dot_serial(&a, &a).sqrt();
            assert!((n_par - n_ser).abs() / n_ser.max(1.0) < 1e-12, "n={n} norm2");
            let mut y_par = b.clone();
            axpy(1.5, &a, &mut y_par);
            let mut y_ser = b.clone();
            axpy_serial(1.5, &a, &mut y_ser);
            assert_eq!(y_par, y_ser, "n={n}: parallel axpy must be bitwise-identical");
        }
    }

    #[test]
    fn norms() {
        let v = [3.0, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-15);
        assert!((norm1(&v) - 7.0).abs() < 1e-15);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-15);
    }

    #[test]
    fn norm2_survives_extreme_magnitudes() {
        // Huge entries: dot(a, a) overflows to inf; dnrm2 path must not.
        let big = [1e200, -1e200];
        let expected = 1e200 * 2.0f64.sqrt();
        assert!((norm2(&big) - expected).abs() / expected < 1e-14, "{}", norm2(&big));
        // Tiny entries: dot(a, a) underflows toward 0.
        let small = [1e-200, 1e-200, 1e-200, 1e-200];
        let expected = 2e-200;
        assert!((norm2(&small) - expected).abs() / expected < 1e-14, "{}", norm2(&small));
        // Mixed magnitudes dominated by the large entry.
        let mixed = [1e200, 1.0, -3.0];
        assert!((norm2(&mixed) - 1e200).abs() / 1e200 < 1e-14);
        // Zero vector and empty slice are exactly 0.
        assert_eq!(norm2(&[0.0, 0.0]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        // Infinities and NaNs propagate.
        assert_eq!(norm2(&[f64::INFINITY, 1.0]), f64::INFINITY);
        assert!(norm2(&[f64::NAN, 1.0]).is_nan());
    }

    /// The extreme-magnitude guarantees must hold above the parallel
    /// threshold too (the dnrm2 fallback triggers off the *parallel* fast
    /// path's unreliable square).
    #[test]
    fn norm2_extreme_magnitudes_above_parallel_threshold() {
        let n = PAR_LEN + 3;
        let mut big = vec![0.0f64; n];
        big[7] = 1e200;
        big[n - 1] = -1e200;
        let expected = 1e200 * 2.0f64.sqrt();
        assert!((norm2(&big) - expected).abs() / expected < 1e-14);
        let mut nan = vec![1.0f64; n];
        nan[n / 2] = f64::NAN;
        assert!(norm2(&nan).is_nan());
        let mut inf = vec![1.0f64; n];
        inf[3] = f64::INFINITY;
        assert_eq!(norm2(&inf), f64::INFINITY);
    }

    #[test]
    fn axpy_axpby() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        axpby(1.0, &x, 0.5, &mut y);
        assert_eq!(y, [7.0, 14.0]);
    }

    #[test]
    fn add_sub_scale() {
        let a = [1.0, 2.0];
        let b = [0.5, 1.0];
        assert_eq!(add(&a, &b), vec![1.5, 3.0]);
        assert_eq!(sub(&a, &b), vec![0.5, 1.0]);
        assert_eq!(scaled(&a, 3.0), vec![3.0, 6.0]);
        let mut c = [2.0, 4.0];
        scale(&mut c, 0.5);
        assert_eq!(c, [1.0, 2.0]);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(rel_err(&a, &a), 0.0);
    }
}
