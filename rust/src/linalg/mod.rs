//! Dense + matrix-free linear algebra substrate.
//!
//! Everything the implicit-differentiation engine needs to solve the linear
//! systems of paper Eq. (2): a dense matrix type with BLAS-like kernels, a
//! matrix-free [`op::LinOp`] abstraction (the paper's "all we need from F is
//! its JVPs or VJPs"), and the iterative solvers the paper names — conjugate
//! gradient [51], GMRES [75], BiCGSTAB [81] — plus normal-equation CG and
//! dense LU/Cholesky factorizations for small systems.
//!
//! Multi-RHS surface: [`op::LinOp::apply_block`], [`cg::block_cg`] and
//! [`solve::solve_block`] solve A X = B for k right-hand sides with one
//! (batched) operator application per iteration — the engine's dense
//! Jacobians and multi-cotangent VJPs ride on it.
//!
//! Large-scale surface: [`sparse::CsrMat`]/[`sparse::CscMat`] give the same
//! `LinOp` contract for d ≫ 10⁴ designs without densifying, and
//! [`solve::SolvePrecision`] selects f32-inner/f64-refined mixed-precision
//! solves (iterative refinement on factorizations, f32-state CG with an f64
//! polish) where the `diff::precision` bounds allow it.

pub mod bicgstab;
pub mod cg;
pub mod chol;
pub mod gmres;
pub mod lu;
pub mod mat;
pub mod op;
pub mod solve;
pub mod sparse;
pub mod vecops;

pub use mat::{gemm_config, simd_tier, GemmConfig, Mat};
pub use op::LinOp;
pub use solve::{
    BlockSolveReport, Factorization, LinearSolveConfig, LinearSolverKind, SolvePrecision,
    SolveReport,
};
pub use sparse::{CscMat, CsrMat};
