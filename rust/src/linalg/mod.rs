//! Dense + matrix-free linear algebra substrate.
//!
//! Everything the implicit-differentiation engine needs to solve the linear
//! systems of paper Eq. (2): a dense matrix type with BLAS-like kernels, a
//! matrix-free [`op::LinOp`] abstraction (the paper's "all we need from F is
//! its JVPs or VJPs"), and the iterative solvers the paper names — conjugate
//! gradient [51], GMRES [75], BiCGSTAB [81] — plus normal-equation CG and
//! dense LU/Cholesky factorizations for small systems.
//!
//! Multi-RHS surface: [`op::LinOp::apply_block`], [`cg::block_cg`] and
//! [`solve::solve_block`] solve A X = B for k right-hand sides with one
//! (batched) operator application per iteration — the engine's dense
//! Jacobians and multi-cotangent VJPs ride on it.

pub mod bicgstab;
pub mod cg;
pub mod chol;
pub mod gmres;
pub mod lu;
pub mod mat;
pub mod op;
pub mod solve;
pub mod vecops;

pub use mat::Mat;
pub use op::LinOp;
pub use solve::{BlockSolveReport, Factorization, LinearSolveConfig, LinearSolverKind, SolveReport};
