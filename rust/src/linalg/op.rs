//! Matrix-free linear operators.
//!
//! The paper's linear systems (Eq. 2) are solved with matrix-free methods:
//! "all we need from F is its JVPs or VJPs". `LinOp` is that abstraction; it
//! is implemented by dense matrices, by autodiff-derived Jacobian operators
//! (∂₁F as a JVP closure) and by the XLA runtime oracles.

use super::mat::Mat;

/// A linear map R^n → R^n (square; the implicit-function-theorem system
/// A J = B always has square A = −∂₁F).
pub trait LinOp {
    /// Dimension d of the (square) operator.
    fn dim(&self) -> usize;
    /// y = A x.
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// y = Aᵀ x. Default errors for operators with no transpose available.
    fn apply_t(&self, _x: &[f64], _y: &mut [f64]) {
        panic!("this LinOp does not implement a transpose product");
    }
    /// Whether the operator is (numerically) symmetric — enables CG.
    fn is_symmetric(&self) -> bool {
        false
    }

    /// Y = A X for a block of k right-hand sides stored as the columns of X
    /// (d×k). The default loops columns through [`LinOp::apply`]; operators
    /// with a native block product — dense matrices (one GEMM), batched
    /// implicit-diff JVPs — override it so a block-CG iteration costs ONE
    /// operator application instead of k.
    fn apply_block(&self, x: &Mat, y: &mut Mat) {
        batch_cols(self.dim(), self.dim(), x, y, |xc, yc| self.apply(xc, yc));
    }

    /// Y = Aᵀ X columnwise; see [`LinOp::apply_block`].
    fn apply_t_block(&self, x: &Mat, y: &mut Mat) {
        batch_cols(self.dim(), self.dim(), x, y, |xc, yc| self.apply_t(xc, yc));
    }

    /// Materialize as a dense matrix, A·I through [`LinOp::apply_block`] —
    /// ONE native block product for operators that have one (dense GEMM,
    /// batched implicit-diff Jacobians), the column loop otherwise. Used by
    /// tests, small systems, and the direct-solve factorization path.
    /// Every call is recorded in [`densify`] so large-d tests can assert the
    /// sparse path never materializes a dense d×d matrix.
    fn to_dense(&self) -> Mat {
        let d = self.dim();
        densify::bump(d);
        let mut m = Mat::zeros(d, d);
        self.apply_block(&Mat::eye(d), &mut m);
        m
    }
}

/// Thread-local ledger of [`LinOp::to_dense`] materializations — the
/// "allocation counter" behind the sparse-path acceptance criterion: a
/// d ≫ 10⁴ hypergradient must complete with `densify::count()` unchanged
/// (and in particular `max_dim()` far below d), because a single dense d×d
/// would be d²·8 bytes of memory and an O(d³) factor away from feasible.
pub mod densify {
    use std::cell::Cell;
    thread_local! {
        static CALLS: Cell<usize> = Cell::new(0);
        static MAX_DIM: Cell<usize> = Cell::new(0);
    }
    pub(super) fn bump(dim: usize) {
        CALLS.with(|c| c.set(c.get() + 1));
        MAX_DIM.with(|c| c.set(c.get().max(dim)));
    }
    /// `to_dense` calls on this thread since the last [`reset`].
    pub fn count() -> usize {
        CALLS.with(|c| c.get())
    }
    /// Largest operator dimension densified since the last [`reset`].
    pub fn max_dim() -> usize {
        MAX_DIM.with(|c| c.get())
    }
    pub fn reset() {
        CALLS.with(|c| c.set(0));
        MAX_DIM.with(|c| c.set(0));
    }
}

/// Column-loop fallback shared by every batched product in the crate
/// (LinOp block defaults here, the `jvp/vjp_*_batch` defaults in
/// `diff::spec` and `mappings::objective`): extract each column of `v`
/// (din-dimensional), apply `f`, write the dout-dimensional result column
/// of `out`. Native block implementations override with one GEMM instead.
pub fn batch_cols(
    din: usize,
    dout: usize,
    v: &Mat,
    out: &mut Mat,
    mut f: impl FnMut(&[f64], &mut [f64]),
) {
    assert_eq!(v.rows, din, "batch input rows mismatch");
    assert_eq!(out.rows, dout, "batch output rows mismatch");
    assert_eq!(v.cols, out.cols, "batch column count mismatch");
    let mut vc = vec![0.0; din];
    let mut oc = vec![0.0; dout];
    for j in 0..v.cols {
        v.col_into(j, &mut vc);
        f(&vc, &mut oc);
        out.set_col(j, &oc);
    }
}

/// Dense matrix as a LinOp.
pub struct DenseOp<'a> {
    pub a: &'a Mat,
    pub symmetric: bool,
}

impl<'a> DenseOp<'a> {
    pub fn new(a: &'a Mat) -> DenseOp<'a> {
        assert_eq!(a.rows, a.cols);
        DenseOp { a, symmetric: false }
    }
    pub fn symmetric(a: &'a Mat) -> DenseOp<'a> {
        assert_eq!(a.rows, a.cols);
        DenseOp { a, symmetric: true }
    }
}

impl LinOp for DenseOp<'_> {
    fn dim(&self) -> usize {
        self.a.rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.matvec_into(x, y);
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        self.a.matvec_t_into(x, y);
    }
    fn apply_block(&self, x: &Mat, y: &mut Mat) {
        self.a.matmul_into(x, y); // one GEMM for the whole block
    }
    fn apply_t_block(&self, x: &Mat, y: &mut Mat) {
        self.a.t_matmul_into(x, y);
    }
    fn is_symmetric(&self) -> bool {
        self.symmetric
    }
}

/// LinOp from closures (the autodiff JVP/VJP path).
pub struct FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    pub d: usize,
    pub fwd: F,
    pub tr: G,
    pub symmetric: bool,
}

impl<F, G> FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    pub fn new(d: usize, fwd: F, tr: G) -> Self {
        FnOp { d, fwd, tr, symmetric: false }
    }
    pub fn sym(d: usize, fwd: F, tr: G) -> Self {
        FnOp { d, fwd, tr, symmetric: true }
    }
}

impl<F, G> LinOp for FnOp<F, G>
where
    F: Fn(&[f64], &mut [f64]),
    G: Fn(&[f64], &mut [f64]),
{
    fn dim(&self) -> usize {
        self.d
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        (self.fwd)(x, y);
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        (self.tr)(x, y);
    }
    fn is_symmetric(&self) -> bool {
        self.symmetric
    }
}

/// The transpose view of an operator.
pub struct TransposedOp<'a, A: LinOp + ?Sized>(pub &'a A);

impl<A: LinOp + ?Sized> LinOp for TransposedOp<'_, A> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.0.apply_t(x, y);
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        self.0.apply(x, y);
    }
    fn apply_block(&self, x: &Mat, y: &mut Mat) {
        self.0.apply_t_block(x, y);
    }
    fn apply_t_block(&self, x: &Mat, y: &mut Mat) {
        self.0.apply_block(x, y);
    }
    fn is_symmetric(&self) -> bool {
        self.0.is_symmetric()
    }
}

/// A Aᵀ (for normal-equation CG on non-symmetric systems).
pub struct AAtOp<'a, A: LinOp + ?Sized> {
    pub a: &'a A,
    buf: std::cell::RefCell<Vec<f64>>,
}

impl<'a, A: LinOp + ?Sized> AAtOp<'a, A> {
    pub fn new(a: &'a A) -> Self {
        let d = a.dim();
        AAtOp { a, buf: std::cell::RefCell::new(vec![0.0; d]) }
    }
}

impl<A: LinOp + ?Sized> LinOp for AAtOp<'_, A> {
    fn dim(&self) -> usize {
        self.a.dim()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let mut t = self.buf.borrow_mut();
        self.a.apply_t(x, &mut t);
        self.a.apply(&t, y);
    }
    fn apply_block(&self, x: &Mat, y: &mut Mat) {
        let mut t = Mat::zeros(self.a.dim(), x.cols);
        self.a.apply_t_block(x, &mut t);
        self.a.apply_block(&t, y);
    }
    fn apply_t_block(&self, x: &Mat, y: &mut Mat) {
        self.apply_block(x, y); // A Aᵀ is symmetric
    }
    fn is_symmetric(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_op_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(6, 6, &mut rng);
        let op = DenseOp::new(&a);
        assert_eq!(op.to_dense(), a);
    }

    #[test]
    fn transposed_op_matches_dense_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(5, 5, &mut rng);
        let op = DenseOp::new(&a);
        let t = TransposedOp(&op);
        assert_eq!(t.to_dense(), a.transpose());
    }

    #[test]
    fn aat_is_symmetric_psd() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(4, 4, &mut rng);
        let op = DenseOp::new(&a);
        let aat = AAtOp::new(&op);
        let m = aat.to_dense();
        for i in 0..4 {
            for j in 0..4 {
                assert!((m.at(i, j) - m.at(j, i)).abs() < 1e-10);
            }
            assert!(m.at(i, i) >= -1e-12);
        }
    }

    #[test]
    fn block_products_match_column_loop() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(7, 7, &mut rng);
        let x = Mat::randn(7, 3, &mut rng);
        let op = DenseOp::new(&a);
        // DenseOp overrides with one GEMM; FnOp uses the column fallback.
        let fallback = FnOp::new(
            7,
            |v: &[f64], y: &mut [f64]| a.matvec_into(v, y),
            |u: &[f64], y: &mut [f64]| a.matvec_t_into(u, y),
        );
        let mut y_gemm = Mat::zeros(7, 3);
        op.apply_block(&x, &mut y_gemm);
        let mut y_cols = Mat::zeros(7, 3);
        fallback.apply_block(&x, &mut y_cols);
        for i in 0..y_gemm.data.len() {
            assert!((y_gemm.data[i] - y_cols.data[i]).abs() < 1e-12);
        }
        let mut yt_gemm = Mat::zeros(7, 3);
        op.apply_t_block(&x, &mut yt_gemm);
        let mut yt_cols = Mat::zeros(7, 3);
        fallback.apply_t_block(&x, &mut yt_cols);
        for i in 0..yt_gemm.data.len() {
            assert!((yt_gemm.data[i] - yt_cols.data[i]).abs() < 1e-12);
        }
        // AAtOp block product vs its own scalar apply.
        let aat = AAtOp::new(&op);
        let mut yb = Mat::zeros(7, 3);
        aat.apply_block(&x, &mut yb);
        let mut xc = vec![0.0; 7];
        let mut yc = vec![0.0; 7];
        for j in 0..3 {
            x.col_into(j, &mut xc);
            aat.apply(&xc, &mut yc);
            for i in 0..7 {
                assert!((yb.at(i, j) - yc[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn densify_counter_records_to_dense_calls() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(6, 6, &mut rng);
        let op = DenseOp::new(&a);
        densify::reset();
        assert_eq!(densify::count(), 0);
        let _ = op.to_dense();
        let _ = op.to_dense();
        assert_eq!(densify::count(), 2);
        assert_eq!(densify::max_dim(), 6);
        // apply/apply_block never densify.
        let x = Mat::randn(6, 2, &mut rng);
        let mut y = Mat::zeros(6, 2);
        op.apply_block(&x, &mut y);
        assert_eq!(densify::count(), 2);
        densify::reset();
        assert_eq!(densify::count(), 0);
        assert_eq!(densify::max_dim(), 0);
    }

    #[test]
    fn fn_op_applies_closures() {
        let op = FnOp::new(
            3,
            |x: &[f64], y: &mut [f64]| {
                for i in 0..3 {
                    y[i] = 2.0 * x[i];
                }
            },
            |x: &[f64], y: &mut [f64]| {
                for i in 0..3 {
                    y[i] = 2.0 * x[i];
                }
            },
        );
        let mut y = vec![0.0; 3];
        op.apply(&[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![2.0, 4.0, 6.0]);
    }
}
