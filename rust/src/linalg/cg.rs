//! Conjugate gradient [Hestenes & Stiefel, 51] for symmetric positive
//! (semi-)definite systems — the paper's default when A is SPD.

use super::op::LinOp;
use super::solve::SolveReport;
use super::vecops::{axpby, axpy, dot, norm2};

/// Solve A x = b with CG. `x` holds the initial guess on entry and the
/// solution on exit. All work buffers are allocated once up front.
pub fn cg(a: &dyn LinOp, b: &[f64], x: &mut [f64], tol: f64, max_iter: usize) -> SolveReport {
    let d = a.dim();
    assert_eq!(b.len(), d);
    assert_eq!(x.len(), d);
    let bnorm = norm2(b).max(1e-30);

    let mut r = vec![0.0; d];
    let mut p = vec![0.0; d];
    let mut ap = vec![0.0; d];

    // r = b − A x
    a.apply(x, &mut ap);
    for i in 0..d {
        r[i] = b[i] - ap[i];
    }
    p.copy_from_slice(&r);
    let mut rs = dot(&r, &r);

    for it in 0..max_iter {
        let res = rs.sqrt() / bnorm;
        if res <= tol {
            return SolveReport { iterations: it, residual: res, converged: true };
        }
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            return SolveReport { iterations: it, residual: res, converged: false };
        }
        let alpha = rs / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        rs = rs_new;
        // p = r + beta p
        axpby(1.0, &r, beta, &mut p);
    }
    SolveReport { iterations: max_iter, residual: rs.sqrt() / bnorm, converged: rs.sqrt() / bnorm <= tol }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::linalg::op::DenseOp;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::randn(n, n, &mut rng);
        a.gram().plus_diag(1.0)
    }

    #[test]
    fn solves_spd_system() {
        let a = spd(20, 1);
        let mut rng = Rng::new(2);
        let x_true = rng.normal_vec(20);
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; 20];
        let rep = cg(&DenseOp::symmetric(&a), &b, &mut x, 1e-12, 200);
        assert!(rep.converged, "{rep:?}");
        for i in 0..20 {
            assert!((x[i] - x_true[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn identity_converges_in_one_iteration() {
        let a = Mat::eye(8);
        let b = vec![1.0; 8];
        let mut x = vec![0.0; 8];
        let rep = cg(&DenseOp::symmetric(&a), &b, &mut x, 1e-14, 10);
        assert!(rep.converged);
        assert!(rep.iterations <= 2);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let a = spd(40, 3);
        let mut rng = Rng::new(4);
        let x_true = rng.normal_vec(40);
        let b = a.matvec(&x_true);
        let mut cold = vec![0.0; 40];
        let rep_cold = cg(&DenseOp::symmetric(&a), &b, &mut cold, 1e-10, 500);
        let mut warm = x_true.iter().map(|v| v + 1e-6).collect::<Vec<_>>();
        let rep_warm = cg(&DenseOp::symmetric(&a), &b, &mut warm, 1e-10, 500);
        assert!(rep_warm.iterations < rep_cold.iterations);
    }

    #[test]
    fn exact_in_at_most_d_iterations() {
        let a = spd(15, 5);
        let b = vec![1.0; 15];
        let mut x = vec![0.0; 15];
        let rep = cg(&DenseOp::symmetric(&a), &b, &mut x, 1e-10, 15 + 2);
        assert!(rep.converged, "CG must converge within d iterations: {rep:?}");
    }
}
