//! Conjugate gradient [Hestenes & Stiefel, 51] for symmetric positive
//! (semi-)definite systems — the paper's default when A is SPD.

use super::mat::Mat;
use super::op::LinOp;
use super::solve::{BlockSolveReport, SolveReport};
use super::vecops::{axpby, axpy, dot, norm2};

/// Solve A x = b with CG. `x` holds the initial guess on entry and the
/// solution on exit. All work buffers are allocated once up front.
pub fn cg(a: &dyn LinOp, b: &[f64], x: &mut [f64], tol: f64, max_iter: usize) -> SolveReport {
    let d = a.dim();
    assert_eq!(b.len(), d);
    assert_eq!(x.len(), d);
    let bnorm = norm2(b).max(1e-30);

    let mut r = vec![0.0; d];
    let mut p = vec![0.0; d];
    let mut ap = vec![0.0; d];

    // r = b − A x
    a.apply(x, &mut ap);
    for i in 0..d {
        r[i] = b[i] - ap[i];
    }
    p.copy_from_slice(&r);
    let mut rs = dot(&r, &r);

    for it in 0..max_iter {
        let res = residual_norm(rs, &r) / bnorm;
        if res <= tol {
            return SolveReport { iterations: it, residual: res, converged: true };
        }
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            return SolveReport { iterations: it, residual: res, converged: false };
        }
        let alpha = rs / pap;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        rs = rs_new;
        // p = r + beta p
        axpby(1.0, &r, beta, &mut p);
    }
    let res = residual_norm(rs, &r) / bnorm;
    SolveReport { iterations: max_iter, residual: res, converged: res <= tol }
}

/// Residual norm from a squared sum, falling back to the dnrm2-safe
/// [`norm2`] when the square has under/overflowed — so a tiny
/// (1e-200-scale) residual never reads as 0 and silently "converges" at the
/// initial guess, and a huge one never turns the relative check into NaN.
#[inline]
fn residual_norm(rs: f64, r: &[f64]) -> f64 {
    if super::vecops::sq_norm_reliable(rs) {
        rs.sqrt()
    } else {
        norm2(r)
    }
}

/// Multi-RHS conjugate gradient: solve A X = B for all k columns of B
/// simultaneously. The per-column arithmetic is identical to running [`cg`]
/// on that column alone (same α/β recurrences, so solutions match the
/// column-by-column path), but every iteration issues ONE block operator
/// application — a single packed GEMM for dense A, a single batched JVP for
/// implicit-diff operators — instead of k matvecs. Columns freeze as they
/// converge; a column whose pᵀAp collapses is frozen and reported
/// unconverged, exactly like the scalar breakdown path.
pub fn block_cg(
    a: &dyn LinOp,
    b: &Mat,
    x: &mut Mat,
    tol: f64,
    max_iter: usize,
) -> BlockSolveReport {
    let d = a.dim();
    let k = b.cols;
    assert_eq!(b.rows, d);
    assert_eq!(x.rows, d);
    assert_eq!(x.cols, k);
    if k == 0 {
        return BlockSolveReport { iterations: 0, max_residual: 0.0, converged: true, rhs: 0 };
    }
    // Overflow-safe per-column ‖b‖ (same dnrm2-backed norm2 the scalar cg
    // uses): a huge RHS must yield a finite bnorm so the residual ratio
    // stays inf (loud failure), never inf/inf = NaN (silent "converged").
    let bnorm: Vec<f64> = {
        let mut bc = vec![0.0; d];
        (0..k)
            .map(|j| {
                b.col_into(j, &mut bc);
                norm2(&bc).max(1e-30)
            })
            .collect()
    };

    let mut r = Mat::zeros(d, k);
    let mut p = Mat::zeros(d, k);
    let mut ap = Mat::zeros(d, k);

    a.apply_block(x, &mut ap);
    for i in 0..d * k {
        r.data[i] = b.data[i] - ap.data[i];
    }
    p.data.copy_from_slice(&r.data);
    let mut rs = col_sq_norms(&r);
    let mut colbuf = vec![0.0; d];
    let mut active: Vec<bool> =
        (0..k).map(|j| col_residual_norm(rs[j], &r, j, &mut colbuf) / bnorm[j] > tol).collect();
    let mut iterations = 0;
    // Hot-loop work buffers, allocated once up front (like scalar cg).
    let mut live: Vec<usize> = Vec::with_capacity(k);
    let mut alpha = vec![0.0; k];
    let mut beta = vec![0.0; k];
    let mut p_sub = Mat::zeros(d, 0);
    let mut ap_sub = Mat::zeros(d, 0);

    for _ in 0..max_iter {
        live.clear();
        live.extend((0..k).filter(|&j| active[j]));
        if live.is_empty() {
            break;
        }
        iterations += 1;
        // Apply the operator to the LIVE columns only: once some columns
        // have converged/stalled, gather the survivors into a narrower
        // block so total cost tracks Σ_j iters_j, not k × max_j iters_j.
        // (Gather/scatter is O(d·live), negligible next to the apply.)
        if live.len() == k {
            a.apply_block(&p, &mut ap);
        } else {
            let m_live = live.len();
            p_sub.cols = m_live;
            p_sub.data.resize(d * m_live, 0.0);
            ap_sub.cols = m_live;
            ap_sub.data.resize(d * m_live, 0.0);
            for i in 0..d {
                let off = i * k;
                let soff = i * m_live;
                for (jj, &j) in live.iter().enumerate() {
                    p_sub.data[soff + jj] = p.data[off + j];
                }
            }
            a.apply_block(&p_sub, &mut ap_sub);
            for i in 0..d {
                let off = i * k;
                let soff = i * m_live;
                for (jj, &j) in live.iter().enumerate() {
                    ap.data[off + j] = ap_sub.data[soff + jj];
                }
            }
        }
        let pap = col_dots(&p, &ap);
        alpha.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..k {
            if active[j] {
                if pap[j].abs() < 1e-300 {
                    // Breakdown: freeze the column. Its residual is still
                    // above tol (it was active), so the final sweep reports
                    // it unconverged — same as scalar cg's breakdown path.
                    active[j] = false;
                } else {
                    alpha[j] = rs[j] / pap[j];
                }
            }
        }
        // X += P·diag(α); R −= AP·diag(α). Row-major streaming: the k
        // columns interleave, so this is one pass over each block.
        for i in 0..d {
            let off = i * k;
            for j in 0..k {
                let al = alpha[j];
                if al != 0.0 {
                    x.data[off + j] += al * p.data[off + j];
                    r.data[off + j] -= al * ap.data[off + j];
                }
            }
        }
        let rs_new = col_sq_norms(&r);
        beta.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..k {
            if active[j] {
                beta[j] = rs_new[j] / rs[j];
                rs[j] = rs_new[j];
                if col_residual_norm(rs[j], &r, j, &mut colbuf) / bnorm[j] <= tol {
                    active[j] = false;
                }
            }
        }
        // P = R + P·diag(β) on still-active columns only.
        for i in 0..d {
            let off = i * k;
            for j in 0..k {
                if active[j] {
                    p.data[off + j] = r.data[off + j] + beta[j] * p.data[off + j];
                }
            }
        }
    }
    let mut max_res = 0.0f64;
    let mut all = true;
    for j in 0..k {
        let res = col_residual_norm(rs[j], &r, j, &mut colbuf) / bnorm[j];
        max_res = max_res.max(res);
        if res > tol {
            all = false;
        }
    }
    BlockSolveReport { iterations, max_residual: max_res, converged: all, rhs: k }
}

/// Mixed-precision CG: f32-state inner solves wrapped in f64 iterative
/// refinement, finished by a plain-f64 [`cg`] polish from the refined
/// iterate. The inner Krylov state (x, r, p) lives in f32 — half the memory
/// traffic of the f64 loop — while every operator application crosses the
/// f64 boundary (the `LinOp` contract stays f64) and every dot product
/// accumulates in f64. Refinement: solve A e ≈ r = b − A x loosely in f32,
/// x ← x + e, re-measure r in f64; each round shrinks the error by roughly
/// the inner tolerance until f32 conditioning stalls, at which point the f64
/// polish takes over — so the result is never worse than running [`cg`]
/// alone with the same budget, and the well-conditioned bulk of the work ran
/// at single precision.
pub fn cg_mixed(
    a: &dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iter: usize,
) -> SolveReport {
    let d = a.dim();
    assert_eq!(b.len(), d);
    assert_eq!(x.len(), d);
    let bnorm = norm2(b).max(1e-30);
    // f32 CG bottoms out near ε_f32 ≈ 1e-7; aim each inner solve comfortably
    // above that so rounds converge instead of thrashing.
    let inner_tol = 1e-5f64.max(tol);
    let mut used = 0usize;
    let mut r = vec![0.0; d];
    let mut ax = vec![0.0; d];
    let mut prev_res = f64::INFINITY;
    const ROUNDS: usize = 4;
    for _ in 0..ROUNDS {
        if used >= max_iter {
            break;
        }
        a.apply(x, &mut ax);
        for i in 0..d {
            r[i] = b[i] - ax[i];
        }
        let res = norm2(&r) / bnorm;
        if res <= tol {
            return SolveReport { iterations: used, residual: res, converged: true };
        }
        if res >= 0.5 * prev_res {
            // Refinement stalled (κ beyond what f32 can bite into): hand the
            // remaining budget to the f64 polish.
            break;
        }
        prev_res = res;
        let (e, its) = cg_f32_inner(a, &r, inner_tol, (max_iter - used).min(d.max(50)));
        used += its;
        if its == 0 {
            break;
        }
        for i in 0..d {
            x[i] += e[i];
        }
    }
    // f64 polish from the refined iterate: a no-op (0 iterations) when
    // refinement already hit tol, a correctness guarantee when it did not.
    let rep = cg(a, b, x, tol, max_iter.saturating_sub(used).max(1));
    SolveReport { iterations: used + rep.iterations, ..rep }
}

/// Inner f32-state CG on A e = r from e = 0. Returns (e as f64, iterations).
/// Dot products accumulate in f64; operator applications convert at the
/// boundary. Breaks on breakdown or two consecutive non-improving steps
/// (f32 plateau) — the caller's refinement/polish handles the rest.
fn cg_f32_inner(a: &dyn LinOp, b: &[f64], tol: f64, max_iter: usize) -> (Vec<f64>, usize) {
    let d = a.dim();
    let mut x32 = vec![0.0f32; d];
    let mut r32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
    let mut p32 = r32.clone();
    let mut p64 = vec![0.0f64; d];
    let mut ap64 = vec![0.0f64; d];
    let mut rs = dot_f32(&r32, &r32);
    let bnorm = rs.sqrt().max(1e-30);
    let mut stall = 0usize;
    let mut its = 0usize;
    for _ in 0..max_iter {
        if rs.sqrt() / bnorm <= tol {
            break;
        }
        for i in 0..d {
            p64[i] = p32[i] as f64;
        }
        a.apply(&p64, &mut ap64);
        let mut pap = 0.0f64;
        for i in 0..d {
            pap += p64[i] * ap64[i];
        }
        if pap.abs() < 1e-30 {
            break;
        }
        let alpha = rs / pap;
        let alpha32 = alpha as f32;
        for i in 0..d {
            x32[i] += alpha32 * p32[i];
            r32[i] -= (alpha * ap64[i]) as f32;
        }
        its += 1;
        let rs_new = dot_f32(&r32, &r32);
        if rs_new >= rs {
            stall += 1;
            if stall >= 2 {
                rs = rs_new;
                break;
            }
        } else {
            stall = 0;
        }
        let beta = (rs_new / rs.max(1e-300)) as f32;
        rs = rs_new;
        for i in 0..d {
            p32[i] = r32[i] + beta * p32[i];
        }
    }
    (x32.iter().map(|&v| v as f64).collect(), its)
}

/// ⟨a, b⟩ over f32 slices, accumulated in f64.
fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for i in 0..a.len() {
        s += a[i] as f64 * b[i] as f64;
    }
    s
}

/// Mixed-precision block CG: the multi-RHS counterpart of [`cg_mixed`].
/// Inner block iterations keep the whole Krylov block state (X, R, P) in
/// flat f32 buffers and issue ONE f64 `apply_block` per iteration (the same
/// batching contract as [`block_cg`], so implicit-diff operators still see
/// batched JVPs); outer f64 refinement re-measures residuals per column, and
/// a final [`block_cg`] polish guarantees the result is never worse than the
/// pure-f64 path with the same budget.
pub fn block_cg_mixed(
    a: &dyn LinOp,
    b: &Mat,
    x: &mut Mat,
    tol: f64,
    max_iter: usize,
) -> BlockSolveReport {
    let d = a.dim();
    let k = b.cols;
    assert_eq!(b.rows, d);
    assert_eq!(x.rows, d);
    assert_eq!(x.cols, k);
    if k == 0 {
        return BlockSolveReport { iterations: 0, max_residual: 0.0, converged: true, rhs: 0 };
    }
    let bnorm: Vec<f64> = {
        let mut bc = vec![0.0; d];
        (0..k)
            .map(|j| {
                b.col_into(j, &mut bc);
                norm2(&bc).max(1e-30)
            })
            .collect()
    };
    let inner_tol = 1e-5f64.max(tol);
    let mut used = 0usize;
    let mut r = Mat::zeros(d, k);
    let mut ax = Mat::zeros(d, k);
    let mut prev_worst = f64::INFINITY;
    const ROUNDS: usize = 4;
    for _ in 0..ROUNDS {
        if used >= max_iter {
            break;
        }
        a.apply_block(x, &mut ax);
        for i in 0..d * k {
            r.data[i] = b.data[i] - ax.data[i];
        }
        let rs = col_sq_norms(&r);
        let mut colbuf = vec![0.0; d];
        let worst = (0..k)
            .map(|j| col_residual_norm(rs[j], &r, j, &mut colbuf) / bnorm[j])
            .fold(0.0f64, f64::max);
        if worst <= tol {
            return BlockSolveReport {
                iterations: used,
                max_residual: worst,
                converged: true,
                rhs: k,
            };
        }
        if worst >= 0.5 * prev_worst {
            break;
        }
        prev_worst = worst;
        let (e, its) = block_cg_f32_inner(a, &r, inner_tol, (max_iter - used).min(d.max(50)));
        used += its;
        if its == 0 {
            break;
        }
        for i in 0..d * k {
            x.data[i] += e[i];
        }
    }
    let rep = block_cg(a, b, x, tol, max_iter.saturating_sub(used).max(1));
    BlockSolveReport { iterations: used + rep.iterations, ..rep }
}

/// Inner f32-state block CG on A E = R from E = 0: flat f32 block buffers,
/// one batched f64 `apply_block` per iteration, per-column α/β in f64.
/// Columns freeze on convergence/breakdown (α_j = 0); no live-column gather
/// — the inner loop is short and loose, so the narrower-block optimization
/// of [`block_cg`] is not worth the shuffling here.
fn block_cg_f32_inner(a: &dyn LinOp, b: &Mat, tol: f64, max_iter: usize) -> (Vec<f64>, usize) {
    let d = b.rows;
    let k = b.cols;
    let n = d * k;
    let mut x32 = vec![0.0f32; n];
    let mut r32: Vec<f32> = b.data.iter().map(|&v| v as f32).collect();
    let mut p32 = r32.clone();
    let mut p64 = Mat::zeros(d, k);
    let mut ap64 = Mat::zeros(d, k);
    let mut rs = col_sq_f32(&r32, k);
    let bnorm: Vec<f64> = rs.iter().map(|&v| v.sqrt().max(1e-30)).collect();
    let mut active: Vec<bool> = (0..k).map(|j| rs[j].sqrt() / bnorm[j] > tol).collect();
    let mut alpha = vec![0.0f64; k];
    let mut its = 0usize;
    for _ in 0..max_iter {
        if !active.iter().any(|&v| v) {
            break;
        }
        for i in 0..n {
            p64.data[i] = p32[i] as f64;
        }
        a.apply_block(&p64, &mut ap64);
        let mut pap = vec![0.0f64; k];
        for i in 0..d {
            let off = i * k;
            for j in 0..k {
                pap[j] += p64.data[off + j] * ap64.data[off + j];
            }
        }
        for j in 0..k {
            alpha[j] = 0.0;
            if active[j] {
                if pap[j].abs() < 1e-30 {
                    active[j] = false;
                } else {
                    alpha[j] = rs[j] / pap[j];
                }
            }
        }
        for i in 0..d {
            let off = i * k;
            for j in 0..k {
                let al = alpha[j];
                if al != 0.0 {
                    x32[off + j] += (al as f32) * p32[off + j];
                    r32[off + j] -= (al * ap64.data[off + j]) as f32;
                }
            }
        }
        its += 1;
        let rs_new = col_sq_f32(&r32, k);
        let mut beta = vec![0.0f32; k];
        for j in 0..k {
            if active[j] {
                // Non-improving column = f32 plateau: freeze it.
                if rs_new[j] >= rs[j] || rs_new[j].sqrt() / bnorm[j] <= tol {
                    active[j] = false;
                } else {
                    beta[j] = (rs_new[j] / rs[j].max(1e-300)) as f32;
                }
                rs[j] = rs_new[j];
            }
        }
        for i in 0..d {
            let off = i * k;
            for j in 0..k {
                if active[j] {
                    p32[off + j] = r32[off + j] + beta[j] * p32[off + j];
                }
            }
        }
    }
    (x32.iter().map(|&v| v as f64).collect(), its)
}

/// Per-column ‖·‖² of a flat row-major d×k f32 block, accumulated in f64.
fn col_sq_f32(data: &[f32], k: usize) -> Vec<f64> {
    let mut s = vec![0.0f64; k];
    for (i, &v) in data.iter().enumerate() {
        let v = v as f64;
        s[i % k] += v * v;
    }
    s
}

/// Per-column version of [`residual_norm`]: trust the squared sum while it
/// is safely representable, otherwise re-measure the column with the
/// dnrm2-safe [`norm2`].
#[inline]
fn col_residual_norm(rs_j: f64, r: &Mat, j: usize, buf: &mut [f64]) -> f64 {
    if super::vecops::sq_norm_reliable(rs_j) {
        rs_j.sqrt()
    } else {
        r.col_into(j, buf);
        norm2(buf)
    }
}

/// Column-wise ‖·‖² in one streaming pass over the block.
fn col_sq_norms(m: &Mat) -> Vec<f64> {
    let mut s = vec![0.0; m.cols];
    for i in 0..m.rows {
        let row = m.row(i);
        for j in 0..m.cols {
            s[j] += row[j] * row[j];
        }
    }
    s
}

/// Column-wise dot products ⟨a_j, b_j⟩ in one streaming pass.
fn col_dots(a: &Mat, b: &Mat) -> Vec<f64> {
    debug_assert_eq!(a.rows, b.rows);
    debug_assert_eq!(a.cols, b.cols);
    let mut s = vec![0.0; a.cols];
    for i in 0..a.rows {
        let ra = a.row(i);
        let rb = b.row(i);
        for j in 0..a.cols {
            s[j] += ra[j] * rb[j];
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::linalg::op::DenseOp;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::randn(n, n, &mut rng);
        a.gram().plus_diag(1.0)
    }

    #[test]
    fn solves_spd_system() {
        let a = spd(20, 1);
        let mut rng = Rng::new(2);
        let x_true = rng.normal_vec(20);
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; 20];
        let rep = cg(&DenseOp::symmetric(&a), &b, &mut x, 1e-12, 200);
        assert!(rep.converged, "{rep:?}");
        for i in 0..20 {
            assert!((x[i] - x_true[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn identity_converges_in_one_iteration() {
        let a = Mat::eye(8);
        let b = vec![1.0; 8];
        let mut x = vec![0.0; 8];
        let rep = cg(&DenseOp::symmetric(&a), &b, &mut x, 1e-14, 10);
        assert!(rep.converged);
        assert!(rep.iterations <= 2);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let a = spd(40, 3);
        let mut rng = Rng::new(4);
        let x_true = rng.normal_vec(40);
        let b = a.matvec(&x_true);
        let mut cold = vec![0.0; 40];
        let rep_cold = cg(&DenseOp::symmetric(&a), &b, &mut cold, 1e-10, 500);
        let mut warm = x_true.iter().map(|v| v + 1e-6).collect::<Vec<_>>();
        let rep_warm = cg(&DenseOp::symmetric(&a), &b, &mut warm, 1e-10, 500);
        assert!(rep_warm.iterations < rep_cold.iterations);
    }

    #[test]
    fn exact_in_at_most_d_iterations() {
        let a = spd(15, 5);
        let b = vec![1.0; 15];
        let mut x = vec![0.0; 15];
        let rep = cg(&DenseOp::symmetric(&a), &b, &mut x, 1e-10, 15 + 2);
        assert!(rep.converged, "CG must converge within d iterations: {rep:?}");
    }

    /// Property test (random SPD A, k ∈ {1, 3, 8}): block-CG on A X = B must
    /// match k independent column-by-column `cg` solves.
    #[test]
    fn block_cg_matches_independent_column_solves() {
        for (&k, seed) in [1usize, 3, 8].iter().zip(11u64..) {
            let n = 30;
            let a = spd(n, seed);
            let mut rng = Rng::new(seed + 50);
            let b = Mat::randn(n, k, &mut rng);
            let op = DenseOp::symmetric(&a);

            let mut x_block = Mat::zeros(n, k);
            let rep = block_cg(&op, &b, &mut x_block, 1e-11, 400);
            assert!(rep.converged, "k={k}: {rep:?}");
            assert_eq!(rep.rhs, k);

            let mut bc = vec![0.0; n];
            for j in 0..k {
                b.col_into(j, &mut bc);
                let mut xc = vec![0.0; n];
                let rep_j = cg(&op, &bc, &mut xc, 1e-11, 400);
                assert!(rep_j.converged);
                for i in 0..n {
                    assert!(
                        (x_block.at(i, j) - xc[i]).abs() < 1e-8,
                        "k={k} col {j} row {i}: {} vs {}",
                        x_block.at(i, j),
                        xc[i]
                    );
                }
            }
        }
    }

    #[test]
    fn block_cg_zero_and_converged_columns_freeze() {
        let n = 12;
        let a = spd(n, 21);
        let mut rng = Rng::new(22);
        // Column 0 is all zeros (immediately converged), column 1 is random.
        let mut b = Mat::zeros(n, 2);
        let rhs = rng.normal_vec(n);
        b.set_col(1, &rhs);
        let op = DenseOp::symmetric(&a);
        let mut x = Mat::zeros(n, 2);
        let rep = block_cg(&op, &b, &mut x, 1e-11, 200);
        assert!(rep.converged, "{rep:?}");
        for i in 0..n {
            assert_eq!(x.at(i, 0), 0.0, "zero RHS column must stay zero");
        }
        let mut xc = vec![0.0; n];
        let _ = cg(&op, &rhs, &mut xc, 1e-11, 200);
        for i in 0..n {
            assert!((x.at(i, 1) - xc[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn cg_mixed_matches_f64_solution() {
        let n = 30;
        let a = spd(n, 41);
        let mut rng = Rng::new(42);
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let op = DenseOp::symmetric(&a);
        let mut x = vec![0.0; n];
        let rep = cg_mixed(&op, &b, &mut x, 1e-11, 500);
        assert!(rep.converged, "{rep:?}");
        assert!(rep.residual <= 1e-11);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-7, "i={i}: {} vs {}", x[i], x_true[i]);
        }
        // Warm start at the solution: refinement measures the residual in
        // f64 and returns without touching the iterate.
        let mut x2 = x_true.clone();
        let rep2 = cg_mixed(&op, &b, &mut x2, 1e-9, 500);
        assert!(rep2.converged);
        assert_eq!(rep2.iterations, 0);
    }

    #[test]
    fn block_cg_mixed_matches_column_solves() {
        let n = 24;
        let k = 5;
        let a = spd(n, 51);
        let mut rng = Rng::new(52);
        let b = Mat::randn(n, k, &mut rng);
        let op = DenseOp::symmetric(&a);
        let mut x_block = Mat::zeros(n, k);
        let rep = block_cg_mixed(&op, &b, &mut x_block, 1e-11, 600);
        assert!(rep.converged, "{rep:?}");
        assert_eq!(rep.rhs, k);
        let mut bc = vec![0.0; n];
        for j in 0..k {
            b.col_into(j, &mut bc);
            let mut xc = vec![0.0; n];
            let rep_j = cg(&op, &bc, &mut xc, 1e-11, 600);
            assert!(rep_j.converged);
            for i in 0..n {
                assert!(
                    (x_block.at(i, j) - xc[i]).abs() < 1e-7,
                    "col {j} row {i}: {} vs {}",
                    x_block.at(i, j),
                    xc[i]
                );
            }
        }
    }

    #[test]
    fn block_cg_handles_empty_block() {
        let a = spd(5, 30);
        let op = DenseOp::symmetric(&a);
        let b = Mat::zeros(5, 0);
        let mut x = Mat::zeros(5, 0);
        let rep = block_cg(&op, &b, &mut x, 1e-10, 10);
        assert!(rep.converged);
        assert_eq!(rep.iterations, 0);
    }
}
