//! Sparse CSR/CSC matrices with parallel SpMM — the large-d regime.
//!
//! Logreg/SVM fixed points over `data/gene_expr.rs`-scale designs (d ≫ 10⁴
//! parameters) must never materialize a dense d×d system: the Hessian is
//! λI + low-rank, so everything the implicit-diff solves need is
//! matrix-vector/matrix-block products with the *design* matrix X and its
//! transpose. [`CsrMat`] (row-compressed; fast `X·v` and row gather) and
//! [`CscMat`] (column-compressed; fast `Xᵀ·u`) provide those products, with
//! row-panel parallel SpMM via [`crate::util::parallel::parallel_chunks_mut`]
//! past a flop threshold. Square instances implement [`LinOp`] so sparse
//! operators drop into every Krylov solver unchanged.

use super::mat::Mat;
use super::op::LinOp;
use super::vecops;
use crate::util::parallel;

/// Parallelize a sparse product once it has this many flops (2·nnz·k).
const SPMM_PAR_FLOPS: f64 = 2e6;

fn spmm_workers(nnz: usize, k: usize) -> usize {
    if 2.0 * nnz as f64 * k as f64 >= SPMM_PAR_FLOPS {
        parallel::default_workers()
    } else {
        1
    }
}

/// Compressed sparse row matrix (rows × cols).
///
/// `indptr[i]..indptr[i+1]` indexes row i's column ids (`indices`, strictly
/// ascending within a row) and values (`data`).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMat {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl CsrMat {
    /// Build from a dense matrix, dropping exact zeros. Row iteration order
    /// is ascending column id — the same order a dense row scan with an
    /// `if x != 0.0` skip visits, so accumulations over a CSR row are
    /// bitwise-identical to the skip-guarded dense loop.
    pub fn from_dense(m: &Mat) -> CsrMat {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMat { rows: m.rows, cols: m.cols, indptr, indices, data }
    }

    /// Build from (row, col, value) triplets: duplicates are summed, entries
    /// sorted by (row, col), exact-zero results kept (caller's values, not
    /// post-sum pruning, decide the pattern).
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> CsrMat {
        let mut t: Vec<(usize, usize, f64)> = triplets.to_vec();
        for &(i, j, _) in &t {
            assert!(i < rows && j < cols, "triplet ({i},{j}) out of {rows}x{cols}");
        }
        t.sort_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(t.len());
        let mut data: Vec<f64> = Vec::with_capacity(t.len());
        let mut last: Option<(usize, usize)> = None;
        for &(i, j, v) in &t {
            if last == Some((i, j)) {
                *data.last_mut().unwrap() += v;
            } else {
                indices.push(j);
                data.push(v);
                indptr[i + 1] += 1; // per-row count, prefix-summed below
                last = Some((i, j));
            }
        }
        for i in 1..=rows {
            indptr[i] += indptr[i - 1];
        }
        CsrMat { rows, cols, indptr, indices, data }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// (column ids, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// Sᵀ as a CSR matrix (O(nnz) counting sort; ascending row order within
    /// each output row). Callers on hot transpose-product paths should build
    /// this once and reuse it.
    pub fn transpose(&self) -> CsrMat {
        let mut counts = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            counts[j + 1] += 1;
        }
        for j in 0..self.cols {
            counts[j + 1] += counts[j];
        }
        let indptr = counts.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        let mut next = counts;
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                let dst = next[j];
                indices[dst] = i;
                data[dst] = v;
                next[j] += 1;
            }
        }
        CsrMat { rows: self.cols, cols: self.rows, indptr, indices, data }
    }

    /// The same pattern/values as a [`CscMat`] (identical arrays, column
    /// compression).
    pub fn to_csc(&self) -> CscMat {
        let t = self.transpose();
        CscMat { rows: self.rows, cols: self.cols, indptr: t.indptr, indices: t.indices, data: t.data }
    }

    /// Dense copy (tests/small matrices only — deliberately NOT on any
    /// solver path).
    pub fn to_dense_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                *m.at_mut(i, j) = v;
            }
        }
        m
    }

    /// y = S x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = S x. Row-gather form; parallel over row panels past the flop
    /// threshold (disjoint output chunks, no locking).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let workers = spmm_workers(self.nnz(), 1);
        if workers <= 1 {
            for i in 0..self.rows {
                let (cols, vals) = self.row(i);
                let mut s = 0.0;
                for (&j, &v) in cols.iter().zip(vals) {
                    s += v * x[j];
                }
                y[i] = s;
            }
            return;
        }
        let rows_per = ((self.rows + workers * 2 - 1) / (workers * 2)).max(1);
        parallel::parallel_chunks_mut(y, rows_per, workers, |ci, ychunk| {
            let r0 = ci * rows_per;
            for (off, yi) in ychunk.iter_mut().enumerate() {
                let (cols, vals) = self.row(r0 + off);
                let mut s = 0.0;
                for (&j, &v) in cols.iter().zip(vals) {
                    s += v * x[j];
                }
                *yi = s;
            }
        });
    }

    /// y = Sᵀ x (allocating).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// y = Sᵀ x. Scatter form (serial — the output rows collide across input
    /// rows). Hot transpose paths should hold the [`CsrMat::transpose`] and
    /// use its gather-form `matvec_into` instead.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                let (cols, vals) = self.row(i);
                for (&j, &v) in cols.iter().zip(vals) {
                    y[j] += xi * v;
                }
            }
        }
    }

    /// C = S · B for dense B (cols × k) into dense C (rows × k) — the SpMM
    /// under `apply_block`. Parallel over disjoint row panels of C.
    pub fn spmm_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(b.rows, self.cols, "spmm shape mismatch");
        assert_eq!(c.rows, self.rows, "spmm output rows mismatch");
        assert_eq!(c.cols, b.cols, "spmm output cols mismatch");
        let k = b.cols;
        c.data.iter_mut().for_each(|v| *v = 0.0);
        if k == 0 {
            return;
        }
        let workers = spmm_workers(self.nnz(), k);
        let run_rows = |r0: usize, cchunk: &mut [f64]| {
            let rows = cchunk.len() / k;
            for off in 0..rows {
                let (cols, vals) = self.row(r0 + off);
                let crow = &mut cchunk[off * k..(off + 1) * k];
                for (&j, &v) in cols.iter().zip(vals) {
                    vecops::axpy_serial(v, b.row(j), crow);
                }
            }
        };
        if workers <= 1 {
            run_rows(0, &mut c.data);
            return;
        }
        let rows_per = ((self.rows + workers * 2 - 1) / (workers * 2)).max(1);
        parallel::parallel_chunks_mut(&mut c.data, rows_per * k, workers, |ci, cchunk| {
            run_rows(ci * rows_per, cchunk);
        });
    }

    /// C = Sᵀ · B (scatter form, serial). Hot paths should precompute the
    /// transpose and call its parallel [`CsrMat::spmm_into`].
    pub fn t_spmm_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(b.rows, self.rows, "t_spmm shape mismatch");
        assert_eq!(c.rows, self.cols, "t_spmm output rows mismatch");
        assert_eq!(c.cols, b.cols, "t_spmm output cols mismatch");
        c.data.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let brow = b.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                vecops::axpy_serial(v, brow, c.row_mut(j));
            }
        }
    }
}

/// Square CSR matrices drop straight into the Krylov solvers. The
/// transpose products use the scatter form — wrap a problem-level operator
/// holding a precomputed transpose when `apply_t` is on the hot path.
impl LinOp for CsrMat {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "LinOp requires a square CsrMat");
        self.rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_t_into(x, y);
    }
    fn apply_block(&self, x: &Mat, y: &mut Mat) {
        self.spmm_into(x, y);
    }
    fn apply_t_block(&self, x: &Mat, y: &mut Mat) {
        self.t_spmm_into(x, y);
    }
}

/// Compressed sparse column matrix (rows × cols): `indptr[j]..indptr[j+1]`
/// indexes column j's row ids and values. The mirror of [`CsrMat`] — gather
/// form for `Sᵀ·u` products, scatter form for `S·v`.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMat {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl CscMat {
    pub fn from_dense(m: &Mat) -> CscMat {
        CsrMat::from_dense(m).to_csc()
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// (row ids, values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// The same pattern/values re-compressed by rows.
    pub fn to_csr(&self) -> CsrMat {
        // A CscMat's arrays ARE the CSR arrays of its transpose; transpose
        // that CSR view to recover the row-compressed original.
        let as_csr_of_t = CsrMat {
            rows: self.cols,
            cols: self.rows,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            data: self.data.clone(),
        };
        as_csr_of_t.transpose()
    }

    /// y = S x (scatter over columns; serial).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                let (rows, vals) = self.col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    y[i] += xj * v;
                }
            }
        }
    }

    /// y = Sᵀ x (gather over columns; parallel over output chunks past the
    /// flop threshold).
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        let workers = spmm_workers(self.nnz(), 1);
        if workers <= 1 {
            for j in 0..self.cols {
                let (rows, vals) = self.col(j);
                let mut s = 0.0;
                for (&i, &v) in rows.iter().zip(vals) {
                    s += v * x[i];
                }
                y[j] = s;
            }
            return;
        }
        let cols_per = ((self.cols + workers * 2 - 1) / (workers * 2)).max(1);
        parallel::parallel_chunks_mut(y, cols_per, workers, |ci, ychunk| {
            let c0 = ci * cols_per;
            for (off, yj) in ychunk.iter_mut().enumerate() {
                let (rows, vals) = self.col(c0 + off);
                let mut s = 0.0;
                for (&i, &v) in rows.iter().zip(vals) {
                    s += v * x[i];
                }
                *yj = s;
            }
        });
    }

    /// C = Sᵀ · B (gather form — one disjoint output row per column of S;
    /// parallel row panels).
    pub fn t_spmm_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(b.rows, self.rows, "csc t_spmm shape mismatch");
        assert_eq!(c.rows, self.cols, "csc t_spmm output rows mismatch");
        assert_eq!(c.cols, b.cols, "csc t_spmm output cols mismatch");
        let k = b.cols;
        c.data.iter_mut().for_each(|v| *v = 0.0);
        if k == 0 {
            return;
        }
        let workers = spmm_workers(self.nnz(), k);
        let run_cols = |c0: usize, cchunk: &mut [f64]| {
            let ncols = cchunk.len() / k;
            for off in 0..ncols {
                let (rows, vals) = self.col(c0 + off);
                let crow = &mut cchunk[off * k..(off + 1) * k];
                for (&i, &v) in rows.iter().zip(vals) {
                    vecops::axpy_serial(v, b.row(i), crow);
                }
            }
        };
        if workers <= 1 {
            run_cols(0, &mut c.data);
            return;
        }
        let cols_per = ((self.cols + workers * 2 - 1) / (workers * 2)).max(1);
        parallel::parallel_chunks_mut(&mut c.data, cols_per * k, workers, |ci, cchunk| {
            run_cols(ci * cols_per, cchunk);
        });
    }
}

impl LinOp for CscMat {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "LinOp requires a square CscMat");
        self.rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_t_into(x, y);
    }
    fn apply_t_block(&self, x: &Mat, y: &mut Mat) {
        self.t_spmm_into(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random m×n matrix with ~density fraction of nonzeros.
    fn sprandn(m: usize, n: usize, density: f64, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(m * n);
        for _ in 0..m * n {
            data.push(if rng.uniform() < density { rng.normal() } else { 0.0 });
        }
        Mat::from_vec(m, n, data)
    }

    #[test]
    fn from_dense_roundtrip_and_nnz() {
        let d = sprandn(13, 9, 0.3, 1);
        let s = CsrMat::from_dense(&d);
        assert_eq!(s.to_dense_mat(), d);
        assert_eq!(s.nnz(), d.data.iter().filter(|&&v| v != 0.0).count());
        // Column ids ascend within each row.
        for i in 0..s.rows {
            let (cols, _) = s.row(i);
            for w in cols.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
        let c = CscMat::from_dense(&d);
        assert_eq!(c.to_csr().to_dense_mat(), d);
    }

    #[test]
    fn from_triplets_sorts_and_sums_duplicates() {
        let t = vec![(2usize, 1usize, 3.0), (0, 2, 1.0), (2, 1, -1.0), (1, 0, 4.0), (0, 0, 2.0)];
        let s = CsrMat::from_triplets(3, 3, &t);
        let d = s.to_dense_mat();
        assert_eq!(d.at(0, 0), 2.0);
        assert_eq!(d.at(0, 2), 1.0);
        assert_eq!(d.at(1, 0), 4.0);
        assert_eq!(d.at(2, 1), 2.0); // 3 − 1 summed
        assert_eq!(s.nnz(), 4);
    }

    #[test]
    fn triplets_with_empty_rows() {
        let s = CsrMat::from_triplets(5, 4, &[(0, 1, 1.0), (4, 3, 2.0)]);
        assert_eq!(s.indptr, vec![0, 1, 1, 1, 1, 2]);
        assert_eq!(s.to_dense_mat().at(4, 3), 2.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let d = sprandn(40, 23, 0.2, 2);
        let s = CsrMat::from_dense(&d);
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(23);
        let u = rng.normal_vec(40);
        let y_s = s.matvec(&x);
        let y_d = d.matvec(&x);
        for i in 0..40 {
            assert!((y_s[i] - y_d[i]).abs() < 1e-12);
        }
        let yt_s = s.matvec_t(&u);
        let yt_d = d.matvec_t(&u);
        for j in 0..23 {
            assert!((yt_s[j] - yt_d[j]).abs() < 1e-12);
        }
        // CSC mirrors.
        let c = s.to_csc();
        let mut y = vec![0.0; 40];
        c.matvec_into(&x, &mut y);
        for i in 0..40 {
            assert!((y[i] - y_d[i]).abs() < 1e-12);
        }
        let mut yt = vec![0.0; 23];
        c.matvec_t_into(&u, &mut yt);
        for j in 0..23 {
            assert!((yt[j] - yt_d[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn spmm_matches_dense_matmul_including_parallel() {
        // Big enough that 2·nnz·k crosses SPMM_PAR_FLOPS → parallel panels.
        let d = sprandn(700, 300, 0.15, 4);
        let s = CsrMat::from_dense(&d);
        assert!(2.0 * s.nnz() as f64 * 40.0 >= super::SPMM_PAR_FLOPS);
        let mut rng = Rng::new(5);
        let b = Mat::randn(300, 40, &mut rng);
        let mut c_s = Mat::zeros(700, 40);
        s.spmm_into(&b, &mut c_s);
        let c_d = d.matmul(&b);
        for i in 0..c_s.data.len() {
            assert!((c_s.data[i] - c_d.data[i]).abs() < 1e-10);
        }
        // Transpose SpMM, both scatter (CSR) and gather (CSC) forms.
        let u = Mat::randn(700, 11, &mut rng);
        let mut ct_scatter = Mat::zeros(300, 11);
        s.t_spmm_into(&u, &mut ct_scatter);
        let mut ct_gather = Mat::zeros(300, 11);
        s.to_csc().t_spmm_into(&u, &mut ct_gather);
        let ct_d = d.t_matmul(&u);
        for i in 0..ct_d.data.len() {
            assert!((ct_scatter.data[i] - ct_d.data[i]).abs() < 1e-10);
            assert!((ct_gather.data[i] - ct_d.data[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn transpose_is_involution_and_matches_dense() {
        let d = sprandn(17, 31, 0.25, 6);
        let s = CsrMat::from_dense(&d);
        let st = s.transpose();
        assert_eq!(st.to_dense_mat(), d.transpose());
        assert_eq!(st.transpose(), s);
    }

    #[test]
    fn square_csr_is_a_linop() {
        let d = sprandn(30, 30, 0.3, 7);
        let s = CsrMat::from_dense(&d);
        assert_eq!(s.dim(), 30);
        let mut rng = Rng::new(8);
        let x = rng.normal_vec(30);
        let mut y = vec![0.0; 30];
        LinOp::apply(&s, &x, &mut y);
        let y_d = d.matvec(&x);
        for i in 0..30 {
            assert!((y[i] - y_d[i]).abs() < 1e-12);
        }
        let xb = Mat::randn(30, 4, &mut rng);
        let mut yb = Mat::zeros(30, 4);
        s.apply_block(&xb, &mut yb);
        let yb_d = d.matmul(&xb);
        for i in 0..yb.data.len() {
            assert!((yb.data[i] - yb_d.data[i]).abs() < 1e-12);
        }
        let mut ytb = Mat::zeros(30, 4);
        s.apply_t_block(&xb, &mut ytb);
        let ytb_d = d.t_matmul(&xb);
        for i in 0..ytb.data.len() {
            assert!((ytb.data[i] - ytb_d.data[i]).abs() < 1e-12);
        }
    }
}
