//! `idiff` CLI launcher.
//!
//! ```text
//! idiff list                      # list experiments (one per paper figure/table)
//! idiff run --exp fig3 [opts]     # run one experiment, write results/<id>.json
//! idiff run --exp all             # run everything at default (CI) scale
//! idiff serve [--addr 127.0.0.1:7878] [--workers N] [--window-ms 2]
//!             [--batch-max 32] [--cache 64]          # catalog request server
//!             [--manifest PATH] [--persist-secs 60]  # warm-start persistence
//! ```

use idiff::coordinator;
use idiff::util::cli::Args;

fn main() {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("list") => coordinator::list_experiments(),
        Some("run") => {
            let exp = args.get_or("exp", "");
            if exp == "all" {
                for (id, _, _) in coordinator::registry() {
                    coordinator::run_experiment(id, &args);
                }
            } else if coordinator::run_experiment(exp, &args).is_none() {
                eprintln!("unknown experiment '{exp}'; try `idiff list`");
                std::process::exit(2);
            }
        }
        Some("serve") => {
            let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
            let defaults = coordinator::serve::ServeConfig::default();
            let cfg = coordinator::serve::ServeConfig {
                workers: args.get_usize("workers", defaults.workers),
                batch_window: std::time::Duration::from_millis(args.get_u64("window-ms", 2)),
                batch_max: args.get_usize("batch-max", defaults.batch_max),
                cache_capacity: args.get_usize("cache", defaults.cache_capacity),
                manifest_path: args.get("manifest").map(std::path::PathBuf::from),
                persist_secs: args.get_u64("persist-secs", defaults.persist_secs),
                ..defaults
            };
            let manifest = cfg.manifest_path.clone();
            let server = std::sync::Arc::new(coordinator::serve::Server::new(cfg));
            // Warm-start from a previous run's manifest, if there is one.
            if let Some(path) = manifest.filter(|p| p.exists()) {
                match server.load_manifest(&path) {
                    Ok(warm) => match warm.cold_start {
                        None => println!(
                            "idiff serve: warm start from {} ({} factorizations, {} rho entries, {} skipped)",
                            path.display(), warm.factorizations, warm.rho_entries, warm.skipped
                        ),
                        Some(reason) => println!("idiff serve: cold start — {reason}"),
                    },
                    Err(e) => eprintln!("idiff serve: cold start — {e}"),
                }
            }
            if let Err(e) = server.serve(&addr) {
                eprintln!("server error: {e}");
                std::process::exit(1);
            }
        }
        _ => {
            println!("idiff — Efficient and Modular Implicit Differentiation (NeurIPS 2022) reproduction");
            println!("usage: idiff <list|run|serve> [--exp NAME] [--key value ...]");
            println!();
            coordinator::list_experiments();
        }
    }
}
