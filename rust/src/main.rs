//! `idiff` CLI launcher.
//!
//! ```text
//! idiff list                      # list experiments (one per paper figure/table)
//! idiff run --exp fig3 [opts]     # run one experiment, write results/<id>.json
//! idiff run --exp all             # run everything at default (CI) scale
//! idiff serve [--addr 127.0.0.1:7878] [--workers N] [--window-ms 2]
//!             [--batch-max 32] [--cache 64]          # catalog request server
//!             [--manifest PATH] [--persist-secs 60]  # warm-start persistence
//!             [--shard i/N] [--vnodes 64]            # cluster shard identity
//!             [--peers host:a,host:b] [--replicate-secs 5]  # warm-state replication
//!             [--accept-queue 1024] [--max-inflight 0]
//!             [--max-solve-inflight 0]               # admission control
//! idiff route --shards host:a,host:b[,...]           # consistent-hash front
//!             [--addr 127.0.0.1:7979] [--workers N] [--vnodes 64]
//!             [--accept-queue 1024] [--max-inflight 0] [--health-secs 2]
//!             [--connect-ms 1500] [--probe-ms 2000]  # upstream/probe timeouts
//!             [--breaker-threshold 1]                # failures that open a breaker
//! ```
//!
//! A sharded serve (`--shard i/N`) owns the ring slice i of N: its manifest
//! (suffixed `.shard-i-of-N`) restores only ring-owned θ's, and the `route`
//! front forwards each (problem, θ) to its owner so no factorization is
//! ever computed twice cluster-wide. With `--peers` (index-aligned with
//! shard ids) each shard additionally replicates its warm θ-slice to its
//! ring successor, so failover lands on a warm replica. SIGTERM/SIGINT on
//! a serve process writes the manifest before exiting; on a router it
//! drains inflight requests first.

use idiff::coordinator;
use idiff::util::cli::Args;

/// Parse `--shard i/N` (e.g. `0/2`). Exits with a usage error on nonsense —
/// a mis-sharded server would silently drop its whole warm-start slice.
fn parse_shard(spec: &str) -> (usize, usize) {
    let parts: Vec<&str> = spec.split('/').collect();
    let parsed = match parts[..] {
        [i, n] => match (i.parse::<usize>(), n.parse::<usize>()) {
            (Ok(i), Ok(n)) if n >= 1 && i < n => Some((i, n)),
            _ => None,
        },
        _ => None,
    };
    parsed.unwrap_or_else(|| {
        eprintln!("invalid --shard '{spec}' (expected i/N with 0 <= i < N, e.g. 0/2)");
        std::process::exit(2);
    })
}

fn main() {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("list") => coordinator::list_experiments(),
        Some("run") => {
            let exp = args.get_or("exp", "");
            if exp == "all" {
                for (id, _, _) in coordinator::registry() {
                    coordinator::run_experiment(id, &args);
                }
            } else if coordinator::run_experiment(exp, &args).is_none() {
                eprintln!("unknown experiment '{exp}'; try `idiff list`");
                std::process::exit(2);
            }
        }
        Some("serve") => {
            let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
            let defaults = coordinator::serve::ServeConfig::default();
            let shard = args.get("shard").map(parse_shard);
            // Each shard persists its own manifest slice; suffix the path so
            // N shards sharing a --manifest flag never clobber each other.
            let manifest_path = args.get("manifest").map(|p| match shard {
                Some((i, n)) => std::path::PathBuf::from(format!("{p}.shard-{i}-of-{n}")),
                None => std::path::PathBuf::from(p),
            });
            let cfg = coordinator::serve::ServeConfig {
                workers: args.get_usize("workers", defaults.workers),
                batch_window: std::time::Duration::from_millis(args.get_u64("window-ms", 2)),
                batch_max: args.get_usize("batch-max", defaults.batch_max),
                cache_capacity: args.get_usize("cache", defaults.cache_capacity),
                manifest_path,
                persist_secs: args.get_u64("persist-secs", defaults.persist_secs),
                shard,
                vnodes: args.get_usize("vnodes", defaults.vnodes),
                accept_queue: args.get_usize("accept-queue", defaults.accept_queue),
                max_inflight: args.get_usize("max-inflight", defaults.max_inflight),
                max_solve_inflight: args
                    .get_usize("max-solve-inflight", defaults.max_solve_inflight),
                handle_signals: true,
                peers: args
                    .get_or("peers", "")
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect(),
                replicate_secs: args.get_u64("replicate-secs", defaults.replicate_secs),
                ..defaults
            };
            let manifest = cfg.manifest_path.clone();
            let server = std::sync::Arc::new(coordinator::serve::Server::new(cfg));
            // Warm-start from a previous run's manifest, if there is one.
            if let Some(path) = manifest.filter(|p| p.exists()) {
                match server.load_manifest(&path) {
                    Ok(warm) => match warm.cold_start {
                        None => println!(
                            "idiff serve: warm start from {} ({} factorizations, {} rho entries, {} skipped)",
                            path.display(), warm.factorizations, warm.rho_entries, warm.skipped
                        ),
                        Some(reason) => println!("idiff serve: cold start — {reason}"),
                    },
                    Err(e) => eprintln!("idiff serve: cold start — {e}"),
                }
            }
            if let Err(e) = server.serve(&addr) {
                eprintln!("server error: {e}");
                std::process::exit(1);
            }
        }
        Some("route") => {
            let addr = args.get_or("addr", "127.0.0.1:7979").to_string();
            let shards: Vec<String> = args
                .get_or("shards", "")
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            if shards.is_empty() {
                eprintln!("idiff route needs --shards host:port[,host:port...]");
                std::process::exit(2);
            }
            let defaults = coordinator::serve::cluster::router::RouterConfig::default();
            let cfg = coordinator::serve::cluster::router::RouterConfig {
                shards,
                workers: args.get_usize("workers", defaults.workers),
                accept_queue: args.get_usize("accept-queue", defaults.accept_queue),
                max_inflight: args.get_usize("max-inflight", defaults.max_inflight),
                health_secs: args.get_u64("health-secs", defaults.health_secs),
                vnodes: args.get_usize("vnodes", defaults.vnodes),
                drain_secs: args.get_u64("drain-secs", defaults.drain_secs),
                connect_timeout: std::time::Duration::from_millis(
                    args.get_u64("connect-ms", defaults.connect_timeout.as_millis() as u64),
                ),
                probe_timeout: std::time::Duration::from_millis(
                    args.get_u64("probe-ms", defaults.probe_timeout.as_millis() as u64),
                ),
                breaker_threshold: args
                    .get_u64("breaker-threshold", defaults.breaker_threshold as u64)
                    as u32,
                ..defaults
            };
            let router =
                std::sync::Arc::new(coordinator::serve::cluster::router::Router::new(cfg));
            if let Err(e) = router.serve(&addr) {
                eprintln!("router error: {e}");
                std::process::exit(1);
            }
        }
        _ => {
            println!("idiff — Efficient and Modular Implicit Differentiation (NeurIPS 2022) reproduction");
            println!("usage: idiff <list|run|serve|route> [--exp NAME] [--key value ...]");
            println!();
            coordinator::list_experiments();
        }
    }
}
