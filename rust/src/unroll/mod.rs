//! The unrolling baseline (paper §1, Figs. 3/4/13/16/17): differentiate
//! *through* the solver iterations instead of using the implicit function
//! theorem.
//!
//! Forward-mode unrolling propagates the tangent dx_{t+1} = ∂₁T dx_t + ∂₂T dθ
//! alongside the iterate — exactly what JAX's forward-mode would do through
//! the loop, expressed with the same JVP oracles the implicit path uses, so
//! runtime comparisons are apples-to-apples. The reverse-mode memory model
//! (iterations × state) drives the Fig. 13 OOM simulation.
//!
//! When the iterate is already converged, the tangent recursion no longer
//! needs the trajectory: every step linearizes at the same x*, and k-step
//! unrolling collapses to the truncated Neumann series of
//! `diff::one_step` ([`unroll_jvp_at`] / [`unroll_vjp_at`]). That is the
//! "unroll" serve mode: trajectory-free, solve-free, error O(ρᵏ).

use crate::diff::spec::FixedPointMap;

/// Forward-mode unrolled differentiation of x_{t+1} = T(x_t, θ).
/// Returns (x_T, ∂x_T/∂θ · v_theta).
pub fn unroll_jvp<T: FixedPointMap>(
    t: &T,
    x0: &[f64],
    theta: &[f64],
    v_theta: &[f64],
    iters: usize,
) -> (Vec<f64>, Vec<f64>) {
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut dx = vec![0.0; d];
    let mut x_next = vec![0.0; d];
    let mut j1 = vec![0.0; d];
    let mut j2 = vec![0.0; d];
    for _ in 0..iters {
        t.eval(&x, theta, &mut x_next);
        t.jvp_x(&x, theta, &dx, &mut j1);
        t.jvp_theta(&x, theta, v_theta, &mut j2);
        for i in 0..d {
            dx[i] = j1[i] + j2[i];
        }
        std::mem::swap(&mut x, &mut x_next);
    }
    (x, dx)
}

/// Forward-mode unrolled solve only (no tangent) — shared baseline runner.
pub fn unroll_solve<T: FixedPointMap>(t: &T, x0: &[f64], theta: &[f64], iters: usize) -> Vec<f64> {
    let mut x = x0.to_vec();
    let mut x_next = vec![0.0; x.len()];
    for _ in 0..iters {
        t.eval(&x, theta, &mut x_next);
        std::mem::swap(&mut x, &mut x_next);
    }
    x
}

/// Reverse-mode unrolling: backpropagate v through the iterations.
/// Requires storing all iterates (the memory cost the paper highlights).
/// Returns vᵀ ∂x_T/∂θ.
pub fn unroll_vjp<T: FixedPointMap>(
    t: &T,
    x0: &[f64],
    theta: &[f64],
    v: &[f64],
    iters: usize,
) -> (Vec<f64>, Vec<f64>) {
    let d = x0.len();
    let n = t.dim_theta();
    // Forward pass: store every iterate (O(iters × d) memory — Fig. 13).
    let mut trajectory = Vec::with_capacity(iters + 1);
    trajectory.push(x0.to_vec());
    let mut x = x0.to_vec();
    let mut x_next = vec![0.0; d];
    for _ in 0..iters {
        t.eval(&x, theta, &mut x_next);
        x.copy_from_slice(&x_next);
        trajectory.push(x.clone());
    }
    // Backward pass.
    let mut bar = v.to_vec(); // adjoint of x_t
    let mut grad_theta = vec![0.0; n];
    let mut tmp_x = vec![0.0; d];
    let mut tmp_t = vec![0.0; n];
    for step in (0..iters).rev() {
        let x_t = &trajectory[step];
        t.vjp_theta(x_t, theta, &bar, &mut tmp_t);
        for i in 0..n {
            grad_theta[i] += tmp_t[i];
        }
        t.vjp_x(x_t, theta, &bar, &mut tmp_x);
        bar.copy_from_slice(&tmp_x);
    }
    (trajectory.pop().unwrap(), grad_theta)
}

/// k-step unrolling AT a converged fixed point x* = T(x*, θ): the tangent
/// recursion with x frozen, dx_k = Σ_{i<k} (∂₁T)^i ∂₂T v. Identical to
/// [`unroll_jvp`] started at x0 = x* for an exactly-converged iterate, but
/// without re-evaluating T or storing anything. k = 1 is one-step
/// differentiation; the error against the implicit JVP is O(ρᵏ).
pub fn unroll_jvp_at<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    v_theta: &[f64],
    k: usize,
) -> Vec<f64> {
    crate::diff::one_step::neumann_jvp(t, x_star, theta, v_theta, k)
}

/// Reverse-mode counterpart of [`unroll_jvp_at`]: the exact adjoint of the
/// k-step frozen-point tangent recursion, ∂₂Tᵀ Σ_{i<k} (∂₁Tᵀ)^i u. Unlike
/// [`unroll_vjp`] it needs no trajectory storage (Fig. 13's memory wall
/// does not apply at a converged point).
pub fn unroll_vjp_at<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    u: &[f64],
    k: usize,
) -> Vec<f64> {
    crate::diff::one_step::neumann_vjp(t, x_star, theta, u, k)
}

/// Reverse-mode unrolling memory model (bytes): storing `iters` iterates of
/// `state_dim` f32 values on device — the quantity that hits the 16 GB GPU
/// budget in paper Fig. 13.
pub fn reverse_memory_bytes(iters: usize, state_dim: usize, bytes_per_scalar: usize) -> u64 {
    (iters as u64) * (state_dim as u64) * (bytes_per_scalar as u64)
}

/// Would reverse-mode unrolling OOM on a device with `budget_bytes`?
pub fn unroll_ooms(iters: usize, state_dim: usize, bytes_per_scalar: usize, budget_bytes: u64) -> bool {
    reverse_memory_bytes(iters, state_dim, bytes_per_scalar) > budget_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::spec::FixedPointMap;

    /// T(x, θ) = 0.5x + θ → x* = 2θ, ∂x* = 2.
    struct Affine;
    impl FixedPointMap for Affine {
        fn dim_x(&self) -> usize {
            1
        }
        fn dim_theta(&self) -> usize {
            1
        }
        fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
            out[0] = 0.5 * x[0] + theta[0];
        }
        fn jvp_x(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
            out[0] = 0.5 * v[0];
        }
        fn vjp_x(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
            out[0] = 0.5 * u[0];
        }
        fn jvp_theta(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
            out[0] = v[0];
        }
        fn vjp_theta(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
            out[0] = u[0];
        }
    }

    #[test]
    fn forward_unroll_converges_to_true_derivative() {
        let (x, dx) = unroll_jvp(&Affine, &[0.0], &[3.0], &[1.0], 100);
        assert!((x[0] - 6.0).abs() < 1e-9);
        assert!((dx[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn truncated_unroll_underestimates() {
        // After few iterations the unrolled derivative is biased toward 0 —
        // the effect behind Fig. 3's unrolling curve.
        let (_, dx3) = unroll_jvp(&Affine, &[0.0], &[3.0], &[1.0], 3);
        assert!(dx3[0] < 2.0);
        let (_, dx10) = unroll_jvp(&Affine, &[0.0], &[3.0], &[1.0], 10);
        assert!(dx10[0] > dx3[0]);
    }

    #[test]
    fn reverse_unroll_matches_forward() {
        let (_, dx) = unroll_jvp(&Affine, &[0.0], &[3.0], &[1.0], 50);
        let (_, gt) = unroll_vjp(&Affine, &[0.0], &[3.0], &[1.0], 50);
        assert!((dx[0] - gt[0]).abs() < 1e-10, "{} vs {}", dx[0], gt[0]);
    }

    #[test]
    fn frozen_point_unroll_matches_trajectory_unroll_at_the_fixed_point() {
        // Starting the trajectory at the exact fixed point x* = 2θ, the
        // iterate never moves, so trajectory unrolling and the frozen-point
        // (Neumann) form must agree term for term.
        for k in [1usize, 3, 20] {
            let (_, dx) = unroll_jvp(&Affine, &[6.0], &[3.0], &[1.0], k);
            let at = unroll_jvp_at(&Affine, &[6.0], &[3.0], &[1.0], k);
            assert!((dx[0] - at[0]).abs() < 1e-12, "k = {k}: {} vs {}", dx[0], at[0]);
            let (_, gt) = unroll_vjp(&Affine, &[6.0], &[3.0], &[1.0], k);
            let at_v = unroll_vjp_at(&Affine, &[6.0], &[3.0], &[1.0], k);
            assert!((gt[0] - at_v[0]).abs() < 1e-12, "vjp k = {k}");
        }
    }

    #[test]
    fn memory_model() {
        // 2500 iters × 700×5 f32 state ≈ 35 MB; definitely no OOM at 16 GiB.
        assert!(!unroll_ooms(2500, 3500, 4, 16 * (1 << 30)));
        // but a 10⁷-dim state at 2500 iters is 100 GB → OOM.
        assert!(unroll_ooms(2500, 10_000_000, 4, 16 * (1 << 30)));
    }
}
