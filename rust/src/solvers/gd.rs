//! Gradient descent with fixed step or backtracking line search.

use super::SolveTrace;
use crate::linalg::vecops;
use crate::mappings::objective::Objective;

#[derive(Clone, Copy, Debug)]
pub struct GdConfig {
    pub step: f64,
    pub max_iter: usize,
    pub tol: f64,
    /// Enable Armijo backtracking (halving) from `step`.
    pub backtracking: bool,
}

impl Default for GdConfig {
    fn default() -> Self {
        GdConfig { step: 1e-2, max_iter: 1000, tol: 1e-10, backtracking: false }
    }
}

/// Minimize f(·, θ) from x0. Returns (x, trace).
pub fn gradient_descent<O: Objective>(
    obj: &O,
    x0: &[f64],
    theta: &[f64],
    cfg: &GdConfig,
) -> (Vec<f64>, SolveTrace) {
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut g = vec![0.0; d];
    let mut trace = SolveTrace::default();
    // Backtracking keeps the accepted step across iterations (doubling it at
    // the start of each), so the search settles near 1/L quickly.
    let mut eta_carry = cfg.step;
    for it in 0..cfg.max_iter {
        obj.grad_x(&x, theta, &mut g);
        let gn = vecops::norm2(&g);
        trace.iterations = it + 1;
        if gn < cfg.tol {
            trace.converged = true;
            break;
        }
        if cfg.backtracking {
            let f0 = obj.value(&x, theta);
            let mut eta = (eta_carry * 2.0).min(cfg.step);
            let gsq = gn * gn;
            // Armijo: f(x − ηg) ≤ f(x) − ½η‖g‖²
            for _ in 0..60 {
                let cand: Vec<f64> = (0..d).map(|i| x[i] - eta * g[i]).collect();
                if obj.value(&cand, theta) <= f0 - 0.5 * eta * gsq {
                    x = cand;
                    eta_carry = eta;
                    break;
                }
                eta *= 0.5;
            }
        } else {
            vecops::axpy(-cfg.step, &g, &mut x);
        }
    }
    (x, trace)
}

/// Run exactly `iters` fixed-step GD iterations (no stopping) — used by the
/// Fig. 3 error study, which needs the iterate after t steps.
pub fn gd_fixed_iters<O: Objective>(
    obj: &O,
    x0: &[f64],
    theta: &[f64],
    step: f64,
    iters: usize,
) -> Vec<f64> {
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut g = vec![0.0; d];
    for _ in 0..iters {
        obj.grad_x(&x, theta, &mut g);
        vecops::axpy(-step, &g, &mut x);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::util::rng::Rng;

    fn quad(seed: u64, d: usize) -> QuadObjective {
        let mut rng = Rng::new(seed);
        QuadObjective {
            q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0),
            r: Mat::randn(d, 1, &mut rng),
            c: rng.normal_vec(d),
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let obj = quad(1, 6);
        let theta = [0.5];
        let (x, trace) = gradient_descent(
            &obj,
            &vec![0.0; 6],
            &theta,
            &GdConfig { step: 0.05, max_iter: 20_000, tol: 1e-10, backtracking: false },
        );
        assert!(trace.converged, "{trace:?}");
        let g = obj.grad_x_vec(&x, &theta);
        assert!(vecops::norm2(&g) < 1e-9);
    }

    #[test]
    fn backtracking_handles_large_initial_step() {
        let obj = quad(2, 5);
        let theta = [0.0];
        let (x, trace) = gradient_descent(
            &obj,
            &vec![0.0; 5],
            &theta,
            &GdConfig { step: 100.0, max_iter: 5000, tol: 1e-6, backtracking: true },
        );
        let gn = vecops::norm2(&obj.grad_x_vec(&x, &theta));
        assert!(trace.converged, "iters={} gn={gn}", trace.iterations);
        assert!(vecops::norm2(&obj.grad_x_vec(&x, &theta)) < 1e-5);
    }

    #[test]
    fn fixed_iters_monotone_error_decay() {
        let obj = quad(3, 4);
        let theta = [1.0];
        let (x_star, _) = gradient_descent(
            &obj,
            &vec![0.0; 4],
            &theta,
            &GdConfig { step: 0.05, max_iter: 50_000, tol: 1e-12, backtracking: false },
        );
        let mut last = f64::INFINITY;
        for iters in [5, 20, 80, 320] {
            let x = gd_fixed_iters(&obj, &vec![0.0; 4], &theta, 0.05, iters);
            let err = vecops::norm2(&vecops::sub(&x, &x_star));
            assert!(
                err < last || err < 1e-11,
                "iters={iters}: {err} !< {last}"
            );
            last = err;
        }
    }
}
