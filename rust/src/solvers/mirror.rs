//! Mirror descent under the KL geometry over products of simplices — the
//! inner solver of Fig. 4(a) (step 1.0 for 100 steps then inverse-sqrt decay,
//! per the paper's Appendix F.1 setup).

use super::SolveTrace;
use crate::mappings::mirror::MirrorGeometry;
use crate::mappings::objective::Objective;

#[derive(Clone, Copy, Debug)]
pub struct MirrorDescentConfig {
    pub step0: f64,
    /// Steps before inverse-sqrt decay kicks in.
    pub warmup: usize,
    pub max_iter: usize,
    pub tol: f64,
}

impl Default for MirrorDescentConfig {
    fn default() -> Self {
        MirrorDescentConfig { step0: 1.0, warmup: 100, max_iter: 2500, tol: 1e-12 }
    }
}

/// Minimize f(·, θ) over the geometry's domain from x0.
pub fn mirror_descent<O: Objective, G: MirrorGeometry>(
    obj: &O,
    geom: &G,
    x0: &[f64],
    theta: &[f64],
    cfg: &MirrorDescentConfig,
) -> (Vec<f64>, SolveTrace) {
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut g = vec![0.0; d];
    let mut xhat = vec![0.0; d];
    let mut y = vec![0.0; d];
    let mut x_new = vec![0.0; d];
    let mut trace = SolveTrace::default();
    for it in 0..cfg.max_iter {
        let eta = if it < cfg.warmup {
            cfg.step0
        } else {
            cfg.step0 / ((it - cfg.warmup + 1) as f64).sqrt()
        };
        obj.grad_x(&x, theta, &mut g);
        geom.mirror_map(&x, &mut xhat);
        for i in 0..d {
            y[i] = xhat[i] - eta * g[i];
        }
        geom.bregman_project(&y, &mut x_new);
        let mut delta = 0.0;
        for i in 0..d {
            delta += (x_new[i] - x[i]) * (x_new[i] - x[i]);
        }
        x.copy_from_slice(&x_new);
        trace.iterations = it + 1;
        if delta.sqrt() < cfg.tol {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mappings::mirror::KlSimplexRows;
    use crate::mappings::objective::QuadObjective;
    use crate::util::rng::Rng;

    #[test]
    fn stays_on_simplex_and_reduces_objective() {
        let (m, k) = (4, 3);
        let d = m * k;
        let mut rng = Rng::new(1);
        let obj = QuadObjective {
            q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(0.1),
            r: Mat::randn(d, 2, &mut rng),
            c: rng.normal_vec(d),
        };
        let geom = KlSimplexRows { m, k };
        let theta = [0.3, -0.1];
        let x0 = vec![1.0 / k as f64; d];
        let f0 = obj.value(&x0, &theta);
        let (x, _) = mirror_descent(&obj, &geom, &x0, &theta, &MirrorDescentConfig::default());
        assert!(obj.value(&x, &theta) < f0);
        for r in 0..m {
            let s: f64 = x[r * k..(r + 1) * k].iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(x[r * k..(r + 1) * k].iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn linear_objective_concentrates_on_best_vertex() {
        let (m, k) = (1, 5);
        let d = k;
        let q = Mat::zeros(d, d).plus_diag(1e-9);
        let r = Mat::from_fn(d, 1, |i, _| if i == 2 { -5.0 } else { 1.0 });
        let obj = QuadObjective { q, r, c: vec![0.0; d] };
        let geom = KlSimplexRows { m, k };
        let (x, _) = mirror_descent(
            &obj,
            &geom,
            &vec![0.2; 5],
            &[1.0],
            &MirrorDescentConfig { max_iter: 4000, ..Default::default() },
        );
        assert!(x[2] > 0.99, "x = {x:?}");
    }
}
