//! Frank–Wolfe over the probability simplex (paper Appendix A
//! "Frank-Wolfe"): LMO = best vertex of the negative gradient; standard
//! 2/(t+2) step. The convex weights over visited vertices are maintained so
//! the SparseMAP-style reduction (differentiate p*(θ) over the simplex of
//! vertex weights) can be applied downstream.

use super::SolveTrace;
use crate::mappings::objective::Objective;

#[derive(Clone, Copy, Debug)]
pub struct FrankWolfeConfig {
    pub max_iter: usize,
    pub tol: f64,
}

impl Default for FrankWolfeConfig {
    fn default() -> Self {
        FrankWolfeConfig { max_iter: 2000, tol: 1e-10 }
    }
}

/// Minimize f(·, θ) over △^d. Returns (x, vertex weights, trace); the weight
/// vector p satisfies x = Σ p_i e_i (here vertices are coordinate basis
/// vectors, so p = x — kept separate for the general-polytope reading).
pub fn frank_wolfe_simplex<O: Objective>(
    obj: &O,
    x0: &[f64],
    theta: &[f64],
    cfg: &FrankWolfeConfig,
) -> (Vec<f64>, Vec<f64>, SolveTrace) {
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut p = x0.to_vec();
    let mut g = vec![0.0; d];
    let mut trace = SolveTrace::default();
    for it in 0..cfg.max_iter {
        obj.grad_x(&x, theta, &mut g);
        // LMO over the simplex: the min-gradient vertex.
        let mut s = 0usize;
        for i in 1..d {
            if g[i] < g[s] {
                s = i;
            }
        }
        // Frank–Wolfe gap: ⟨g, x − e_s⟩.
        let gap: f64 = (0..d).map(|i| g[i] * x[i]).sum::<f64>() - g[s];
        trace.iterations = it + 1;
        trace.values.push(gap);
        if gap < cfg.tol {
            trace.converged = true;
            break;
        }
        let gamma = 2.0 / (it as f64 + 2.0);
        for i in 0..d {
            x[i] *= 1.0 - gamma;
            p[i] *= 1.0 - gamma;
        }
        x[s] += gamma;
        p[s] += gamma;
    }
    (x, p, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::util::rng::Rng;

    #[test]
    fn converges_on_simplex_quadratic() {
        let d = 6;
        let mut rng = Rng::new(1);
        let obj = QuadObjective {
            q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0),
            r: Mat::randn(d, 1, &mut rng),
            c: rng.normal_vec(d),
        };
        let theta = [0.5];
        let x0 = vec![1.0 / d as f64; d];
        let (x, p, trace) =
            frank_wolfe_simplex(&obj, &x0, &theta, &FrankWolfeConfig { max_iter: 80_000, tol: 1e-5 });
        assert!(trace.converged, "gap = {:?}", trace.values.last());
        let s: f64 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(x.iter().all(|&v| v >= -1e-12));
        // weights mirror the iterate for basis-vertex polytopes
        for i in 0..d {
            assert!((p[i] - x[i]).abs() < 1e-12);
        }
        // cross-check against projected gradient
        let mut x_pg = x0.clone();
        let mut g = vec![0.0; d];
        for _ in 0..30_000 {
            obj.grad_x(&x_pg, &theta, &mut g);
            let y: Vec<f64> = (0..d).map(|i| x_pg[i] - 0.05 * g[i]).collect();
            let mut z = vec![0.0; d];
            crate::proj::simplex::project_simplex(&y, &mut z);
            x_pg = z;
        }
        for i in 0..d {
            assert!((x[i] - x_pg[i]).abs() < 1e-3, "i={i}: {} vs {}", x[i], x_pg[i]);
        }
    }

    #[test]
    fn linear_objective_finds_vertex() {
        let d = 4;
        let q = Mat::zeros(d, d);
        let r = Mat::from_fn(d, 1, |i, _| if i == 1 { -1.0 } else { 1.0 });
        let obj = QuadObjective { q, r, c: vec![0.0; d] };
        let (x, _, _) = frank_wolfe_simplex(
            &obj,
            &vec![0.25; 4],
            &[1.0],
            &FrankWolfeConfig { max_iter: 5000, tol: 1e-10 },
        );
        assert!(x[1] > 0.999, "x = {x:?}");
    }
}
