//! Block coordinate descent over simplex blocks (rows) — the third inner
//! solver of Fig. 4. Each sweep takes a projected-gradient step per block
//! with a blockwise step size; the SVM model layers an exact-subproblem
//! variant on top (ml::svm).

use super::SolveTrace;
use crate::mappings::objective::Objective;
use crate::proj::simplex;

#[derive(Clone, Copy, Debug)]
pub struct BcdConfig {
    /// Number of full sweeps.
    pub sweeps: usize,
    pub step: f64,
    pub tol: f64,
}

impl Default for BcdConfig {
    fn default() -> Self {
        BcdConfig { sweeps: 500, step: 1.0, tol: 1e-12 }
    }
}

/// Minimize f(·, θ) over △^k × … × △^k (m row blocks of size k).
pub fn block_coordinate_descent<O: Objective>(
    obj: &O,
    x0: &[f64],
    theta: &[f64],
    k: usize,
    cfg: &BcdConfig,
) -> (Vec<f64>, SolveTrace) {
    let d = x0.len();
    assert_eq!(d % k, 0);
    let m = d / k;
    let mut x = x0.to_vec();
    let mut g = vec![0.0; d];
    let mut trace = SolveTrace::default();
    for sweep in 0..cfg.sweeps {
        let mut max_move = 0.0f64;
        for b in 0..m {
            obj.grad_x(&x, theta, &mut g);
            let s = b * k;
            let y: Vec<f64> = (0..k).map(|j| x[s + j] - cfg.step * g[s + j]).collect();
            let mut z = vec![0.0; k];
            simplex::project_simplex(&y, &mut z);
            for j in 0..k {
                max_move = max_move.max((z[j] - x[s + j]).abs());
                x[s + j] = z[j];
            }
        }
        trace.iterations = sweep + 1;
        if max_move < cfg.tol {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::util::rng::Rng;

    #[test]
    fn feasible_and_descending() {
        let (m, k) = (3, 4);
        let d = m * k;
        let mut rng = Rng::new(1);
        let obj = QuadObjective {
            q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(0.5),
            r: Mat::randn(d, 1, &mut rng),
            c: rng.normal_vec(d),
        };
        let theta = [0.4];
        let x0 = vec![1.0 / k as f64; d];
        let f0 = obj.value(&x0, &theta);
        let (x, _) =
            block_coordinate_descent(&obj, &x0, &theta, k, &BcdConfig { sweeps: 300, step: 0.02, tol: 1e-12 });
        assert!(obj.value(&x, &theta) < f0 + 1e-12, "{} !< {}", obj.value(&x, &theta), f0);
        for b in 0..m {
            let s: f64 = x[b * k..(b + 1) * k].iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn agrees_with_projected_gd_solution() {
        // On a strongly-convex problem both solvers find the same optimum —
        // the paper's decoupling claim at the solver level.
        let (m, k) = (2, 3);
        let d = m * k;
        let mut rng = Rng::new(2);
        let obj = QuadObjective {
            q: Mat::randn(d + 2, d, &mut rng).gram().plus_diag(1.0),
            r: Mat::randn(d, 1, &mut rng),
            c: rng.normal_vec(d),
        };
        let theta = [1.0];
        let x0 = vec![1.0 / k as f64; d];
        let (x_bcd, _) = block_coordinate_descent(
            &obj,
            &x0,
            &theta,
            k,
            &BcdConfig { sweeps: 2000, step: 0.1, tol: 1e-13 },
        );
        // projected GD via the fixed-point map iterated directly
        let mut x_pg = x0.clone();
        let mut g = vec![0.0; d];
        for _ in 0..20000 {
            obj.grad_x(&x_pg, &theta, &mut g);
            let y: Vec<f64> = (0..d).map(|i| x_pg[i] - 0.05 * g[i]).collect();
            let mut z = vec![0.0; d];
            crate::proj::simplex::project_rows_simplex(&y, k, &mut z);
            x_pg = z;
        }
        for i in 0..d {
            assert!((x_bcd[i] - x_pg[i]).abs() < 1e-5, "i={i}: {} vs {}", x_bcd[i], x_pg[i]);
        }
    }
}
