//! Proximal gradient descent, plain and accelerated (FISTA).

use super::SolveTrace;
use crate::linalg::vecops;
use crate::mappings::objective::Objective;
use crate::prox::Prox;

#[derive(Clone, Copy, Debug)]
pub struct ProxGdConfig {
    pub step: f64,
    pub max_iter: usize,
    pub tol: f64,
    /// FISTA momentum.
    pub accelerated: bool,
}

impl Default for ProxGdConfig {
    fn default() -> Self {
        ProxGdConfig { step: 1e-3, max_iter: 2500, tol: 1e-10, accelerated: true }
    }
}

/// Minimize f(x, θ_f) + g(x, θ_g); θ = [θ_f ‖ θ_g] (same layout as the
/// prox-grad fixed-point mapping).
pub fn prox_gradient_descent<O: Objective, P: Prox>(
    obj: &O,
    prox: &P,
    x0: &[f64],
    theta: &[f64],
    cfg: &ProxGdConfig,
) -> (Vec<f64>, SolveTrace) {
    let d = x0.len();
    let (tf, tg) = theta.split_at(obj.dim_theta());
    let mut x = x0.to_vec();
    let mut z = x0.to_vec(); // extrapolated point (FISTA)
    let mut t_mom = 1.0;
    let mut g = vec![0.0; d];
    let mut y = vec![0.0; d];
    let mut x_new = vec![0.0; d];
    let mut trace = SolveTrace::default();
    for it in 0..cfg.max_iter {
        let point = if cfg.accelerated { &z } else { &x };
        obj.grad_x(point, tf, &mut g);
        for i in 0..d {
            y[i] = point[i] - cfg.step * g[i];
        }
        prox.prox(&y, tg, cfg.step, &mut x_new);
        let delta = {
            let mut s = 0.0;
            for i in 0..d {
                let dlt = x_new[i] - x[i];
                s += dlt * dlt;
            }
            s.sqrt()
        };
        if cfg.accelerated {
            let t_next = 0.5 * (1.0 + f64::sqrt(1.0 + 4.0 * t_mom * t_mom));
            let beta = (t_mom - 1.0) / t_next;
            for i in 0..d {
                z[i] = x_new[i] + beta * (x_new[i] - x[i]);
            }
            t_mom = t_next;
        }
        x.copy_from_slice(&x_new);
        trace.iterations = it + 1;
        if delta < cfg.tol * (1.0 + vecops::norm2(&x)) {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mappings::objective::QuadObjective;
    use crate::prox::LassoProx;
    use crate::util::rng::Rng;

    fn lasso_problem(seed: u64, d: usize) -> (QuadObjective, LassoProx) {
        let mut rng = Rng::new(seed);
        let obj = QuadObjective {
            q: Mat::randn(d + 3, d, &mut rng).gram().plus_diag(0.5),
            r: Mat::randn(d, 1, &mut rng),
            c: rng.normal_vec(d),
        };
        (obj, LassoProx { d })
    }

    #[test]
    fn solves_lasso_to_fixed_point() {
        let (obj, prox) = lasso_problem(1, 8);
        let theta = [1.0, 0.4]; // θ_f, λ
        let cfg = ProxGdConfig { step: 0.02, max_iter: 50_000, tol: 1e-13, accelerated: false };
        let (x, trace) = prox_gradient_descent(&obj, &prox, &vec![0.0; 8], &theta, &cfg);
        assert!(trace.converged);
        // optimality: x = prox(x − η∇f(x))
        let g = obj.grad_x_vec(&x, &theta[..1]);
        let y: Vec<f64> = (0..8).map(|i| x[i] - 0.02 * g[i]).collect();
        let fp = prox.prox_vec(&y, &theta[1..], 0.02);
        for i in 0..8 {
            assert!((fp[i] - x[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn fista_not_slower_than_plain() {
        let (obj, prox) = lasso_problem(2, 12);
        let theta = [0.5, 0.3];
        let plain = ProxGdConfig { step: 0.01, max_iter: 100_000, tol: 1e-10, accelerated: false };
        let fista = ProxGdConfig { accelerated: true, ..plain };
        let (_, t_plain) = prox_gradient_descent(&obj, &prox, &vec![0.0; 12], &theta, &plain);
        let (_, t_fista) = prox_gradient_descent(&obj, &prox, &vec![0.0; 12], &theta, &fista);
        assert!(t_fista.iterations <= t_plain.iterations, "{} vs {}", t_fista.iterations, t_plain.iterations);
    }

    #[test]
    fn induces_sparsity_for_large_lambda() {
        let (obj, prox) = lasso_problem(3, 10);
        let theta = [0.2, 50.0];
        let cfg = ProxGdConfig::default();
        let (x, _) = prox_gradient_descent(&obj, &prox, &vec![1.0; 10], &theta, &cfg);
        assert!(x.iter().all(|&v| v == 0.0), "x = {x:?}");
    }
}
