//! FIRE (Fast Inertial Relaxation Engine) [Bitzek et al. 2006, ref 15] —
//! the domain-specific structural-relaxation optimizer the paper's molecular
//! dynamics experiment minimizes energy with (§4.4). Deliberately
//! discontinuous (velocity resets), which is exactly why unrolling through
//! it diverges while implicit differentiation does not (Fig. 17).

use super::SolveTrace;
use crate::linalg::vecops;

#[derive(Clone, Copy, Debug)]
pub struct FireConfig {
    pub dt_start: f64,
    pub dt_max: f64,
    pub n_min: usize,
    pub f_inc: f64,
    pub f_dec: f64,
    pub alpha_start: f64,
    pub f_alpha: f64,
    pub max_iter: usize,
    pub force_tol: f64,
}

impl Default for FireConfig {
    fn default() -> Self {
        FireConfig {
            dt_start: 0.1,
            dt_max: 0.4,
            n_min: 5,
            f_inc: 1.1,
            f_dec: 0.5,
            alpha_start: 0.1,
            f_alpha: 0.99,
            max_iter: 4000,
            force_tol: 1e-10,
        }
    }
}

/// Minimize an energy given its force oracle (−∇E). `force(x, out)`.
pub fn fire_minimize(
    force: impl Fn(&[f64], &mut [f64]),
    x0: &[f64],
    cfg: &FireConfig,
) -> (Vec<f64>, SolveTrace) {
    let d = x0.len();
    let mut x = x0.to_vec();
    let mut v = vec![0.0; d];
    let mut f = vec![0.0; d];
    let mut dt = cfg.dt_start;
    let mut alpha = cfg.alpha_start;
    let mut n_pos = 0usize;
    let mut trace = SolveTrace::default();
    force(&x, &mut f);
    for it in 0..cfg.max_iter {
        // Velocity-Verlet step.
        for i in 0..d {
            v[i] += dt * f[i];
            x[i] += dt * v[i];
        }
        force(&x, &mut f);
        let p = vecops::dot(&f, &v);
        let fnorm = vecops::norm2(&f).max(1e-300);
        let vnorm = vecops::norm2(&v);
        if p > 0.0 {
            // Mix velocity toward the force direction.
            for i in 0..d {
                v[i] = (1.0 - alpha) * v[i] + alpha * vnorm * f[i] / fnorm;
            }
            n_pos += 1;
            if n_pos > cfg.n_min {
                dt = (dt * cfg.f_inc).min(cfg.dt_max);
                alpha *= cfg.f_alpha;
            }
        } else {
            // Uphill: stop dead (the discontinuity).
            v.iter_mut().for_each(|vi| *vi = 0.0);
            dt *= cfg.f_dec;
            alpha = cfg.alpha_start;
            n_pos = 0;
        }
        trace.iterations = it + 1;
        trace.values.push(fnorm);
        if fnorm < cfg.force_tol {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic_bowl() {
        // E = ½‖x − a‖², force = a − x.
        let a = [1.0, -2.0, 0.5];
        let force = |x: &[f64], out: &mut [f64]| {
            for i in 0..3 {
                out[i] = a[i] - x[i];
            }
        };
        let (x, trace) = fire_minimize(force, &[5.0, 5.0, 5.0], &FireConfig::default());
        assert!(trace.converged, "{trace:?}");
        for i in 0..3 {
            assert!((x[i] - a[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn handles_nonconvex_double_well() {
        // E = (x² − 1)², force = −4x(x² − 1); minima at ±1.
        let force = |x: &[f64], out: &mut [f64]| {
            out[0] = -4.0 * x[0] * (x[0] * x[0] - 1.0);
        };
        let (x, trace) = fire_minimize(force, &[0.3], &FireConfig::default());
        assert!(trace.converged);
        assert!((x[0].abs() - 1.0).abs() < 1e-7, "x = {}", x[0]);
    }

    #[test]
    fn force_norm_decreases_overall() {
        let force = |x: &[f64], out: &mut [f64]| {
            for i in 0..x.len() {
                out[i] = -x[i] * (1.0 + 0.1 * (i as f64));
            }
        };
        let (_, trace) = fire_minimize(force, &[2.0, -3.0, 1.0, 0.7], &FireConfig::default());
        assert!(trace.converged);
        let first = trace.values[0];
        let last = *trace.values.last().unwrap();
        assert!(last < first * 1e-6);
    }
}
