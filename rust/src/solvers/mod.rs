//! Inner solvers — the black boxes that implicit differentiation is added
//! *on top of* (the paper's whole point: these are interchangeable with any
//! optimality mapping).
//!
//! - [`gd`]: gradient descent with optional backtracking line search
//! - [`prox_gd`]: proximal gradient / FISTA (accelerated)
//! - [`mirror`]: mirror descent under the KL geometry
//! - [`bcd`]: block coordinate descent over simplex blocks (SVM dual)
//! - [`fire`]: the FIRE structural-relaxation optimizer [Bitzek et al., 15]
//! - [`frank_wolfe`]: Frank–Wolfe over the simplex (paper Appendix A)

pub mod bcd;
pub mod fire;
pub mod frank_wolfe;
pub mod gd;
pub mod mirror;
pub mod prox_gd;

/// Common solver telemetry.
#[derive(Clone, Debug, Default)]
pub struct SolveTrace {
    pub iterations: usize,
    /// Objective (or residual) value per logged step.
    pub values: Vec<f64>,
    pub converged: bool,
}
