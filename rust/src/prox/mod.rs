//! Proximity-operator catalog — paper Appendix C.2.
//!
//! Each operator provides `prox` plus analytic Jacobian products in both the
//! input y and the regularization parameter θ, for use in the
//! proximal-gradient fixed point (paper Eq. 7).

use crate::ad::num_grad;

/// A parametric proximity operator y ↦ prox_{ηg}(y, θ).
pub trait Prox {
    fn dim(&self) -> usize;
    fn dim_theta(&self) -> usize;

    /// out = prox_{ηg}(y, θ).
    fn prox(&self, y: &[f64], theta: &[f64], eta: f64, out: &mut [f64]);

    /// out = ∂_y prox · v.
    fn jvp_y(&self, y: &[f64], theta: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let r = num_grad::jvp_fd(|yy| self.prox_vec(yy, theta, eta), y, v, 1e-6);
        out.copy_from_slice(&r);
    }
    /// out = ∂_θ prox · v.
    fn jvp_theta(&self, y: &[f64], theta: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        if self.dim_theta() == 0 {
            out.iter_mut().for_each(|o| *o = 0.0);
            return;
        }
        let r = num_grad::jvp_fd(|tt| self.prox_vec(y, tt, eta), theta, v, 1e-6);
        out.copy_from_slice(&r);
    }
    /// out = ∂_y proxᵀ · u.
    fn vjp_y(&self, y: &[f64], theta: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        let r = num_grad::vjp_fd(|yy| self.prox_vec(yy, theta, eta), y, u, 1e-6);
        out.copy_from_slice(&r);
    }
    /// out = ∂_θ proxᵀ · u.
    fn vjp_theta(&self, y: &[f64], theta: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        if self.dim_theta() == 0 {
            return;
        }
        let r = num_grad::vjp_fd(|tt| self.prox_vec(y, tt, eta), theta, u, 1e-6);
        out.copy_from_slice(&r);
    }

    fn prox_vec(&self, y: &[f64], theta: &[f64], eta: f64) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.prox(y, theta, eta, &mut out);
        out
    }
}

/// Soft-thresholding ST(a, b)_i = sign(a_i)·max(|a_i| − b, 0).
#[inline]
pub fn soft_threshold(a: f64, b: f64) -> f64 {
    a.signum() * (a.abs() - b).max(0.0)
}

/// Lasso prox: g(x, θ) = θ‖x‖₁ → prox_{ηg}(y) = ST(y, ηθ). θ = [λ].
pub struct LassoProx {
    pub d: usize,
}

impl Prox for LassoProx {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn prox(&self, y: &[f64], t: &[f64], eta: f64, out: &mut [f64]) {
        let lam = eta * t[0];
        for i in 0..y.len() {
            out[i] = soft_threshold(y[i], lam);
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let lam = eta * t[0];
        for i in 0..y.len() {
            out[i] = if y[i].abs() > lam { v[i] } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, eta, u, out);
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let lam = eta * t[0];
        for i in 0..y.len() {
            out[i] = if y[i].abs() > lam { -eta * y[i].signum() * v[0] } else { 0.0 };
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        let lam = eta * t[0];
        out[0] = 0.0;
        for i in 0..y.len() {
            if y[i].abs() > lam {
                out[0] -= eta * y[i].signum() * u[i];
            }
        }
    }
}

/// Elastic-net prox: g(x, θ) = θ₁‖x‖₁ + θ₂‖x‖²/2 →
/// prox(y) = ST(y, ηθ₁)/(1 + ηθ₂). θ = [λ₁, λ₂].
pub struct ElasticNetProx {
    pub d: usize,
}

impl Prox for ElasticNetProx {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        2
    }
    fn prox(&self, y: &[f64], t: &[f64], eta: f64, out: &mut [f64]) {
        let (l1, l2) = (eta * t[0], eta * t[1]);
        let scale = 1.0 / (1.0 + l2);
        for i in 0..y.len() {
            out[i] = soft_threshold(y[i], l1) * scale;
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let (l1, l2) = (eta * t[0], eta * t[1]);
        let scale = 1.0 / (1.0 + l2);
        for i in 0..y.len() {
            out[i] = if y[i].abs() > l1 { v[i] * scale } else { 0.0 };
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, eta, u, out);
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let (l1, l2) = (eta * t[0], eta * t[1]);
        let scale = 1.0 / (1.0 + l2);
        for i in 0..y.len() {
            if y[i].abs() > l1 {
                let st = soft_threshold(y[i], l1);
                out[i] = -eta * y[i].signum() * scale * v[0] - st * scale * scale * eta * v[1];
            } else {
                out[i] = 0.0;
            }
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        let (l1, l2) = (eta * t[0], eta * t[1]);
        let scale = 1.0 / (1.0 + l2);
        out[0] = 0.0;
        out[1] = 0.0;
        for i in 0..y.len() {
            if y[i].abs() > l1 {
                let st = soft_threshold(y[i], l1);
                out[0] -= eta * y[i].signum() * scale * u[i];
                out[1] -= st * scale * scale * eta * u[i];
            }
        }
    }
}

/// Group lasso (block soft-thresholding) over contiguous equal-size groups:
/// prox(y)_g = max(1 − ηθ/‖y_g‖, 0) y_g. θ = [λ].
pub struct GroupLassoProx {
    pub d: usize,
    pub group_size: usize,
}

impl Prox for GroupLassoProx {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn prox(&self, y: &[f64], t: &[f64], eta: f64, out: &mut [f64]) {
        let lam = eta * t[0];
        for (yg, og) in y.chunks(self.group_size).zip(out.chunks_mut(self.group_size)) {
            let n = crate::linalg::vecops::norm2(yg);
            let s = if n > lam { 1.0 - lam / n } else { 0.0 };
            for i in 0..yg.len() {
                og[i] = s * yg[i];
            }
        }
    }
    fn jvp_y(&self, y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let lam = eta * t[0];
        for ((yg, vg), og) in y
            .chunks(self.group_size)
            .zip(v.chunks(self.group_size))
            .zip(out.chunks_mut(self.group_size))
        {
            let n = crate::linalg::vecops::norm2(yg);
            if n > lam {
                // J_g = (1 − λ/n) I + (λ/n³) y_g y_gᵀ
                let s = 1.0 - lam / n;
                let yv = crate::linalg::vecops::dot(yg, vg);
                let coef = lam * yv / (n * n * n);
                for i in 0..yg.len() {
                    og[i] = s * vg[i] + coef * yg[i];
                }
            } else {
                og.iter_mut().for_each(|o| *o = 0.0);
            }
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, eta, u, out); // block Jacobians are symmetric
    }
}

/// Quadratic (ridge) prox: g = θ‖x‖²/2 → prox(y) = y/(1 + ηθ).
pub struct RidgeProx {
    pub d: usize,
}

impl Prox for RidgeProx {
    fn dim(&self) -> usize {
        self.d
    }
    fn dim_theta(&self) -> usize {
        1
    }
    fn prox(&self, y: &[f64], t: &[f64], eta: f64, out: &mut [f64]) {
        let s = 1.0 / (1.0 + eta * t[0]);
        for i in 0..y.len() {
            out[i] = s * y[i];
        }
    }
    fn jvp_y(&self, _y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let s = 1.0 / (1.0 + eta * t[0]);
        for i in 0..v.len() {
            out[i] = s * v[i];
        }
    }
    fn vjp_y(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        self.jvp_y(y, t, eta, u, out);
    }
    fn jvp_theta(&self, y: &[f64], t: &[f64], eta: f64, v: &[f64], out: &mut [f64]) {
        let denom = 1.0 + eta * t[0];
        let ds = -eta / (denom * denom);
        for i in 0..y.len() {
            out[i] = ds * y[i] * v[0];
        }
    }
    fn vjp_theta(&self, y: &[f64], t: &[f64], eta: f64, u: &[f64], out: &mut [f64]) {
        let denom = 1.0 + eta * t[0];
        let ds = -eta / (denom * denom);
        out[0] = ds * crate::linalg::vecops::dot(y, u);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_prox_jacobians<P: Prox>(p: &P, theta: &[f64], eta: f64, seed: u64, tol: f64) {
        let mut rng = Rng::new(seed);
        for _ in 0..20 {
            let y = rng.normal_vec(p.dim());
            let v = rng.normal_vec(p.dim());
            let mut jv = vec![0.0; p.dim()];
            p.jvp_y(&y, theta, eta, &v, &mut jv);
            let fd = crate::ad::num_grad::jvp_fd(|yy| p.prox_vec(yy, theta, eta), &y, &v, 1e-7);
            for i in 0..p.dim() {
                assert!((jv[i] - fd[i]).abs() < tol, "jvp_y {i}: {} vs {}", jv[i], fd[i]);
            }
            if p.dim_theta() > 0 {
                let vt = rng.normal_vec(p.dim_theta());
                let mut jt = vec![0.0; p.dim()];
                p.jvp_theta(&y, theta, eta, &vt, &mut jt);
                let fd =
                    crate::ad::num_grad::jvp_fd(|tt| p.prox_vec(&y, tt, eta), theta, &vt, 1e-7);
                for i in 0..p.dim() {
                    assert!((jt[i] - fd[i]).abs() < tol, "jvp_θ {i}: {} vs {}", jt[i], fd[i]);
                }
                // adjoint identity for θ-side
                let u = rng.normal_vec(p.dim());
                let mut vjt = vec![0.0; p.dim_theta()];
                p.vjp_theta(&y, theta, eta, &u, &mut vjt);
                let lhs: f64 = u.iter().zip(&jt).map(|(a, b)| a * b).sum();
                let rhs: f64 = vjt.iter().zip(&vt).map(|(a, b)| a * b).sum();
                assert!((lhs - rhs).abs() < 1e-8, "adjoint: {lhs} vs {rhs}");
            }
        }
    }

    #[test]
    fn soft_threshold_values() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
    }

    #[test]
    fn lasso_prox_jacobians() {
        check_prox_jacobians(&LassoProx { d: 8 }, &[0.7], 1.0, 1, 1e-6);
        check_prox_jacobians(&LassoProx { d: 8 }, &[0.3], 0.5, 2, 1e-6);
    }

    #[test]
    fn elastic_net_jacobians() {
        check_prox_jacobians(&ElasticNetProx { d: 8 }, &[0.5, 1.0], 1.0, 3, 1e-6);
    }

    #[test]
    fn group_lasso_jacobians() {
        check_prox_jacobians(&GroupLassoProx { d: 9, group_size: 3 }, &[0.4], 1.0, 4, 1e-6);
    }

    #[test]
    fn ridge_prox_jacobians() {
        check_prox_jacobians(&RidgeProx { d: 6 }, &[2.0], 1.0, 5, 1e-6);
    }

    #[test]
    fn prox_is_argmin_certificate() {
        // For lasso: z = prox(y) must satisfy 0 ∈ z − y + ηθ ∂‖z‖₁.
        let p = LassoProx { d: 6 };
        let mut rng = Rng::new(6);
        let y = rng.normal_vec(6);
        let theta = [0.8];
        let z = p.prox_vec(&y, &theta, 1.0);
        for i in 0..6 {
            if z[i] != 0.0 {
                assert!((z[i] - y[i] + theta[0] * z[i].signum()).abs() < 1e-12);
            } else {
                assert!(y[i].abs() <= theta[0] + 1e-12);
            }
        }
    }

    #[test]
    fn group_lasso_kills_small_groups() {
        let p = GroupLassoProx { d: 4, group_size: 2 };
        let y = [0.1, 0.1, 3.0, 4.0];
        let z = p.prox_vec(&y, &[1.0], 1.0);
        assert_eq!(&z[..2], &[0.0, 0.0]);
        // surviving group shrunk toward origin, direction preserved
        assert!(z[2] > 0.0 && z[3] > 0.0);
        assert!((z[3] / z[2] - 4.0 / 3.0).abs() < 1e-12);
    }
}
