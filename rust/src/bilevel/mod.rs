//! Bi-level optimization machinery (paper §4): hypergradients of an outer
//! loss L(x*(θ), θ) through the inner solution, computed either by implicit
//! differentiation (VJP through the optimality mapping) or by unrolling, and
//! small outer optimizers (GD, momentum, Adam).

use crate::diff::mode::{DiffMode, ModeDecision, ModePolicy};
use crate::diff::one_step::{estimate_contraction, CONTRACTION_POWER_ITERS};
use crate::diff::root::{implicit_vjp, implicit_vjp_multi};
use crate::diff::spec::{FixedPointMap, FixedPointResidual, RootMap};
use crate::linalg::mat::Mat;
use crate::linalg::solve::LinearSolveConfig;

/// How the hypergradient is obtained — the axis Figs. 3/4 compare, plus the
/// Jacobian-free one-step estimator (Bolte et al., 2023).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HypergradMethod {
    Implicit,
    UnrollForward,
    UnrollReverse,
    OneStep,
}

/// Hypergradient of L(x*(θ), θ) via implicit differentiation of a root map:
/// ∇θ = (∂x*)ᵀ ∇_x L + ∇_θ L.
pub fn hypergrad_implicit<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    grad_x_outer: &[f64],
    grad_theta_outer: &[f64],
    cfg: &LinearSolveConfig,
) -> Vec<f64> {
    assert_eq!(
        grad_theta_outer.len(),
        m.dim_theta(),
        "grad_theta_outer must have length dim_theta"
    );
    let (mut g, _rep) = implicit_vjp(m, x_star, theta, grad_x_outer, cfg);
    for (gi, &go) in g.iter_mut().zip(grad_theta_outer) {
        *gi += go;
    }
    g
}

/// Batched hypergradients: k outer cotangents (columns of `grad_x_outer`,
/// d×k — e.g. several validation losses, ensemble members, or per-example
/// outer gradients) share ONE block solve Aᵀ U = V, the multi-RHS version
/// of the paper's VJP-reuse trick. `grad_theta_outer` (n×k) is added
/// columnwise. Column j equals `hypergrad_implicit` on column j.
pub fn hypergrad_implicit_multi<M: RootMap + ?Sized>(
    m: &M,
    x_star: &[f64],
    theta: &[f64],
    grad_x_outer: &Mat,
    grad_theta_outer: &Mat,
    cfg: &LinearSolveConfig,
) -> Mat {
    assert_eq!(
        (grad_theta_outer.rows, grad_theta_outer.cols),
        (m.dim_theta(), grad_x_outer.cols),
        "grad_theta_outer must be dim_theta × k"
    );
    let (mut g, _rep) = implicit_vjp_multi(m, x_star, theta, grad_x_outer, cfg);
    for (gi, go) in g.data.iter_mut().zip(grad_theta_outer.data.iter()) {
        *gi += *go;
    }
    g
}

/// Hypergradient via a fixed-point mapping (residual form of Eq. 3).
pub fn hypergrad_fixed_point<T: FixedPointMap>(
    t: T,
    x_star: &[f64],
    theta: &[f64],
    grad_x_outer: &[f64],
    grad_theta_outer: &[f64],
    cfg: &LinearSolveConfig,
) -> Vec<f64> {
    let res = FixedPointResidual(t);
    hypergrad_implicit(&res, x_star, theta, grad_x_outer, grad_theta_outer, cfg)
}

/// Jacobian-free one-step hypergradient at a converged x*: ∂₂Tᵀ ∇_x L +
/// ∇_θ L — no linear solve, error O(ρ) in the contraction factor.
pub fn hypergrad_one_step<T: FixedPointMap + ?Sized>(
    t: &T,
    x_star: &[f64],
    theta: &[f64],
    grad_x_outer: &[f64],
    grad_theta_outer: &[f64],
) -> Vec<f64> {
    let mut g = crate::diff::one_step::one_step_vjp(t, x_star, theta, grad_x_outer);
    for (gi, &go) in g.iter_mut().zip(grad_theta_outer) {
        *gi += go;
    }
    g
}

/// Mode-dispatching hypergradient through a fixed-point mapping: the single
/// entry point behind the serve protocol's `"mode"` field. `Implicit`
/// solves the residual system (exact up to `cfg`), `OneStep` and `Unroll`
/// are solve-free with O(ρ) / O(ρᵏ) error, and `Auto` resolves via
/// [`ModePolicy::default`] after estimating ρ by power iteration (a
/// standalone caller has no θ-factorization cache, so `Auto` here never
/// reports a warm cache).
pub fn hypergrad_fixed_point_mode<T: FixedPointMap>(
    t: T,
    x_star: &[f64],
    theta: &[f64],
    grad_x_outer: &[f64],
    grad_theta_outer: &[f64],
    mode: DiffMode,
    unroll_iters: Option<usize>,
    cfg: &LinearSolveConfig,
) -> Vec<f64> {
    let decision = {
        // ρ is only needed when the policy has a choice to make.
        let need_rho =
            mode == DiffMode::Auto || (mode == DiffMode::Unroll && unroll_iters.is_none());
        let rho = if need_rho {
            estimate_contraction(&t, x_star, theta, CONTRACTION_POWER_ITERS, 0x10de)
        } else {
            0.0
        };
        ModePolicy::default().resolve(mode, rho, false, unroll_iters)
    };
    match decision {
        ModeDecision::Implicit => {
            hypergrad_fixed_point(t, x_star, theta, grad_x_outer, grad_theta_outer, cfg)
        }
        ModeDecision::OneStep => {
            hypergrad_one_step(&t, x_star, theta, grad_x_outer, grad_theta_outer)
        }
        ModeDecision::Unroll(k) => {
            let mut g = crate::diff::one_step::neumann_vjp(&t, x_star, theta, grad_x_outer, k);
            for (gi, &go) in g.iter_mut().zip(grad_theta_outer) {
                *gi += go;
            }
            g
        }
    }
}

/// Hypergradient via reverse-mode unrolling of the fixed-point iteration.
pub fn hypergrad_unroll_reverse<T: FixedPointMap>(
    t: &T,
    x0: &[f64],
    theta: &[f64],
    grad_x_outer: &[f64],
    grad_theta_outer: &[f64],
    iters: usize,
) -> Vec<f64> {
    let (_x, mut g) = crate::unroll::unroll_vjp(t, x0, theta, grad_x_outer, iters);
    for (gi, &go) in g.iter_mut().zip(grad_theta_outer) {
        *gi += go;
    }
    g
}

/// Outer optimizers.
pub mod outer {
    /// Plain gradient step with optional inverse-sqrt decay after `warmup`.
    pub struct OuterGd {
        pub step0: f64,
        pub warmup: usize,
        t: usize,
    }

    impl OuterGd {
        pub fn new(step0: f64, warmup: usize) -> Self {
            OuterGd { step0, warmup, t: 0 }
        }
        pub fn step(&mut self, theta: &mut [f64], grad: &[f64]) {
            let eta = if self.t < self.warmup {
                self.step0
            } else {
                self.step0 / ((self.t - self.warmup + 1) as f64).sqrt()
            };
            for i in 0..theta.len() {
                theta[i] -= eta * grad[i];
            }
            self.t += 1;
        }
    }

    /// Heavy-ball momentum (the dataset-distillation outer optimizer:
    /// momentum 0.9, step 1 in the paper's Appendix F.3).
    pub struct Momentum {
        pub step: f64,
        pub beta: f64,
        v: Vec<f64>,
    }

    impl Momentum {
        pub fn new(step: f64, beta: f64, dim: usize) -> Self {
            Momentum { step, beta, v: vec![0.0; dim] }
        }
        pub fn step(&mut self, theta: &mut [f64], grad: &[f64]) {
            for i in 0..theta.len() {
                self.v[i] = self.beta * self.v[i] + grad[i];
                theta[i] -= self.step * self.v[i];
            }
        }
    }

    /// Adam [Kingma & Ba, 56] with the default hyper-parameters — the
    /// outer optimizer of the task-driven dictionary-learning experiment.
    pub struct Adam {
        pub step: f64,
        pub beta1: f64,
        pub beta2: f64,
        pub eps: f64,
        m: Vec<f64>,
        v: Vec<f64>,
        t: usize,
    }

    impl Adam {
        pub fn new(step: f64, dim: usize) -> Self {
            Adam {
                step,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                m: vec![0.0; dim],
                v: vec![0.0; dim],
                t: 0,
            }
        }
        pub fn step(&mut self, theta: &mut [f64], grad: &[f64]) {
            self.t += 1;
            let b1t = 1.0 - self.beta1.powi(self.t as i32);
            let b2t = 1.0 - self.beta2.powi(self.t as i32);
            for i in 0..theta.len() {
                self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
                self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
                let mhat = self.m[i] / b1t;
                let vhat = self.v[i] / b2t;
                theta[i] -= self.step * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diff::spec::ClosureRoot;
    use crate::linalg::vecops;

    /// Inner: x*(θ) = 2θ (root of x − 2θ). Outer: L = ½‖x*‖² + ½‖θ‖².
    /// ∇θL = 4θ + θ = 5θ.
    #[test]
    fn implicit_hypergrad_linear_case() {
        let f = ClosureRoot {
            d: 2,
            n: 2,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                out[0] = x[0] - 2.0 * th[0];
                out[1] = x[1] - 2.0 * th[1];
            },
            symmetric: true,
        };
        let theta = [1.0, -0.5];
        let x = [2.0, -1.0];
        let grad_x = x; // ∇_x L = x*
        let grad_t = theta; // ∇_θ L = θ
        let g = hypergrad_implicit(&f, &x, &theta, &grad_x, &grad_t, &LinearSolveConfig::default());
        assert!((g[0] - 5.0).abs() < 1e-8, "{g:?}");
        assert!((g[1] + 2.5).abs() < 1e-8);
    }

    #[test]
    fn multi_cotangent_hypergrad_matches_single_columns() {
        let f = ClosureRoot {
            d: 2,
            n: 2,
            f: |x: &[f64], th: &[f64], out: &mut [f64]| {
                out[0] = x[0] - 2.0 * th[0];
                out[1] = x[1] - 2.0 * th[1];
            },
            symmetric: true,
        };
        let theta = [1.0, -0.5];
        let x = [2.0, -1.0];
        let cfg = LinearSolveConfig::default();
        let gx = Mat::from_vec(2, 3, vec![2.0, 1.0, 0.0, -1.0, 0.0, 1.0]);
        let gt = Mat::from_vec(2, 3, vec![1.0, 0.0, 0.5, -0.5, 0.0, 0.0]);
        let block = hypergrad_implicit_multi(&f, &x, &theta, &gx, &gt, &cfg);
        let mut gxc = vec![0.0; 2];
        let mut gtc = vec![0.0; 2];
        for j in 0..3 {
            gx.col_into(j, &mut gxc);
            gt.col_into(j, &mut gtc);
            let g = hypergrad_implicit(&f, &x, &theta, &gxc, &gtc, &cfg);
            for i in 0..2 {
                assert!(
                    (block.at(i, j) - g[i]).abs() < 1e-9,
                    "({i},{j}): {} vs {}",
                    block.at(i, j),
                    g[i]
                );
            }
        }
    }

    /// Unrolled reverse hypergradient approaches the implicit one as the
    /// iteration count grows.
    #[test]
    fn unroll_converges_to_implicit() {
        struct T;
        impl crate::diff::spec::FixedPointMap for T {
            fn dim_x(&self) -> usize {
                1
            }
            fn dim_theta(&self) -> usize {
                1
            }
            fn eval(&self, x: &[f64], th: &[f64], out: &mut [f64]) {
                out[0] = 0.7 * x[0] + th[0];
            }
            fn jvp_x(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
                out[0] = 0.7 * v[0];
            }
            fn vjp_x(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
                out[0] = 0.7 * u[0];
            }
            fn jvp_theta(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
                out[0] = v[0];
            }
            fn vjp_theta(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
                out[0] = u[0];
            }
        }
        // x* = θ/0.3; L = x* → ∂L/∂θ = 1/0.3
        let theta = [0.6];
        let x_star = [2.0];
        let gi =
            hypergrad_fixed_point(T, &x_star, &theta, &[1.0], &[0.0], &LinearSolveConfig::default());
        assert!((gi[0] - 1.0 / 0.3).abs() < 1e-8);
        let g30 = hypergrad_unroll_reverse(&T, &[0.0], &theta, &[1.0], &[0.0], 30);
        let g100 = hypergrad_unroll_reverse(&T, &[0.0], &theta, &[1.0], &[0.0], 100);
        assert!((g100[0] - gi[0]).abs() < (g30[0] - gi[0]).abs());
        assert!((g100[0] - gi[0]).abs() < 1e-8);
    }

    /// The mode-dispatching entry point on the 0.7-contraction: implicit is
    /// exact, unroll(k) approaches it geometrically, one-step lands within
    /// the O(ρ) bound, and auto (cold, ρ = 0.7) takes the one-step route.
    #[test]
    fn mode_dispatch_obeys_contraction_bounds() {
        struct T;
        impl crate::diff::spec::FixedPointMap for T {
            fn dim_x(&self) -> usize {
                1
            }
            fn dim_theta(&self) -> usize {
                1
            }
            fn eval(&self, x: &[f64], th: &[f64], out: &mut [f64]) {
                out[0] = 0.7 * x[0] + th[0];
            }
            fn jvp_x(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
                out[0] = 0.7 * v[0];
            }
            fn vjp_x(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
                out[0] = 0.7 * u[0];
            }
            fn jvp_theta(&self, _x: &[f64], _t: &[f64], v: &[f64], out: &mut [f64]) {
                out[0] = v[0];
            }
            fn vjp_theta(&self, _x: &[f64], _t: &[f64], u: &[f64], out: &mut [f64]) {
                out[0] = u[0];
            }
        }
        let theta = [0.6];
        let x_star = [2.0]; // x* = θ/0.3
        let cfg = LinearSolveConfig::default();
        let gi = hypergrad_fixed_point_mode(
            T, &x_star, &theta, &[1.0], &[0.0], DiffMode::Implicit, None, &cfg,
        );
        assert!((gi[0] - 1.0 / 0.3).abs() < 1e-8);
        // One-step: g = ∂₂Tᵀ·1 = 1, error exactly ρ·|g_impl| here.
        let g1 = hypergrad_fixed_point_mode(
            T, &x_star, &theta, &[1.0], &[0.0], DiffMode::OneStep, None, &cfg,
        );
        assert!((g1[0] - 1.0).abs() < 1e-12);
        assert!((g1[0] - gi[0]).abs() <= 1.01 * 0.7 * gi[0].abs());
        // Unroll(k): Σ_{i<k} 0.7^i, error ρᵏ·|g_impl|.
        for k in [2usize, 5, 20] {
            let gk = hypergrad_fixed_point_mode(
                T, &x_star, &theta, &[1.0], &[0.0], DiffMode::Unroll, Some(k), &cfg,
            );
            let err = (gk[0] - gi[0]).abs();
            assert!(err <= 1.01 * 0.7f64.powi(k as i32) * gi[0].abs(), "k = {k}: {err}");
        }
        // Auto with ρ = 0.7 < rho_max resolves to one-step.
        let ga = hypergrad_fixed_point_mode(
            T, &x_star, &theta, &[1.0], &[0.0], DiffMode::Auto, None, &cfg,
        );
        assert_eq!(ga[0], g1[0]);
    }

    #[test]
    fn outer_optimizers_minimize_quadratic() {
        // minimize ½‖θ − a‖² with all three optimizers.
        let a = [3.0, -1.0];
        for opt in 0..3 {
            let mut theta = [0.0, 0.0];
            let mut gd = outer::OuterGd::new(0.2, 10);
            let mut mom = outer::Momentum::new(0.1, 0.9, 2);
            let mut adam = outer::Adam::new(0.2, 2);
            for _ in 0..300 {
                let grad: Vec<f64> = (0..2).map(|i| theta[i] - a[i]).collect();
                match opt {
                    0 => gd.step(&mut theta, &grad),
                    1 => mom.step(&mut theta, &grad),
                    _ => adam.step(&mut theta, &grad),
                }
            }
            let err = vecops::norm2(&vecops::sub(&theta, &a));
            assert!(err < 1e-2, "optimizer {opt} err={err}");
        }
    }
}
