//! Conic-programming residual map (paper Appendix A, Eq. 18) — the
//! homogeneous self-dual embedding used by diffcp/cvxpylayers [2, 3]:
//!
//! ```text
//!   F(x, θ) = ((θ − I)Π + I)x,   Π = proj onto R^p × K* × R₊
//! ```
//!
//! where θ(λ) is the skew-symmetric matrix assembled from (c, E, d). The
//! cone here is K = R^m₊ (LP cone, self-dual), covering linear programs;
//! the key differentiation oracle is just ∂Π, a diagonal 0/1 mask.

use crate::diff::spec::RootMap;
use crate::linalg::mat::Mat;

/// Conic residual mapping for the LP cone. θ = (c ‖ d); E fixed.
pub struct ConicResidualMap {
    pub e: Mat, // m×p
}

impl ConicResidualMap {
    pub fn dims(&self) -> (usize, usize) {
        (self.e.cols, self.e.rows) // (p, m)
    }
    /// N = p + m + 1.
    pub fn n(&self) -> usize {
        self.e.cols + self.e.rows + 1
    }

    /// Π x: identity on the first p coords (free), relu on the next m
    /// (K* = R^m₊) and relu on the last (R₊).
    fn proj(&self, x: &[f64], out: &mut [f64]) {
        let (p, _m) = self.dims();
        for i in 0..x.len() {
            out[i] = if i < p { x[i] } else { x[i].max(0.0) };
        }
    }
    /// Diagonal mask of ∂Π at x.
    fn proj_mask(&self, x: &[f64]) -> Vec<f64> {
        let (p, _m) = self.dims();
        (0..x.len())
            .map(|i| if i < p || x[i] > 0.0 { 1.0 } else { 0.0 })
            .collect()
    }

    /// y = θ(c, E, d) · w with the skew structure
    /// θ = [[0, Eᵀ, c], [−E, 0, d], [−cᵀ, −dᵀ, 0]].
    fn theta_mul(&self, c: &[f64], d: &[f64], w: &[f64], out: &mut [f64]) {
        let (p, m) = self.dims();
        let (wu, rest) = w.split_at(p);
        let (wv, ww) = rest.split_at(m);
        let t = ww[0];
        // top block: Eᵀ wv + c t
        let etv = self.e.matvec_t(wv);
        for i in 0..p {
            out[i] = etv[i] + c[i] * t;
        }
        // middle: −E wu + d t
        let eu = self.e.matvec(wu);
        for i in 0..m {
            out[p + i] = -eu[i] + d[i] * t;
        }
        // last: −cᵀwu − dᵀwv
        out[p + m] = -crate::linalg::vecops::dot(c, wu) - crate::linalg::vecops::dot(d, wv);
    }

    /// θᵀ = −θ for skew-symmetric θ.
    fn theta_mul_t(&self, c: &[f64], d: &[f64], w: &[f64], out: &mut [f64]) {
        self.theta_mul(c, d, w, out);
        for o in out.iter_mut() {
            *o = -*o;
        }
    }

    fn split_theta<'a>(&self, t: &'a [f64]) -> (&'a [f64], &'a [f64]) {
        t.split_at(self.dims().0)
    }
}

impl RootMap for ConicResidualMap {
    fn dim_x(&self) -> usize {
        self.n()
    }
    fn dim_theta(&self) -> usize {
        let (p, m) = self.dims();
        p + m
    }
    fn eval(&self, x: &[f64], theta: &[f64], out: &mut [f64]) {
        let (c, d) = self.split_theta(theta);
        let n = self.n();
        let mut pi = vec![0.0; n];
        self.proj(x, &mut pi);
        // F = θΠx − Πx + x
        self.theta_mul(c, d, &pi, out);
        for i in 0..n {
            out[i] += x[i] - pi[i];
        }
    }
    fn jvp_x(&self, x: &[f64], theta: &[f64], v: &[f64], out: &mut [f64]) {
        let (c, d) = self.split_theta(theta);
        let n = self.n();
        let mask = self.proj_mask(x);
        let dpi: Vec<f64> = (0..n).map(|i| mask[i] * v[i]).collect();
        self.theta_mul(c, d, &dpi, out);
        for i in 0..n {
            out[i] += v[i] - dpi[i];
        }
    }
    fn vjp_x(&self, x: &[f64], theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (c, d) = self.split_theta(theta);
        let n = self.n();
        let mask = self.proj_mask(x);
        // F = (θ−I)Πx + x ⇒ ∂Fᵀu = ∂Πᵀ(θᵀ−I)u + u = D((−θ−I)u) + u
        let mut tu = vec![0.0; n];
        self.theta_mul_t(c, d, u, &mut tu);
        for i in 0..n {
            out[i] = mask[i] * (tu[i] - u[i]) + u[i];
        }
    }
    fn jvp_theta(&self, x: &[f64], _theta: &[f64], v: &[f64], out: &mut [f64]) {
        // dF = dθ · Πx with dθ assembled from (dc, dd).
        let (p, m) = self.dims();
        let (dc, dd) = v.split_at(p);
        let n = self.n();
        let mut pi = vec![0.0; n];
        self.proj(x, &mut pi);
        let (pu, rest) = pi.split_at(p);
        let (pv, pw) = rest.split_at(m);
        let t = pw[0];
        for i in 0..p {
            out[i] = dc[i] * t;
        }
        for i in 0..m {
            out[p + i] = dd[i] * t;
        }
        out[p + m] =
            -crate::linalg::vecops::dot(dc, pu) - crate::linalg::vecops::dot(dd, pv);
    }
    fn vjp_theta(&self, x: &[f64], _theta: &[f64], u: &[f64], out: &mut [f64]) {
        let (p, m) = self.dims();
        let n = self.n();
        let mut pi = vec![0.0; n];
        self.proj(x, &mut pi);
        let (pu, rest) = pi.split_at(p);
        let (pv, pw) = rest.split_at(m);
        let t = pw[0];
        let (u1, restu) = u.split_at(p);
        let (u2, u3) = restu.split_at(m);
        // ⟨u, dθ Πx⟩ = Σ dc_i (u1_i t − u3 pu_i) + Σ dd_j (u2_j t − u3 pv_j)
        for i in 0..p {
            out[i] = u1[i] * t - u3[0] * pu[i];
        }
        for j in 0..m {
            out[p + j] = u2[j] * t - u3[0] * pv[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (ConicResidualMap, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let (m, p) = (4, 3);
        let e = Mat::randn(m, p, &mut rng);
        let map = ConicResidualMap { e };
        let theta = rng.normal_vec(p + m);
        let x = rng.normal_vec(p + m + 1);
        (map, theta, x)
    }

    #[test]
    fn jvp_x_matches_fd() {
        let (map, theta, x) = setup(1);
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(map.dim_x());
        let mut jv = vec![0.0; map.dim_x()];
        map.jvp_x(&x, &theta, &v, &mut jv);
        let fd = crate::ad::num_grad::jvp_fd(|xx| map.eval_vec(xx, &theta), &x, &v, 1e-7);
        for i in 0..jv.len() {
            assert!((jv[i] - fd[i]).abs() < 1e-6, "i={i}: {} vs {}", jv[i], fd[i]);
        }
    }

    #[test]
    fn jvp_theta_matches_fd() {
        let (map, theta, x) = setup(3);
        let mut rng = Rng::new(4);
        let v = rng.normal_vec(map.dim_theta());
        let mut jt = vec![0.0; map.dim_x()];
        map.jvp_theta(&x, &theta, &v, &mut jt);
        let fd = crate::ad::num_grad::jvp_fd(|tt| map.eval_vec(&x, tt), &theta, &v, 1e-7);
        for i in 0..jt.len() {
            assert!((jt[i] - fd[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn adjoint_identities() {
        let (map, theta, x) = setup(5);
        let mut rng = Rng::new(6);
        let v = rng.normal_vec(map.dim_x());
        let u = rng.normal_vec(map.dim_x());
        let mut jv = vec![0.0; map.dim_x()];
        map.jvp_x(&x, &theta, &v, &mut jv);
        let mut vj = vec![0.0; map.dim_x()];
        map.vjp_x(&x, &theta, &u, &mut vj);
        let lhs = crate::linalg::vecops::dot(&u, &jv);
        let rhs = crate::linalg::vecops::dot(&vj, &v);
        assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
        let vt = rng.normal_vec(map.dim_theta());
        let mut jt = vec![0.0; map.dim_x()];
        map.jvp_theta(&x, &theta, &vt, &mut jt);
        let mut vjt = vec![0.0; map.dim_theta()];
        map.vjp_theta(&x, &theta, &u, &mut vjt);
        let lhs = crate::linalg::vecops::dot(&u, &jt);
        let rhs = crate::linalg::vecops::dot(&vjt, &vt);
        assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
    }

    #[test]
    fn skew_structure() {
        // θ(λ) is skew-symmetric: ⟨w, θw⟩ = 0 for all w.
        let (map, theta, _x) = setup(7);
        let (c, d) = map.split_theta(&theta);
        let mut rng = Rng::new(8);
        for _ in 0..10 {
            let w = rng.normal_vec(map.n());
            let mut tw = vec![0.0; map.n()];
            map.theta_mul(c, d, &w, &mut tw);
            let ip = crate::linalg::vecops::dot(&w, &tw);
            assert!(ip.abs() < 1e-10, "⟨w, θw⟩ = {ip}");
        }
    }
}
